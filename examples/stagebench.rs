use caf_ocl::runtime::*;
use std::time::{Duration, Instant};
fn main() {
    let m = Manifest::load("artifacts").unwrap();
    let q = DeviceQueue::start("bench", None).unwrap();
    let n = 65536usize;
    let names: Vec<String> = ["sort","chunklit","fillslit","interleave","count","scan","move","lut"]
        .iter().map(|s| format!("wah_{s}_{n}")).collect();
    for k in &names {
        let meta = m.get(k).unwrap();
        q.compile(k, m.hlo_path(meta)).wait(Duration::from_secs(120)).unwrap();
    }
    let t = Duration::from_secs(300);
    let vals: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(2654435761) % 512).collect();
    let (b, e) = q.upload(HostData::U32(vals)); e.wait(t).unwrap();
    let time_stage = |name: &str, args: Vec<u64>| -> u64 {
        let (out, done) = q.execute(name, args.clone(), Dtype::U32, vec![]);
        done.wait(t).unwrap();
        let t0 = Instant::now();
        for _ in 0..3 {
            let (o2, d2) = q.execute(name, args.clone(), Dtype::U32, vec![]);
            d2.wait(t).unwrap();
            q.free(o2);
        }
        println!("{:24} {:9.2} ms", name, t0.elapsed().as_secs_f64()/3.0*1e3);
        out
    };
    let sp = time_stage(&names[0], vec![b]);
    let cl = time_stage(&names[1], vec![sp]);
    let fl = time_stage(&names[2], vec![cl]);
    let idx = time_stage(&names[3], vec![fl]);
    let cts = time_stage(&names[4], vec![idx]);
    let scn = time_stage(&names[5], vec![cts]);
    let _mv = time_stage(&names[6], vec![idx, scn]);
    let _lt = time_stage(&names[7], vec![fl, sp]);
    // sort-stage ablation: device-native bitonic network vs lax.sort
    let bit = "wah_bitonic_65536";
    if m.contains(bit) {
        let meta = m.get(bit).unwrap();
        q.compile(bit, m.hlo_path(meta)).wait(Duration::from_secs(120)).unwrap();
        let _ = time_stage(bit, vec![b]);
    }
    q.stop();
}
