//! Quickstart (paper §3.3/§3.4, Listings 1+2): spawn an OpenCL actor for
//! the square-matrix-multiply kernel and `request` a product.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::opencl::{Manager, Mode, OpenClSystemExt};
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    // paper Listing 2: load the module, grab the manager
    let system = ActorSystem::new(SystemConfig::default());
    Manager::load(&system);
    let mngr = system.opencl_manager();

    // spawn the OpenCL actor for the 256x256 matmul kernel
    let mx_dim = 256usize;
    let worker = mngr.spawn_simple("matmul_256", Mode::Val, Mode::Val)?;

    // request(worker, m, m) ... receive(result)
    let m: Vec<f32> = (0..mx_dim * mx_dim).map(|i| (i % 7) as f32 * 0.5).collect();
    let me = system.scoped();
    let result: Vec<f32> = me
        .request(&worker, (m.clone(), m.clone()))
        .receive(Duration::from_secs(60))
        .map_err(|e| anyhow::anyhow!(e.reason))?;

    // verify against the native CPU baseline and print a corner
    let want = caf_ocl::workload::matmul_naive(&m, &m, mx_dim);
    let max_err = result
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("matmul {mx_dim}x{mx_dim} on device \"{}\"", mngr.default_device()?.name);
    println!("top-left 4x4 of the product:");
    for r in 0..4 {
        let row: Vec<String> = (0..4)
            .map(|c| format!("{:8.1}", result[r * mx_dim + c]))
            .collect();
        println!("  {}", row.join(" "));
    }
    println!("max |device - cpu| = {max_err:e}");
    assert!(max_err < 1e-2, "device result diverges from CPU");
    println!("quickstart OK");

    mngr.stop_devices();
    system.shutdown();
    Ok(())
}
