//! Network transparency (paper §3.1 "location transparency" + §3.5's
//! mem_ref restriction): two actor systems on one host talk over TCP; the
//! client drives the server's published OpenCL actor through a proxy handle
//! that is indistinguishable from a local one — and sending a `mem_ref`
//! across the wire raises the documented error.
//!
//! ```sh
//! make artifacts && cargo run --release --example distributed
//! ```

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::net::Node;
use caf_ocl::opencl::{Manager, MemRef, Mode, OpenClSystemExt};
use std::time::Duration;

const T: Duration = Duration::from_secs(60);

fn main() -> anyhow::Result<()> {
    // ---- "server" process: owns the device, publishes the kernel actor ---
    let server_sys = ActorSystem::new(SystemConfig::default());
    Manager::load(&server_sys);
    let server_mngr = server_sys.opencl_manager();
    let kernel_actor = server_mngr.spawn_simple("empty_1024", Mode::Val, Mode::Val)?;
    // facades register under names like any actor
    server_sys.registry().put("device-worker", kernel_actor);
    // a ref-producing facade for the negative test
    let ref_actor = server_mngr.spawn_simple("empty_1024", Mode::Val, Mode::Ref)?;
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0")?;
    println!("server published 'device-worker' at {addr}");

    // ---- "client" process: no device of its own ---------------------------
    let client_sys = ActorSystem::new(SystemConfig::default());
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "device-worker")?;
    println!("client proxy: {remote:?}");

    let me = client_sys.scoped();
    let data: Vec<u32> = (0..1024).map(|i| i * 7).collect();
    let out: Vec<u32> = me
        .request(&remote, data.clone())
        .receive(T)
        .map_err(|e| anyhow::anyhow!(e.reason))?;
    assert_eq!(out, data);
    println!("remote kernel round-trip OK ({} words)", out.len());

    // ---- the mem_ref restriction (design option (a)) ----------------------
    let server_me = server_sys.scoped();
    let r: MemRef = server_me
        .request(&ref_actor, data.clone())
        .receive(T)
        .map_err(|e| anyhow::anyhow!(e.reason))?;
    let err = server_me.request(&remote, r).receive_msg(T);
    match err {
        Err(e) => println!("sending a mem_ref over the wire correctly failed:\n  {}", e.reason),
        Ok(_) => anyhow::bail!("mem_ref crossed the network — restriction broken!"),
    }

    println!("distributed OK");
    server.stop();
    server_mngr.stop_devices();
    client_sys.shutdown();
    server_sys.shutdown();
    Ok(())
}
