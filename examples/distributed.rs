//! Network transparency (paper §3.1 "location transparency" + §3.5's
//! mem_ref restriction): two actor systems on one host talk over TCP.
//! Node A owns the device and publishes an OpenCL facade; node B has no
//! device at all and drives the kernel remotely with `Vec<ArgValue>`
//! requests through a proxy handle that is indistinguishable from a local
//! one. Sending a `mem_ref` across the wire — bare or inside an argument
//! list — raises the documented error on the *sender*.
//!
//! Runs out of the box on the stub backend (host-emulated kernels, no
//! `make artifacts` needed):
//!
//! ```sh
//! cargo run --release --example distributed
//! ```

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::net::Node;
use caf_ocl::opencl::{ArgValue, Manager, MemRef, Mode, OpenClSystemExt};
use std::time::Duration;

const T: Duration = Duration::from_secs(60);

/// Write a stub-backend manifest: host-emulated kernels (`emu=` extras,
/// see `runtime::client::HostOp`) that exercise the full facade pipeline —
/// upload, execute, download, events — without a real XLA backend.
fn stub_artifacts() -> anyhow::Result<String> {
    let dir = std::env::temp_dir().join(format!("caf-ocl-distributed-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    std::fs::write(
        dir.join("manifest.txt"),
        "vadd_f32_4096|emu|f32:4096 f32:4096|f32:4096|emu=add n=4096\n\
         stage_u32_4096|emu|u32:4096|u32:4096|emu=identity n=4096\n",
    )?;
    Ok(dir.to_string_lossy().to_string())
}

fn main() -> anyhow::Result<()> {
    // ---- "server" process: owns the device, publishes the kernel actor ---
    let server_sys =
        ActorSystem::new(SystemConfig::default().with_artifacts_dir(stub_artifacts()?));
    Manager::load(&server_sys);
    let server_mngr = server_sys.opencl_manager();
    let kernel_actor = server_mngr.spawn_simple("vadd_f32_4096", Mode::Val, Mode::Val)?;
    // facades register under names like any actor
    server_sys.registry().put("device-worker", kernel_actor);
    // a ref-producing facade for the negative test
    let ref_actor = server_mngr.spawn_simple("stage_u32_4096", Mode::Val, Mode::Ref)?;
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0")?;
    println!("server published 'device-worker' at {addr}");

    // ---- "client" process: no device of its own ---------------------------
    let client_sys = ActorSystem::new(SystemConfig::default());
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "device-worker")?;
    println!("client proxy: {remote:?}");

    // the paper's scenario: kernel inputs travel as a typed argument list
    let a: Vec<f32> = (0..4096).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..4096).map(|i| (i * 7) as f32).collect();
    let args = vec![ArgValue::from(a.clone()), ArgValue::from(b.clone())];
    let me = client_sys.scoped();
    let out: Vec<f32> = me
        .request(&remote, args)
        .receive(T)
        .map_err(|e| anyhow::anyhow!(e.reason))?;
    let expect: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
    assert_eq!(out, expect);
    println!("remote kernel round-trip OK ({} words summed)", out.len());

    // ---- the mem_ref restriction (design option (a)) ----------------------
    let server_me = server_sys.scoped();
    let r: MemRef = server_me
        .request(&ref_actor, (0..4096u32).collect::<Vec<u32>>())
        .receive(T)
        .map_err(|e| anyhow::anyhow!(e.reason))?;
    let err = server_me
        .request(&remote, vec![ArgValue::Ref(r)])
        .receive_msg(T);
    match err {
        Err(e) => println!(
            "sending a mem_ref over the wire correctly failed:\n  {}",
            e.reason
        ),
        Ok(_) => anyhow::bail!("mem_ref crossed the network — restriction broken!"),
    }

    println!("distributed OK");
    server.stop();
    client.stop();
    server_mngr.stop_devices();
    client_sys.shutdown();
    server_sys.shutdown();
    Ok(())
}
