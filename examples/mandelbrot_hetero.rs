//! Heterogeneous offload (paper §5.4, Fig 7): split a Mandelbrot image
//! between CPU actors and an OpenCL device actor in 10% steps and watch the
//! total runtime as work shifts to the device.
//!
//! ```sh
//! make artifacts && cargo run --release --example mandelbrot_hetero \
//!     [-- --device tesla|phi|host]
//! ```

use caf_ocl::actor::{ActorSystem, Behavior, SystemConfig};
use caf_ocl::opencl::{Manager, Mode, OpenClSystemExt};
use caf_ocl::sim::{tesla_c2075, xeon_phi_5110p};
use caf_ocl::util::cli::Args;
use caf_ocl::workload::mandelbrot_rows;
use std::time::{Duration, Instant};

const W: usize = 960;
const H: usize = 540;
const CHUNK_ROWS: usize = 54; // 10% of the image per device dispatch
const ITERS: u32 = 100;
const T: Duration = Duration::from_secs(600);

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let which = args.get_or("device", "tesla");
    let spec = match which {
        "tesla" => tesla_c2075(),
        "phi" => xeon_phi_5110p(),
        _ => caf_ocl::opencl::DeviceSpec::host(),
    };
    println!("offload target: {}", spec.name);

    let system = ActorSystem::new(SystemConfig::default());
    Manager::load_with(&system, vec![spec]);
    let mngr = system.opencl_manager();

    // the device actor renders 54-row chunks given a row offset
    let kernel = format!("mandel_w{W}_h{H}_c{CHUNK_ROWS}_it{ITERS}");
    let device_actor = mngr.spawn_simple(&kernel, Mode::Val, Mode::Val)?;

    // a CPU actor renders arbitrary row bands natively
    let cpu_actor = system.spawn(|_| {
        Behavior::new().on(|_ctx, &(y0, rows): &(usize, usize)| {
            caf_ocl::actor::reply(mandelbrot_rows(W, H, y0, rows, ITERS))
        })
    });

    let me = system.scoped();
    println!(
        "{:>8} {:>12} {:>12} {:>12}",
        "offload", "total [ms]", "cpu [ms]", "device [ms]"
    );
    for step in 0..=10usize {
        let device_chunks = step; // each chunk is 10% of the rows
        let cpu_rows = H - device_chunks * CHUNK_ROWS;
        let t0 = Instant::now();
        // launch device chunks first (async), CPU band in parallel
        let pending: Vec<_> = (0..device_chunks)
            .map(|k| {
                let y0 = (cpu_rows + k * CHUNK_ROWS) as u32;
                me.request(&device_actor, vec![y0])
            })
            .collect();
        let cpu_pending =
            (cpu_rows > 0).then(|| me.request(&cpu_actor, (0usize, cpu_rows)));
        let t_cpu0 = Instant::now();
        let cpu_part: Vec<u32> = match cpu_pending {
            Some(p) => p.receive(T).map_err(|e| anyhow::anyhow!(e.reason))?,
            None => Vec::new(),
        };
        let cpu_ms = t_cpu0.elapsed().as_secs_f64() * 1e3;
        let t_dev0 = Instant::now();
        let mut dev_part: Vec<u32> = Vec::new();
        for p in pending {
            dev_part.extend(p.receive::<Vec<u32>>(T).map_err(|e| anyhow::anyhow!(e.reason))?);
        }
        let dev_ms = t_dev0.elapsed().as_secs_f64() * 1e3;
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;

        // verify the composed image equals the all-CPU render
        if step == 0 || step == 10 {
            let whole = mandelbrot_rows(W, H, 0, H, ITERS);
            let mut composed = cpu_part.clone();
            composed.extend(&dev_part);
            assert_eq!(composed, whole, "split render must equal whole render");
        }
        println!(
            "{:>7}% {:>12.2} {:>12.2} {:>12.2}",
            step * 10,
            total_ms,
            cpu_ms,
            dev_ms
        );
    }

    println!("mandelbrot_hetero OK");
    mngr.stop_devices();
    system.shutdown();
    Ok(())
}
