//! Kernel stages on resident memory (paper §3.5, Listing 3): chain OpenCL
//! actors so intermediate results never leave the device, including custom
//! pre-processing around a user-defined matrix type.
//!
//! ```sh
//! make artifacts && cargo run --release --example matrix_pipeline
//! ```

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::opencl::{ArgValue, KernelSpawn, Manager, MemRef, Mode, NdRange, OpenClSystemExt};
use std::time::Duration;

/// The paper's `square_matrix<Size>` message type (Listing 3).
#[derive(Clone)]
struct SquareMatrix {
    data: Vec<f32>,
}

fn main() -> anyhow::Result<()> {
    let system = ActorSystem::new(SystemConfig::default());
    Manager::load(&system);
    let mngr = system.opencl_manager();
    let n = 256usize;
    let t = Duration::from_secs(120);

    // --- stage 1: accepts SquareMatrix messages via preprocess, squares the
    // matrix, and forwards a device reference (no copy back) ---------------
    let program = mngr.create_kernel_program("matmul_256")?;
    let square = mngr.spawn_cl(
        KernelSpawn::new(program.clone(), "matmul_256")
            .range(NdRange::d2(n, n))
            .inputs(Mode::Val, 2)
            .output(Mode::Ref)
            .preprocess(|msg| {
                // Listing 3's `preprocess`: convert the matrix to flat arrays
                let m = msg.downcast_ref::<SquareMatrix>()?;
                Some(vec![
                    ArgValue::from(m.data.clone()),
                    ArgValue::from(m.data.clone()),
                ])
            }),
    )?;

    // --- stage 2: consumes the reference + a host operand, returns values --
    let stats = std::sync::Arc::new(caf_ocl::opencl::FacadeStats::default());
    let multiply_back = mngr.spawn_cl(
        KernelSpawn::new(program, "matmul_256")
            .range(NdRange::d2(n, n))
            .input_modes(&[Mode::Ref, Mode::Val])
            .output(Mode::Val)
            .with_stats(stats.clone()),
    )?;

    let me = system.scoped();
    let m: Vec<f32> = (0..n * n).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();

    // M^2 stays on the device...
    let r: MemRef = me
        .request(&square, SquareMatrix { data: m.clone() })
        .receive(t)
        .map_err(|e| anyhow::anyhow!(e.reason))?;
    println!("stage 1 forwarded {r:?} (execution may still be in flight)");

    // ...and feeds stage 2 together with a fresh host operand: M^2 * M
    let out: Vec<f32> = me
        .request(
            &multiply_back,
            vec![ArgValue::from(r), ArgValue::from(m.clone())],
        )
        .receive(t)
        .map_err(|e| anyhow::anyhow!(e.reason))?;

    // verify M^3 against the CPU
    let m2 = caf_ocl::workload::matmul_naive(&m, &m, n);
    let m3 = caf_ocl::workload::matmul_naive(&m2, &m, n);
    let max_err = out
        .iter()
        .zip(&m3)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    println!("M^3 via two chained device stages: max |err| = {max_err:e}");
    assert!(max_err < 1e-1);
    println!(
        "device executions: {}, cumulative device time: {:.3} ms",
        stats.launched.load(std::sync::atomic::Ordering::Relaxed),
        stats.device_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e6
    );
    println!("matrix_pipeline OK");

    mngr.stop_devices();
    system.shutdown();
    Ok(())
}
