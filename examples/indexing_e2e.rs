//! END-TO-END driver (paper §4, Fig 3): build WAH bitmap indexes over a
//! realistic synthetic trace through the full stack — actor system, OpenCL
//! manager, the 8-stage device pipeline over resident memory — verify every
//! bitmap against the raw stream and against the CPU oracle, and report the
//! headline GPU-vs-CPU metric. Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example indexing_e2e [-- --full]
//! ```

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::indexing::gpu_pipeline::GpuIndexer;
use caf_ocl::indexing::CpuIndexer;
use caf_ocl::opencl::{Manager, OpenClSystemExt};
use caf_ocl::sim::tesla_c2075;
use caf_ocl::util::cli::Args;
use caf_ocl::workload::ValueStream;
use std::time::{Duration, Instant};

const T: Duration = Duration::from_secs(600);

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let full = args.flag("full");
    let system = ActorSystem::new(SystemConfig::default());
    // two devices: the raw PJRT host queue and the simulated Tesla
    Manager::load_with(
        &system,
        vec![caf_ocl::opencl::DeviceSpec::host(), tesla_c2075()],
    );
    let mngr = system.opencl_manager();
    let me = system.scoped();

    // a VAST-like trace: Zipf-distributed field values (e.g. ports)
    let sizes: &[usize] = if full {
        &[4096, 16384, 65536, 262144, 1048576]
    } else {
        &[4096, 16384, 65536]
    };
    println!("trace distribution: Zipf(card=512, s=1.1); capacities {sizes:?}");
    println!(
        "{:>10} {:>14} {:>14} {:>12} {:>10} {:>10}",
        "N", "cpu [ms]", "gpu [ms]", "index words", "ratio", "verified"
    );

    for &n in sizes {
        let values = ValueStream::Zipf {
            cardinality: 512,
            s: 1.1,
        }
        .generate(n, 0xFACE + n as u64);

        // CPU baseline (single pass, streaming encoders)
        let cpu = CpuIndexer::new(1024);
        let t0 = Instant::now();
        let cpu_idx = cpu.index(&values);
        let cpu_ms = t0.elapsed().as_secs_f64() * 1e3;

        // device pipeline (on the plain PJRT device, id 0)
        let gpu = GpuIndexer::build(&mngr, 0, n)?;
        // warm once (compile amortized at build; warm JIT caches)
        let _ = gpu.index(&me, &values, T)?;
        let t0 = Instant::now();
        let gpu_idx = gpu.index(&me, &values, T)?;
        let gpu_ms = t0.elapsed().as_secs_f64() * 1e3;

        // close the loop: every value's positions decode exactly
        gpu_idx.verify(&values).map_err(|e| anyhow::anyhow!(e))?;
        assert_eq!(
            gpu_idx.words, cpu_idx.words,
            "GPU and CPU indexes must agree word-for-word"
        );
        let ratio = gpu_idx.compression_ratio(n);
        println!(
            "{:>10} {:>14.3} {:>14.3} {:>12} {:>10.2} {:>10}",
            n,
            cpu_ms,
            gpu_ms,
            gpu_idx.words.len(),
            ratio,
            "yes"
        );
    }

    println!("\nindexing_e2e OK — see EXPERIMENTS.md for the recorded run");
    mngr.stop_devices();
    system.shutdown();
    Ok(())
}
