use caf_ocl::runtime::*;
use std::time::{Duration, Instant};
fn main() {
    let m = Manifest::load("/tmp/probes").unwrap();
    let q = DeviceQueue::start("probe", None).unwrap();
    let t = Duration::from_secs(300);
    let vals: Vec<u32> = (0..65536u32).map(|i| i.wrapping_mul(2654435761) % 60000).collect();
    let (b, e) = q.upload(HostData::U32(vals)); e.wait(t).unwrap();
    let mut names = m.names(); names.sort();
    for k in names {
        let meta = m.get(k).unwrap();
        q.compile(k, m.hlo_path(meta)).wait(t).unwrap();
        let (o, d) = q.execute(k, vec![b], Dtype::U32, vec![]);
        d.wait(t).unwrap(); q.free(o);
        let t0 = Instant::now();
        for _ in 0..3 {
            let (o, d) = q.execute(k, vec![b], Dtype::U32, vec![]);
            d.wait(t).unwrap(); q.free(o);
        }
        println!("{:20} {:9.2} ms", k, t0.elapsed().as_secs_f64()/3.0*1e3);
    }
    q.stop();
}
