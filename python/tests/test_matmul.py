"""Pallas matmul kernel vs the numpy oracle (hypothesis sweep over shapes)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mmk
from compile.kernels import ref


def run(a, b, tile=None):
    return np.array(mmk.matmul(jnp.asarray(a), jnp.asarray(b),
                               tile or mmk.pick_tile(a.shape[0])))


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([8, 16, 32, 64, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    np.testing.assert_allclose(run(a, b), ref.matmul(a, b),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([32, 64]), t=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_tile_invariance(n, t, seed):
    """Result must not depend on the tiling choice."""
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    np.testing.assert_allclose(run(a, b, t), run(a, b, n), rtol=1e-4,
                               atol=1e-4)


def test_matmul_identity():
    n = 64
    eye = np.eye(n, dtype=np.float32)
    rng = np.random.default_rng(7)
    a = rng.standard_normal((n, n)).astype(np.float32)
    np.testing.assert_allclose(run(a, eye), a, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(run(eye, a), a, rtol=1e-5, atol=1e-5)


def test_matmul_zeros_and_dtype():
    n = 32
    z = np.zeros((n, n), np.float32)
    out = run(z, z)
    assert out.dtype == np.float32
    assert not out.any()


def test_pick_tile_divides():
    for n in (8, 16, 64, 128, 256, 384, 512, 1000):
        t = mmk.pick_tile(n)
        assert n % t == 0


@pytest.mark.parametrize("n", [64, 128, 256])
def test_matmul_artifact_sizes(n):
    """The exact sizes shipped as artifacts stay correct."""
    rng = np.random.default_rng(n)
    a = rng.standard_normal((n, n)).astype(np.float32)
    b = rng.standard_normal((n, n)).astype(np.float32)
    np.testing.assert_allclose(run(a, b), ref.matmul(a, b),
                               rtol=1e-4, atol=1e-4)
