"""AOT path: artifact table is well-formed and lowers to parseable HLO text."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_artifact_table_shapes_consistent():
    """eval_shape succeeds for every artifact and matches the manifest fmt."""
    seen = set()
    for name, fn, ins, extras in aot.artifact_table():
        assert name not in seen, f"duplicate artifact {name}"
        seen.add(name)
        out = jax.eval_shape(fn, *ins)
        assert not isinstance(out, (tuple, list)), \
            f"{name}: artifacts must have exactly one output array"
        assert aot.fmt_spec(out)  # formattable
        for s in ins:
            assert aot.fmt_spec(s)
    # every figure's artifacts are present
    names = seen
    assert "matmul_256" in names
    assert "empty_1024" in names
    assert any(n.startswith("wah_fused_") for n in names)
    assert any(n.startswith("mandel_") for n in names)


def test_wah_stage_shapes_chain():
    """Output shape of each stage equals the input shape of the next."""
    n = 4096
    g = 2 * n // aot.GROUP
    sort_out = jax.eval_shape(model.build_wah_stage("sort", n),
                              aot.spec(jnp.uint32, n))
    assert sort_out.shape == (2 * n,)
    cl_out = jax.eval_shape(model.build_wah_stage("chunklit", n), sort_out)
    assert cl_out.shape == (2 * n,)
    fl_out = jax.eval_shape(model.build_wah_stage("fillslit", n), cl_out)
    il_out = jax.eval_shape(model.build_wah_stage("interleave", n), fl_out)
    ct_out = jax.eval_shape(model.build_wah_stage("count", n), il_out)
    assert ct_out.shape == (g,)
    sc_out = jax.eval_shape(model.build_wah_stage("scan", n), ct_out)
    assert sc_out.shape == (aot.CFG + g,)
    mv_out = jax.eval_shape(model.build_wah_stage("move", n), il_out, sc_out)
    assert mv_out.shape == (aot.CFG + 2 * n,)
    lut_out = jax.eval_shape(model.build_wah_stage("lut", n), fl_out,
                             sort_out)
    assert lut_out.shape == (aot.CFG + aot.WAH_CARD,)


def test_lowering_produces_hlo_text():
    """Small artifact lowers to HLO text with a single-array entry layout."""
    fn = model.build_empty(1024)
    text = aot.to_hlo_text(fn, [aot.spec(jnp.uint32, 1024)])
    assert "ENTRY" in text
    assert "u32[1024]" in text
    # non-tuple root: the entry layout maps u32[1024] -> u32[1024]
    assert "->u32[1024]" in text.replace(" ", "")


def test_hlo_text_is_deterministic():
    fn = model.build_matmul(64)
    ins = [aot.spec(jnp.float32, 64, 64)] * 2
    assert aot.to_hlo_text(fn, ins) == aot.to_hlo_text(fn, ins)


def test_fmt_spec():
    assert aot.fmt_spec(aot.spec(jnp.uint32, 5)) == "u32:5"
    assert aot.fmt_spec(aot.spec(jnp.float32, 2, 3)) == "f32:2x3"


def test_values_fit_cid_packing():
    """Manifest capacities respect the cid collision-freedom bound."""
    for n in aot.WAH_SIZES:
        assert n <= 31 * (1 << 16)
        assert (2 * n) % aot.GROUP == 0
    assert aot.WAH_CARD <= 1 << 16
