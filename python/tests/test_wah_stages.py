"""Each WAH pipeline stage (Pallas/L2) vs its numpy oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

N = 256  # capacity used throughout (2N divisible by the 128 group size)
C = 64


def gen_values(seed, n=N, card=C, pad_frac=0.0):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, card - 1, n).astype(np.uint32)
    n_pad = int(n * pad_frac)
    if n_pad:
        vals[n - n_pad:] = card - 1
    return vals


values_st = st.builds(gen_values, seed=st.integers(0, 2**31 - 1),
                      pad_frac=st.sampled_from([0.0, 0.1, 0.5]))


@settings(max_examples=25, deadline=None)
@given(vals=values_st)
def test_sort_stage(vals):
    got = np.array(model.stage_sort(jnp.asarray(vals)))
    np.testing.assert_array_equal(got, ref.wah_sort(vals))


@settings(max_examples=25, deadline=None)
@given(vals=values_st)
def test_chunklit_stage(vals):
    sp = ref.wah_sort(vals)
    got = np.array(model.stage_chunklit(jnp.asarray(sp)))
    np.testing.assert_array_equal(got, ref.wah_chunklit(sp))


@settings(max_examples=25, deadline=None)
@given(vals=values_st)
def test_fillslit_stage(vals):
    cl = ref.wah_chunklit(ref.wah_sort(vals))
    got = np.array(model.stage_fillslit(jnp.asarray(cl)))
    np.testing.assert_array_equal(got, ref.wah_fillslit(cl))


@settings(max_examples=25, deadline=None)
@given(vals=values_st)
def test_interleave_stage(vals):
    fl = ref.wah_fillslit(ref.wah_chunklit(ref.wah_sort(vals)))
    got = np.array(model.stage_interleave(jnp.asarray(fl)))
    np.testing.assert_array_equal(got, ref.wah_interleave(fl))


@settings(max_examples=25, deadline=None)
@given(vals=values_st)
def test_lut_stage(vals):
    sp = ref.wah_sort(vals)
    fl = ref.wah_fillslit(ref.wah_chunklit(sp))
    got = np.array(model.stage_lut(jnp.asarray(fl), jnp.asarray(sp), C))
    np.testing.assert_array_equal(got, ref.wah_lut(fl, sp, C))


# -- edge cases ------------------------------------------------------------

def _stage_chain(vals):
    sp = np.array(model.stage_sort(jnp.asarray(vals)))
    cl = np.array(model.stage_chunklit(jnp.asarray(sp)))
    fl = np.array(model.stage_fillslit(jnp.asarray(cl)))
    return sp, cl, fl


def test_all_same_value():
    """One value everywhere: a single bitmap of dense literals."""
    vals = np.full(N, 3, np.uint32)
    sp, cl, fl = _stage_chain(vals)
    np.testing.assert_array_equal(cl, ref.wah_chunklit(ref.wah_sort(vals)))
    np.testing.assert_array_equal(
        fl, ref.wah_fillslit(ref.wah_chunklit(ref.wah_sort(vals))))
    # every chunk is fully or partially occupied: no fills except none at all
    fills = fl[:N]
    assert (fills == 0).all()


def test_all_distinct_values():
    """Values 0..62 cycling: many sparse bitmaps with fills."""
    vals = (np.arange(N, dtype=np.uint32) % (C - 1)).astype(np.uint32)
    sp, cl, fl = _stage_chain(vals)
    np.testing.assert_array_equal(
        fl, ref.wah_fillslit(ref.wah_chunklit(ref.wah_sort(vals))))


def test_all_pad():
    """Degenerate input: every slot is the pad value."""
    vals = np.full(N, C - 1, np.uint32)
    sp = ref.wah_sort(vals)
    fl = ref.wah_fillslit(ref.wah_chunklit(sp))
    got = np.array(model.stage_lut(jnp.asarray(fl), jnp.asarray(sp), C))
    want = ref.wah_lut(fl, sp, C)
    np.testing.assert_array_equal(got, want)
    assert got[0] == 0  # no distinct real values
    assert got[1] == 0  # no real words


def test_single_occurrence_per_chunk_boundary():
    """Positions straddling chunk boundaries (30, 31, 61, 62)."""
    vals = np.full(N, C - 1, np.uint32)
    for pos in (0, 30, 31, 61, 62, 93):
        vals[pos] = 5
    sp, cl, fl = _stage_chain(vals)
    np.testing.assert_array_equal(
        fl, ref.wah_fillslit(ref.wah_chunklit(ref.wah_sort(vals))))


def test_mlit_merges_full_chunk():
    """31 occurrences of one value in one chunk -> one full literal."""
    vals = np.full(N, C - 1, np.uint32)
    vals[:31] = 9
    sp = ref.wah_sort(vals)
    cl = np.array(model.stage_chunklit(jnp.asarray(sp)))
    # head of the run for value 9 is at sorted index 0, full 31-bit literal
    assert cl[N] == (1 << 31) - 1
