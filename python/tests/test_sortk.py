"""Bitonic sort kernel vs the sort-stage oracle."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sortk


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([64, 256, 1024]), seed=st.integers(0, 2**31 - 1),
       card=st.sampled_from([4, 64, 1024]))
def test_bitonic_matches_stable_sort(n, seed, card):
    card = min(card, 65535)
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, card, n).astype(np.uint32)
    got = np.array(sortk.bitonic_sort(jnp.asarray(vals)))
    want = ref.wah_sort(vals)
    np.testing.assert_array_equal(got, want)


def test_bitonic_is_stable_on_duplicates():
    vals = np.zeros(256, np.uint32)  # all equal: positions must stay sorted
    got = np.array(sortk.bitonic_sort(jnp.asarray(vals)))
    np.testing.assert_array_equal(got[256:], np.arange(256, dtype=np.uint32))


def test_bitonic_reverse_input():
    vals = np.arange(512, dtype=np.uint32)[::-1].copy()
    got = np.array(sortk.bitonic_sort(jnp.asarray(vals)))
    np.testing.assert_array_equal(got, ref.wah_sort(vals))
