"""End-to-end WAH pipeline: staged == fused == decodable ground truth."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

N = 256
C = 64
CFG = ref.CFG


def staged(vals):
    """Run the full staged pipeline through the L2 stage functions."""
    sp = model.stage_sort(jnp.asarray(vals))
    cl = model.stage_chunklit(sp)
    fl = model.stage_fillslit(cl)
    idx = model.stage_interleave(fl)
    counts = model.stage_count(idx)
    scan = model.stage_scan(counts)
    moved = model.stage_move(idx, scan)
    lut = model.stage_lut(fl, sp, C)
    return np.array(moved), np.array(lut)


def gen_values(seed, pad_frac=0.0):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, C - 1, N).astype(np.uint32)
    n_pad = int(N * pad_frac)
    if n_pad:
        vals[N - n_pad:] = C - 1
    return vals


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       pad_frac=st.sampled_from([0.0, 0.25]))
def test_staged_decodes_to_ground_truth(seed, pad_frac):
    """The headline invariant: decoding bitmap of v == positions of v."""
    vals = gen_values(seed, pad_frac)
    moved, lut = staged(vals)
    posmap = ref.wah_index_positions(moved, lut, C)
    n_real = N - int(N * pad_frac)
    for v in range(C - 1):
        expect = [i for i in np.where(vals == v)[0] if i < n_real or True]
        got = posmap.get(v, [])
        assert got == list(np.where(vals == v)[0]), f"value {v}"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_staged_equals_ref_pipeline(seed):
    vals = gen_values(seed)
    moved, lut = staged(vals)
    moved_r, lut_r = ref.wah_pipeline(vals, C)
    np.testing.assert_array_equal(moved, moved_r)
    np.testing.assert_array_equal(lut, lut_r)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       pad_frac=st.sampled_from([0.0, 0.5]))
def test_fused_equals_staged(seed, pad_frac):
    """Ablation A invariant: the monolithic artifact computes the same index."""
    vals = gen_values(seed, pad_frac)
    moved, lut = staged(vals)
    fused = np.array(model.wah_fused(jnp.asarray(vals), C))
    np.testing.assert_array_equal(fused[CFG:CFG + 2 * N], moved[CFG:])
    np.testing.assert_array_equal(fused[CFG + 2 * N:], lut[CFG:])
    assert fused[0] == moved[0]
    assert fused[1] == lut[1]
    assert fused[3] == lut[0]


def test_compression_beats_raw_on_sparse_data():
    """Sanity: WAH compresses a sparse index below the verbatim bitmaps."""
    rng = np.random.default_rng(11)
    vals = rng.integers(0, 8, N).astype(np.uint32)
    moved, lut = staged(vals)
    words_real = int(lut[1])
    raw_words = 8 * ((N + 30) // 31)  # 8 distinct bitmaps, uncompressed
    assert words_real < raw_words


def test_index_word_budget():
    """Never more than 2 words per input element survive compaction."""
    for seed in range(5):
        vals = gen_values(seed)
        moved, _ = staged(vals)
        assert int(moved[0]) <= 2 * N
