"""Billeter stream-compaction kernels vs numpy oracles + invariants."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import compaction, ref

GROUP = compaction.GROUP


def gen_idx(seed, groups, zero_frac):
    rng = np.random.default_rng(seed)
    m = groups * GROUP
    idx = rng.integers(1, 2**31, m).astype(np.uint32)
    idx[rng.random(m) < zero_frac] = 0
    return idx


idx_st = st.builds(gen_idx, seed=st.integers(0, 2**31 - 1),
                   groups=st.sampled_from([1, 2, 4, 8]),
                   zero_frac=st.sampled_from([0.0, 0.3, 0.9, 1.0]))


@settings(max_examples=25, deadline=None)
@given(idx=idx_st)
def test_count_matches_ref(idx):
    got = np.array(compaction.count_elements(jnp.asarray(idx)))
    np.testing.assert_array_equal(got, ref.wah_count(idx))


@settings(max_examples=25, deadline=None)
@given(idx=idx_st)
def test_scan_stage_matches_ref(idx):
    counts = ref.wah_count(idx)
    got = np.array(model.stage_scan(jnp.asarray(counts)))
    np.testing.assert_array_equal(got, ref.wah_scan(counts))


@settings(max_examples=25, deadline=None)
@given(idx=idx_st)
def test_move_matches_ref(idx):
    scan = ref.wah_scan(ref.wah_count(idx))
    got = np.array(model.stage_move(jnp.asarray(idx), jnp.asarray(scan)))
    np.testing.assert_array_equal(got, ref.wah_move(idx, scan))


@settings(max_examples=25, deadline=None)
@given(idx=idx_st)
def test_compaction_preserves_order_and_multiset(idx):
    """Survivors appear compacted, in order, nothing lost or invented."""
    scan = ref.wah_scan(ref.wah_count(idx))
    out = np.array(model.stage_move(jnp.asarray(idx), jnp.asarray(scan)))
    m = int(out[0])
    survivors = out[ref.CFG:ref.CFG + m]
    np.testing.assert_array_equal(survivors, idx[idx != 0])
    # tail is zero padding
    assert not out[ref.CFG + m:].any()


def test_group_ranks():
    idx = gen_idx(3, 2, 0.5)
    ranks = np.array(compaction.group_ranks(jnp.asarray(idx)))
    for g in range(2):
        blk = idx[g * GROUP:(g + 1) * GROUP]
        expect = np.cumsum(blk != 0) - (blk != 0)
        np.testing.assert_array_equal(ranks[g * GROUP:(g + 1) * GROUP],
                                      expect)


def test_all_zero_input():
    idx = np.zeros(GROUP, np.uint32)
    scan = ref.wah_scan(ref.wah_count(idx))
    out = np.array(model.stage_move(jnp.asarray(idx), jnp.asarray(scan)))
    assert out[0] == 0
    assert not out[ref.CFG:].any()


def test_no_zero_input():
    idx = np.arange(1, GROUP + 1, dtype=np.uint32)
    scan = ref.wah_scan(ref.wah_count(idx))
    out = np.array(model.stage_move(jnp.asarray(idx), jnp.asarray(scan)))
    assert out[0] == GROUP
    np.testing.assert_array_equal(out[ref.CFG:ref.CFG + GROUP], idx)
