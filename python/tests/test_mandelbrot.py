"""Pallas mandelbrot kernel vs the numpy oracle + chunking invariants."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import mandelbrot as mbk
from compile.kernels import ref


def run(width, height, y0, rows, iters):
    y = jnp.asarray(np.array([y0], dtype=np.uint32))
    return np.array(mbk.mandelbrot_chunk(y, width, height, rows, iters))


@settings(max_examples=15, deadline=None)
@given(width=st.sampled_from([16, 32, 64]),
       rows=st.sampled_from([4, 8, 16]),
       y0=st.integers(0, 48),
       iters=st.sampled_from([1, 10, 50]))
def test_chunk_matches_ref(width, rows, y0, iters):
    height = 64
    got = run(width, height, y0, rows, iters)
    want = ref.mandelbrot(width, height, y0, rows, iters)
    np.testing.assert_array_equal(got, want)


def test_chunks_tile_the_full_image():
    """Rendering in 4 chunks equals rendering the whole image at once."""
    w, h, it = 32, 32, 30
    whole = ref.mandelbrot(w, h, 0, h, it)
    parts = [run(w, h, y0, 8, it) for y0 in range(0, h, 8)]
    np.testing.assert_array_equal(np.concatenate(parts, axis=0), whole)


def test_counts_bounded_by_iters():
    out = run(32, 32, 0, 32, 25)
    assert out.max() <= 25
    assert out.dtype == np.uint32


def test_interior_point_never_escapes():
    """The paper picked an inner cut; points inside the set hit max iters."""
    w = h = 64
    it = 40
    img = ref.mandelbrot(w, h, 0, h, it)
    # c = -0.2 - 0.55i is inside the main cardioid; find its pixel
    col = int((-0.2 - ref.MANDEL_X0) / (ref.MANDEL_X1 - ref.MANDEL_X0) * w)
    row = int((-0.55 - ref.MANDEL_Y0) / (ref.MANDEL_Y1 - ref.MANDEL_Y0) * h)
    assert img[row, col] == it


def test_row_offset_consistency():
    """chunk(y0)[i] == chunk(0 at full height)[y0+i]."""
    w, h, it = 32, 64, 20
    full = ref.mandelbrot(w, h, 0, h, it)
    part = run(w, h, 24, 8, it)
    np.testing.assert_array_equal(part, full[24:32])
