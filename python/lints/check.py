#!/usr/bin/env python3
"""Whole-crate invariant engine for the caf_ocl tree (stdlib-only driver).

PR 8's regex linter institutionalized the manual review ritual; this engine
replaces its character-stripper with a real Rust token stream (see
``engine/lexer.py``) and grows the rule surface from per-line greps to
whole-crate passes:

  R1 balance           — brace/paren/bracket balance over code tokens;
                         unterminated attributes.
  R2 seqcst-pairing    — every SeqCst fence carries a `pairs with:
                         <file.rs>::<token>` annotation that resolves.
  R3 no-unwrap         — no `.unwrap()` / `.expect(` in production code.
  R4 promise-paths     — file-level: promise-minting files contain a
                         deliver path; pending-map registrars contain all
                         three exits; FutureSlot definers contain resolve.
  R5 codec-clamp       — wire-derived `with_capacity` sits under a
                         Reader::count clamp.
  R6 interposition     — model-interposed files never import std atomics
                         directly.
  P1 promise-lifecycle — per-binding path analysis: every minted promise
                         reaches deliver/fail/hand-off on every exit path.
  P2 gauge-balance     — steering-gauge increments have crate-reachable
                         decrements; monotonic counters never decrement;
                         `?` exits after an increment don't leak it.
  P3 ordering-graph    — per-variable atomics table over the interposition
                         surface; acquire/release pairing; Relaxed RMWs on
                         release variables; one-sided SeqCst.
  P4 unsafe-inventory  — every unsafe carries `// SAFETY:`; the checked-in
                         baseline makes new unsafe an explicit diff.

Waivers: `// lint-ok: <why>` (any rule) or `// lint-ok(rule,...): <why>`
on the finding's line or its anchor (e.g. a promise's binding line).
Unused waivers and waivers without a reason are themselves findings
(waiver-hygiene), so suppressions can't rot.

Usage (from the repository root):

    python3 python/lints/check.py [--json PATH] [--update-baseline]

Exit status 0 iff there are no active (unwaived) findings.
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from engine import Context, config  # noqa: E402
from engine.passes import ALL, unsafe_inventory  # noqa: E402
from engine.report import Report  # noqa: E402
from engine.source import SourceFile  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
SRC = os.path.join(REPO, "rust", "src")


def rust_files(root: str):
    for dirpath, _dirnames, filenames in os.walk(root):
        for name in sorted(filenames):
            if name.endswith(".rs"):
                yield os.path.join(dirpath, name)


def load_tree(repo: str) -> tuple[dict, dict]:
    sources: dict[str, SourceFile] = {}
    for path in rust_files(os.path.join(repo, "rust", "src")):
        rel = os.path.relpath(path, repo)
        with open(path, encoding="utf-8") as f:
            sources[rel] = SourceFile(path, rel, f.read())
    extra: dict[str, SourceFile] = {}
    for extra_root in config.RUST_EXTRA_ROOTS:
        root = os.path.join(repo, extra_root)
        if not os.path.isdir(root):
            continue
        for path in rust_files(root):
            rel = os.path.relpath(path, repo)
            with open(path, encoding="utf-8") as f:
                src = SourceFile(path, rel, f.read())
            # tests/benches are outside every rule's scope except balance;
            # their waivers can never be "used" and are not hygiene debt
            for w in src.waivers:
                w.in_test = True
            extra[rel] = src
    return sources, extra


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", metavar="PATH", help="write the full JSON report here")
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite python/lints/unsafe_baseline.json from the current tree",
    )
    args = ap.parse_args(argv)

    if not os.path.isdir(SRC):
        print(f"error: {SRC} not found; run from the repo", file=sys.stderr)
        return 2

    sources, extra = load_tree(REPO)
    report = Report()
    ctx = Context(REPO, sources, extra, report)

    if args.update_baseline:
        path = unsafe_inventory.write_baseline(ctx)
        print(f"unsafe baseline rewritten: {os.path.relpath(path, REPO)}")
        return 0

    for pass_mod in ALL:
        pass_mod.run(ctx)

    all_sources = ctx.all_sources()
    report.apply_waivers(all_sources)
    active = report.active()

    if args.json:
        with open(args.json, "w", encoding="utf-8") as f:
            f.write(report.to_json(all_sources))

    budget = report.waiver_budget(all_sources)
    if active:
        for f in active:
            print(f)
        print(f"\n{len(active)} active finding(s).", file=sys.stderr)
        return 1
    waived = sum(b["waived_findings"] for b in budget.values())
    budget_note = (
        " (" + ", ".join(f"{r}: {b['waived_findings']}" for r, b in sorted(budget.items()) if b["waived_findings"]) + " waived)"
        if waived
        else ""
    )
    print(f"lints clean: {len(all_sources)} files, {len(report.findings)} findings, "
          f"{waived} waived{budget_note}.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
