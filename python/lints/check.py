#!/usr/bin/env python3
"""Toolchain-free invariant linter for the caf_ocl tree.

PRs 1-6 were verified in an environment without a Rust toolchain; every
review ran the same manual ritual: brace-balance scans, call-site greps for
the SeqCst Dekker pairings, "does every promise get delivered" greps, and a
check that the wire codec never preallocates from an unclamped count. This
script institutionalizes that ritual as an executable check that needs
nothing but a Python 3 stdlib — it runs in this container, in CI, and on
any contributor machine, with or without cargo.

Rules (see STATIC_ANALYSIS.md for the rationale and the waiver syntax):

  R1 balance        — per-file brace/paren/bracket balance on comment- and
                      string-stripped source; every `#[cfg(...)]` attribute
                      must close before EOF.
  R2 seqcst-pairing — every `fence(Ordering::SeqCst)` in rust/src must carry
                      a `pairs with: <file.rs>::<token>` annotation within
                      the preceding comment block, and the referenced file
                      must exist and define the referenced token. SeqCst
                      fences are halves of Dekker handshakes; an unpaired
                      one is either dead weight or a protocol with a silent
                      second half.
  R3 no-unwrap      — no `.unwrap()` / `.expect(` in production code
                      (rust/src minus util/, minus `#[cfg(test)]` regions,
                      minus the bench harness src/bench.rs). Waive a
                      genuinely-infallible site with a `lint-ok:` comment on
                      the same line, stating why.
  R4 promise-paths  — every file that creates a `ResponsePromise` (via
                      `make_promise()` or `ResponsePromise::new`) must also
                      contain a `deliver` call path (`deliver`,
                      `deliver_msg`, `deliver_err`, `deliver_result` — the
                      resolve/fail surface of request.rs), so no file mints
                      promises it structurally cannot fulfill. Extended to
                      the async completion surface: a file that registers
                      correlated pending state (inserting into a `pending`
                      map keyed by mid) must also contain the reply-removal
                      path (`pending...remove`), a failure path
                      (`fail_one`/`fail_pending`), and a reaper/timeout
                      path, so every registered entry structurally reaches
                      exactly one of reply / error / timeout; and a file
                      defining a `FutureSlot` must contain its exactly-once
                      `resolve(` transition.
  R5 codec-clamp    — in rust/src/net/codec.rs every `with_capacity(` in a
                      decode path must sit within a few lines of a
                      `count(...)` clamp (the Reader::count preallocation
                      bound from PR 2), so a hostile element count can never
                      reserve unbacked gigabytes. Constant literal
                      capacities (encode-side arenas) are exempt — the
                      hazard is wire-derived counts.
  R6 interposition  — the files interposed by the `model` feature must pull
                      their atomics through `crate::loom_types`, never
                      `std::sync::atomic` directly (outside test regions):
                      a direct import silently drops that file out of the
                      model checker's coverage.

Exit status 0 iff the tree is clean. Run from the repository root:

    python3 python/lints/check.py
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
SRC = os.path.join(REPO, "rust", "src")

# R3 scope: production source minus the documented exemptions.
UNWRAP_EXEMPT_PREFIXES = (
    os.path.join("rust", "src", "util") + os.sep,
)
UNWRAP_EXEMPT_FILES = {
    # The bench harness lives in src so the bench binaries and the tier-1
    # perf gates can share probes; it is measurement scaffolding, and a
    # panic on a malformed environment is the desired behavior there.
    os.path.join("rust", "src", "bench.rs"),
}

# R6 scope: the model checker's interposition surface (ISSUE 7 tentpole).
INTERPOSED_FILES = {
    os.path.join("rust", "src", "concurrent", "mpsc.rs"),
    os.path.join("rust", "src", "concurrent", "deque.rs"),
    os.path.join("rust", "src", "concurrent", "parker.rs"),
    os.path.join("rust", "src", "actor", "mailbox.rs"),
    os.path.join("rust", "src", "actor", "cell.rs"),
    os.path.join("rust", "src", "actor", "scheduler.rs"),
    os.path.join("rust", "src", "runtime", "event.rs"),
}

WAIVER = "lint-ok:"


class Finding:
    def __init__(self, rule: str, path: str, line: int, msg: str):
        self.rule = rule
        self.path = path
        self.line = line
        self.msg = msg

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


def rust_files(root: str):
    for dirpath, _dirnames, filenames in os.walk(root):
        for name in sorted(filenames):
            if name.endswith(".rs"):
                yield os.path.join(dirpath, name)


def strip_source(text: str) -> str:
    """Blank out comments, string literals, char literals and lifetimes.

    Structural characters ({}()[]) and newlines are preserved so balance
    checks and line numbers keep working; everything inside a stripped
    region becomes spaces. Handles nested block comments, escape sequences,
    and raw strings (r"...", r#"..."#) — and tells a char literal `'a'`
    apart from a lifetime `'a` by requiring the closing quote.
    """
    out = list(text)
    i, n = 0, len(text)

    def blank(a: int, b: int) -> None:
        for k in range(a, b):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = text.find("\n", i)
            j = n if j == -1 else j
            blank(i, j)
            i = j
        elif c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if text.startswith("/*", j):
                    depth += 1
                    j += 2
                elif text.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c == "r" and re.match(r'r#*"', text[i:]):
            m = re.match(r'r(#*)"', text[i:])
            closing = '"' + m.group(1)
            j = text.find(closing, i + len(m.group(0)))
            j = n if j == -1 else j + len(closing)
            blank(i, j)
            i = j
        elif c == '"':
            j = i + 1
            while j < n:
                if text[j] == "\\":
                    j += 2
                elif text[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c == "'":
            # char literal: 'x' or '\x..' etc.; otherwise a lifetime
            m = re.match(r"'(\\.[^']*|[^'\\])'", text[i:])
            if m:
                blank(i, i + len(m.group(0)))
                i += len(m.group(0))
            else:
                i += 1
        else:
            i += 1
    return "".join(out)


def test_region_mask(stripped: str) -> list[bool]:
    """True per line for lines inside a `#[cfg(test)] mod ... { }` region."""
    lines = stripped.split("\n")
    mask = [False] * len(lines)
    i = 0
    while i < len(lines):
        if re.search(r"#\[cfg\(test\)\]", lines[i]):
            # find the opening brace of the following item, then its close
            depth = 0
            opened = False
            j = i
            while j < len(lines):
                for ch in lines[j]:
                    if ch == "{":
                        depth += 1
                        opened = True
                    elif ch == "}":
                        depth -= 1
                mask[j] = True
                if opened and depth <= 0:
                    break
                j += 1
            i = j + 1
        else:
            i += 1
    return mask


def check_balance(path: str, rel: str, stripped: str, findings: list[Finding]):
    pairs = {"}": "{", ")": "(", "]": "["}
    stack: list[tuple[str, int]] = []
    line = 1
    for ch in stripped:
        if ch == "\n":
            line += 1
        elif ch in "{([":
            stack.append((ch, line))
        elif ch in "})]":
            if not stack or stack[-1][0] != pairs[ch]:
                findings.append(
                    Finding("balance", rel, line, f"unbalanced `{ch}`")
                )
                return
            stack.pop()
    for ch, line in stack:
        findings.append(Finding("balance", rel, line, f"unclosed `{ch}`"))
    # every #[cfg attribute must close its bracket before EOF
    for m in re.finditer(r"#\[cfg", stripped):
        j, depth = m.start(), 0
        closed = False
        while j < len(stripped):
            if stripped[j] == "[":
                depth += 1
            elif stripped[j] == "]":
                depth -= 1
                if depth == 0:
                    closed = True
                    break
            j += 1
        if not closed:
            at = stripped.count("\n", 0, m.start()) + 1
            findings.append(Finding("balance", rel, at, "unterminated #[cfg attribute"))


PAIRS_RE = re.compile(r"pairs with:\s*(.+)")
PAIRS_REF_RE = re.compile(r"([\w/]+\.rs)::(\w+)")


def check_seqcst_pairing(
    rel: str,
    raw_lines: list[str],
    stripped_lines: list[str],
    test_mask: list[bool],
    findings: list[Finding],
):
    for idx, sline in enumerate(stripped_lines):
        if test_mask[idx]:
            continue
        if "fence(Ordering::SeqCst)" not in sline:
            continue
        # look for a `pairs with:` annotation on this line or the comment
        # block directly above (up to 12 lines)
        window = raw_lines[max(0, idx - 12) : idx + 1]
        annot = None
        for w in window:
            m = PAIRS_RE.search(w)
            if m:
                annot = m.group(1)
        if annot is None:
            findings.append(
                Finding(
                    "seqcst-pairing",
                    rel,
                    idx + 1,
                    "SeqCst fence without a `pairs with: <file.rs>::<token>` "
                    "annotation naming its Dekker partner",
                )
            )
            continue
        refs = PAIRS_REF_RE.findall(annot)
        if not refs:
            findings.append(
                Finding(
                    "seqcst-pairing",
                    rel,
                    idx + 1,
                    f"`pairs with:` annotation has no `<file.rs>::<token>` reference: {annot!r}",
                )
            )
            continue
        for fname, token in refs:
            target = find_src_file(fname)
            if target is None:
                findings.append(
                    Finding(
                        "seqcst-pairing", rel, idx + 1,
                        f"`pairs with:` references unknown file {fname}",
                    )
                )
                continue
            with open(target, encoding="utf-8") as f:
                if token not in f.read():
                    findings.append(
                        Finding(
                            "seqcst-pairing", rel, idx + 1,
                            f"`pairs with:` token `{token}` not found in {fname}",
                        )
                    )


def find_src_file(name: str) -> str | None:
    """Resolve `scheduler.rs` or `actor/scheduler.rs` under rust/src."""
    cand = os.path.join(SRC, name)
    if os.path.isfile(cand):
        return cand
    base = os.path.basename(name)
    for p in rust_files(SRC):
        if os.path.basename(p) == base:
            return p
    return None


UNWRAP_RE = re.compile(r"\.(unwrap\(\)|expect\()")


def check_no_unwrap(
    rel: str,
    raw_lines: list[str],
    stripped_lines: list[str],
    test_mask: list[bool],
    findings: list[Finding],
):
    if rel in UNWRAP_EXEMPT_FILES:
        return
    if any(rel.startswith(p) for p in UNWRAP_EXEMPT_PREFIXES):
        return
    for idx, sline in enumerate(stripped_lines):
        if test_mask[idx]:
            continue
        if not UNWRAP_RE.search(sline):
            continue
        if WAIVER in raw_lines[idx]:
            continue
        findings.append(
            Finding(
                "no-unwrap",
                rel,
                idx + 1,
                "unwrap()/expect() in production code — handle the error, "
                f"use a poison-tolerant lock, or waive with `// {WAIVER} <why>`",
            )
        )


def check_promise_paths(rel: str, stripped: str, findings: list[Finding]):
    creates = "make_promise()" in stripped or "ResponsePromise::new" in stripped
    if not creates:
        return
    if rel in (
        # the ResponsePromise definition site
        os.path.join("rust", "src", "actor", "request.rs"),
        # Context::make_promise — mints the promise and *returns* it to the
        # handler, which is the actual creation site the rule audits
        os.path.join("rust", "src", "actor", "cell.rs"),
    ):
        return
    if re.search(r"\bdeliver(_msg|_err|_result)?\b", stripped):
        return
    findings.append(
        Finding(
            "promise-paths",
            rel,
            1,
            "file creates ResponsePromises but contains no deliver/deliver_err "
            "path — every promise minted here can only resolve via Drop's "
            "broken-promise error",
        )
    )


def check_pending_paths(rel: str, stripped: str, findings: list[Finding]):
    """R4's async half: registered pending state must be resolvable.

    A pending-map registration (insert keyed by mid) is a pledge that the
    entry later reaches exactly one of reply / error / timeout. The file
    making that pledge must therefore contain all three exits: the
    reply-removal path, a connection-failure path (fail_one/fail_pending),
    and a reaper/timeout path. Likewise a file defining a FutureSlot (the
    future's receiving half) must contain its exactly-once `resolve(`
    transition — a slot with no resolve path can only hang.
    """
    if re.search(r"\bpending\b[^\n]{0,120}\.insert\(", stripped):
        missing = []
        if not re.search(r"\bpending\b[^\n]{0,120}\.remove\(", stripped):
            missing.append("reply removal (pending...remove)")
        if not re.search(r"\bfail_(one|pending)\b", stripped):
            missing.append("failure path (fail_one/fail_pending)")
        if "Reaper" not in stripped:
            missing.append("reaper/timeout path")
        if missing:
            findings.append(
                Finding(
                    "promise-paths",
                    rel,
                    1,
                    "file registers pending-map entries but lacks: "
                    + "; ".join(missing)
                    + " — a registered request could resolve never or twice",
                )
            )
    if "struct FutureSlot" in stripped and not re.search(r"\bresolve\(", stripped):
        findings.append(
            Finding(
                "promise-paths",
                rel,
                1,
                "file defines FutureSlot but no `resolve(` transition — "
                "futures minted here can only hang",
            )
        )


def check_codec_clamp(rel: str, stripped_lines: list[str], test_mask: list[bool], findings: list[Finding]):
    if rel != os.path.join("rust", "src", "net", "codec.rs"):
        return
    for idx, sline in enumerate(stripped_lines):
        if test_mask[idx] or "with_capacity(" not in sline:
            continue
        # constant capacities (encode-side arenas) are not the hazard: the
        # rule exists for *wire-derived* counts reserving unbacked memory
        if re.search(r"with_capacity\(\s*\d+(_usize|usize)?\s*\)", sline):
            continue
        window = stripped_lines[max(0, idx - 4) : idx + 1]
        if any(re.search(r"\bcount\(", w) for w in window):
            continue
        findings.append(
            Finding(
                "codec-clamp",
                rel,
                idx + 1,
                "decoder preallocation without a Reader::count clamp within "
                "reach — a hostile count could reserve unbacked memory",
            )
        )


def check_interposition(rel: str, stripped_lines: list[str], test_mask: list[bool], findings: list[Finding]):
    if rel not in INTERPOSED_FILES:
        return
    for idx, sline in enumerate(stripped_lines):
        if test_mask[idx]:
            continue
        if re.search(r"use\s+std::sync::atomic", sline) or re.search(
            r"use\s+std::cell::UnsafeCell", sline
        ):
            findings.append(
                Finding(
                    "interposition",
                    rel,
                    idx + 1,
                    "model-interposed file imports std atomics/UnsafeCell "
                    "directly — route through crate::loom_types or the model "
                    "checker silently loses this file's coverage",
                )
            )


def main() -> int:
    findings: list[Finding] = []
    if not os.path.isdir(SRC):
        print(f"error: {SRC} not found; run from the repo", file=sys.stderr)
        return 2
    for path in rust_files(SRC):
        rel = os.path.relpath(path, REPO)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        raw_lines = text.split("\n")
        stripped = strip_source(text)
        stripped_lines = stripped.split("\n")
        mask = test_region_mask(stripped)
        check_balance(path, rel, stripped, findings)
        check_seqcst_pairing(rel, raw_lines, stripped_lines, mask, findings)
        check_no_unwrap(rel, raw_lines, stripped_lines, mask, findings)
        check_promise_paths(rel, stripped, findings)
        check_pending_paths(rel, stripped, findings)
        check_codec_clamp(rel, stripped_lines, mask, findings)
        check_interposition(rel, stripped_lines, mask, findings)
    # tests/benches/examples still get the cheap structural check: a brace
    # imbalance there breaks the build just as hard
    for extra_root in (
        os.path.join(REPO, "rust", "tests"),
        os.path.join(REPO, "rust", "benches"),
        os.path.join(REPO, "examples"),
    ):
        if not os.path.isdir(extra_root):
            continue
        for path in rust_files(extra_root):
            rel = os.path.relpath(path, REPO)
            with open(path, encoding="utf-8") as f:
                stripped = strip_source(f.read())
            check_balance(path, rel, stripped, findings)

    if findings:
        for f in findings:
            print(f)
        print(f"\n{len(findings)} finding(s).", file=sys.stderr)
        return 1
    print("lints clean.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
