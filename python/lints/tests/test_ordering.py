import unittest

from lintest import findings_of, make_ctx

from engine.passes import ordering

# fixtures must live on the interposition surface — the pass only reads
# the files the model checker interposes
SURFACE_A = "rust/src/concurrent/mpsc.rs"
SURFACE_B = "rust/src/actor/mailbox.rs"


def run_on(files):
    ctx = make_ctx(files)
    ordering.run(ctx)
    return ctx


class PairingTest(unittest.TestCase):
    def test_unpaired_release_store(self):
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { self.head.store(p, Ordering::Release); }\n"
                    "fn sub(&self) { let h = self.head.load(Ordering::Relaxed); }"
                )
            }
        )
        fs = findings_of(ctx, "ordering-graph")
        self.assertEqual(len(fs), 1)
        self.assertIn("Release store to `head`", fs[0].msg)

    def test_release_acquire_pair_clean(self):
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { self.head.store(p, Ordering::Release); }\n"
                    "fn sub(&self) { let h = self.head.load(Ordering::Acquire); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])

    def test_pairing_aggregates_across_surface_files(self):
        # the store and its acquire live in different interposed files — the
        # pass must aggregate by variable name across the surface
        ctx = run_on(
            {
                SURFACE_A: "fn pub_(&self) { self.state.store(1, Ordering::Release); }",
                SURFACE_B: "fn sub(&self) { let s = self.state.load(Ordering::Acquire); }",
            }
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])

    def test_unpaired_acquire_load(self):
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { self.tail.store(p, Ordering::Relaxed); }\n"
                    "fn sub(&self) { let t = self.tail.load(Ordering::Acquire); }"
                )
            }
        )
        fs = findings_of(ctx, "ordering-graph")
        self.assertEqual(len(fs), 1)
        self.assertIn("Acquire load of `tail`", fs[0].msg)

    def test_release_fence_mitigates_relaxed_store(self):
        # the Chase–Lev idiom: Relaxed store published by a standalone fence
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { fence(Ordering::Release); "
                    "self.bottom.store(b, Ordering::Relaxed); }\n"
                    "fn sub(&self) { let b = self.bottom.load(Ordering::Acquire); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])


class RmwTest(unittest.TestCase):
    def test_rmw_provides_both_sides(self):
        # an AcqRel RMW is simultaneously the acquire reader and the release
        # writer — a lone one plus Relaxed accesses must not trip pairing
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn bump(&self) { self.refs.fetch_add(1, Ordering::AcqRel); }\n"
                    "fn peek(&self) { let r = self.refs.load(Ordering::Acquire); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])

    def test_relaxed_rmw_on_release_var(self):
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { self.state.store(1, Ordering::Release); }\n"
                    "fn sub(&self) { let s = self.state.load(Ordering::Acquire); }\n"
                    "fn bump(&self) { self.state.fetch_add(1, Ordering::Relaxed); }"
                )
            }
        )
        fs = findings_of(ctx, "ordering-graph")
        self.assertEqual(len(fs), 1)
        self.assertIn("fully Relaxed RMW on `state`", fs[0].msg)
        self.assertEqual(fs[0].line, 3)

    def test_compare_exchange_failure_ordering_counts_as_load(self):
        # the Acquire failure ordering is the variable's only acquire side
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn claim(&self) { self.state.compare_exchange(0, 1, "
                    "Ordering::Release, Ordering::Acquire); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])
        table = ctx.report.tables["atomics_table"]
        cell = table[f"{SURFACE_A}::state"]
        self.assertIn("load", cell)  # the (fail) pseudo-access
        self.assertIn("Acquire", cell["load"])


class SeqCstTest(unittest.TestCase):
    def test_one_sided_seqcst(self):
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { self.flag.store(true, Ordering::SeqCst); }\n"
                    "fn sub(&self) { let f = self.flag.load(Ordering::Acquire); }"
                )
            }
        )
        fs = findings_of(ctx, "ordering-graph")
        self.assertEqual(len(fs), 1)
        self.assertIn("one-sided SeqCst on `flag`", fs[0].msg)

    def test_both_sided_seqcst_clean(self):
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { self.flag.store(true, Ordering::SeqCst); }\n"
                    "fn sub(&self) { let f = self.flag.load(Ordering::SeqCst); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])

    def test_seqcst_fence_mitigates(self):
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { self.flag.store(true, Ordering::SeqCst); }\n"
                    "fn sub(&self) { fence(Ordering::SeqCst); "
                    "let f = self.flag.load(Ordering::Acquire); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])


class ScopeTest(unittest.TestCase):
    def test_non_surface_files_ignored(self):
        ctx = run_on(
            {
                "rust/src/runtime/facade.rs": (
                    "fn pub_(&self) { self.head.store(p, Ordering::Release); }\n"
                    "fn sub(&self) { let h = self.head.load(Ordering::Relaxed); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])

    def test_non_atomic_calls_without_ordering_ignored(self):
        # `load`-alikes with no Ordering argument are not atomic ops
        ctx = run_on(
            {SURFACE_A: "fn f(&self) { let v = self.cache.load(); }"}
        )
        self.assertEqual(findings_of(ctx, "ordering-graph"), [])
        self.assertEqual(ctx.report.tables["atomics_table"], {})

    def test_table_published(self):
        ctx = run_on(
            {
                SURFACE_A: (
                    "fn pub_(&self) { self.head.store(p, Ordering::Release); }\n"
                    "fn sub(&self) { let h = self.head.load(Ordering::Acquire); }"
                )
            }
        )
        cell = ctx.report.tables["atomics_table"][f"{SURFACE_A}::head"]
        self.assertEqual(cell["store"], {"Release": 1})
        self.assertEqual(cell["load"], {"Acquire": 1})


if __name__ == "__main__":
    unittest.main()
