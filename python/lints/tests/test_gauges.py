import unittest

from lintest import findings_of, make_ctx

from engine.passes import gauges


def run_on(files):
    ctx = make_ctx(files)
    gauges.run(ctx)
    return ctx


class CrateWideBalanceTest(unittest.TestCase):
    def test_increment_without_any_drain(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn admit(&self) { self.inflight.fetch_add(1, Ordering::AcqRel); }"
                )
            }
        )
        fs = findings_of(ctx, "gauge-balance")
        self.assertEqual(len(fs), 1)
        self.assertIn("inflight", fs[0].msg)
        self.assertIn("ratchet", fs[0].msg)

    def test_decrement_in_another_file_balances(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn admit(&self) { self.inflight.fetch_add(1, Ordering::AcqRel); }"
                ),
                "rust/src/b.rs": (
                    "fn retire(&self) { self.inflight.fetch_sub(1, Ordering::AcqRel); }"
                ),
            }
        )
        self.assertEqual(findings_of(ctx, "gauge-balance"), [])

    def test_resync_store_balances(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn admit(&self) { self.routed.fetch_add(1, Ordering::Relaxed); }\n"
                    "fn resync(&self) { self.routed.store(0, Ordering::Relaxed); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "gauge-balance"), [])

    def test_fetch_update_saturating_sub_is_a_decrement(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn admit(&self) { self.launched.fetch_add(1, Ordering::AcqRel); }\n"
                    "fn undo(&self) { self.launched.fetch_update(Ordering::AcqRel, "
                    "Ordering::Acquire, |v| Some(v.saturating_sub(1))); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "gauge-balance"), [])
        ledger = ctx.report.tables["gauge_ledger"]
        self.assertEqual(len(ledger["launched"]["dec"]), 1)

    def test_monotonic_counter_decrement_is_the_defect(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn oops(&self) { self.shed.fetch_sub(1, Ordering::Relaxed); }"
                )
            }
        )
        fs = findings_of(ctx, "gauge-balance")
        self.assertEqual(len(fs), 1)
        self.assertIn("monotonic counter `shed`", fs[0].msg)

    def test_pipeline_occupancy_gauge_is_in_the_ledger(self):
        # ISSUE 10: the pipeline drivers' occupancy gauge joins the
        # balanced set — an admit with no retire anywhere is a finding,
        # and the production shape (fetch_add + saturating fetch_update)
        # balances
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn admit(&self) "
                    "{ self.pipe_pending.fetch_add(1, Ordering::Relaxed); }"
                )
            }
        )
        fs = findings_of(ctx, "gauge-balance")
        self.assertEqual(len(fs), 1)
        self.assertIn("pipe_pending", fs[0].msg)
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn admit(&self) "
                    "{ self.pipe_pending.fetch_add(1, Ordering::Relaxed); }\n"
                    "fn retire(&self) { self.pipe_pending.fetch_update(Ordering::Relaxed, "
                    "Ordering::Relaxed, |v| Some(v.saturating_sub(1))); }"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "gauge-balance"), [])

    def test_migration_counter_is_monotonic(self):
        # ISSUE 10: explicit device-to-device transfers only ever grow
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn oops(&self) "
                    "{ self.migrations.fetch_sub(1, Ordering::Relaxed); }"
                )
            }
        )
        fs = findings_of(ctx, "gauge-balance")
        self.assertEqual(len(fs), 1)
        self.assertIn("monotonic counter `migrations`", fs[0].msg)

    def test_test_code_is_out_of_scope(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "#[cfg(test)]\nmod t {\n    fn f(g: &G) "
                    "{ g.inflight.fetch_add(1, Ordering::AcqRel); }\n}\n"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "gauge-balance"), [])


class EarlyExitTest(unittest.TestCase):
    DEC_ELSEWHERE = "fn retire(&self) { self.inflight.fetch_sub(1, Ordering::AcqRel); }"

    def test_question_mark_after_increment_leaks(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn admit(&self) -> Result<(), E> {\n"
                    "    self.inflight.fetch_add(1, Ordering::AcqRel);\n"
                    "    self.sink.push(msg)?;\n"
                    "    Ok(())\n"
                    "}\n" + self.DEC_ELSEWHERE
                )
            }
        )
        fs = findings_of(ctx, "gauge-balance")
        self.assertEqual(len(fs), 1)
        self.assertIn("`?` exit after increment of `inflight`", fs[0].msg)
        self.assertEqual(fs[0].line, 3)
        self.assertEqual(fs[0].anchor_lines, (2,))

    def test_decrement_before_question_mark_guards(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn admit(&self) -> Result<(), E> {\n"
                    "    self.inflight.fetch_add(1, Ordering::AcqRel);\n"
                    "    self.inflight.fetch_sub(1, Ordering::AcqRel);\n"
                    "    self.sink.push(msg)?;\n"
                    "    Ok(())\n"
                    "}\n"
                )
            }
        )
        self.assertEqual(findings_of(ctx, "gauge-balance"), [])

    def test_undo_helper_call_guards_via_fixpoint(self):
        # launch_refused decrements; admit calls it before the `?` — the
        # fixpoint must recognize the call as an undo even across files
        ctx = run_on(
            {
                "rust/src/helpers.rs": (
                    "fn launch_refused(&self) "
                    "{ self.launched.fetch_sub(1, Ordering::AcqRel); }"
                ),
                "rust/src/a.rs": (
                    "fn admit(&self) -> Result<(), E> {\n"
                    "    self.launched.fetch_add(1, Ordering::AcqRel);\n"
                    "    self.launch_refused();\n"
                    "    self.sink.push(msg)?;\n"
                    "    Ok(())\n"
                    "}\n"
                ),
            }
        )
        self.assertEqual(findings_of(ctx, "gauge-balance"), [])

    def test_transitive_undo_helper(self):
        # admit -> on_refuse -> launch_refused: two hops through the fixpoint
        ctx = run_on(
            {
                "rust/src/helpers.rs": (
                    "fn launch_refused(&self) "
                    "{ self.launched.fetch_sub(1, Ordering::AcqRel); }\n"
                    "fn on_refuse(&self) { self.launch_refused(); }"
                ),
                "rust/src/a.rs": (
                    "fn admit(&self) -> Result<(), E> {\n"
                    "    self.launched.fetch_add(1, Ordering::AcqRel);\n"
                    "    self.on_refuse();\n"
                    "    self.sink.push(msg)?;\n"
                    "    Ok(())\n"
                    "}\n"
                ),
            }
        )
        self.assertEqual(findings_of(ctx, "gauge-balance"), [])


class LedgerTest(unittest.TestCase):
    def test_ledger_published_with_kinds_and_sites(self):
        ctx = run_on(
            {
                "rust/src/a.rs": (
                    "fn f(&self) { self.inflight.fetch_add(1, Ordering::AcqRel); }\n"
                    "fn g(&self) { self.inflight.fetch_sub(1, Ordering::AcqRel); }\n"
                    "fn h(&self) { self.shed.fetch_add(1, Ordering::Relaxed); }"
                )
            }
        )
        ledger = ctx.report.tables["gauge_ledger"]
        self.assertEqual(ledger["inflight"]["kind"], "balanced")
        self.assertEqual(ledger["inflight"]["inc"], ["rust/src/a.rs:1"])
        self.assertEqual(ledger["inflight"]["dec"], ["rust/src/a.rs:2"])
        self.assertEqual(ledger["shed"]["kind"], "monotonic")


if __name__ == "__main__":
    unittest.main()
