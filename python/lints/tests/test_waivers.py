import unittest

from lintest import make_ctx, make_source

from engine.report import Finding


def apply(files, findings):
    ctx = make_ctx(files)
    ctx.report.extend(findings)
    ctx.report.apply_waivers(ctx.sources)
    return ctx


class WaiverParseTest(unittest.TestCase):
    def test_unscoped_and_scoped(self):
        src = make_source(
            "fn f() { x(); } // lint-ok: exempt for reasons\n"
            "fn g() { y(); } // lint-ok(no-unwrap, balance): more reasons\n"
        )
        self.assertEqual(len(src.waivers), 2)
        self.assertIsNone(src.waivers[0].rules)
        self.assertEqual(src.waivers[1].rules, frozenset({"no-unwrap", "balance"}))
        self.assertEqual(src.waivers[0].reason, "exempt for reasons")

    def test_waiver_in_string_is_not_a_waiver(self):
        src = make_source('fn f() { let s = "// lint-ok: nope"; }\n')
        self.assertEqual(src.waivers, [])


class WaiverApplyTest(unittest.TestCase):
    FILES = {
        "rust/src/a.rs": (
            "fn f() { x.unwrap(); } // lint-ok(no-unwrap): init-time, cannot fail\n"
        )
    }

    def test_scoped_waiver_suppresses_matching_rule(self):
        ctx = apply(self.FILES, [Finding("no-unwrap", "rust/src/a.rs", 1, "unwrap")])
        self.assertEqual(ctx.report.active(), [])
        self.assertEqual(len(ctx.report.findings), 1)
        self.assertIsNotNone(ctx.report.findings[0].waived_by)

    def test_scoped_waiver_does_not_cover_other_rules(self):
        ctx = apply(self.FILES, [Finding("balance", "rust/src/a.rs", 1, "brace")])
        active = ctx.report.active()
        # the balance finding survives AND the no-unwrap waiver is now unused
        rules = sorted(f.rule for f in active)
        self.assertEqual(rules, ["balance", "waiver-hygiene"])

    def test_anchor_line_waiver(self):
        # a promise-lifecycle leak reported at the exit line may be waived at
        # the binding line carried in anchor_lines
        files = {
            "rust/src/a.rs": (
                "fn f() {\n"
                "    let p = mint(); // lint-ok(promise-lifecycle): guard is exhaustive\n"
                "    return;\n"
                "}\n"
            )
        }
        f = Finding("promise-lifecycle", "rust/src/a.rs", 3, "leak", anchor_lines=(2,))
        ctx = apply(files, [f])
        self.assertEqual(ctx.report.active(), [])

    def test_unused_waiver_is_a_finding(self):
        ctx = apply(self.FILES, [])
        active = ctx.report.active()
        self.assertEqual(len(active), 1)
        self.assertEqual(active[0].rule, "waiver-hygiene")
        self.assertIn("unused waiver", active[0].msg)
        self.assertIn("no-unwrap", active[0].msg)

    def test_empty_reason_is_a_finding(self):
        files = {"rust/src/a.rs": "fn f() { x.unwrap(); } // lint-ok(no-unwrap):\n"}
        ctx = apply(files, [Finding("no-unwrap", "rust/src/a.rs", 1, "unwrap")])
        active = ctx.report.active()
        self.assertTrue(any("without a reason" in f.msg for f in active))

    def test_test_region_waivers_exempt(self):
        # waivers inside #[cfg(test)] can never be used (test code is out of
        # every rule's scope) — they must not be flagged as unused
        files = {
            "rust/src/a.rs": (
                "#[cfg(test)]\nmod t {\n"
                "    fn f() { x.unwrap(); } // lint-ok: test scaffolding\n"
                "}\n"
            )
        }
        ctx = apply(files, [])
        self.assertEqual(ctx.report.active(), [])

    def test_waiver_budget(self):
        ctx = apply(self.FILES, [Finding("no-unwrap", "rust/src/a.rs", 1, "unwrap")])
        budget = ctx.report.waiver_budget(ctx.sources)
        self.assertEqual(
            budget["no-unwrap"], {"waived_findings": 1, "waiver_sites": 1}
        )

    def test_json_report_carries_waiver(self):
        import json

        ctx = apply(self.FILES, [Finding("no-unwrap", "rust/src/a.rs", 1, "unwrap")])
        doc = json.loads(ctx.report.to_json(ctx.sources))
        self.assertEqual(doc["active_findings"], 0)
        self.assertEqual(len(doc["findings"]), 1)
        self.assertEqual(
            doc["findings"][0]["waived"]["reason"], "init-time, cannot fail"
        )


if __name__ == "__main__":
    unittest.main()
