import unittest

from lintest import make_source

from engine import items
from engine.lexer import IDENT, code_tokens, lex


def mask_for(text):
    code = code_tokens(lex(text))
    return code, items.test_mask(code)


def masked_idents(text):
    code, mask = mask_for(text)
    return {t.text for i, t in enumerate(code) if mask[i] and t.kind == IDENT}


class TestMaskTest(unittest.TestCase):
    def test_cfg_test_mod_masked(self):
        text = """
fn prod() { body(); }
#[cfg(test)]
mod tests {
    fn helper() { test_body(); }
}
fn prod2() { body2(); }
"""
        ids = masked_idents(text)
        self.assertIn("helper", ids)
        self.assertIn("test_body", ids)
        self.assertNotIn("prod", ids)
        self.assertNotIn("body2", ids)

    def test_cfg_all_and_any_mask(self):
        for head in ('#[cfg(all(test, feature = "x"))]', "#[cfg(any(test, doc))]"):
            ids = masked_idents(head + "\nfn only_in_tests() { t(); }")
            self.assertIn("only_in_tests", ids, head)

    def test_cfg_not_test_is_production(self):
        ids = masked_idents("#[cfg(not(test))]\nfn prod() { body(); }")
        self.assertEqual(ids, set())

    def test_stacked_attributes(self):
        text = '#[allow(dead_code)]\n#[cfg(test)]\n#[derive(Debug)]\nstruct T { x: u32 }'
        ids = masked_idents(text)
        self.assertIn("T", ids)

    def test_semicolon_item(self):
        ids = masked_idents("#[cfg(test)]\nuse crate::test_util::probe;\nfn prod() {}")
        self.assertIn("probe", ids)
        self.assertNotIn("prod", ids)

    def test_attr_in_string_is_not_an_attribute(self):
        # the token stream never surfaces #[cfg(test)] spelled inside a string
        code, mask = mask_for('fn f() { let s = "#[cfg(test)]"; real(); }')
        self.assertFalse(any(mask))


class FunctionExtractTest(unittest.TestCase):
    def test_boundaries_and_names(self):
        text = """
fn alpha(x: u32) -> u32 { x + 1 }
impl Foo {
    pub fn beta(&self) { if x { y(); } }
}
trait T { fn decl_only(&self); }
"""
        code = code_tokens(lex(text))
        fns = items.extract_functions(code, items.test_mask(code))
        names = [f.name for f in fns]
        self.assertEqual(names, ["alpha", "beta"])  # decl_only has no body

    def test_in_test_flag(self):
        text = "#[cfg(test)]\nmod t { fn inner() { x(); } }\nfn outer() { y(); }"
        code = code_tokens(lex(text))
        fns = items.extract_functions(code, items.test_mask(code))
        flags = {f.name: f.in_test for f in fns}
        self.assertTrue(flags["inner"])
        self.assertFalse(flags["outer"])


class BlockTreeTest(unittest.TestCase):
    def _tree(self, body):
        text = f"fn f() {body}"
        code = code_tokens(lex(text))
        fns = items.extract_functions(code, [False] * len(code))
        return items.build_block_tree(code, fns[0].body_start, fns[0].body_end)

    def _constructs(self, block, out=None):
        out = [] if out is None else out
        for e in block.elements:
            if isinstance(e, items.Block):
                out.append(e.construct)
                self._constructs(e, out)
        return out

    def test_constructs_tagged(self):
        tree = self._tree(
            "{ if a { x(); } else if b { y(); } else { z(); } "
            "match m { _ => {} } loop { break; } while c { w(); } "
            "for i in 0..2 { v(); } unsafe { u(); } { plain(); } }"
        )
        cs = self._constructs(tree)
        for want in ("if", "elseif", "else", "match", "loop", "while", "for", "unsafe", "plain"):
            self.assertIn(want, cs)

    def test_closure_detection(self):
        cs = self._constructs(self._tree("{ run(move |ctx, res| { body(); }); }"))
        self.assertIn("closure", cs)

    def test_match_arm_not_closure(self):
        cs = self._constructs(self._tree("{ match x { A | B => { arm(); } } }"))
        self.assertNotIn("closure", cs)

    def test_brace_in_parens_does_not_steal_keyword(self):
        # the `{` of a struct literal inside the scrutinee parens must not
        # consume the pending `match`
        cs = self._constructs(self._tree("{ match wrap(Pt { x: 1 }) { _ => {} } }"))
        self.assertIn("match", cs)


if __name__ == "__main__":
    unittest.main()
