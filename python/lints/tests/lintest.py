"""Shared helpers for the analyzer self-tests.

Not named test_* so unittest discovery doesn't collect it. Bootstraps
sys.path so `engine` imports resolve when running

    python3 -m unittest discover python/lints/tests

from the repository root.
"""

from __future__ import annotations

import os
import re
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from engine import Context  # noqa: E402
from engine.report import Report  # noqa: E402
from engine.source import SourceFile  # noqa: E402


def make_source(text: str, rel: str = "rust/src/fixture.rs") -> SourceFile:
    return SourceFile(rel, rel, text)


def make_ctx(files: dict[str, str], repo: str = "/nonexistent") -> Context:
    sources = {rel: SourceFile(rel, rel, text) for rel, text in files.items()}
    return Context(repo, sources, {}, Report())


def findings_of(ctx: Context, rule: str | None = None):
    fs = ctx.report.findings
    return [f for f in fs if rule is None or f.rule == rule]


# The PR-8 regex stripper, verbatim — kept here (and only here) as the
# regression oracle: tests prove its false-positive classes against the
# token-level engine that replaced it.
def old_strip_source(text: str) -> str:
    out = list(text)
    i, n = 0, len(text)

    def blank(a: int, b: int) -> None:
        for k in range(a, min(b, n)):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = text.find("\n", i)
            j = n if j == -1 else j
            blank(i, j)
            i = j
        elif c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if text.startswith("/*", j):
                    depth += 1
                    j += 2
                elif text.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c == "r" and re.match(r'r#*"', text[i:]):
            m = re.match(r'r(#*)"', text[i:])
            closing = '"' + m.group(1)
            j = text.find(closing, i + len(m.group(0)))
            j = n if j == -1 else j + len(closing)
            blank(i, j)
            i = j
        elif c == '"':
            j = i + 1
            while j < n:
                if text[j] == "\\":
                    j += 2
                elif text[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            blank(i, j)
            i = j
        elif c == "'":
            m = re.match(r"'(\\.[^']*|[^'\\])'", text[i:])
            if m:
                blank(i, i + len(m.group(0)))
                i += len(m.group(0))
            else:
                i += 1
        else:
            i += 1
    return "".join(out)
