import os
import tempfile
import unittest

from lintest import findings_of, make_ctx

from engine.passes import unsafe_inventory

DOCUMENTED = (
    "fn grab(&self) -> &T {\n"
    "    // SAFETY: the slot was initialized by push() and no other reader\n"
    "    // exists while the guard is held.\n"
    "    unsafe { &*self.ptr }\n"
    "}\n"
)


class RepoCase(unittest.TestCase):
    """Base: a temp repo dir so baseline reads/writes stay isolated."""

    def setUp(self):
        self._tmp = tempfile.TemporaryDirectory()
        self.repo = self._tmp.name
        os.makedirs(os.path.join(self.repo, "python", "lints"))
        self.addCleanup(self._tmp.cleanup)

    def ctx_with_baseline(self, files):
        """Write a baseline from `files`, then return a fresh ctx over them."""
        unsafe_inventory.write_baseline(make_ctx(files, self.repo))
        return make_ctx(files, self.repo)


class RationaleTest(RepoCase):
    def run_rationale(self, text):
        ctx = self.ctx_with_baseline({"rust/src/a.rs": text})
        unsafe_inventory.run(ctx)
        return [
            f
            for f in findings_of(ctx, "unsafe-inventory")
            if "SAFETY" in f.msg
        ]

    def test_safety_comment_above(self):
        self.assertEqual(self.run_rationale(DOCUMENTED), [])

    def test_safety_comment_block_first_line(self):
        # keyword on the *first* line of a tall comment block: the window
        # only reaches 3 lines up, so block expansion must find it
        text = (
            "fn grab(&self) -> &T {\n"
            "    // SAFETY: a long rationale whose keyword line scrolls\n"
            "    // out of the 3-line window because the explanation\n"
            "    // continues for several lines before the site,\n"
            "    // like this one does.\n"
            "    unsafe { &*self.ptr }\n"
            "}\n"
        )
        self.assertEqual(self.run_rationale(text), [])

    def test_doc_safety_section(self):
        text = (
            "/// Reads the slot.\n"
            "///\n"
            "/// # Safety\n"
            "///\n"
            "/// Caller must hold the guard.\n"
            "unsafe fn grab(&self) -> &T { &*self.ptr }\n"
        )
        self.assertEqual(self.run_rationale(text), [])

    def test_missing_rationale(self):
        fs = self.run_rationale("fn grab(&self) -> &T { unsafe { &*self.ptr } }\n")
        self.assertEqual(len(fs), 1)
        self.assertIn("without a `// SAFETY:`", fs[0].msg)

    def test_unrelated_comment_is_not_a_rationale(self):
        fs = self.run_rationale(
            "fn grab(&self) -> &T {\n"
            "    // fast path\n"
            "    unsafe { &*self.ptr }\n"
            "}\n"
        )
        self.assertEqual(len(fs), 1)


class BaselineTest(RepoCase):
    def test_missing_baseline_is_a_finding(self):
        ctx = make_ctx({"rust/src/a.rs": DOCUMENTED}, self.repo)
        unsafe_inventory.run(ctx)
        fs = findings_of(ctx, "unsafe-inventory")
        self.assertEqual(len(fs), 1)
        self.assertIn("baseline file missing", fs[0].msg)

    def test_matching_baseline_clean(self):
        ctx = self.ctx_with_baseline({"rust/src/a.rs": DOCUMENTED})
        unsafe_inventory.run(ctx)
        self.assertEqual(findings_of(ctx, "unsafe-inventory"), [])
        inv = ctx.report.tables["unsafe_inventory"]
        self.assertEqual(len(inv), 1)
        self.assertEqual(inv[0]["item"], "fn grab")
        self.assertEqual(inv[0]["kind"], "block")

    def test_new_unsafe_is_baseline_drift(self):
        self.ctx_with_baseline({"rust/src/a.rs": DOCUMENTED})
        grown = DOCUMENTED + (
            "fn grab2(&self) -> &T {\n"
            "    // SAFETY: same argument as grab().\n"
            "    unsafe { &*self.ptr }\n"
            "}\n"
        )
        ctx = make_ctx({"rust/src/a.rs": grown}, self.repo)
        unsafe_inventory.run(ctx)
        fs = findings_of(ctx, "unsafe-inventory")
        self.assertEqual(len(fs), 1)
        self.assertIn("not in the baseline", fs[0].msg)
        self.assertIn("grab2", fs[0].msg)

    def test_removed_unsafe_is_stale_baseline(self):
        self.ctx_with_baseline({"rust/src/a.rs": DOCUMENTED})
        ctx = make_ctx({"rust/src/a.rs": "fn grab(&self) -> u32 { 0 }\n"}, self.repo)
        unsafe_inventory.run(ctx)
        fs = findings_of(ctx, "unsafe-inventory")
        self.assertEqual(len(fs), 1)
        self.assertIn("no longer exists", fs[0].msg)

    def test_moved_code_does_not_churn_baseline(self):
        # the key is (file, item, kind) with a count — reordering items in the
        # file changes every line number but must not produce drift
        self.ctx_with_baseline(
            {"rust/src/a.rs": "fn other() {}\n\n\n" + DOCUMENTED}
        )
        ctx = make_ctx({"rust/src/a.rs": DOCUMENTED + "\nfn other() {}\n"}, self.repo)
        unsafe_inventory.run(ctx)
        self.assertEqual(findings_of(ctx, "unsafe-inventory"), [])

    def test_unsafe_impl_keyed_by_token_tail(self):
        text = (
            "// SAFETY: T: Send suffices — the cell adds no sharing.\n"
            "unsafe impl<T: Send> Send for Cell<T> {}\n"
        )
        ctx = self.ctx_with_baseline({"rust/src/a.rs": text})
        unsafe_inventory.run(ctx)
        self.assertEqual(findings_of(ctx, "unsafe-inventory"), [])
        inv = ctx.report.tables["unsafe_inventory"]
        self.assertEqual(inv[0]["kind"], "impl")

    def test_test_code_not_inventoried(self):
        ctx = self.ctx_with_baseline(
            {
                "rust/src/a.rs": (
                    "#[cfg(test)]\nmod t {\n"
                    "    fn f(p: *const u8) { unsafe { p.read() }; }\n"
                    "}\n"
                )
            }
        )
        unsafe_inventory.run(ctx)
        self.assertEqual(findings_of(ctx, "unsafe-inventory"), [])
        self.assertEqual(ctx.report.tables["unsafe_inventory"], [])


if __name__ == "__main__":
    unittest.main()
