import unittest

from lintest import make_source  # noqa: F401  (bootstraps sys.path)

from engine import lexer


def kinds(text):
    return [(t.kind, t.text) for t in lexer.lex(text)]


def braces(text):
    return [
        t.text
        for t in lexer.lex(text)
        if t.kind == lexer.PUNCT and t.text in "{}()[]"
    ]


class LexerTest(unittest.TestCase):
    def test_idents_and_puncts(self):
        self.assertEqual(
            kinds("fn f(x: u32) {}"),
            [
                ("ident", "fn"),
                ("ident", "f"),
                ("punct", "("),
                ("ident", "x"),
                ("punct", ":"),
                ("ident", "u32"),
                ("punct", ")"),
                ("punct", "{"),
                ("punct", "}"),
            ],
        )

    def test_raw_string_any_hash_depth(self):
        for text in ('r"{ }"', 'r#"{ "quoted" }"#', 'r##"{ "#hash" }"##'):
            toks = lexer.lex(text)
            self.assertEqual([t.kind for t in toks], [lexer.RAW_STR], text)
            self.assertEqual(braces(text), [], text)

    def test_raw_string_only_at_token_start(self):
        # `x2r"\"{"` — an identifier ending in r directly abutting a string:
        # must lex as IDENT + STR, never a phantom raw string opened at the
        # identifier's trailing `r` (the old stripper's bug class)
        toks = lexer.lex('x2r"\\"{"')
        self.assertEqual([t.kind for t in toks], [lexer.IDENT, lexer.STR])
        self.assertEqual(toks[0].text, "x2r")
        self.assertEqual(braces('x2r"\\"{"'), [])

    def test_byte_literals(self):
        self.assertEqual([t.kind for t in lexer.lex('b"{ }"')], [lexer.STR])
        self.assertEqual([t.kind for t in lexer.lex("b'{'")], [lexer.CHAR])
        self.assertEqual([t.kind for t in lexer.lex('br#"{"#')], [lexer.RAW_STR])

    def test_char_vs_lifetime(self):
        self.assertEqual(kinds("'a'"), [("char", "'a'")])
        self.assertEqual(kinds("'a")[0][0], lexer.LIFETIME)
        self.assertEqual(kinds("'static")[0][0], lexer.LIFETIME)
        self.assertEqual(kinds("'_")[0][0], lexer.LIFETIME)
        # char escapes
        for c in ("'\\''", "'\\\\'", "'\\n'", "'\\x7f'", "'\\u{1F600}'"):
            self.assertEqual([t.kind for t in lexer.lex(c)], [lexer.CHAR], c)

    def test_brace_char_literal_hidden(self):
        self.assertEqual(braces("let c = '{';"), [])
        self.assertEqual(braces("match c { '{' => 1, '}' => 2, _ => 0 }"), ["{", "}"])

    def test_nested_block_comment(self):
        text = "/* outer /* inner { */ still comment } */ fn f() {}"
        toks = lexer.lex(text)
        self.assertEqual(toks[0].kind, lexer.BLOCK_COMMENT)
        self.assertEqual(braces(text), ["(", ")", "{", "}"])

    def test_line_comment_kinds(self):
        for text in ("// x {", "/// doc {", "//! inner {"):
            toks = lexer.lex(text)
            self.assertEqual(toks[0].kind, lexer.LINE_COMMENT, text)
            self.assertEqual(braces(text), [], text)

    def test_string_escapes(self):
        self.assertEqual(braces('let s = "{\\"}";'), [])
        self.assertEqual(braces('let s = "\\\\"; let t = "{";'), [])

    def test_raw_ident(self):
        toks = lexer.lex("let r#match = 1;")
        self.assertIn(("ident", "r#match"), [(t.kind, t.text) for t in toks])

    def test_numbers_and_ranges(self):
        toks = kinds("for i in 0..10 { let x = 1.5e-3f64; let y = 0xff_u32; }")
        self.assertIn(("num", "0"), toks)
        self.assertIn(("num", "10"), toks)
        self.assertIn(("num", "1.5e-3f64"), toks)
        self.assertIn(("num", "0xff_u32"), toks)

    def test_line_numbers(self):
        toks = lexer.lex("a\nb\n\nc")
        self.assertEqual([(t.text, t.line) for t in toks], [("a", 1), ("b", 2), ("c", 4)])
        # multi-line tokens advance the line counter
        toks = lexer.lex('r"x\ny" z')
        self.assertEqual(toks[1].line, 2)

    def test_code_comment_split(self):
        toks = lexer.lex("a // c\nb")
        self.assertEqual([t.text for t in lexer.code_tokens(toks)], ["a", "b"])
        self.assertEqual(len(lexer.comment_tokens(toks)), 1)


if __name__ == "__main__":
    unittest.main()
