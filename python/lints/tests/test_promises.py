import unittest

from lintest import make_source

from engine.passes import promises


def lifecycle(body: str):
    src = make_source("fn handler(&mut self) {\n" + body + "\n}\n")
    return promises.check_lifecycle(src)


def file_level(text: str, rel: str = "rust/src/fixture.rs"):
    return promises.check_file_level(make_source(text, rel))


class LifecycleLeakTest(unittest.TestCase):
    def test_leak_on_early_return(self):
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    if self.closed {
        return;
    }
    promise.deliver(reply);
"""
        )
        self.assertEqual(len(fs), 1)
        self.assertIn("returns", fs[0].msg)
        self.assertIn("`promise`", fs[0].msg)

    def test_leak_via_question_mark(self):
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    let frame = self.codec.encode(&msg)?;
    promise.deliver(frame);
"""
        )
        self.assertEqual(len(fs), 1)
        self.assertIn("`?`", fs[0].msg)

    def test_leak_falls_off_end(self):
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    self.metrics.observe();
"""
        )
        self.assertEqual(len(fs), 1)
        self.assertIn("falls off the end", fs[0].msg)

    def test_anchor_is_binding_line(self):
        fs = lifecycle("\n    let p = self.ctx.make_promise();\n    return;\n")
        self.assertEqual(len(fs), 1)
        # waiver may sit on the `let` line, not only the exit line
        self.assertEqual(fs[0].anchor_lines, (3,))


class LifecycleCleanTest(unittest.TestCase):
    def test_clean_if_else_both_deliver(self):
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    if ok {
        promise.deliver(reply);
    } else {
        promise.fail(err);
    }
"""
        )
        self.assertEqual(fs, [])

    def test_if_without_else_is_maybe_not_reported(self):
        # only *provably* unconsumed paths are findings; an if-without-else
        # that delivers inside lands on MAYBE and stays quiet
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    if ok {
        promise.deliver(reply);
    }
"""
        )
        self.assertEqual(fs, [])

    def test_clean_closure_capture(self):
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    self.scheduler.spawn(move || {
        promise.deliver(compute());
    });
"""
        )
        self.assertEqual(fs, [])

    def test_clean_struct_shorthand_handoff(self):
        fs = lifecycle(
            """
    let slot = FutureSlot::new();
    self.pending.push(RequestFuture { slot });
"""
        )
        self.assertEqual(fs, [])

    def test_clean_returned_binding(self):
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    return promise;
"""
        )
        self.assertEqual(fs, [])

    def test_clean_bare_argument_handoff(self):
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    self.router.register(id, promise);
"""
        )
        self.assertEqual(fs, [])

    def test_panic_path_is_not_a_leak(self):
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    if broken {
        panic!("invariant");
    }
    promise.deliver(reply);
"""
        )
        self.assertEqual(fs, [])

    def test_match_is_scanned_linearly(self):
        # documented approximation: consumption anywhere inside a match body
        # counts for the whole match (false-negative direction)
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    match kind {
        Kind::A => promise.deliver(a),
        Kind::B => {}
    }
"""
        )
        self.assertEqual(fs, [])

    def test_pattern_let_is_not_a_mint_binding(self):
        # `let Some(x) = ...` must not bind `Some` as a promise
        fs = lifecycle(
            """
    if let Some(err) = self.guard(self.ctx.make_promise()) {
        log(err);
    }
    return;
"""
        )
        self.assertEqual(fs, [])

    def test_test_functions_are_skipped(self):
        src = make_source(
            "#[cfg(test)]\nmod t {\n    fn leaky() {\n"
            "        let p = ctx.make_promise();\n        return;\n    }\n}\n"
        )
        self.assertEqual(promises.check_lifecycle(src), [])

    def test_inspect_guarded_return_is_flagged(self):
        # documented conservative behavior: INSPECT calls don't consume, so
        # a return guarded only by is_resolved() still reports — waive at the
        # binding line if the pattern is intentional
        fs = lifecycle(
            """
    let promise = self.ctx.make_promise();
    if promise.is_resolved() {
        return;
    }
    promise.deliver(reply);
"""
        )
        self.assertEqual(len(fs), 1)
        self.assertIn("returns", fs[0].msg)


class FileLevelTest(unittest.TestCase):
    def test_mint_without_deliver(self):
        fs = file_level("fn f(ctx: &Ctx) { let p = ctx.make_promise(); keep(p); }")
        self.assertEqual(len(fs), 1)
        self.assertIn("no deliver", fs[0].msg)

    def test_mint_with_deliver_clean(self):
        fs = file_level(
            "fn f(ctx: &Ctx) { let p = ctx.make_promise(); p.deliver_err(e); }"
        )
        self.assertEqual(fs, [])

    def test_def_file_exempt(self):
        fs = file_level(
            "fn make_promise(&self) -> ResponsePromise { ResponsePromise::new() }",
            rel="rust/src/actor/request.rs",
        )
        self.assertEqual(fs, [])

    def test_pending_map_missing_exits(self):
        fs = file_level("fn f(&mut self) { self.pending.insert(id, slot); }")
        self.assertEqual(len(fs), 1)
        self.assertIn("reply removal", fs[0].msg)
        self.assertIn("fail_one/fail_pending", fs[0].msg)
        self.assertIn("reaper", fs[0].msg)

    def test_pending_map_complete_clean(self):
        fs = file_level(
            """
fn f(&mut self) { self.pending.insert(id, slot); }
fn g(&mut self) { self.pending.remove(&id); }
fn fail_one(&mut self, id: u64) {}
struct Reaper;
"""
        )
        self.assertEqual(fs, [])

    def test_future_slot_without_resolve(self):
        fs = file_level("struct FutureSlot { state: State }")
        self.assertEqual(len(fs), 1)
        self.assertIn("resolve", fs[0].msg)


if __name__ == "__main__":
    unittest.main()
