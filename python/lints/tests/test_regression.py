"""Regression fixture: the PR-8 regex stripper's false-positive class.

The old stripper scanned characters, so a token ending in `r` (an
identifier like `x2r`, or the lifetime `'r`) directly abutting a string
literal opened a *phantom raw string* at that trailing `r`. Raw strings
ignore escapes, so the phantom terminates at the string's first escaped
quote — leaking the string's remaining content (braces included) into the
"code" the balance rule counted, and leaving a stray quote that cascades
into swallowing real code. The token-level lexer lexes identifiers and
lifetimes atomically and recognizes raw strings only in token-start
position, so the class is gone by construction.

These tests pin both halves: the old stripper *does* miscount the fixture
(so the fixture stays honest if someone edits it), and the new engine does
not.
"""

import unittest

from lintest import make_source, old_strip_source

from engine import lexer
from engine.passes import structural

# Valid Rust (macro token trees admit `'r` directly before a string): the
# old stripper sees `r"\"` as a raw string, terminates it at the escaped
# quote, and the rest of the line — brace included — leaks into "code".
LIFETIME_FIXTURE = '''fn demo() {
    emit!('r"\\"{ not code }");
    let pat = "}{";
}
'''

# Same class via an identifier ending in `r` (valid in edition-2015 macro
# token trees; the analyzer must stay sound on vendored sources too).
IDENT_FIXTURE = '''fn demo() {
    legacy_macro!(x2r"\\"{ not code }");
}
'''


def old_braces(text):
    return [c for c in old_strip_source(text) if c in "{}"]


def new_braces(text):
    return [
        t.text
        for t in lexer.lex(text)
        if t.kind == lexer.PUNCT and t.text in "{}"
    ]


class StripperRegressionTest(unittest.TestCase):
    def test_old_stripper_miscounts_lifetime_fixture(self):
        seen = old_braces(LIFETIME_FIXTURE)
        # the phantom raw string leaks string-content braces and unbalances
        self.assertNotEqual(seen, ["{", "}"])
        self.assertNotEqual(seen.count("{"), seen.count("}"))

    def test_old_stripper_miscounts_ident_fixture(self):
        self.assertNotEqual(old_braces(IDENT_FIXTURE), ["{", "}"])

    def test_engine_counts_exactly_the_fn_braces(self):
        self.assertEqual(new_braces(LIFETIME_FIXTURE), ["{", "}"])
        self.assertEqual(new_braces(IDENT_FIXTURE), ["{", "}"])

    def test_balance_pass_clean_on_fixtures(self):
        for text in (LIFETIME_FIXTURE, IDENT_FIXTURE):
            src = make_source(text)
            self.assertEqual(structural.check_file(src), [])

    def test_balance_pass_still_catches_real_imbalance(self):
        src = make_source("fn f() { if x { y(); }\n")
        findings = structural.check_file(src)
        self.assertTrue(findings)
        self.assertEqual(findings[0].rule, "balance")


if __name__ == "__main__":
    unittest.main()
