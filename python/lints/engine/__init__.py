"""Token-level invariant engine for the caf_ocl tree (stdlib-only).

Layering:

* ``lexer``   — Rust token stream (comments preserved as tokens);
* ``items``   — attributes, ``#[cfg(test)]`` masking, functions, block trees;
* ``source``  — one lexed file + derived views + the waiver table;
* ``report``  — findings, waiver application, JSON rendering;
* ``config``  — the policy tables (scopes, gauges, resolver surfaces);
* ``passes``  — the rules themselves (R1–R6 re-hosted, P1–P4 new).

Every pass has the same signature, ``run(ctx)``, where ``ctx`` is the
driver's :class:`Context` below.
"""

from __future__ import annotations


class Context:
    """Everything a pass needs: the loaded tree and the shared report."""

    __slots__ = ("repo", "sources", "extra", "report")

    def __init__(self, repo: str, sources: dict, extra: dict, report) -> None:
        self.repo = repo
        # rel path -> SourceFile for rust/src (full rule surface)
        self.sources = sources
        # rel path -> SourceFile for tests/benches/examples (structural only)
        self.extra = extra
        self.report = report

    def all_sources(self) -> dict:
        merged = dict(self.sources)
        merged.update(self.extra)
        return merged
