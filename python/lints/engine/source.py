"""SourceFile: one lexed Rust file plus the derived views passes consume.

Everything is computed once per file and shared by all passes: the full
token stream, the comment-free code stream, the per-code-token test mask,
extracted functions, per-line comment text, and the waiver table.

Waiver syntax (checked by the waiver-hygiene step in the driver):

    // lint-ok: <reason>              waives any rule on this line
    // lint-ok(rule[,rule...]): <reason>   waives only the named rules

A waiver must carry a reason; a bare `lint-ok:` with an empty reason is
itself a finding. Waivers inside test-masked regions are ignored entirely
(test code is outside every rule's scope, so they can never be "used").
"""

from __future__ import annotations

import re

from . import items, lexer


class Waiver:
    __slots__ = ("path", "line", "rules", "reason", "used", "in_test")

    def __init__(self, path: str, line: int, rules: frozenset[str] | None, reason: str, in_test: bool):
        self.path = path
        self.line = line
        self.rules = rules  # None = waives any rule
        self.reason = reason
        self.used = False
        self.in_test = in_test

    def covers(self, rule: str) -> bool:
        return self.rules is None or rule in self.rules


_WAIVER_RE = re.compile(r"lint-ok(?:\(([\w,\- ]+)\))?:\s*(.*)")


class SourceFile:
    def __init__(self, path: str, rel: str, text: str):
        self.path = path
        self.rel = rel
        self.text = text
        self.tokens = lexer.lex(text)
        self.code = lexer.code_tokens(self.tokens)
        self.comments = lexer.comment_tokens(self.tokens)
        self.mask = items.test_mask(self.code)
        self.functions = items.extract_functions(self.code, self.mask)
        self.attributes = items.find_attributes(self.code)
        # per-line comment text (a line can carry several comments)
        self.comments_by_line: dict[int, list[str]] = {}
        for c in self.comments:
            for off, piece in enumerate(c.text.split("\n")):
                self.comments_by_line.setdefault(c.line + off, []).append(piece)
        self._line_in_test = self._compute_line_test_mask()
        self.waivers = self._collect_waivers()

    # -- test-region helpers ------------------------------------------------

    def _compute_line_test_mask(self) -> set[int]:
        lines: set[int] = set()
        run_start = None
        for i, t in enumerate(self.code):
            if self.mask[i]:
                if run_start is None:
                    run_start = t.line
                lines.add(t.line)
            else:
                run_start = None
        return lines

    def line_in_test(self, line: int) -> bool:
        return line in self._line_in_test

    # -- waivers ------------------------------------------------------------

    def _collect_waivers(self) -> list[Waiver]:
        out: list[Waiver] = []
        for line, pieces in sorted(self.comments_by_line.items()):
            for piece in pieces:
                m = _WAIVER_RE.search(piece)
                if not m:
                    continue
                rules = m.group(1)
                ruleset = (
                    frozenset(r.strip() for r in rules.split(",") if r.strip())
                    if rules
                    else None
                )
                out.append(
                    Waiver(
                        self.rel,
                        line,
                        ruleset,
                        m.group(2).strip(),
                        self.line_in_test(line),
                    )
                )
        return out

    def waiver_for(self, rule: str, lines: tuple[int, ...]) -> Waiver | None:
        """First waiver covering `rule` on any of `lines` (finding + anchor)."""
        for w in self.waivers:
            if w.line in lines and w.covers(rule) and not w.in_test:
                return w
        return None

    # -- comment lookups ----------------------------------------------------

    def comment_text_near(self, line: int, above: int) -> str:
        """Concatenated comment text on `line` and up to `above` lines before."""
        parts: list[str] = []
        for ln in range(max(1, line - above), line + 1):
            parts.extend(self.comments_by_line.get(ln, ()))
        return "\n".join(parts)
