r"""Token-level Rust lexer for the invariant engine.

The old `check.py` worked on regex-stripped text; every rule inherited the
stripper's blind spots (mid-identifier raw-string detection, char-vs-lifetime
ambiguity, attribute text inside strings). This lexer produces a real token
stream instead, so the passes reason about tokens, not characters:

* nested block comments (``/* /* */ */``) and all three comment flavors
  (``//``, ``///``, ``//!``) are single tokens with their text preserved —
  annotation rules (``pairs with:``, ``SAFETY:``, ``lint-ok:``) read them
  directly instead of re-scanning raw lines;
* raw strings ``r"..."`` / ``r#"..."#`` (any hash depth) and their byte
  variants are recognized only in token-start position — an identifier that
  merely *ends* in ``r`` or ``br`` can never open a phantom raw string the way
  a character-scanner could;
* char literals are told apart from lifetimes by the closing quote, with full
  escape-sequence support (``'\u{1F600}'``, ``'\''``, ``'\\'``); everything
  that is not a closed char literal lexes as a lifetime token (``'a``,
  ``'static``, ``'_``, loop labels);
* numbers absorb type suffixes and float forms without swallowing range
  operators (``0..n``) or method calls on literals.

Guarantees (what passes may rely on):
* every brace/paren/bracket in real code appears as a ``punct`` token exactly
  once, and never from inside a comment, string, or char literal;
* ``Token.line`` is the 1-based source line of the token's first character;
* the concatenation order of tokens is source order.

Known approximations (documented, covered by fixtures):
* shebang/BOM handling is trivial (neither occurs in this tree);
* exotic numeric forms lex as a single ``num`` token without validation —
  the engine never interprets numeric values beyond "is a literal".
"""

from __future__ import annotations

import re

# Token kinds.
IDENT = "ident"
LIFETIME = "lifetime"
CHAR = "char"
STR = "str"
RAW_STR = "raw_str"
NUM = "num"
PUNCT = "punct"
LINE_COMMENT = "line_comment"
BLOCK_COMMENT = "block_comment"

COMMENT_KINDS = (LINE_COMMENT, BLOCK_COMMENT)

_CHAR_RE = re.compile(
    r"""'(?:
          \\u\{[0-9a-fA-F_]{1,6}\}   # '\u{7FFF}'
        | \\x[0-9a-fA-F]{2}          # '\x7f'
        | \\.                        # '\n' '\'' '\\'
        | [^\\'\n]                   # 'a' '{' '"'
        )'""",
    re.VERBOSE,
)
_LIFETIME_RE = re.compile(r"'(?:_|[A-Za-z][A-Za-z0-9_]*)")
_RAW_OPEN_RE = re.compile(r'(#*)"')
_IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
_IDENT_CONT = _IDENT_START | set("0123456789")


class Token:
    __slots__ = ("kind", "text", "line", "index")

    def __init__(self, kind: str, text: str, line: int, index: int):
        self.kind = kind
        self.text = text
        self.line = line
        self.index = index

    def __repr__(self) -> str:  # debugging aid
        return f"Token({self.kind}, {self.text!r}, line={self.line})"


def lex(text: str) -> list[Token]:
    """Lex `text` into a list of Tokens (comments included, whitespace not)."""
    toks: list[Token] = []
    i, n, line = 0, len(text), 1

    def emit(kind: str, end: int) -> None:
        nonlocal i, line
        toks.append(Token(kind, text[i:end], line, len(toks)))
        line += text.count("\n", i, end)
        i = end

    while i < n:
        c = text[i]
        nxt = text[i + 1] if i + 1 < n else ""

        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r\x0c":
            i += 1
            continue

        if c == "/" and nxt == "/":
            j = text.find("\n", i)
            emit(LINE_COMMENT, n if j == -1 else j)
            continue

        if c == "/" and nxt == "*":
            depth, j = 1, i + 2
            while j < n and depth:
                if text.startswith("/*", j):
                    depth += 1
                    j += 2
                elif text.startswith("*/", j):
                    depth -= 1
                    j += 2
                else:
                    j += 1
            emit(BLOCK_COMMENT, j)
            continue

        # raw / byte string starts. These fire only in token-start position:
        # identifiers are lexed atomically below, so `attr"x"` lexes as the
        # ident `attr` followed by a plain string — never a phantom raw
        # string opened at its trailing `r` (an old-stripper bug class).
        if c == "r" or (c == "b" and nxt in ('"', "'", "r")):
            start = i + (2 if text.startswith("br", i) else 1)
            if text.startswith("b'", i):
                m = _CHAR_RE.match(text, i + 1)
                if m:
                    emit(CHAR, m.end())
                    continue
            elif c == "b" and nxt == '"':
                j = _scan_plain_string(text, i + 1)
                emit(STR, j)
                continue
            else:
                m = _RAW_OPEN_RE.match(text, start)
                if m:
                    closing = '"' + m.group(1)
                    j = text.find(closing, m.end())
                    emit(RAW_STR, n if j == -1 else j + len(closing))
                    continue
            # not a literal after all (`r#ident`, bare `b` ident, ...):
            # fall through to identifier lexing

        if c == '"':
            emit(STR, _scan_plain_string(text, i))
            continue

        if c == "'":
            m = _CHAR_RE.match(text, i)
            if m:
                emit(CHAR, m.end())
                continue
            m = _LIFETIME_RE.match(text, i)
            if m:
                emit(LIFETIME, m.end())
                continue
            emit(PUNCT, i + 1)  # stray quote (invalid source)
            continue

        if c in _IDENT_START:
            j = i + 1
            # raw identifier `r#type`
            if c == "r" and nxt == "#" and i + 2 < n and text[i + 2] in _IDENT_START:
                j = i + 3
            while j < n and text[j] in _IDENT_CONT:
                j += 1
            emit(IDENT, j)
            continue

        if c.isdigit():
            j = i + 1
            while j < n:
                ch = text[j]
                if ch in _IDENT_CONT:
                    j += 1
                elif (
                    ch == "."
                    and j + 1 < n
                    and text[j + 1].isdigit()
                    and not text.startswith("..", j)
                ):
                    j += 1
                elif (
                    ch in "+-"
                    and text[j - 1] in "eE"
                    and j + 1 < n
                    and text[j + 1].isdigit()
                ):
                    j += 1
                else:
                    break
            emit(NUM, j)
            continue

        emit(PUNCT, i + 1)

    return toks


def _scan_plain_string(text: str, start: int) -> int:
    """Return the end offset of the plain string opening at `start` ('"')."""
    j, n = start + 1, len(text)
    while j < n:
        if text[j] == "\\":
            j += 2
        elif text[j] == '"':
            return j + 1
        else:
            j += 1
    return n  # unterminated (invalid source): consume to EOF


def code_tokens(toks: list[Token]) -> list[Token]:
    """The token stream with comments removed (structure/code passes)."""
    return [t for t in toks if t.kind not in COMMENT_KINDS]


def comment_tokens(toks: list[Token]) -> list[Token]:
    """Only the comment tokens (annotation/waiver passes)."""
    return [t for t in toks if t.kind in COMMENT_KINDS]
