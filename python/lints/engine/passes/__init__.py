"""The rule passes. Order matters only for report readability."""

from . import gauges, legacy, ordering, promises, structural, unsafe_inventory

ALL = (structural, legacy, promises, gauges, ordering, unsafe_inventory)
