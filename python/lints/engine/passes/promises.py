"""R4 promise-paths (file-level) + P1 promise-lifecycle (intraprocedural).

R4 is the PR-8 rule, re-hosted: a file that mints ResponsePromises must
contain a deliver path; a file registering correlated pending state must
contain all three exits (reply removal, failure, reaper); a file defining
FutureSlot must contain its `resolve(` transition.

P1 is the new per-binding path analysis. For every `let <name> = <mint>;`
in a non-test function (mints: `make_promise`, `ResponsePromise::new`,
`FutureSlot::new`), the binding must reach **at least one** of
resolve / fail / hand-off on every exit path of its enclosing scope.
"At most once" is already enforced by Rust's move semantics; the analyzer's
value-add is "at least once" — a path where the binding is silently dropped
resolves only via Drop's broken-promise fallback, which loses the typed
error the handler meant to send.

Path model (approximations documented in STATIC_ANALYSIS.md, each covered
by a fixture):

* consumption = resolver call (`deliver*`/`fail`/`resolve`/`complete`),
  any non-INSPECT method or field access, a bare use (argument, struct
  shorthand, return value), a `&`-borrow handed to a helper, or capture by
  a closure;
* exits = `?`, `return` (after scanning the returned expression for the
  binding), and falling off the end of the enclosing block;
* `panic!`/`unreachable!`/`todo!` and `break`/`continue` diverge without a
  leak report;
* `if/else if/else` chains merge branch results exactly (all-consume with
  an `else` ⇒ consumed); `match` bodies and loops are scanned linearly —
  consumption anywhere inside counts (a deliberate false-negative
  direction: the pass only fires when a binding is provably untouched);
* findings are reported only for *provably* unconsumed paths (state NO),
  never for the MAYBE lattice point.
"""

from __future__ import annotations

from .. import config
from ..items import Block, build_block_tree
from ..lexer import IDENT, PUNCT
from ..report import Finding
from .common import at, is_ident, is_punct, nontest

# -- R4: file-level presence checks ------------------------------------------


def _seq(code, i, first, second) -> bool:
    """code[i:] spells `first :: second`."""
    return (
        is_ident(at(code, i), first)
        and is_punct(at(code, i + 1), ":")
        and is_punct(at(code, i + 2), ":")
        and is_ident(at(code, i + 3), second)
    )


def check_file_level(src) -> list[Finding]:
    findings: list[Finding] = []
    code = src.code
    idents = {t.text for t in code if t.kind == IDENT}

    mints = "make_promise" in idents or any(
        _seq(code, i, "ResponsePromise", "new") for i in range(len(code))
    )
    if mints and src.rel not in config.PROMISE_DEF_FILES:
        if not idents & {"deliver", "deliver_msg", "deliver_err", "deliver_result"}:
            findings.append(
                Finding(
                    "promise-paths",
                    src.rel,
                    1,
                    "file creates ResponsePromises but contains no deliver/deliver_err "
                    "path — every promise minted here can only resolve via Drop's "
                    "broken-promise error",
                )
            )

    def pending_calls(method: str) -> bool:
        for i, t in enumerate(code):
            if not is_ident(t, "pending"):
                continue
            for j in range(i + 1, min(i + 14, len(code) - 1)):
                if is_punct(code[j], ";"):
                    break
                if is_punct(code[j], ".") and is_ident(at(code, j + 1), method):
                    return True
        return False

    if pending_calls("insert"):
        missing = []
        if not pending_calls("remove"):
            missing.append("reply removal (pending...remove)")
        if not idents & {"fail_one", "fail_pending"}:
            missing.append("failure path (fail_one/fail_pending)")
        if "Reaper" not in idents:
            missing.append("reaper/timeout path")
        if missing:
            findings.append(
                Finding(
                    "promise-paths",
                    src.rel,
                    1,
                    "file registers pending-map entries but lacks: "
                    + "; ".join(missing)
                    + " — a registered request could resolve never or twice",
                )
            )

    defines_slot = any(
        is_ident(t, "struct") and is_ident(at(code, i + 1), "FutureSlot")
        for i, t in enumerate(code)
    )
    if defines_slot and not any(
        is_ident(t, "resolve") and is_punct(at(code, i + 1), "(")
        for i, t in enumerate(code)
    ):
        findings.append(
            Finding(
                "promise-paths",
                src.rel,
                1,
                "file defines FutureSlot but no `resolve(` transition — "
                "futures minted here can only hang",
            )
        )
    return findings


# -- P1: per-binding lifecycle -----------------------------------------------

NO, MAYBE, YES = 0, 1, 2

_DIVERGE_MACROS = {"panic", "unreachable", "todo", "unimplemented"}


def _contains_ident(elems, name: str) -> bool:
    for e in elems:
        if isinstance(e, Block):
            if _contains_ident(e.elements, name):
                return True
        elif e.kind == IDENT and e.text == name:
            return True
    return False


def _is_mint_stmt(tokens) -> str | None:
    """If this `let` statement mints a promise-like value, the binding name."""
    # simple pattern only: `let [mut] name ... = ...` — tuple/struct patterns
    # are not promise mints in this tree
    k = 1
    if is_ident(at(tokens, k), "mut"):
        k += 1
    nm = at(tokens, k)
    if nm is None or nm.kind != IDENT or nm.text == "_":
        return None
    # the name must be a plain binding: `let name = ...` or `let name: T = ...`
    # — anything else (`let Some(x) = ...`, tuple patterns, if-let heads) is
    # a pattern, not a promise mint binding
    after = at(tokens, k + 1)
    if not (is_punct(after, "=") or is_punct(after, ":")):
        return None
    minted = False
    for i, t in enumerate(tokens):
        if is_ident(t, "make_promise"):
            minted = True
        elif _seq(tokens, i, "ResponsePromise", "new") or _seq(tokens, i, "FutureSlot", "new"):
            minted = True
    return nm.text if minted else None


class _Leak:
    __slots__ = ("line", "what")

    def __init__(self, line: int, what: str):
        self.line = line
        self.what = what


def _use_effect(elems, i, name: str) -> int | None:
    """Effect of the `name` token at elems[i]: YES (consumed) or None."""
    prev = elems[i - 1] if i > 0 and not isinstance(elems[i - 1], Block) else None
    if is_punct(prev, ".") or is_punct(prev, ":"):
        return None  # field access on another value / path segment
    nxt = elems[i + 1] if i + 1 < len(elems) and not isinstance(elems[i + 1], Block) else None
    if is_punct(nxt, "."):
        m = elems[i + 2] if i + 2 < len(elems) and not isinstance(elems[i + 2], Block) else None
        if m is not None and m.kind == IDENT:
            if m.text in config.PROMISE_RESOLVERS:
                return YES
            if m.text in config.PROMISE_INSPECT:
                return None
        return YES  # unknown method / field — hand-off (lenient)
    if is_punct(nxt, ":"):
        # `name: value` field init — the ident is a field label, not a use
        # (`name::` paths were already rejected via prev `:` check elsewhere)
        nxt2 = elems[i + 2] if i + 2 < len(elems) and not isinstance(elems[i + 2], Block) else None
        if not is_punct(nxt2, ":"):
            return None
    if is_punct(nxt, "="):
        nxt2 = elems[i + 2] if i + 2 < len(elems) and not isinstance(elems[i + 2], Block) else None
        if not is_punct(nxt2, "="):
            return None  # reassignment target, not a use
    return YES  # bare use: argument, struct shorthand, return value, borrow


def _scan(elems, start: int, name: str, status: int, leaks: list) -> int:
    i = start
    n = len(elems)
    while i < n:
        e = elems[i]
        if isinstance(e, Block):
            if e.construct in ("if", "elseif"):
                branch_sts = []
                has_else = False
                j = i
                while j < n:
                    b = elems[j]
                    if isinstance(b, Block) and b.construct in ("if", "elseif", "else"):
                        branch_sts.append(_scan(b.elements, 0, name, status, leaks))
                        if b.construct == "else":
                            has_else = True
                        # chain continues only through an `else` token
                        k = j + 1
                        cont = False
                        while k < n and not isinstance(elems[k], Block):
                            t = elems[k]
                            if is_punct(t, ";"):
                                break
                            if is_ident(t, "else"):
                                cont = True
                            k += 1
                        if cont and k < n:
                            j = k
                            continue
                    break
                if status == NO:
                    if has_else and branch_sts and all(s == YES for s in branch_sts):
                        status = YES
                    elif any(s != NO for s in branch_sts):
                        status = MAYBE
                i = j + 1
                continue
            if e.construct == "closure":
                if _contains_ident(e.elements, name):
                    status = YES  # captured: ownership handed to the closure
                i += 1
                continue
            if e.construct in ("loop", "while", "for"):
                st = _scan(e.elements, 0, name, status, leaks)
                if status == NO and st != NO:
                    status = MAYBE
                i += 1
                continue
            # match / plain / unsafe / else (outside a chain): linear merge
            status = _scan(e.elements, 0, name, status, leaks)
            i += 1
            continue

        if e.kind == IDENT and e.text == name:
            eff = _use_effect(elems, i, name)
            if eff is not None and status == NO:
                status = eff
            elif eff is not None:
                status = max(status, eff)
            i += 1
            continue

        if is_punct(e, "?"):
            if status == NO:
                leaks.append(_Leak(e.line, "may exit via `?`"))
            i += 1
            continue

        if e.kind == IDENT and e.text == "return":
            # scan the returned expression for the binding first
            j = i + 1
            span = []
            while j < n:
                t = elems[j]
                if not isinstance(t, Block) and is_punct(t, ";"):
                    break
                span.append(t)
                j += 1
            if _contains_ident(span, name):
                status = YES
            elif status == NO:
                leaks.append(_Leak(e.line, "returns"))
            # the linear flow of this element list ends here; any leak on
            # this path is already recorded, so no end-of-scope report
            return max(status, YES)

        if e.kind == IDENT and e.text in _DIVERGE_MACROS:
            nxt = elems[i + 1] if i + 1 < n and not isinstance(elems[i + 1], Block) else None
            if is_punct(nxt, "!"):
                return max(status, YES)  # diverging path: no leak possible

        if e.kind == IDENT and e.text in ("break", "continue"):
            return max(status, YES)  # leaves this scope's linear flow

        i += 1
    return status


def _walk_blocks(block: Block, src, fn, findings: list) -> None:
    elems = block.elements
    i = 0
    while i < len(elems):
        e = elems[i]
        if isinstance(e, Block):
            _walk_blocks(e, src, fn, findings)
            i += 1
            continue
        if e.kind == IDENT and e.text == "let":
            # find the end of this statement, walking nested blocks normally
            j = i
            stmt_tokens = []
            while j < len(elems):
                t = elems[j]
                if isinstance(t, Block):
                    _walk_blocks(t, src, fn, findings)
                elif is_punct(t, ";"):
                    break
                else:
                    stmt_tokens.append(t)
                j += 1
            name = _is_mint_stmt(stmt_tokens)
            if name is not None:
                leaks: list[_Leak] = []
                status = _scan(elems, j + 1, name, NO, leaks)
                if status == NO:
                    leaks.append(_Leak(e.line, "falls off the end of its scope"))
                seen_lines = set()
                for lk in leaks:
                    if lk.line in seen_lines:
                        continue
                    seen_lines.add(lk.line)
                    findings.append(
                        Finding(
                            "promise-lifecycle",
                            src.rel,
                            lk.line,
                            f"promise binding `{name}` (line {e.line}, fn `{fn.name}`) "
                            f"{lk.what} without reaching deliver/fail/hand-off — "
                            "this path resolves only via Drop's broken-promise fallback",
                            anchor_lines=(e.line,),
                        )
                    )
            i = j + 1
            continue
        i += 1


def check_lifecycle(src) -> list[Finding]:
    findings: list[Finding] = []
    for fn in src.functions:
        if fn.in_test:
            continue
        tree = build_block_tree(src.code, fn.body_start, fn.body_end)
        _walk_blocks(tree, src, fn, findings)
    return findings


def run(ctx) -> None:
    for src in ctx.sources.values():
        ctx.report.extend(check_file_level(src))
        ctx.report.extend(check_lifecycle(src))
    ctx.report.bump("promise_bindings_files", len(ctx.sources))
