"""R1 balance — structural sanity, re-hosted on the token stream.

The lexer already guarantees that braces inside comments, strings, chars
and raw strings never surface as punct tokens, so the balance check here is
exact: every ``{``/``(``/``[`` it sees is real code structure. This is the
fix for the old stripper's false-positive class (braces inside raw strings
containing ``"#`` sequences, and ``'{'`` char literals next to lifetimes) —
covered by the regression fixture in ``tests/test_regression.py``.

Also flags any attribute whose ``]`` never arrives (truncated-file guard).
Runs over rust/src *and* tests/benches/examples: an imbalance there breaks
the build just as hard.
"""

from __future__ import annotations

from ..lexer import PUNCT
from ..report import Finding

_PAIRS = {"}": "{", ")": "(", "]": "["}


def check_file(src) -> list[Finding]:
    findings: list[Finding] = []
    stack = []
    for t in src.code:
        if t.kind != PUNCT:
            continue
        if t.text in "{([":
            stack.append(t)
        elif t.text in "})]":
            if not stack or stack[-1].text != _PAIRS[t.text]:
                findings.append(Finding("balance", src.rel, t.line, f"unbalanced `{t.text}`"))
                return findings
            stack.pop()
    for t in stack:
        findings.append(Finding("balance", src.rel, t.line, f"unclosed `{t.text}`"))
    for a in src.attributes:
        if not a.closed:
            findings.append(Finding("balance", src.rel, a.line, "unterminated attribute"))
    return findings


def run(ctx) -> None:
    for src in ctx.all_sources().values():
        ctx.report.extend(check_file(src))
