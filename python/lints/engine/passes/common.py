"""Small token-walk helpers shared by the passes."""

from __future__ import annotations

from ..lexer import IDENT, PUNCT, Token


def nontest(src):
    """Yield (index, token) over code tokens outside test-masked regions."""
    for i, t in enumerate(src.code):
        if not src.mask[i]:
            yield i, t


def is_punct(t: Token | None, text: str) -> bool:
    return t is not None and t.kind == PUNCT and t.text == text


def is_ident(t: Token | None, text: str | None = None) -> bool:
    return t is not None and t.kind == IDENT and (text is None or t.text == text)


def at(code: list[Token], i: int) -> Token | None:
    return code[i] if 0 <= i < len(code) else None


def match_path(code: list[Token], i: int, *segments: str) -> bool:
    """True if code[i:] spells `seg1 :: seg2 :: ...` (idents joined by ::)."""
    for k, seg in enumerate(segments):
        if not is_ident(at(code, i), seg):
            return False
        if k + 1 < len(segments):
            if not (is_punct(at(code, i + 1), ":") and is_punct(at(code, i + 2), ":")):
                return False
            i += 3
    return True


def close_paren(code: list[Token], open_i: int) -> int:
    """Index of the `)` matching the `(` at open_i (or len(code))."""
    depth = 0
    for j in range(open_i, len(code)):
        t = code[j]
        if t.kind == PUNCT:
            if t.text == "(":
                depth += 1
            elif t.text == ")":
                depth -= 1
                if depth == 0:
                    return j
    return len(code)


def call_orderings(code: list[Token], open_i: int) -> list[str]:
    """The `Ordering::X` names inside the call parens opening at open_i."""
    end = close_paren(code, open_i)
    out = []
    j = open_i
    while j < end:
        if (
            is_ident(at(code, j), "Ordering")
            and is_punct(at(code, j + 1), ":")
            and is_punct(at(code, j + 2), ":")
            and is_ident(at(code, j + 3))
        ):
            out.append(code[j + 3].text)
            j += 4
            continue
        j += 1
    return out
