"""P3 ordering-graph — acquire/release pairing over the interposition surface.

Builds a table, per atomic variable of the R6-interposed files (the model
checker's surface), of every load / store / RMW and its Ordering. RMWs
count on both sides: ``AcqRel`` contributes an Acquire load-side and a
Release store-side; ``compare_exchange`` contributes its failure ordering
as an extra load-side. Variables are aggregated by field name across the
surface — the concurrency core is one protocol, and its methods touch each
other's fields across files (mailbox state from cell, etc.).

Checks (each suppressible per-variable by fence mitigation — the Chase–Lev
deque legitimately publishes with Relaxed stores + a standalone fence, so a
Release-or-SeqCst ``fence`` in a file that touches the variable's weak side
counts as providing that side):

* **unpaired-release** — a Release-or-stronger store with no
  Acquire-or-stronger load anywhere on the surface: the release publishes
  to nobody, so either it is dead weight or its reader is silently Relaxed;
* **unpaired-acquire** — an Acquire-or-stronger load with no
  Release-or-stronger store: the acquire synchronizes with nothing;
* **relaxed-rmw-on-release-var** — a fully Relaxed RMW on a variable that
  elsewhere uses Release stores: the RMW joins the variable's modification
  order without joining its happens-before protocol, which is almost
  always an accident;
* **seqcst-onesided** — SeqCst on only one side of a variable with no
  SeqCst fence in reach: SeqCst buys a total order only when both sides
  pay for it.

The full table is published into the JSON report (`atomics_table`).
"""

from __future__ import annotations

from .. import config
from ..lexer import IDENT
from ..report import Finding
from .common import at, call_orderings, is_ident, is_punct, nontest

_LOAD_OPS = {"load"}
_STORE_OPS = {"store"}
_RMW_OPS = {
    "swap",
    "compare_exchange",
    "compare_exchange_weak",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_nand",
    "fetch_max",
    "fetch_min",
    "fetch_update",
}

# strength on each side; None = not applicable to that side
_LOAD_STRENGTH = {"Relaxed": 0, "Acquire": 1, "AcqRel": 1, "SeqCst": 2}
_STORE_STRENGTH = {"Relaxed": 0, "Release": 1, "AcqRel": 1, "SeqCst": 2}


class _Access:
    __slots__ = ("var", "op", "cls", "load_ord", "store_ord", "rel", "line")

    def __init__(self, var, op, cls, load_ord, store_ord, rel, line):
        self.var = var
        self.op = op
        self.cls = cls  # "load" | "store" | "rmw"
        self.load_ord = load_ord  # Ordering name or None
        self.store_ord = store_ord
        self.rel = rel
        self.line = line


def _rmw_sides(op: str, ords: list[str]) -> tuple[str, str]:
    """(load_ord, store_ord) for an RMW given its Ordering argument(s)."""
    if op in ("compare_exchange", "compare_exchange_weak"):
        success = ords[0] if ords else "Relaxed"
        return success, success  # failure ordering handled as extra load
    if op == "fetch_update":
        set_ord = ords[0] if ords else "Relaxed"
        fetch_ord = ords[1] if len(ords) > 1 else "Relaxed"
        return fetch_ord, set_ord
    o = ords[0] if ords else "Relaxed"
    if o == "AcqRel":
        return "Acquire", "Release"
    if o == "Acquire":
        return "Acquire", "Relaxed"
    if o == "Release":
        return "Relaxed", "Release"
    return o, o  # Relaxed / SeqCst


def collect(src) -> tuple[list[_Access], set[str]]:
    accesses: list[_Access] = []
    fences: set[str] = set()
    code = src.code
    for i, t in nontest(src):
        if t.kind != IDENT:
            continue
        if t.text == "fence" and is_punct(at(code, i + 1), "("):
            fences.update(call_orderings(code, i + 1))
            continue
        if not (is_punct(at(code, i + 1), ".")):
            continue
        op = at(code, i + 2)
        if op is None or op.kind != IDENT or not is_punct(at(code, i + 3), "("):
            continue
        name = op.text
        if name not in _LOAD_OPS | _STORE_OPS | _RMW_OPS:
            continue
        ords = call_orderings(code, i + 3)
        if not ords:
            continue  # not an atomic op (e.g. mpsc `load`-alikes without Ordering)
        var = t.text
        if name in _LOAD_OPS:
            accesses.append(_Access(var, name, "load", ords[0], None, src.rel, t.line))
        elif name in _STORE_OPS:
            accesses.append(_Access(var, name, "store", None, ords[0], src.rel, t.line))
        else:
            lo, so = _rmw_sides(name, ords)
            accesses.append(_Access(var, name, "rmw", lo, so, src.rel, t.line))
            if name in ("compare_exchange", "compare_exchange_weak") and len(ords) > 1:
                accesses.append(
                    _Access(var, name + "(fail)", "load", ords[1], None, src.rel, t.line)
                )
    return accesses, fences


def run(ctx) -> None:
    accesses: list[_Access] = []
    file_fences: dict[str, set[str]] = {}
    for rel in sorted(config.INTERPOSED_FILES):
        src = ctx.sources.get(rel)
        if src is None:
            continue
        acc, fences = collect(src)
        accesses.extend(acc)
        file_fences[rel] = fences

    by_var: dict[str, list[_Access]] = {}
    for a in accesses:
        by_var.setdefault(a.var, []).append(a)

    # published table: file::var -> op-class x Ordering counts
    table: dict[str, dict] = {}
    for a in accesses:
        key = f"{a.rel}::{a.var}"
        cell = table.setdefault(key, {})
        ords = a.load_ord if a.cls == "load" else a.store_ord if a.cls == "store" else f"{a.load_ord}/{a.store_ord}"
        cell.setdefault(a.cls, {}).setdefault(ords, 0)
        cell[a.cls][ords] += 1
    ctx.report.publish("atomics_table", {k: table[k] for k in sorted(table)})
    ctx.report.publish(
        "fences", {k: sorted(v) for k, v in sorted(file_fences.items()) if v}
    )

    findings: list[Finding] = []
    for var, accs in sorted(by_var.items()):
        load_max = max(
            (_LOAD_STRENGTH.get(a.load_ord, 0) for a in accs if a.load_ord), default=-1
        )
        store_max = max(
            (_STORE_STRENGTH.get(a.store_ord, 0) for a in accs if a.store_ord), default=-1
        )
        files = {a.rel for a in accs}

        def fence_mitigated(side_strength: int) -> bool:
            """A fence of the needed strength in any file touching the var."""
            need = {"Release", "SeqCst", "AcqRel"} if side_strength else {"Acquire", "SeqCst", "AcqRel"}
            return any(file_fences.get(rel, set()) & need for rel in files)

        has_release_store = store_max >= 1
        has_acquire_load = load_max >= 1
        has_load_side = any(a.load_ord for a in accs)
        has_store_side = any(a.store_ord for a in accs)

        if has_release_store and has_load_side and not has_acquire_load:
            if not fence_mitigated(0):
                for a in accs:
                    if a.store_ord and _STORE_STRENGTH.get(a.store_ord, 0) >= 1:
                        findings.append(
                            Finding(
                                "ordering-graph",
                                a.rel,
                                a.line,
                                f"Release store to `{var}` but every load of "
                                "it on the interposition surface is Relaxed "
                                "and no acquire fence is in reach — the "
                                "release publishes to nobody",
                            )
                        )
                        break

        if has_acquire_load and has_store_side and not has_release_store:
            if not fence_mitigated(1):
                for a in accs:
                    if a.load_ord and _LOAD_STRENGTH.get(a.load_ord, 0) >= 1:
                        findings.append(
                            Finding(
                                "ordering-graph",
                                a.rel,
                                a.line,
                                f"Acquire load of `{var}` but every store to "
                                "it on the interposition surface is Relaxed "
                                "and no release fence is in reach — the "
                                "acquire synchronizes with nothing",
                            )
                        )
                        break

        if has_release_store:
            for a in accs:
                if (
                    a.cls == "rmw"
                    and a.load_ord == "Relaxed"
                    and a.store_ord == "Relaxed"
                    and not (file_fences.get(a.rel, set()) & {"SeqCst", "AcqRel", "Release"})
                ):
                    findings.append(
                        Finding(
                            "ordering-graph",
                            a.rel,
                            a.line,
                            f"fully Relaxed RMW on `{var}`, which elsewhere "
                            "uses Release stores — the RMW joins the "
                            "modification order without joining the "
                            "happens-before protocol",
                        )
                    )

        seq_load = any(a.load_ord == "SeqCst" for a in accs)
        seq_store = any(a.store_ord == "SeqCst" for a in accs)
        if seq_load != seq_store and (seq_load or seq_store):
            if not any(file_fences.get(rel, set()) & {"SeqCst"} for rel in files):
                side = "load" if seq_load else "store"
                for a in accs:
                    hit = a.load_ord == "SeqCst" if seq_load else a.store_ord == "SeqCst"
                    if hit:
                        findings.append(
                            Finding(
                                "ordering-graph",
                                a.rel,
                                a.line,
                                f"one-sided SeqCst on `{var}` ({side} side only, "
                                "no SeqCst fence in reach) — SeqCst buys a total "
                                "order only when both sides pay for it",
                            )
                        )
                        break
    ctx.report.extend(findings)
