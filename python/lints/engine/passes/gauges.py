"""P2 gauge-balance — every steering-gauge increment must be undoable.

The admission/steering layer (PR 3/6) makes load decisions off a handful of
atomic gauges. A gauge that can only go up is a slow poison: the scheduler
sheds load forever after a failure path forgets the decrement. This pass
keeps a crate-wide ledger per gauge *field name*:

* **balanced gauges** (`inflight`, `routed`, `batch_pending`, `launched`)
  must have at least one decrement / drain / resync reachable somewhere in
  the crate for their increments;
* **monotonic counters** (`shed`, `overloaded`, `deadline*`, `retired`)
  must never be decremented — a decrement there silently falsifies the
  stats surface that ops dashboards and the soak harness read;
* **early-exit check**: inside a single function, an increment followed by
  a `?` exit with no decrement (direct, or via a call to a function that
  transitively decrements the gauge — computed as a fixpoint so undo
  helpers like `launch_refused` count at their call sites) is flagged: that
  error path leaks the gauge.

Ledger attribution is by field name, so same-named gauges on different
structs share a ledger. That is a documented approximation: it can only
*hide* an imbalance (both structs' decrements count for either), never
invent one — the lenient direction for a gate.

The full ledger is published into the JSON report (`gauge_ledger`).
"""

from __future__ import annotations

from .. import config
from ..lexer import IDENT, NUM, PUNCT
from ..report import Finding
from .common import at, close_paren, is_ident, is_punct, nontest

_INC_OPS = {"fetch_add"}
_DEC_OPS = {"fetch_sub"}
_RESYNC_OPS = {"store"}

_ALL_GAUGES = set(config.BALANCED_GAUGES) | set(config.MONOTONIC_COUNTERS)


class _Event:
    __slots__ = ("gauge", "kind", "rel", "line", "fn", "index")

    def __init__(self, gauge, kind, rel, line, fn, index):
        self.gauge = gauge
        self.kind = kind  # "inc" | "dec" | "resync"
        self.rel = rel
        self.line = line
        self.fn = fn  # enclosing function name or None
        self.index = index  # code-token index


def _classify_fetch_update(code, open_i) -> str:
    """fetch_update bodies: subtraction ⇒ dec, addition ⇒ inc, else resync."""
    end = close_paren(code, open_i)
    for j in range(open_i, end):
        t = code[j]
        if t.kind == IDENT and t.text in ("saturating_sub", "checked_sub", "wrapping_sub"):
            return "dec"
        if t.kind == IDENT and t.text in ("saturating_add", "checked_add", "wrapping_add"):
            return "inc"
        if is_punct(t, "-") and at(code, j + 1) is not None and at(code, j + 1).kind in (NUM, IDENT):
            return "dec"
        if is_punct(t, "+") and at(code, j + 1) is not None and at(code, j + 1).kind in (NUM, IDENT):
            return "inc"
    return "resync"


def _enclosing_fn(src, index):
    for fn in src.functions:
        if fn.body_start <= index <= fn.body_end:
            return fn.name
    return None


def collect_events(src) -> list[_Event]:
    events: list[_Event] = []
    code = src.code
    for i, t in nontest(src):
        if t.kind != IDENT or t.text not in _ALL_GAUGES:
            continue
        if not is_punct(at(code, i + 1), "."):
            continue
        op = at(code, i + 2)
        if op is None or op.kind != IDENT or not is_punct(at(code, i + 3), "("):
            continue
        if op.text in _INC_OPS:
            kind = "inc"
        elif op.text in _DEC_OPS:
            kind = "dec"
        elif op.text in _RESYNC_OPS:
            kind = "resync"
        elif op.text == "fetch_update":
            kind = _classify_fetch_update(code, i + 3)
        else:
            continue
        events.append(_Event(t.text, kind, src.rel, t.line, _enclosing_fn(src, i), i))
    return events


def _dec_fn_fixpoint(per_file_events, sources) -> dict[str, set[str]]:
    """gauge -> names of functions that (transitively) dec/resync it."""
    decfns: dict[str, set[str]] = {}
    for events in per_file_events.values():
        for ev in events:
            if ev.kind in ("dec", "resync") and ev.fn:
                decfns.setdefault(ev.gauge, set()).add(ev.fn)
    changed = True
    while changed:
        changed = False
        for src in sources.values():
            code = src.code
            for fn in src.functions:
                if fn.in_test:
                    continue
                for gauge, names in decfns.items():
                    if fn.name in names:
                        continue
                    for i in range(fn.body_start, fn.body_end):
                        t = code[i]
                        if (
                            t.kind == IDENT
                            and t.text in names
                            and is_punct(at(code, i + 1), "(")
                        ):
                            names.add(fn.name)
                            changed = True
                            break
    return decfns


def run(ctx) -> None:
    per_file: dict[str, list[_Event]] = {}
    for rel, src in ctx.sources.items():
        evs = collect_events(src)
        if evs:
            per_file[rel] = evs

    ledger: dict[str, dict] = {}
    for events in per_file.values():
        for ev in events:
            g = ledger.setdefault(
                ev.gauge, {"kind": "", "inc": [], "dec": [], "resync": []}
            )
            g[ev.kind].append(f"{ev.rel}:{ev.line}")
    for gauge, g in ledger.items():
        g["kind"] = "balanced" if gauge in config.BALANCED_GAUGES else "monotonic"
    ctx.report.publish("gauge_ledger", {k: ledger[k] for k in sorted(ledger)})

    findings: list[Finding] = []

    # balanced gauges: crate-wide pairing
    for gauge in config.BALANCED_GAUGES:
        g = ledger.get(gauge)
        if not g or not g["inc"]:
            continue
        if g["dec"] or g["resync"]:
            continue
        for events in per_file.values():
            for ev in events:
                if ev.gauge == gauge and ev.kind == "inc":
                    findings.append(
                        Finding(
                            "gauge-balance",
                            ev.rel,
                            ev.line,
                            f"increment of balanced gauge `{gauge}` has no "
                            "decrement/drain/resync anywhere in the crate — "
                            "the gauge can only ratchet up",
                        )
                    )

    # monotonic counters: decrements are themselves the defect
    for events in per_file.values():
        for ev in events:
            if ev.gauge in config.MONOTONIC_COUNTERS and ev.kind == "dec":
                findings.append(
                    Finding(
                        "gauge-balance",
                        ev.rel,
                        ev.line,
                        f"monotonic counter `{ev.gauge}` is decremented — "
                        "stats counters only ever accumulate; a decrement "
                        "falsifies the ops surface",
                    )
                )

    # early-exit check: inc ... `?` with no dec/undo-call in between
    decfns = _dec_fn_fixpoint(per_file, ctx.sources)
    for rel, events in per_file.items():
        src = ctx.sources[rel]
        code = src.code
        for ev in events:
            if ev.kind != "inc" or ev.gauge not in config.BALANCED_GAUGES:
                continue
            fn = next(
                (f for f in src.functions if f.body_start <= ev.index <= f.body_end),
                None,
            )
            if fn is None:
                continue
            undo_names = decfns.get(ev.gauge, set())
            guarded = False
            for i in range(ev.index + 4, fn.body_end):
                t = code[i]
                if t.kind == IDENT and (
                    (t.text == ev.gauge and not guarded)
                    or (t.text in undo_names and is_punct(at(code, i + 1), "("))
                ):
                    # a later touch of the gauge or a call to an undo helper
                    # guards every `?` after it
                    guarded = True
                elif is_punct(t, "?") and not guarded:
                    findings.append(
                        Finding(
                            "gauge-balance",
                            src.rel,
                            t.line,
                            f"`?` exit after increment of `{ev.gauge}` "
                            f"(line {ev.line}) with no decrement or undo-helper "
                            "call in between — this error path leaks the gauge",
                            anchor_lines=(ev.line,),
                        )
                    )
                    break
    ctx.report.extend(findings)
