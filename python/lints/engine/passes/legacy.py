"""R2 seqcst-pairing, R3 no-unwrap, R5 codec-clamp, R6 interposition —
the PR-8 rules, re-hosted on the token stream.

Semantics are unchanged from the regex linter (STATIC_ANALYSIS.md documents
each rule's rationale); what changed is the *evidence*: matches come from
code tokens, so a ``fence(Ordering::SeqCst)`` inside a string or doc
comment no longer counts, ``.unwrap()`` is the two-token method call rather
than a substring, and annotations are read from comment tokens instead of
raw lines. Waivers are applied centrally by the driver, not here.
"""

from __future__ import annotations

import os
import re

from .. import config
from ..lexer import IDENT, NUM, PUNCT
from ..report import Finding
from .common import at, call_orderings, close_paren, is_ident, is_punct, nontest

_PAIRS_RE = re.compile(r"pairs with:\s*(.+)")
_PAIRS_REF_RE = re.compile(r"([\w/]+\.rs)::(\w+)")


# -- R2 seqcst-pairing -------------------------------------------------------


def _find_src(ctx, name: str):
    """Resolve `scheduler.rs` or `actor/scheduler.rs` among rust/src files."""
    for rel, src in ctx.sources.items():
        if rel == os.path.join("rust", "src", name.replace("/", os.sep)):
            return src
    base = os.path.basename(name)
    for rel, src in ctx.sources.items():
        if os.path.basename(rel) == base:
            return src
    return None


def check_seqcst_pairing(ctx, src) -> list[Finding]:
    findings: list[Finding] = []
    code = src.code
    for i, t in nontest(src):
        if not (is_ident(t, "fence") and is_punct(at(code, i + 1), "(")):
            continue
        if "SeqCst" not in call_orderings(code, i + 1):
            continue
        annot = None
        for m in _PAIRS_RE.finditer(src.comment_text_near(t.line, above=12)):
            annot = m.group(1)
        if annot is None:
            findings.append(
                Finding(
                    "seqcst-pairing",
                    src.rel,
                    t.line,
                    "SeqCst fence without a `pairs with: <file.rs>::<token>` "
                    "annotation naming its Dekker partner",
                )
            )
            continue
        refs = _PAIRS_REF_RE.findall(annot)
        if not refs:
            findings.append(
                Finding(
                    "seqcst-pairing",
                    src.rel,
                    t.line,
                    f"`pairs with:` annotation has no `<file.rs>::<token>` reference: {annot!r}",
                )
            )
            continue
        for fname, token in refs:
            target = _find_src(ctx, fname)
            if target is None:
                findings.append(
                    Finding(
                        "seqcst-pairing",
                        src.rel,
                        t.line,
                        f"`pairs with:` references unknown file {fname}",
                    )
                )
            elif not any(tok.kind == IDENT and tok.text == token for tok in target.code):
                findings.append(
                    Finding(
                        "seqcst-pairing",
                        src.rel,
                        t.line,
                        f"`pairs with:` token `{token}` not found in {fname}",
                    )
                )
    return findings


# -- R3 no-unwrap ------------------------------------------------------------


def check_no_unwrap(src) -> list[Finding]:
    if src.rel in config.UNWRAP_EXEMPT_FILES:
        return []
    if any(src.rel.startswith(p) for p in config.UNWRAP_EXEMPT_PREFIXES):
        return []
    findings: list[Finding] = []
    code = src.code
    for i, t in nontest(src):
        if not is_punct(t, "."):
            continue
        m = at(code, i + 1)
        if is_ident(m, "unwrap") and is_punct(at(code, i + 2), "(") and is_punct(at(code, i + 3), ")"):
            pass
        elif is_ident(m, "expect") and is_punct(at(code, i + 2), "("):
            pass
        else:
            continue
        findings.append(
            Finding(
                "no-unwrap",
                src.rel,
                m.line,
                "unwrap()/expect() in production code — handle the error, "
                "use a poison-tolerant lock, or waive with `// lint-ok: <why>`",
            )
        )
    return findings


# -- R5 codec-clamp ----------------------------------------------------------


def check_codec_clamp(src) -> list[Finding]:
    if src.rel != config.CODEC_FILE:
        return []
    code = src.code
    clamp_lines = {
        t.line
        for i, t in nontest(src)
        if is_ident(t, "count") and is_punct(at(code, i + 1), "(")
    }
    findings: list[Finding] = []
    for i, t in nontest(src):
        if not (is_ident(t, "with_capacity") and is_punct(at(code, i + 1), "(")):
            continue
        end = close_paren(code, i + 1)
        args = code[i + 2 : end]
        # constant capacities (encode-side arenas) are not the hazard: the
        # rule exists for *wire-derived* counts reserving unbacked memory
        if len(args) == 1 and args[0].kind == NUM:
            continue
        if any(ln in clamp_lines for ln in range(t.line - 4, t.line + 1)):
            continue
        findings.append(
            Finding(
                "codec-clamp",
                src.rel,
                t.line,
                "decoder preallocation without a Reader::count clamp within "
                "reach — a hostile count could reserve unbacked memory",
            )
        )
    return findings


# -- R6 interposition --------------------------------------------------------

def check_interposition(src) -> list[Finding]:
    if src.rel not in config.INTERPOSED_FILES:
        return []
    findings: list[Finding] = []
    code = src.code
    for i, t in nontest(src):
        if not is_ident(t, "use"):
            continue
        # collect every ident of this use declaration up to `;` — grouped
        # imports (`use std::cell::{Cell, UnsafeCell}`) are included, which
        # the old line regex missed
        path: list[str] = []
        j = i + 1
        while j < len(code):
            tj = code[j]
            if tj.kind == IDENT:
                path.append(tj.text)
            elif is_punct(tj, ";"):
                break
            j += 1
        bad = tuple(path[:3]) == ("std", "sync", "atomic") or (
            tuple(path[:2]) == ("std", "cell") and "UnsafeCell" in path
        )
        if bad:
            findings.append(
                Finding(
                    "interposition",
                    src.rel,
                    t.line,
                    "model-interposed file imports std atomics/UnsafeCell "
                    "directly — route through crate::loom_types or the model "
                    "checker silently loses this file's coverage",
                )
            )
    return findings


def run(ctx) -> None:
    for src in ctx.sources.values():
        ctx.report.extend(check_seqcst_pairing(ctx, src))
        ctx.report.extend(check_no_unwrap(src))
        ctx.report.extend(check_codec_clamp(src))
        ctx.report.extend(check_interposition(src))
