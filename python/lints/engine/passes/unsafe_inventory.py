"""P4 unsafe-inventory — every `unsafe` audited, new unsafe an explicit diff.

Two obligations per production `unsafe` site in rust/src:

* a rationale in the comments on the site's line, within three lines above,
  or in the contiguous comment block directly above — spelled ``SAFETY:``
  or as a ``# Safety`` doc section (``unsafe`` without an argument for
  *why* it is sound is a review debt);
* membership in the checked-in baseline ``python/lints/unsafe_baseline.json``.
  The baseline is keyed by (file, enclosing item, kind) with a count —
  deliberately line-number-free, so moving code never churns it, while
  *adding* an unsafe block anywhere is a baseline diff that must be
  committed alongside its justification (run ``--update-baseline``).
  Stale baseline entries (unsafe that no longer exists) are also findings:
  the inventory must match reality in both directions.

The current inventory is published into the JSON report (`unsafe_inventory`).
"""

from __future__ import annotations

import json
import os

from .. import config
from ..report import Finding
from .common import at, is_ident, is_punct, nontest

_KINDS = {"fn": "fn", "impl": "impl", "trait": "trait"}


def _enclosing_item(src, index: int) -> str:
    for fn in src.functions:
        if fn.sig_start <= index <= fn.body_end:
            return f"fn {fn.name}"
    # module-level unsafe (unsafe impl / static initializer): describe it by
    # the few tokens that follow, which is stable under reordering
    tail = []
    code = src.code
    j = index
    while j < len(code) and len(tail) < 6:
        t = code[j]
        if is_punct(t, "{") or is_punct(t, ";"):
            break
        tail.append(t.text)
        j += 1
    return " ".join(tail)


def _site_kind(src, index: int) -> str:
    nxt = at(src.code, index + 1)
    if nxt is not None and nxt.kind == "ident" and nxt.text in _KINDS:
        return _KINDS[nxt.text]
    return "block"


def _has_rationale(src, line: int) -> bool:
    """A SAFETY rationale covering the site.

    Accepted: any comment line on the site's line or within 3 lines above,
    *expanded to its full contiguous comment block*, containing ``SAFETY:``
    or a ``# Safety`` doc-section header. The block expansion matters for
    multi-line rationales whose keyword is on the block's first line.
    """
    for ln in range(line, max(0, line - 4), -1):
        if ln not in src.comments_by_line:
            continue
        lo = ln
        while lo - 1 in src.comments_by_line:
            lo -= 1
        hi = ln
        while hi + 1 in src.comments_by_line and hi + 1 <= line:
            hi += 1
        block = []
        for k in range(lo, hi + 1):
            block.extend(src.comments_by_line[k])
        text = "\n".join(block).lower()
        if "safety:" in text or "# safety" in text:
            return True
    return False


def collect_sites(src) -> list[dict]:
    sites = []
    for i, t in nontest(src):
        if not is_ident(t, "unsafe"):
            continue
        sites.append(
            {
                "file": src.rel.replace(os.sep, "/"),
                "item": _enclosing_item(src, i),
                "kind": _site_kind(src, i),
                "line": t.line,  # not part of the baseline key
            }
        )
    return sites


def _key(site: dict) -> tuple:
    return (site["file"], site["item"], site["kind"])


def _baseline_path(repo: str) -> str:
    return os.path.join(repo, config.UNSAFE_BASELINE)


def load_baseline(repo: str) -> dict[tuple, int] | None:
    path = _baseline_path(repo)
    if not os.path.isfile(path):
        return None
    with open(path, encoding="utf-8") as f:
        doc = json.load(f)
    out: dict[tuple, int] = {}
    for site in doc.get("sites", ()):
        out[(site["file"], site["item"], site["kind"])] = site.get("count", 1)
    return out


def write_baseline(ctx) -> str:
    counts: dict[tuple, int] = {}
    for src in ctx.sources.values():
        for site in collect_sites(src):
            counts[_key(site)] = counts.get(_key(site), 0) + 1
    doc = {
        "comment": "unsafe inventory baseline — regenerate with "
        "`python3 python/lints/check.py --update-baseline` and commit the "
        "diff together with the new site's SAFETY rationale",
        "sites": [
            {"file": f, "item": it, "kind": k, "count": n}
            for (f, it, k), n in sorted(counts.items())
        ],
    }
    path = _baseline_path(ctx.repo)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return path


def run(ctx) -> None:
    all_sites: list[dict] = []
    findings: list[Finding] = []
    for src in ctx.sources.values():
        for site in collect_sites(src):
            all_sites.append(site)
            if not _has_rationale(src, site["line"]):
                findings.append(
                    Finding(
                        "unsafe-inventory",
                        src.rel,
                        site["line"],
                        f"unsafe {site['kind']} without a `// SAFETY:` comment "
                        "on the site or the lines directly above — state why "
                        "this is sound",
                    )
                )

    ctx.report.publish(
        "unsafe_inventory",
        sorted(all_sites, key=lambda s: (s["file"], s["line"])),
    )
    ctx.report.bump("unsafe_sites", len(all_sites))

    baseline = load_baseline(ctx.repo)
    if baseline is None:
        findings.append(
            Finding(
                "unsafe-inventory",
                config.UNSAFE_BASELINE,
                1,
                "unsafe baseline file missing — generate it with "
                "`python3 python/lints/check.py --update-baseline` and commit it",
            )
        )
        ctx.report.extend(findings)
        return

    current: dict[tuple, int] = {}
    for site in all_sites:
        current[_key(site)] = current.get(_key(site), 0) + 1

    for key, n in sorted(current.items()):
        base_n = baseline.get(key, 0)
        if n > base_n:
            # report at the actual site line(s) for the new occurrences
            lines = [
                s["line"]
                for s in all_sites
                if _key(s) == key
            ][base_n:]
            rel = key[0].replace("/", os.sep)
            for line in lines:
                findings.append(
                    Finding(
                        "unsafe-inventory",
                        rel,
                        line,
                        f"unsafe {key[2]} in `{key[1]}` is not in the baseline "
                        "— audit it, then run `--update-baseline` and commit "
                        "the diff",
                    )
                )
    for key, base_n in sorted(baseline.items()):
        if current.get(key, 0) < base_n:
            findings.append(
                Finding(
                    "unsafe-inventory",
                    config.UNSAFE_BASELINE,
                    1,
                    f"baseline lists unsafe {key[2]} in `{key[1]}` ({key[0]}) "
                    "that no longer exists — refresh with `--update-baseline` "
                    "so the inventory matches reality",
                )
            )
    ctx.report.extend(findings)
