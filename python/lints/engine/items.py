"""Item extraction over the token stream: attributes, test-region masking,
function boundaries, and the per-function block tree.

The extractor recovers just enough structure for the passes:

* **attributes** — every ``#[...]`` span, with its token text, so
  ``#[cfg(test)]`` masking is decided on tokens (``cfg(all(test, ...))`` and
  ``cfg(any(test, ...))`` mask; ``cfg(not(test))`` does NOT — it is
  production code), instead of a line regex that only knew the literal
  spelling ``#[cfg(test)]``;
* **test mask** — a boolean per code-token index covering every item behind
  a test cfg (the attribute itself, any stacked attributes, and the item
  body through its closing brace or terminating semicolon);
* **functions** — ``fn`` items with their name, signature span, and body
  token span (trait-method declarations without a body are skipped);
* **block tree** — each function body parsed into nested blocks tagged with
  the construct that introduced them (``if`` / ``elseif`` / ``else`` /
  ``match`` / ``loop`` / ``while`` / ``for`` / ``closure`` / ``unsafe`` /
  ``plain``), which is what the promise-lifecycle pass walks.

Known approximations (documented in STATIC_ANALYSIS.md, covered by
fixtures): construct tagging keys on the nearest unconsumed control keyword
at paren-depth 0, so a bare struct literal in head position would mislabel —
Rust's own grammar forbids exactly that, which is why the heuristic holds;
``match`` arm boundaries are not recovered (arms are analyzed as one linear
region); nested ``fn`` items inside a function body are rare and analyzed as
plain blocks of the outer function.
"""

from __future__ import annotations

from .lexer import CHAR, IDENT, LIFETIME, NUM, PUNCT, RAW_STR, STR, Token

CONSTRUCTS = (
    "if",
    "elseif",
    "else",
    "match",
    "loop",
    "while",
    "for",
    "closure",
    "unsafe",
    "plain",
)

_CTRL_KEYWORDS = {"if", "match", "loop", "while", "for"}


class Attr:
    """One `#[...]` / `#![...]` attribute: token index span and flat text."""

    __slots__ = ("start", "end", "text", "line", "closed")

    def __init__(self, start: int, end: int, text: str, line: int, closed: bool):
        self.start = start  # index (into code tokens) of the `#`
        self.end = end  # index one past the closing `]`
        self.text = text
        self.line = line
        self.closed = closed


def find_attributes(code: list[Token]) -> list[Attr]:
    attrs: list[Attr] = []
    i, n = 0, len(code)
    while i < n:
        t = code[i]
        if t.kind == PUNCT and t.text == "#":
            j = i + 1
            if j < n and code[j].kind == PUNCT and code[j].text == "!":
                j += 1
            if j < n and code[j].kind == PUNCT and code[j].text == "[":
                depth, k = 0, j
                closed = False
                while k < n:
                    tk = code[k]
                    if tk.kind == PUNCT and tk.text == "[":
                        depth += 1
                    elif tk.kind == PUNCT and tk.text == "]":
                        depth -= 1
                        if depth == 0:
                            closed = True
                            break
                    k += 1
                end = k + 1 if closed else n
                text = " ".join(tok.text for tok in code[i:end])
                attrs.append(Attr(i, end, text, t.line, closed))
                i = end
                continue
        i += 1
    return attrs


def attr_is_test_cfg(attr: Attr) -> bool:
    """True when the attribute gates the following item to test builds.

    Walks the attribute's own tokens with a wrapper stack, so `cfg(test)`,
    `cfg(all(test, feature = "x"))` and `cfg(any(test, doc))` all mask,
    while `cfg(not(test))` (production-only code) does not.
    """
    words = attr.text.split()
    if "cfg" not in words:
        return False
    stack: list[str] = []
    prev = ""
    for w in words:
        if w == "(":
            stack.append(prev)
        elif w == ")":
            if stack:
                stack.pop()
        elif w == "test" and "cfg" in stack and "not" not in stack:
            return True
        prev = w
    return False


def test_mask(code: list[Token]) -> list[bool]:
    """Per-code-token mask: True inside an item gated by a test cfg."""
    mask = [False] * len(code)
    attrs = find_attributes(code)
    # group stacked attributes by adjacency: an attr directly following
    # another attr's end belongs to the same item
    i = 0
    while i < len(attrs):
        group = [attrs[i]]
        j = i + 1
        while j < len(attrs) and attrs[j].start == group[-1].end:
            group.append(attrs[j])
            j += 1
        if any(attr_is_test_cfg(a) for a in group):
            start = group[0].start
            end = _item_end(code, group[-1].end)
            for k in range(start, end):
                mask[k] = True
        i = j
    return mask


def _item_end(code: list[Token], i: int) -> int:
    """Index one past the end of the item starting at code[i].

    The item ends at the matching `}` of its first top-level `{`, or at the
    first top-level `;` (use/const/fn-declaration), whichever comes first.
    """
    depth = 0
    n = len(code)
    while i < n:
        t = code[i]
        if t.kind == PUNCT:
            if t.text in "([{":
                depth += 1
                if t.text == "{" and depth == 1:
                    # consume through the matching close brace
                    brace = 1
                    i += 1
                    while i < n and brace:
                        tt = code[i]
                        if tt.kind == PUNCT and tt.text == "{":
                            brace += 1
                        elif tt.kind == PUNCT and tt.text == "}":
                            brace -= 1
                        i += 1
                    return i
            elif t.text in ")]}":
                depth -= 1
            elif t.text == ";" and depth == 0:
                return i + 1
        i += 1
    return n


class FnItem:
    __slots__ = ("name", "line", "sig_start", "body_start", "body_end", "in_test")

    def __init__(self, name: str, line: int, sig_start: int, body_start: int, body_end: int, in_test: bool):
        self.name = name
        self.line = line
        self.sig_start = sig_start  # index of the `fn` token
        self.body_start = body_start  # index of the opening `{`
        self.body_end = body_end  # index of the matching `}`
        self.in_test = in_test


def extract_functions(code: list[Token], mask: list[bool]) -> list[FnItem]:
    fns: list[FnItem] = []
    i, n = 0, len(code)
    while i < n:
        t = code[i]
        if t.kind == IDENT and t.text == "fn" and i + 1 < n and code[i + 1].kind == IDENT:
            name = code[i + 1].text
            # find the body `{` at paren/bracket depth 0, or a `;` (no body)
            j = i + 2
            depth = 0
            body_start = -1
            while j < n:
                tj = code[j]
                if tj.kind == PUNCT:
                    if tj.text in "([":
                        depth += 1
                    elif tj.text in ")]":
                        depth -= 1
                    elif tj.text == "{" and depth == 0:
                        body_start = j
                        break
                    elif tj.text == ";" and depth == 0:
                        break  # trait method declaration
                j += 1
            if body_start >= 0:
                brace, k = 1, body_start + 1
                while k < n and brace:
                    tk = code[k]
                    if tk.kind == PUNCT and tk.text == "{":
                        brace += 1
                    elif tk.kind == PUNCT and tk.text == "}":
                        brace -= 1
                    k += 1
                body_end = k - 1
                fns.append(
                    FnItem(name, t.line, i, body_start, body_end, bool(mask[i]))
                )
                # continue scanning *inside* the body too (nested fns are
                # extracted as their own items; closures are not fns)
            i += 2
            continue
        i += 1
    return fns


class Block:
    """A `{}` region of a function body: tokens interleaved with sub-blocks."""

    __slots__ = ("construct", "elements", "line")

    def __init__(self, construct: str, line: int):
        self.construct = construct
        self.elements: list[object] = []  # Token | Block
        self.line = line


def build_block_tree(code: list[Token], start: int, end: int) -> Block:
    """Parse code[start+1:end] (the body between braces) into a Block tree."""
    root = Block("fn", code[start].line if start < len(code) else 0)
    _parse_into(root, code, start + 1, end)
    return root


def _parse_into(block: Block, code: list[Token], i: int, end: int) -> int:
    pending_kw: str | None = None
    pending_else = False
    paren_depth = 0
    recent: list[Token] = []  # tokens since last `;`/`{`/`}` — closure sniff
    while i < end:
        t = code[i]
        if t.kind == PUNCT and t.text in "([":
            paren_depth += 1
        elif t.kind == PUNCT and t.text in ")]":
            paren_depth -= 1
        elif t.kind == IDENT and paren_depth == 0:
            if t.text in _CTRL_KEYWORDS:
                pending_kw = t.text
            elif t.text == "else":
                pending_else = True
                block.elements.append(t)
                recent.append(t)
                i += 1
                continue
        if t.kind == PUNCT and t.text == "{":
            construct = "plain"
            if paren_depth == 0 and pending_kw is not None:
                construct = "elseif" if (pending_else and pending_kw == "if") else pending_kw
                pending_kw = None
                pending_else = False
            elif paren_depth == 0 and pending_else:
                construct = "else"
                pending_else = False
            elif _looks_like_closure(recent):
                construct = "closure"
            elif recent and recent[-1].kind == IDENT and recent[-1].text == "unsafe":
                construct = "unsafe"
            sub = Block(construct, t.line)
            i = _parse_into(sub, code, i + 1, _match_brace(code, i, end))
            block.elements.append(sub)
            recent = []
            continue
        if t.kind == PUNCT and t.text == "}":
            return i + 1
        block.elements.append(t)
        if t.kind == PUNCT and t.text == ";":
            pending_kw = None
            pending_else = False
            recent = []
        else:
            recent.append(t)
            if len(recent) > 16:
                recent.pop(0)
        i += 1
    return i


def _match_brace(code: list[Token], open_i: int, hard_end: int) -> int:
    depth = 0
    for j in range(open_i, hard_end + 1):
        t = code[j]
        if t.kind == PUNCT and t.text == "{":
            depth += 1
        elif t.kind == PUNCT and t.text == "}":
            depth -= 1
            if depth == 0:
                return j + 1
    return hard_end


def _looks_like_closure(recent: list[Token]) -> bool:
    """True when the tokens right before a `{` close a closure head `|..|`.

    Walks backwards from the `{`. A match-arm arrow (`=>`, seen reversed as
    `>` then `=`) means the block is an arm body, never a closure; a `;`
    bounds the statement. Commas do NOT bound the scan — closure heads like
    `move |ctx, res| {` contain them.
    """
    pipes = 0
    prev_was_gt = False
    for t in reversed(recent):
        if t.kind == PUNCT and t.text == "=" and prev_was_gt:
            return False  # `=> {`: a match-arm body
        prev_was_gt = t.kind == PUNCT and t.text == ">"
        if t.kind == PUNCT and t.text == "|":
            pipes += 1
            if pipes == 2:
                return True
        elif t.kind == PUNCT and t.text == ";":
            break
    return False
