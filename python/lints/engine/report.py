"""Findings, waiver application, and the machine-readable report.

A pass emits `Finding`s unconditionally; the driver applies waivers
(recording which waiver suppressed what), turns unused waivers into
`waiver-hygiene` findings, and renders two outputs:

* human text — one `path:line: [rule] message` per unwaived finding;
* a stable JSON report (`--json`) with every finding (waived ones carry
  their waiver), the per-rule waiver budget, the atomics table (P3), the
  unsafe inventory (P4), and run metadata — CI uploads this as an artifact
  so a finding's full context survives the log scroll.
"""

from __future__ import annotations

import json
from typing import Iterable


class Finding:
    __slots__ = ("rule", "path", "line", "msg", "anchor_lines", "waived_by")

    def __init__(self, rule: str, path: str, line: int, msg: str, anchor_lines: tuple[int, ...] = ()):
        self.rule = rule
        self.path = path
        self.line = line
        self.msg = msg
        # lines (besides `line`) where a waiver for this finding may sit,
        # e.g. the binding line of a promise whose leak is reported at an
        # exit line
        self.anchor_lines = anchor_lines
        self.waived_by = None  # Waiver | None

    def key(self) -> tuple:
        return (self.path, self.line, self.rule, self.msg)

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"

    def to_json(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line, "msg": self.msg}
        if self.waived_by is not None:
            d["waived"] = {
                "line": self.waived_by.line,
                "reason": self.waived_by.reason,
            }
        return d


class Report:
    """Accumulates pass output and renders the two report forms."""

    def __init__(self) -> None:
        self.findings: list[Finding] = []
        self.tables: dict[str, object] = {}  # pass-published extras (JSON-able)
        self.stats: dict[str, int] = {}

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, findings: Iterable[Finding]) -> None:
        self.findings.extend(findings)

    def publish(self, name: str, table: object) -> None:
        self.tables[name] = table

    def bump(self, stat: str, n: int = 1) -> None:
        self.stats[stat] = self.stats.get(stat, 0) + n

    # -- waiver application --------------------------------------------------

    def apply_waivers(self, sources: dict[str, object]) -> None:
        """Suppress findings covered by a waiver; flag unused/empty waivers."""
        for f in self.findings:
            src = sources.get(f.path)
            if src is None:
                continue
            lines = (f.line,) + f.anchor_lines
            w = src.waiver_for(f.rule, lines)
            if w is not None:
                f.waived_by = w
                w.used = True
        hygiene: list[Finding] = []
        for src in sources.values():
            for w in src.waivers:
                if w.in_test:
                    continue
                if not w.reason:
                    hygiene.append(
                        Finding(
                            "waiver-hygiene",
                            w.path,
                            w.line,
                            "waiver without a reason — state why the rule "
                            "does not apply here",
                        )
                    )
                elif not w.used:
                    hygiene.append(
                        Finding(
                            "waiver-hygiene",
                            w.path,
                            w.line,
                            "unused waiver — nothing on this line trips "
                            f"{'any rule' if w.rules is None else ', '.join(sorted(w.rules))}; "
                            "delete it (stale waivers hide future findings)",
                        )
                    )
        self.findings.extend(hygiene)

    # -- outputs -------------------------------------------------------------

    def active(self) -> list[Finding]:
        return sorted(
            (f for f in self.findings if f.waived_by is None),
            key=lambda f: (f.path, f.line, f.rule),
        )

    def waiver_budget(self, sources: dict[str, object]) -> dict[str, dict[str, int]]:
        """Per-rule counts of waivers in force (and the unused leftovers)."""
        budget: dict[str, dict[str, int]] = {}
        for f in self.findings:
            if f.waived_by is not None:
                b = budget.setdefault(f.rule, {"waived_findings": 0, "waiver_sites": 0})
                b["waived_findings"] += 1
        sites: dict[str, set] = {}
        for src in sources.values():
            for w in src.waivers:
                if w.in_test or not w.used:
                    continue
                for rule in w.rules or ("*",):
                    sites.setdefault(rule, set()).add((w.path, w.line))
        for rule, s in sites.items():
            if rule == "*":
                # an unscoped waiver counts against every rule it suppressed;
                # approximate its site count under a catch-all bucket
                budget.setdefault("unscoped", {"waived_findings": 0, "waiver_sites": 0})[
                    "waiver_sites"
                ] += len(s)
            else:
                budget.setdefault(rule, {"waived_findings": 0, "waiver_sites": 0})[
                    "waiver_sites"
                ] += len(s)
        return budget

    def to_json(self, sources: dict[str, object]) -> str:
        doc = {
            "version": 1,
            "findings": [f.to_json() for f in sorted(self.findings, key=lambda f: f.key())],
            "active_findings": len(self.active()),
            "waiver_budget": self.waiver_budget(sources),
            "stats": dict(sorted(self.stats.items())),
        }
        doc.update(self.tables)
        return json.dumps(doc, indent=2, sort_keys=False) + "\n"
