"""Scoping tables shared by the passes.

Everything here is a *policy* decision (which files are exempt, which
gauges are balanced, which methods resolve a promise); the mechanics live
in the pass modules. Grow these tables as the crate grows — the engine
side needs no change.
"""

from __future__ import annotations

import os

# R3 scope: production source minus the documented exemptions.
UNWRAP_EXEMPT_PREFIXES = (os.path.join("rust", "src", "util") + os.sep,)
UNWRAP_EXEMPT_FILES = {
    # The bench harness lives in src so the bench binaries and the tier-1
    # perf gates can share probes; it is measurement scaffolding, and a
    # panic on a malformed environment is the desired behavior there.
    os.path.join("rust", "src", "bench.rs"),
}

# R6 / P3 scope: the model checker's interposition surface (ISSUE 7).
INTERPOSED_FILES = {
    os.path.join("rust", "src", "concurrent", "mpsc.rs"),
    os.path.join("rust", "src", "concurrent", "deque.rs"),
    os.path.join("rust", "src", "concurrent", "parker.rs"),
    os.path.join("rust", "src", "actor", "mailbox.rs"),
    os.path.join("rust", "src", "actor", "cell.rs"),
    os.path.join("rust", "src", "actor", "scheduler.rs"),
    os.path.join("rust", "src", "runtime", "event.rs"),
}

# R5 scope.
CODEC_FILE = os.path.join("rust", "src", "net", "codec.rs")

# R4 scope exemptions (definition/mint sites audited by hand).
PROMISE_DEF_FILES = {
    # the ResponsePromise definition site
    os.path.join("rust", "src", "actor", "request.rs"),
    # Context::make_promise — mints the promise and *returns* it to the
    # handler, which is the actual creation site the rule audits
    os.path.join("rust", "src", "actor", "cell.rs"),
}

# P1: what mints a promise-like value, what resolves it, what merely
# inspects it. Any method NOT in INSPECT counts as consumption (hand-off or
# resolve) — the unsound-lenient direction, chosen so the pass only fires
# when a binding is provably never touched again on some exit path.
PROMISE_MINTS = ("make_promise", "ResponsePromise::new", "FutureSlot::new")
PROMISE_RESOLVERS = {
    "deliver",
    "deliver_msg",
    "deliver_err",
    "deliver_result",
    "fail",
    "resolve",
    "complete",
}
PROMISE_INSPECT = {
    "clone",
    "is_resolved",
    "is_done",
    "is_empty",
    "len",
    "as_ref",
    "borrow",
    "try_result",
}

# P2: the steering gauges. `balanced` gauges must have a crate-reachable
# decrement/drain/resync for their increments; `monotonic` counters must
# never be decremented. Attribution is by *field name* — same-named gauges
# on different structs share a ledger (documented approximation; it errs
# toward fewer findings, never more).
# `pipe_pending` is the pipeline drivers' occupancy gauge (ISSUE 10): a
# whole pipeline admission increments it once, retirement decrements via a
# saturating fetch_update, and the dispatcher steers on it — so a leak
# would silently starve a replica. `migrations` counts explicit
# device-to-device transfers and only ever grows.
BALANCED_GAUGES = ("inflight", "routed", "batch_pending", "launched", "pipe_pending")
MONOTONIC_COUNTERS = ("overloaded", "shed", "deadline", "deadline_failed", "migrations")

# P4: unsafe inventory baseline (checked in; --update-baseline rewrites).
UNSAFE_BASELINE = os.path.join("python", "lints", "unsafe_baseline.json")

RUST_EXTRA_ROOTS = (
    os.path.join("rust", "tests"),
    os.path.join("rust", "benches"),
    "examples",
)
