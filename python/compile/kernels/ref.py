"""Pure numpy oracles for every kernel in this package.

These are the CORE correctness signal: pytest checks each Pallas kernel and
each composed L2 stage against the functions here, and the Rust side checks
its CPU WAH encoder against the very same algorithm (mirrored in
``rust/src/indexing/wah.rs``).

Conventions (shared with the Rust coordinator — see DESIGN.md §5):

* all WAH arrays are ``uint32``;
* a *chunk* covers 31 bit positions (the payload width of a WAH literal);
* a literal word has the MSB clear, a fill word is ``(1<<31) | run_length``
  (only zero-fills occur in this index: gaps between occupied chunks);
* ``cid = (value << 16) | chunk`` — values are restricted to ``< 2**16``
  and input length to ``31 * 2**16`` so cid is collision-free;
* stages exchange a single u32 array; multi-output stages pack a ``CFG``-word
  config prefix (the paper's "configuration array", Listing 5).
"""

from __future__ import annotations

import numpy as np

CFG = 8  # config prefix words
FILL_FLAG = np.uint32(1 << 31)
INVALID = np.uint32(0xFFFFFFFF)
GROUP = 128  # Billeter stream-compaction work-group size (paper §4.1)
CHUNK_BITS = 31


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

def matmul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-major square matrix product, f32 accumulation (paper Listing 1)."""
    return (a.astype(np.float32) @ b.astype(np.float32)).astype(np.float32)


# ---------------------------------------------------------------------------
# mandelbrot
# ---------------------------------------------------------------------------

# Paper §5.4: the image covers the region [-0.5 - 0.7375i, 0.1 - 0.1375i].
MANDEL_X0, MANDEL_X1 = -0.5, 0.1
MANDEL_Y0, MANDEL_Y1 = -0.7375, -0.1375


def mandelbrot(width: int, height: int, y_start: int, rows: int,
               iters: int) -> np.ndarray:
    """Escape-iteration counts for ``rows`` rows starting at ``y_start``.

    Returns u32[rows, width]. The chunked form mirrors the offload split of
    the heterogeneous benchmark (Fig 7/8): each 10% chunk of the image is
    one kernel execution with a row offset.
    """
    xs = MANDEL_X0 + (MANDEL_X1 - MANDEL_X0) * (
        np.arange(width, dtype=np.float32) / np.float32(width))
    ys = MANDEL_Y0 + (MANDEL_Y1 - MANDEL_Y0) * (
        (y_start + np.arange(rows, dtype=np.float32)) / np.float32(height))
    cx = np.broadcast_to(xs[None, :], (rows, width)).astype(np.float32)
    cy = np.broadcast_to(ys[:, None], (rows, width)).astype(np.float32)
    zx = np.zeros_like(cx)
    zy = np.zeros_like(cy)
    count = np.zeros((rows, width), dtype=np.uint32)
    for _ in range(iters):
        live = zx * zx + zy * zy <= np.float32(4.0)
        count += live.astype(np.uint32)
        nzx = zx * zx - zy * zy + cx
        nzy = np.float32(2.0) * zx * zy + cy
        zx = np.where(live, nzx, zx)
        zy = np.where(live, nzy, zy)
    return count


# ---------------------------------------------------------------------------
# WAH bitmap index — per-stage oracles
# ---------------------------------------------------------------------------

def wah_sort(values: np.ndarray) -> np.ndarray:
    """Stage 1: stable sort by value; returns sorted_values ++ positions."""
    values = values.astype(np.uint32)
    order = np.argsort(values, kind="stable").astype(np.uint32)
    return np.concatenate([values[order], order])


def wah_chunklit(sorted_pairs: np.ndarray) -> np.ndarray:
    """Stage 2: chunk ids + run-merged literals; returns cid ++ mlit.

    ``mlit[i]`` is the OR of the literals of the run *starting* at ``i`` —
    only meaningful at run heads, which is all downstream stages read.
    """
    n = sorted_pairs.shape[0] // 2
    val = sorted_pairs[:n].astype(np.uint64)
    pos = sorted_pairs[n:].astype(np.uint64)
    chunk = pos // CHUNK_BITS
    bit = pos % CHUNK_BITS
    cid = ((val << np.uint64(16)) | chunk).astype(np.uint32)
    lit = (np.uint32(1) << bit.astype(np.uint32)).astype(np.uint32)
    # suffix OR within equal-cid segments (runs are at most 31 long)
    mlit = lit.copy()
    for i in range(n - 2, -1, -1):
        if cid[i] == cid[i + 1]:
            mlit[i] |= mlit[i + 1]
    return np.concatenate([cid, mlit])


def wah_fillslit(chunklit: np.ndarray) -> np.ndarray:
    """Stage 3: per-head fill words and head literals; fills ++ headlits."""
    n = chunklit.shape[0] // 2
    cid = chunklit[:n]
    mlit = chunklit[n:]
    val = cid >> np.uint32(16)
    chunk = cid & np.uint32(0xFFFF)
    fills = np.zeros(n, dtype=np.uint32)
    headlits = np.zeros(n, dtype=np.uint32)
    for i in range(n):
        head = i == 0 or cid[i] != cid[i - 1]
        if not head:
            continue
        headlits[i] = mlit[i]
        if i == 0 or val[i] != val[i - 1]:
            gap = int(chunk[i])  # fill from chunk 0 of a fresh bitmap
        else:
            gap = int(chunk[i]) - int(chunk[i - 1]) - 1
        if gap > 0:
            fills[i] = FILL_FLAG | np.uint32(gap)
    return np.concatenate([fills, headlits])


def wah_interleave(fillslit: np.ndarray) -> np.ndarray:
    """Stage 4 (paper's prepare_index): idx[2i]=fill[i], idx[2i+1]=lit[i]."""
    n = fillslit.shape[0] // 2
    out = np.zeros(2 * n, dtype=np.uint32)
    out[0::2] = fillslit[:n]
    out[1::2] = fillslit[n:]
    return out


def wah_count(idx: np.ndarray) -> np.ndarray:
    """Stage 5 (count_elements): non-zero count per group of 128."""
    g = idx.shape[0] // GROUP
    return (idx.reshape(g, GROUP) != 0).sum(axis=1).astype(np.uint32)


def wah_scan(counts: np.ndarray) -> np.ndarray:
    """Stage 6: cfg ++ exclusive scan of group counts; cfg[0] = total."""
    excl = np.concatenate([[np.uint32(0)],
                           np.cumsum(counts)[:-1].astype(np.uint32)])
    cfg = np.zeros(CFG, dtype=np.uint32)
    cfg[0] = counts.sum()
    return np.concatenate([cfg, excl.astype(np.uint32)])


def wah_move(idx: np.ndarray, scan: np.ndarray) -> np.ndarray:
    """Stage 7 (move_valid_elements): cfg ++ zero-padded compacted index."""
    out = np.zeros(CFG + idx.shape[0], dtype=np.uint32)
    out[0] = scan[0]  # total survivors
    survivors = idx[idx != 0]
    out[CFG:CFG + survivors.shape[0]] = survivors
    return out


def wah_lut(fillslit: np.ndarray, sorted_pairs: np.ndarray,
            cardinality: int) -> np.ndarray:
    """Stage 8: cfg ++ per-value offset table into the compacted index.

    cfg[0] = number of distinct non-pad values, cfg[1] = total surviving
    words belonging to non-pad values, cfg[2] = total surviving words.
    Pad entries carry value ``cardinality - 1`` and sort to the end.
    """
    n = fillslit.shape[0] // 2
    val = sorted_pairs[:n]
    pad = np.uint32(cardinality - 1)
    idx = wah_interleave(fillslit)
    valid = idx != 0
    vscan = np.concatenate([[0], np.cumsum(valid)[:-1]]).astype(np.uint32)
    lut = np.full(cardinality, INVALID, dtype=np.uint32)
    n_distinct = 0
    for i in range(n):
        vhead = i == 0 or val[i] != val[i - 1]
        if vhead and val[i] != pad:
            lut[val[i]] = vscan[2 * i]
            n_distinct += 1
    slot_val = np.repeat(val, 2)
    words_real = int((valid & (slot_val != pad)).sum())
    cfg = np.zeros(CFG, dtype=np.uint32)
    cfg[0] = n_distinct
    cfg[1] = words_real
    cfg[2] = int(valid.sum())
    return np.concatenate([cfg, lut])


def wah_pipeline(values: np.ndarray, cardinality: int):
    """All stages chained; returns (move_out, lut_out)."""
    s = wah_sort(values)
    cl = wah_chunklit(s)
    fl = wah_fillslit(cl)
    idx = wah_interleave(fl)
    counts = wah_count(idx)
    scan = wah_scan(counts)
    moved = wah_move(idx, scan)
    lut = wah_lut(fl, s, cardinality)
    return moved, lut


def wah_fused(values: np.ndarray, cardinality: int) -> np.ndarray:
    """Monolithic variant (ablation A): cfg ++ compacted[2N] ++ lut[C]."""
    moved, lut = wah_pipeline(values, cardinality)
    n2 = values.shape[0] * 2
    cfg = moved[:CFG].copy()
    cfg[1] = lut[1]  # words belonging to non-pad values
    cfg[3] = lut[0]  # number of distinct values
    return np.concatenate([cfg, moved[CFG:CFG + n2], lut[CFG:]])


# ---------------------------------------------------------------------------
# WAH decode (verification only — used by tests to close the loop)
# ---------------------------------------------------------------------------

def wah_decode(words: np.ndarray) -> list[int]:
    """Decode a WAH word sequence into the list of set bit positions."""
    positions = []
    chunk = 0
    for w in words:
        w = int(w)
        if w & (1 << 31):
            chunk += w & 0x3FFFFFFF
        else:
            for b in range(CHUNK_BITS):
                if w & (1 << b):
                    positions.append(chunk * CHUNK_BITS + b)
            chunk += 1
    return positions


def wah_index_positions(moved: np.ndarray, lut: np.ndarray,
                        cardinality: int) -> dict[int, list[int]]:
    """Extract per-value positions from pipeline output (test utility)."""
    words_real = int(lut[1])
    offsets = lut[CFG:]
    body = moved[CFG:]
    # bitmap of value v spans [offsets[v], next valid offset)
    order = [(int(offsets[v]), v) for v in range(cardinality)
             if offsets[v] != INVALID]
    order.sort()
    out = {}
    for k, (off, v) in enumerate(order):
        end = order[k + 1][0] if k + 1 < len(order) else words_real
        out[v] = wah_decode(body[off:end])
    return out
