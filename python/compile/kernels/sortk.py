"""L1 Pallas kernel: bitonic key-value sort (sort-stage ablation).

The paper's GPU indexer uses a 16-bit-cardinality radix sort; our production
sort stage uses XLA's variadic sort (`model.stage_sort`), which profiling
shows is ~85% of the whole pipeline (EXPERIMENTS.md §Perf). This kernel is
the device-native alternative: a full bitonic network over packed u32 keys
(`value << 16 | position` — this jaxlib build has x64 disabled, and both
fields fit 16 bits for the ablation capacities), which is exactly the
data-parallel sorting network a GPU/TPU work-group implementation uses.
Packing makes the sort stable in (value, position) — the property the
downstream chunk/fill stages rely on — because positions are unique.

O(n log^2 n) compare-exchanges in log^2(n)/2 fully-vectorized steps; each
step is a gather + select over the whole array (one VMEM-resident tile under
interpret mode; a Mosaic lowering would tile the early small-stride stages).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_kernel(v_ref, o_ref, *, n):
    vals = v_ref[...]
    pos = jax.lax.broadcasted_iota(jnp.uint32, (n,), 0)
    keys = (vals << jnp.uint32(16)) | pos
    idx = jax.lax.broadcasted_iota(jnp.uint32, (n,), 0)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            partner = idx ^ jnp.uint32(j)
            pk = keys[partner]
            is_lo = idx < partner
            ascending = (idx & jnp.uint32(k)) == 0
            kmin = jnp.minimum(keys, pk)
            kmax = jnp.maximum(keys, pk)
            # in an ascending block the lower index keeps the minimum
            want_min = is_lo == ascending
            keys = jnp.where(want_min, kmin, kmax)
            j //= 2
        k *= 2
    o_ref[:n] = keys >> jnp.uint32(16)
    o_ref[n:] = keys & jnp.uint32(0xFFFF)


def bitonic_sort(values: jax.Array) -> jax.Array:
    """u32[N] -> u32[2N]: sorted values ++ original positions.

    Drop-in replacement for ``model.stage_sort``; N must be a power of two.
    """
    n = values.shape[0]
    assert n & (n - 1) == 0, "bitonic sort needs a power-of-two length"
    assert n <= 1 << 16, "positions must fit 16 bits (u32 packed keys)"
    return pl.pallas_call(
        functools.partial(_bitonic_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((2 * n,), jnp.uint32),
        interpret=True,
    )(values)


def build(n: int):
    """Artifact function f(values: u32[n]) -> u32[2n]."""

    def fn(values):
        return bitonic_sort(values)

    return fn
