"""Log-step prefix sums (Hillis-Steele doubling).

The deployment target is xla_extension 0.5.1 (the version the rust `xla`
crate links); that XLA lowers ``jnp.cumsum`` to a ``reduce_window`` which its
CPU backend executes in O(N x window) — measured 16 s for one 131072-element
scan (EXPERIMENTS.md §Perf). These helpers express the same scans as
O(log N) shift-adds, which both old and new XLA compile to tight
vectorized loops — and which is also exactly how a GPU/TPU work-group scan
is written (the Billeter scan phase the paper uses).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def excl_scan_1d(x: jax.Array) -> jax.Array:
    """Exclusive prefix sum of a 1-D array, log-step."""
    n = x.shape[0]
    inc = x
    s = 1
    while s < n:
        pad = jnp.zeros((s,), x.dtype)
        inc = inc + jnp.concatenate([pad, inc[:-s]])
        s *= 2
    return inc - x


def incl_scan_rows(x: jax.Array) -> jax.Array:
    """Inclusive prefix sum along axis 1 of a 2-D array, log-step."""
    g, w = x.shape
    inc = x
    s = 1
    while s < w:
        pad = jnp.zeros((g, s), x.dtype)
        inc = inc + jnp.concatenate([pad, inc[:, :-s]], axis=1)
        s *= 2
    return inc


def excl_scan_rows(x: jax.Array) -> jax.Array:
    """Exclusive prefix sum along axis 1 of a 2-D array, log-step."""
    return incl_scan_rows(x) - x


def row_sums(x: jax.Array) -> jax.Array:
    """Per-row sums via an f32 GEMV (old XLA's row reduce is slow; the
    values are group counts <= 128, exactly representable in f32)."""
    ones = jnp.ones((x.shape[1],), jnp.float32)
    return jnp.dot(x.astype(jnp.float32), ones).astype(x.dtype)
