"""L1 Pallas kernel: Mandelbrot escape iterations (paper §5.4).

The heterogeneous-scaling benchmark (Fig 7/8) renders a cut of the Mandelbrot
set covering ``[-0.5 - 0.7375i, 0.1 - 0.1375i]`` and offloads the image to a
device in 10% steps. We therefore compile a *chunk* kernel: it renders
``rows`` consecutive image rows starting at a row offset that arrives as a
(tiny) u32[1] input, so one artifact serves every offload fraction.

TPU adaptation: one grid step renders a ``TR x width`` row tile held in VMEM
(the OpenCL version used one work-item per pixel). The escape loop is a
``fori_loop`` over full VPU-width f32 tiles — this is an elementwise
workload, so the roofline is VPU/memory bound, not MXU (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

X0, X1 = -0.5, 0.1
Y0, Y1 = -0.7375, -0.1375


def _mandel_kernel(y0_ref, o_ref, *, width, height, rows_per_block, iters):
    tile = pl.program_id(0)
    base = y0_ref[0] + tile.astype(jnp.uint32) * jnp.uint32(rows_per_block)
    shape = (rows_per_block, width)
    row = (jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
           + base).astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.uint32, shape, 1).astype(jnp.float32)
    cx = jnp.float32(X0) + jnp.float32(X1 - X0) * col / jnp.float32(width)
    cy = jnp.float32(Y0) + jnp.float32(Y1 - Y0) * row / jnp.float32(height)

    def body(_, state):
        zx, zy, count = state
        live = zx * zx + zy * zy <= jnp.float32(4.0)
        count = count + live.astype(jnp.uint32)
        nzx = zx * zx - zy * zy + cx
        nzy = jnp.float32(2.0) * zx * zy + cy
        zx = jnp.where(live, nzx, zx)
        zy = jnp.where(live, nzy, zy)
        return zx, zy, count

    zx = jnp.zeros(shape, jnp.float32)
    zy = jnp.zeros(shape, jnp.float32)
    count = jnp.zeros(shape, jnp.uint32)
    _, _, count = jax.lax.fori_loop(0, iters, body, (zx, zy, count))
    o_ref[...] = count


def pick_rows_per_block(rows: int) -> int:
    """Row-tile height: keeps the VMEM tile around <=1 MiB for wide images."""
    for r in (8, 6, 4, 3, 2):
        if rows % r == 0:
            return r
    return 1


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def mandelbrot_chunk(y_start: jax.Array, width: int, height: int,
                     rows: int, iters: int) -> jax.Array:
    """Render ``rows`` rows of the ``width x height`` image from ``y_start``.

    ``y_start`` is u32[1] (runtime input — the offload split point);
    everything else is baked into the artifact.
    """
    rpb = pick_rows_per_block(rows)
    kernel = functools.partial(_mandel_kernel, width=width, height=height,
                               rows_per_block=rpb, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(rows // rpb,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rpb, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, width), jnp.uint32),
        interpret=True,
    )(y_start)


def build(width: int, height: int, rows: int, iters: int):
    """Artifact function f(y0: u32[1]) -> u32[rows, width]."""

    def fn(y0):
        return mandelbrot_chunk(y0, width, height, rows, iters)

    return fn
