"""L1 Pallas kernels: Billeter-style stream compaction (paper §4.1).

The paper uses the stream-compaction algorithm of Billeter et al. (HPG'09)
with work-groups of 128: ``count_elements`` counts the valid entries of each
group into local memory, an exclusive scan over group counts assigns output
windows, and ``move_valid_elements`` scatters each group's survivors into its
window.

TPU adaptation: an OpenCL work-group of 128 items sharing local memory maps
to a 128-word tile; the in-group shuffle becomes an in-tile rank (local
exclusive cumsum). The *global* scatter of the move phase is expressed at L2
as an XLA scatter over the per-group windows (see ``model.py``) — on a real
TPU Mosaic would emit the same dynamic-store pattern.

Interpret-mode note (measured, see EXPERIMENTS.md §Perf): a Pallas ``grid``
under ``interpret=True`` lowers to a sequential loop that re-slices the full
array every step — O(grid x N) instead of O(N). The group structure is
therefore expressed as a *reshape to (G, 128) tiles inside one kernel
invocation* here; on a real Mosaic lowering the commented BlockSpec variant
(one grid step per work-group) is the shape to use.  The arithmetic per
group is identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import scanops

GROUP = 128


def _count_kernel(x_ref, o_ref, *, g):
    tiles = x_ref[...].reshape(g, GROUP)  # one row per OpenCL work-group
    o_ref[...] = scanops.row_sums((tiles != 0).astype(jnp.uint32))


def count_elements(idx: jax.Array) -> jax.Array:
    """u32[M] -> u32[M/128]: non-zero count of each 128-word group.

    Mosaic/TPU variant (grid over work-groups):
        grid=(g,), in_specs=[BlockSpec((GROUP,), lambda i: (i,))],
        out_specs=BlockSpec((1,), lambda i: (i,))
    """
    m = idx.shape[0]
    assert m % GROUP == 0, "index length must be a multiple of the group size"
    g = m // GROUP
    return pl.pallas_call(
        functools.partial(_count_kernel, g=g),
        out_shape=jax.ShapeDtypeStruct((g,), jnp.uint32),
        interpret=True,
    )(idx)


def _scan_kernel(c_ref, o_ref):
    o_ref[...] = scanops.excl_scan_1d(c_ref[...])


def scan_counts(counts: jax.Array) -> jax.Array:
    """u32[G] -> u32[G]: exclusive prefix sum (single-tile kernel).

    G = M/128 is small (<= 16384 for our largest capacity), so a single
    VMEM-resident tile suffices — the classic single-workgroup scan phase.
    """
    return pl.pallas_call(
        _scan_kernel,
        out_shape=jax.ShapeDtypeStruct(counts.shape, jnp.uint32),
        interpret=True,
    )(counts)


def _rank_kernel(x_ref, o_ref, *, g):
    tiles = x_ref[...].reshape(g, GROUP)
    v = (tiles != 0).astype(jnp.uint32)
    o_ref[...] = scanops.excl_scan_rows(v).reshape(g * GROUP)


def group_ranks(idx: jax.Array) -> jax.Array:
    """u32[M] -> u32[M]: rank of each element among its group's survivors."""
    m = idx.shape[0]
    g = m // GROUP
    return pl.pallas_call(
        functools.partial(_rank_kernel, g=g),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.uint32),
        interpret=True,
    )(idx)


def move_valid(idx: jax.Array, scan_excl: jax.Array) -> jax.Array:
    """Scatter survivors into their windows; zero-padded to len(idx).

    ``tgt[i] = scan_excl[group(i)] + rank_in_group(i)`` — the Billeter move
    phase. The in-tile rank is the Pallas kernel above; the global scatter is
    an XLA ``.at[].set`` (see module docstring).
    """
    m = idx.shape[0]
    ranks = group_ranks(idx)
    group_of = jnp.arange(m, dtype=jnp.uint32) // jnp.uint32(GROUP)
    tgt = scan_excl[group_of] + ranks
    valid = idx != 0
    dest = jnp.where(valid, tgt, jnp.uint32(m))  # invalid -> overflow slot
    out = jnp.zeros((m + 1,), jnp.uint32).at[dest].set(idx)
    return out[:m]
