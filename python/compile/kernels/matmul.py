"""L1 Pallas kernel: tiled square matrix multiply (paper Listing 1).

The OpenCL kernel ``m_mult`` assigns one work-item per output element with a
2-D NDRange. The TPU adaptation (DESIGN.md §2) instead tiles the *output*
into MXU-shaped blocks: one grid step computes a ``TILE x TILE`` output block
from a ``TILE x N`` row panel of A and an ``N x TILE`` column panel of B, all
resident in VMEM. ``jnp.dot`` inside the kernel targets the MXU systolic
array; ``preferred_element_type=float32`` keeps f32 accumulation like the
OpenCL original.

VMEM footprint per grid step (f32): ``2 * TILE * N + TILE^2`` words — for
N=512, TILE=128 that is 516 KiB, comfortably inside the ~16 MiB budget.
Run under ``interpret=True`` on CPU PJRT (see DESIGN.md §3).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...],
                         preferred_element_type=jnp.float32)


def pick_tile(n: int) -> int:
    """Largest MXU-friendly tile dividing ``n`` (128 preferred)."""
    for t in (128, 64, 32, 16, 8):
        if n % t == 0:
            return t
    return n


@functools.partial(jax.jit, static_argnums=(2,))
def matmul(a: jax.Array, b: jax.Array, tile: int | None = None) -> jax.Array:
    """``a @ b`` for square f32 matrices via the tiled Pallas kernel."""
    n = a.shape[0]
    t = tile or pick_tile(n)
    grid = (n // t, n // t)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, t), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(a, b)


def build(n: int):
    """Return the artifact function f(a, b) -> a @ b for size ``n``."""
    t = pick_tile(n)

    def fn(a, b):
        return matmul(a, b, t)

    return fn
