"""L1 Pallas kernel: the empty stage (paper §3.6).

The paper estimates stage-messaging cost with "an actor with an empty kernel"
that receives a memory reference and answers once its (no-op) kernel ran.
This is that kernel: an identity copy over a u32 buffer — the cheapest
possible device dispatch, so end-to-end latency measures pure actor +
command-queue overhead.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _empty_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def empty(x: jax.Array) -> jax.Array:
    """Identity dispatch: u32[N] -> u32[N]."""
    return pl.pallas_call(
        _empty_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


def build(n: int):
    """Artifact function f(x: u32[n]) -> x."""

    def fn(x):
        return empty(x)

    return fn
