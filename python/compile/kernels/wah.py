"""L1 Pallas kernels for the WAH bitmap-index pipeline (paper §4, DESIGN.md §5).

Each kernel is one *stage* of the Fusco-style GPU indexing algorithm and maps
one-to-one onto an OpenCL actor in the Rust coordinator. All arrays are u32;
stage outputs are single arrays (PJRT tuple buffers cannot be split by the
rust `xla` crate, see DESIGN.md §2) with halves packed back-to-back.

TPU adaptation notes: the shift-OR run merge (``_chunklit``) needs a 31-wide
halo between tiles; under ``interpret=True`` we use one whole-array block and
document the halo-tiling strategy for a real Mosaic lowering instead of
emulating it. The per-group kernels (``compaction.py``) are genuinely tiled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

CFG = 8
CHUNK_BITS = 31
# numpy scalars embed as jaxpr literals (jnp arrays would be captured consts,
# which pallas kernels reject)
FILL_FLAG = np.uint32(1 << 31)
INVALID = np.uint32(0xFFFFFFFF)


def _shift_up(x, s, fill):
    """x[i] <- x[i+s], tail padded with ``fill`` (suffix neighbour)."""
    return jnp.concatenate([x[s:], jnp.full((s,), fill, x.dtype)])


def _shift_down(x, s, fill):
    """x[i] <- x[i-s], head padded with ``fill`` (prefix neighbour)."""
    return jnp.concatenate([jnp.full((s,), fill, x.dtype), x[:-s]])


# ---------------------------------------------------------------------------
# stage 2: chunk ids + run-merged literals
# ---------------------------------------------------------------------------

def _chunklit_kernel(sp_ref, o_ref, *, n):
    val = sp_ref[:n]
    pos = sp_ref[n:]
    chunk = pos // jnp.uint32(CHUNK_BITS)
    bit = pos % jnp.uint32(CHUNK_BITS)
    cid = (val << jnp.uint32(16)) | chunk
    lit = jnp.uint32(1) << bit
    # Suffix OR across equal-cid runs. A run has <= 31 members (31 distinct
    # bit positions per chunk), so 5 doubling steps cover any run: after the
    # step of stride s, lit[i] holds the OR of positions i..i+2s-1 of its
    # segment. Segment guard: only fold when the neighbour shares the cid.
    for s in (1, 2, 4, 8, 16):
        lit_s = _shift_up(lit, s, jnp.uint32(0))
        cid_s = _shift_up(cid, s, INVALID)
        lit = jnp.where(cid_s == cid, lit | lit_s, lit)
    o_ref[:n] = cid
    o_ref[n:] = lit


def chunklit(sorted_pairs: jax.Array) -> jax.Array:
    """u32[2N] (values ++ positions, sorted) -> u32[2N] (cid ++ mlit)."""
    n = sorted_pairs.shape[0] // 2
    return pl.pallas_call(
        functools.partial(_chunklit_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((2 * n,), jnp.uint32),
        interpret=True,
    )(sorted_pairs)


# ---------------------------------------------------------------------------
# stage 3: fill words + head literals
# ---------------------------------------------------------------------------

def _fillslit_kernel(cl_ref, o_ref, *, n):
    cid = cl_ref[:n]
    mlit = cl_ref[n:]
    cid_prev = _shift_down(cid, 1, INVALID)
    val = cid >> jnp.uint32(16)
    chunk = cid & jnp.uint32(0xFFFF)
    val_prev = cid_prev >> jnp.uint32(16)
    chunk_prev = cid_prev & jnp.uint32(0xFFFF)
    head = cid != cid_prev
    head = head.at[0].set(True)
    vhead = val != val_prev
    vhead = vhead.at[0].set(True)
    # fresh bitmap: zero-fill covering chunks [0, chunk); continuation:
    # zero-fill covering the gap between consecutive occupied chunks.
    gap = jnp.where(vhead, chunk, chunk - chunk_prev - jnp.uint32(1))
    fill = jnp.where(head & (gap > 0), FILL_FLAG | gap, jnp.uint32(0))
    o_ref[:n] = fill
    o_ref[n:] = jnp.where(head, mlit, jnp.uint32(0))


def fillslit(chunklit_out: jax.Array) -> jax.Array:
    """u32[2N] (cid ++ mlit) -> u32[2N] (fills ++ head literals)."""
    n = chunklit_out.shape[0] // 2
    return pl.pallas_call(
        functools.partial(_fillslit_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((2 * n,), jnp.uint32),
        interpret=True,
    )(chunklit_out)


# ---------------------------------------------------------------------------
# stage 4: interleave (the paper's prepare_index, Listing 5)
# ---------------------------------------------------------------------------

def _interleave_kernel(fl_ref, o_ref, *, n):
    fills = fl_ref[:n]
    lits = fl_ref[n:]
    o_ref[...] = jnp.stack([fills, lits], axis=1).reshape(2 * n)


def interleave(fillslit_out: jax.Array) -> jax.Array:
    """u32[2N] (fills ++ lits) -> u32[2N] with idx[2i]=fill, idx[2i+1]=lit."""
    n = fillslit_out.shape[0] // 2
    return pl.pallas_call(
        functools.partial(_interleave_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((2 * n,), jnp.uint32),
        interpret=True,
    )(fillslit_out)
