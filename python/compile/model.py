"""L2: the compute graphs behind every AOT artifact.

Each ``build_*`` function returns a plain jax function with *fixed shapes*
(OpenCL actors are likewise spawned for a fixed ``nd_range``). ``aot.py``
lowers them to HLO text; ``python/tests`` exercise them against the numpy
oracles in ``kernels/ref.py``.

Single-output convention: the rust `xla` crate cannot split tuple-typed PJRT
buffers, so every artifact returns exactly one array. Multi-quantity stages
pack a CFG-word config prefix — the paper's "configuration array passed along
the pipeline" (Listing 5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import compaction, empty as empty_k, mandelbrot, matmul, scanops, wah

CFG = wah.CFG
INVALID = np.uint32(0xFFFFFFFF)
GROUP = compaction.GROUP


# ---------------------------------------------------------------------------
# WAH pipeline stages (one artifact per stage per capacity)
# ---------------------------------------------------------------------------

def stage_sort(values: jax.Array) -> jax.Array:
    """u32[N] -> u32[2N]: sorted values ++ original positions.

    The paper's GPU implementation used a 16-bit-cardinality radix sort; the
    substrate-native equivalent here is XLA's stable variadic sort (DESIGN.md
    §5 — a Pallas bitonic pass exists as an ablation in ``sortk.py``).
    """
    n = values.shape[0]
    pos = jnp.arange(n, dtype=jnp.uint32)
    sv, sp = jax.lax.sort((values, pos), dimension=0, is_stable=True,
                          num_keys=1)
    return jnp.concatenate([sv, sp])


def stage_chunklit(sorted_pairs: jax.Array) -> jax.Array:
    return wah.chunklit(sorted_pairs)


def stage_fillslit(chunklit_out: jax.Array) -> jax.Array:
    return wah.fillslit(chunklit_out)


def stage_interleave(fillslit_out: jax.Array) -> jax.Array:
    return wah.interleave(fillslit_out)


def stage_count(idx: jax.Array) -> jax.Array:
    return compaction.count_elements(idx)


def stage_scan(counts: jax.Array) -> jax.Array:
    """u32[G] -> u32[CFG+G]: cfg[0]=total survivors, then exclusive scan."""
    excl = compaction.scan_counts(counts)
    cfg = jnp.zeros((CFG,), jnp.uint32).at[0].set(jnp.sum(counts))
    return jnp.concatenate([cfg, excl])


def stage_move(idx: jax.Array, scan_out: jax.Array) -> jax.Array:
    """(u32[2N], u32[CFG+G]) -> u32[CFG+2N]: cfg[0]=m, compacted index."""
    compacted = compaction.move_valid(idx, scan_out[CFG:])
    cfg = jnp.zeros((CFG,), jnp.uint32).at[0].set(scan_out[0])
    return jnp.concatenate([cfg, compacted])


def stage_lut(fillslit_out: jax.Array, sorted_pairs: jax.Array,
              cardinality: int) -> jax.Array:
    """(u32[2N], u32[2N]) -> u32[CFG+C]: per-value offsets into the index.

    cfg[0]=distinct non-pad values, cfg[1]=surviving words of non-pad values,
    cfg[2]=total surviving words. Pad entries carry value C-1.
    """
    n = fillslit_out.shape[0] // 2
    c = cardinality
    pad = jnp.uint32(c - 1)
    val = sorted_pairs[:n]
    fills = fillslit_out[:n]
    lits = fillslit_out[n:]
    vf = (fills != 0).astype(jnp.uint32)
    vl = (lits != 0).astype(jnp.uint32)
    # offset of sorted-index i's fill slot (2i) in the interleaved order:
    # vscan[2i] = sum_{j<i} (vf[j] + vl[j]) — no 2N-array materialisation
    offs = scanops.excl_scan_1d(vf + vl)
    val_prev = jnp.concatenate([jnp.full((1,), INVALID, jnp.uint32),
                                val[:-1]])
    vhead = (val != val_prev)
    key = jnp.where(vhead & (val != pad), val, jnp.uint32(c))
    lut = jnp.full((c + 1,), INVALID, jnp.uint32).at[key].set(offs)[:c]
    real = (val != pad).astype(jnp.uint32)
    n_distinct = jnp.sum((vhead & (val != pad)).astype(jnp.uint32))
    words_real = jnp.sum((vf + vl) * real)
    words_all = jnp.sum(vf + vl)
    cfg = (jnp.zeros((CFG,), jnp.uint32)
           .at[0].set(n_distinct)
           .at[1].set(words_real)
           .at[2].set(words_all))
    return jnp.concatenate([cfg, lut])


def build_wah_stage(stage: str, n: int, cardinality: int = 1024):
    """Return the artifact function for one pipeline stage at capacity n."""
    g = 2 * n // GROUP
    if stage == "sort":
        return stage_sort
    if stage == "chunklit":
        return stage_chunklit
    if stage == "fillslit":
        return stage_fillslit
    if stage == "interleave":
        return stage_interleave
    if stage == "count":
        return stage_count
    if stage == "scan":
        return stage_scan
    if stage == "move":
        return stage_move
    if stage == "lut":
        return lambda fl, sp: stage_lut(fl, sp, cardinality)
    raise ValueError(f"unknown stage {stage!r} (n={n}, g={g})")


def wah_fused(values: jax.Array, cardinality: int) -> jax.Array:
    """Monolithic WAH index build (ablation A, design discussion §3.6).

    The same kernels chained inside ONE jit — the "actor wrapping multiple
    kernel executions" alternative. Output: cfg ++ compacted[2N] ++ lut[C];
    cfg[0]=m survivors, cfg[1]=non-pad words, cfg[3]=distinct values.
    """
    sp = stage_sort(values)
    cl = stage_chunklit(sp)
    fl = stage_fillslit(cl)
    idx = stage_interleave(fl)
    counts = stage_count(idx)
    scan = stage_scan(counts)
    moved = stage_move(idx, scan)
    lut = stage_lut(fl, sp, cardinality)
    cfg = (moved[:CFG]
           .at[1].set(lut[1])
           .at[3].set(lut[0]))
    return jnp.concatenate([cfg, moved[CFG:], lut[CFG:]])


def build_wah_fused(n: int, cardinality: int = 1024):
    def fn(values):
        return wah_fused(values, cardinality)

    return fn


# ---------------------------------------------------------------------------
# other artifacts
# ---------------------------------------------------------------------------

def build_matmul(n: int):
    return matmul.build(n)


def build_mandel(width: int, height: int, rows: int, iters: int):
    return mandelbrot.build(width, height, rows, iters)


def build_empty(n: int):
    return empty_k.build(n)
