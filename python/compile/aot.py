"""AOT compile path: lower every artifact to HLO *text* + write the manifest.

Run once at build time (``make artifacts``); Python never appears on the
request path. The rust coordinator reads ``artifacts/manifest.txt`` and
compiles each HLO module on its PJRT client at program-creation time — the
analog of OpenCL's runtime kernel compilation (``clBuildProgram``).

HLO text (NOT ``lowered.compiler_ir("hlo")`` protos and NOT ``.serialize()``)
is the interchange format: jax >= 0.5 emits 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=False`` so single-array outputs produce
plain (chainable) array buffers.

Manifest line format (no JSON dependency on the rust side)::

    name|file|in_dtype:shape[,shape...] .. |out_dtype:shape|key=val key=val

Shapes are ``x``-separated dims, e.g. ``f32:256x256``.
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import sortk

CFG = model.CFG
GROUP = model.GROUP

# capacities for the WAH pipeline sweep (Fig 3); paper: 10k..20M values
WAH_SIZES = [4096, 16384, 65536, 262144, 1048576]
WAH_CARD = 1024
# matmul sizes (Fig 5); paper: 1000..12000
MATMUL_SIZES = [64, 128, 256, 384, 512]
# mandelbrot chunk shapes (Fig 7/8); paper: 1920x1080 and 16000x16000
MANDEL = [
    (960, 540, 54, 100),     # Fig 7 (small image), 10%-row chunks
    (2048, 2040, 204, 100),  # Fig 8a (large image)
    (2048, 2040, 204, 1000),  # Fig 8b (large image, deep iteration)
]
EMPTY_N = 1024

U32 = jnp.uint32
F32 = jnp.float32


def spec(dtype, *dims):
    return jax.ShapeDtypeStruct(tuple(dims), dtype)


def artifact_table():
    """Yield (name, fn, [input ShapeDtypeStruct], extras dict)."""
    for n in MATMUL_SIZES:
        yield (f"matmul_{n}", model.build_matmul(n),
               [spec(F32, n, n), spec(F32, n, n)],
               {"n": n, "range": f"{n}x{n}"})
    for (w, h, ch, it) in MANDEL:
        name = f"mandel_w{w}_h{h}_c{ch}_it{it}"
        yield (name, model.build_mandel(w, h, ch, it), [spec(U32, 1)],
               {"w": w, "h": h, "ch": ch, "it": it, "range": f"{ch}x{w}"})
    for n in WAH_SIZES:
        g = 2 * n // GROUP
        c = WAH_CARD
        stages = {
            "sort": ([spec(U32, n)], {}),
            "chunklit": ([spec(U32, 2 * n)], {}),
            "fillslit": ([spec(U32, 2 * n)], {}),
            "interleave": ([spec(U32, 2 * n)], {}),
            "count": ([spec(U32, 2 * n)], {"group": GROUP}),
            "scan": ([spec(U32, g)], {}),
            "move": ([spec(U32, 2 * n), spec(U32, CFG + g)],
                     {"group": GROUP}),
            "lut": ([spec(U32, 2 * n), spec(U32, 2 * n)], {"c": c}),
        }
        for stage, (ins, extra) in stages.items():
            yield (f"wah_{stage}_{n}", model.build_wah_stage(stage, n, c),
                   ins, {"n": n, "range": str(ins[0].shape[0]), **extra})
        yield (f"wah_fused_{n}", model.build_wah_fused(n, c),
               [spec(U32, n)], {"n": n, "c": c, "range": str(n)})
    # sort-stage ablation: device-native bitonic network (DESIGN.md §6)
    for n in [4096, 16384, 65536]:
        yield (f"wah_bitonic_{n}", sortk.build(n), [spec(U32, n)],
               {"n": n, "range": str(n)})
    yield (f"empty_{EMPTY_N}", model.build_empty(EMPTY_N),
           [spec(U32, EMPTY_N)], {"n": EMPTY_N, "range": str(EMPTY_N)})


def to_hlo_text(fn, in_specs) -> str:
    lowered = jax.jit(fn).lower(*in_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


_SHORT = {"uint32": "u32", "float32": "f32", "int32": "s32",
          "uint64": "u64", "float64": "f64"}


def fmt_spec(s) -> str:
    dt = _SHORT[str(jnp.dtype(s.dtype))]
    dims = "x".join(str(d) for d in s.shape)
    return f"{dt}:{dims}"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the HLO file already exists")
    ap.add_argument("--only", default=None,
                    help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = []
    n_lowered = 0
    for name, fn, ins, extras in artifact_table():
        if args.only and args.only not in name:
            continue
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out, fname)
        out_spec = jax.eval_shape(fn, *ins)
        line = "|".join([
            name, fname,
            " ".join(fmt_spec(s) for s in ins),
            fmt_spec(out_spec),
            " ".join(f"{k}={v}" for k, v in extras.items()),
        ])
        manifest.append(line)
        if os.path.exists(path) and not args.force:
            continue
        text = to_hlo_text(fn, ins)
        with open(path, "w") as f:
            f.write(text)
        n_lowered += 1
        print(f"  lowered {name} ({len(text) // 1024} KiB)", flush=True)
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"aot: {len(manifest)} artifacts ({n_lowered} lowered) -> "
          f"{args.out}/manifest.txt")
    return 0


if __name__ == "__main__":
    sys.exit(main())
