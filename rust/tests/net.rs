//! Network transparency tests: remote requests through proxies, the
//! mem_ref serialization error (design option (a)), disconnect handling.

use caf_ocl::actor::*;
use caf_ocl::net::Node;
use std::time::Duration;

const T: Duration = Duration::from_secs(10);

#[test]
fn remote_request_roundtrip() {
    let server_sys = ActorSystem::new(SystemConfig::default().with_threads(2));
    let _adder = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, (a, b): &(Vec<u32>, Vec<u32>)| {
            let sum: Vec<u32> = a.iter().zip(b).map(|(x, y)| x + y).collect();
            reply(sum)
        }),
        SpawnOptions::named("adder"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(SystemConfig::default().with_threads(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "adder").unwrap();
    assert_eq!(remote.kind(), "remote");

    let me = client_sys.scoped();
    let out: Vec<u32> = me
        .request(&remote, (vec![1u32, 2], vec![10u32, 20]))
        .receive(T)
        .unwrap();
    assert_eq!(out, vec![11, 22]);

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn unknown_published_name_errors() {
    let server_sys = ActorSystem::new(SystemConfig::default().with_threads(2));
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(SystemConfig::default().with_threads(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "ghost").unwrap();
    let me = client_sys.scoped();
    let r = me.request(&remote, 1u32).receive_msg(T);
    assert!(r.is_err());
    assert!(r.unwrap_err().reason.contains("ghost"));

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn memref_cannot_cross_the_wire() {
    // design option (a): sending a mem_ref to a remote actor raises an
    // error at the sender instead of shipping dangling device state
    use caf_ocl::opencl::{Manager, Mode};
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        return;
    }
    let server_sys = ActorSystem::new(SystemConfig::default().with_threads(2));
    let _sink = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, _: &u32| no_reply()),
        SpawnOptions::named("sink"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(SystemConfig::default().with_threads(2));
    let mgr = Manager::load(&client_sys);
    let facade = mgr.spawn_simple("empty_1024", Mode::Val, Mode::Ref).unwrap();
    let me = client_sys.scoped();
    let r: caf_ocl::opencl::MemRef = me
        .request(&facade, (0..1024u32).collect::<Vec<u32>>())
        .receive(T)
        .unwrap();

    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "sink").unwrap();
    let err = me.request(&remote, r).receive_msg(T);
    assert!(err.is_err());
    assert!(
        err.unwrap_err().reason.contains("cannot be serialized"),
        "error must name the serialization restriction"
    );

    server.stop();
    mgr.stop_devices();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn fire_and_forget_send() {
    let server_sys = ActorSystem::new(SystemConfig::default().with_threads(2));
    let (tx, rx) = std::sync::mpsc::channel::<u32>();
    let _probe = server_sys.spawn_opts(
        move |_| {
            let tx = tx.clone();
            Behavior::new().on(move |_c, &x: &u32| {
                tx.send(x).unwrap();
                no_reply()
            })
        },
        SpawnOptions::named("probe"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(SystemConfig::default().with_threads(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "probe").unwrap();
    remote.send_from(None, Message::new(77u32));
    assert_eq!(rx.recv_timeout(T).unwrap(), 77);

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}
