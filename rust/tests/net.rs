//! Network transparency tests: remote requests through proxies, the
//! mem_ref serialization error (design option (a)), disconnect handling,
//! the `Vec<ArgValue>` wire format against a published OpenCL facade
//! (stub backend), connection lifecycle (sharing, reconnect, deadlines,
//! monitors), and the malformed-frame robustness matrix.
//!
//! `NET_TEST_TIMEOUT_MS` (set by CI) bounds every blocking receive so a
//! hung-socket regression fails fast instead of stalling the runner.

use caf_ocl::actor::*;
use caf_ocl::net::{Node, MAX_CHUNKED, MAX_FRAME};
use caf_ocl::opencl::{ArgValue, Manager, Mode};
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

const T: Duration = Duration::from_secs(10);

/// Receive deadline: overridable so CI can fail fast on hangs.
fn net_t() -> Duration {
    std::env::var("NET_TEST_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .map(Duration::from_millis)
        .unwrap_or(T)
}

/// Write a stub-backend artifact manifest (host-emulated kernels, see
/// `runtime::client::HostOp`) into a per-test temp dir, so the full facade
/// pipeline runs without `make artifacts` or a real XLA backend.
fn stub_artifacts(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("caf-ocl-net-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "vadd_f32_1024|emu|f32:1024 f32:1024|f32:1024|emu=add n=1024\n\
         copy_u32_1024|emu|u32:1024|u32:1024|emu=identity n=1024\n",
    )
    .unwrap();
    dir.to_string_lossy().to_string()
}

fn config(threads: usize) -> SystemConfig {
    SystemConfig::default().with_threads(threads)
}

/// An actor that accepts anything and never responds (for deadline and
/// disconnect tests): `Reply::Promised` without a promise ever delivering.
fn spawn_blackhole(sys: &ActorSystem, name: &str) -> ActorRef {
    sys.spawn_opts(
        |_| Behavior::new().on_any(|_c, _m| Reply::Promised),
        SpawnOptions::named(name),
    )
}

#[test]
fn remote_request_roundtrip() {
    let server_sys = ActorSystem::new(config(2));
    let _adder = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, (a, b): &(Vec<u32>, Vec<u32>)| {
            let sum: Vec<u32> = a.iter().zip(b).map(|(x, y)| x + y).collect();
            reply(sum)
        }),
        SpawnOptions::named("adder"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "adder").unwrap();
    assert_eq!(remote.kind(), "remote");

    let me = client_sys.scoped();
    let out: Vec<u32> = me
        .request(&remote, (vec![1u32, 2], vec![10u32, 20]))
        .receive(net_t())
        .unwrap();
    assert_eq!(out, vec![11, 22]);

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn unknown_published_name_errors() {
    let server_sys = ActorSystem::new(config(2));
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "ghost").unwrap();
    let me = client_sys.scoped();
    let r = me.request(&remote, 1u32).receive_msg(net_t());
    assert!(r.is_err());
    assert!(r.unwrap_err().reason.contains("ghost"));

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn fire_and_forget_send() {
    let server_sys = ActorSystem::new(config(2));
    let (tx, rx) = std::sync::mpsc::channel::<u32>();
    let _probe = server_sys.spawn_opts(
        move |_| {
            let tx = tx.clone();
            Behavior::new().on(move |_c, &x: &u32| {
                tx.send(x).unwrap();
                no_reply()
            })
        },
        SpawnOptions::named("probe"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "probe").unwrap();
    remote.send_from(None, Message::new(77u32));
    assert_eq!(rx.recv_timeout(net_t()).unwrap(), 77);

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

// ---------------------------------------------------------------------------
// the paper's distribution scenario: Vec<ArgValue> against a published
// OpenCL facade (stub backend)
// ---------------------------------------------------------------------------

#[test]
fn remote_opencl_facade_computes_vec_argvalue() {
    // node A: owns the (stub) device, publishes the kernel actor
    let server_sys =
        ActorSystem::new(config(2).with_artifacts_dir(stub_artifacts("facade")));
    let mgr = Manager::load(&server_sys);
    let facade = mgr
        .spawn_simple("vadd_f32_1024", Mode::Val, Mode::Val)
        .unwrap();
    server_sys.registry().put("device-worker", facade);
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    // node B: no device of its own, drives the facade through a proxy
    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client
        .remote_actor(&addr.to_string(), "device-worker")
        .unwrap();

    let a: Vec<f32> = (0..1024).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..1024).map(|i| (i * 2) as f32).collect();
    let args = vec![ArgValue::from(a.clone()), ArgValue::from(b.clone())];
    let me = client_sys.scoped();
    let out: Vec<f32> = me.request(&remote, args).receive(net_t()).unwrap();
    let expect: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
    assert_eq!(out, expect);

    // a wrong-arity argument list fails in the facade and the error makes
    // it back over the wire
    let short = vec![ArgValue::from(a.clone())];
    let err = me.request(&remote, short).receive_msg(net_t());
    assert!(err.is_err());
    assert!(err.unwrap_err().reason.contains("2 arguments"));

    server.stop();
    client.stop();
    mgr.stop_devices();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn replicated_facade_serves_remote_clients_with_placement() {
    // the dispatcher of a Placement::Replicated spawn is an ordinary
    // ActorRef: publish it by name and remote clients get multi-device
    // placement for free — requests from the wire spread across devices
    use caf_ocl::opencl::{DeviceSpec, KernelSpawn, Placement, PlacementPolicy};

    let server_sys =
        ActorSystem::new(config(4).with_artifacts_dir(stub_artifacts("replicated")));
    let mgr = Manager::load_with(
        &server_sys,
        vec![DeviceSpec::host(), DeviceSpec::host()],
    );
    let program = mgr.create_kernel_program("copy_u32_1024").unwrap();
    let dispatcher = mgr
        .spawn_cl(
            KernelSpawn::new(program, "copy_u32_1024")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .placement(Placement::replicated(PlacementPolicy::RoundRobin)),
        )
        .unwrap();
    server_sys.registry().put("replicated-worker", dispatcher);
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client
        .remote_actor(&addr.to_string(), "replicated-worker")
        .unwrap();

    let me = client_sys.scoped();
    for i in 0..4u32 {
        let data: Vec<u32> = (0..1024).map(|x| x + i).collect();
        let args = vec![ArgValue::from(data.clone())];
        let out: Vec<u32> = me.request(&remote, args).receive(net_t()).unwrap();
        assert_eq!(out, data);
    }
    // round-robin spread the remote burst across both server devices
    let l0 = mgr.device(0).unwrap().queue.stats().launched();
    let l1 = mgr.device(1).unwrap().queue.stats().launched();
    assert_eq!((l0, l1), (2, 2), "remote requests must be placed across devices");

    server.stop();
    client.stop();
    mgr.stop_devices();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn ref_payload_fails_on_sender_with_device_local() {
    // design option (a): device references never cross the wire — neither
    // as a bare MemRef nor inside a Vec<ArgValue>
    let server_sys =
        ActorSystem::new(config(2).with_artifacts_dir(stub_artifacts("memref")));
    let mgr = Manager::load(&server_sys);
    let ref_facade = mgr
        .spawn_simple("copy_u32_1024", Mode::Val, Mode::Ref)
        .unwrap();
    spawn_blackhole(&server_sys, "sink");
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "sink").unwrap();

    // produce a device-resident reference locally on the server system
    let server_me = server_sys.scoped();
    let r: caf_ocl::opencl::MemRef = server_me
        .request(&ref_facade, (0..1024u32).collect::<Vec<u32>>())
        .receive(net_t())
        .unwrap();

    // bare MemRef
    let err = server_me.request(&remote, r.clone()).receive_msg(net_t());
    assert!(err.is_err());
    assert!(
        err.unwrap_err().reason.contains("cannot be serialized"),
        "error must name the serialization restriction"
    );

    // Ref inside an argument list
    let args = vec![ArgValue::from(vec![1u32; 4]), ArgValue::Ref(r)];
    let err = server_me.request(&remote, args).receive_msg(net_t());
    assert!(err.is_err());
    assert!(err.unwrap_err().reason.contains("cannot be serialized"));

    server.stop();
    client.stop();
    mgr.stop_devices();
    client_sys.shutdown();
    server_sys.shutdown();
}

// ---------------------------------------------------------------------------
// framing robustness
// ---------------------------------------------------------------------------

/// Open a raw socket, fire `bytes`, and assert the server closes the
/// connection (EOF or reset) without answering.
fn assert_closed_without_reply(addr: &SocketAddr, bytes: &[u8]) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(bytes).unwrap();
    s.flush().unwrap();
    s.set_read_timeout(Some(net_t())).unwrap();
    let mut buf = [0u8; 64];
    match s.read(&mut buf) {
        Ok(0) => {}     // clean close
        Err(_) => {}    // reset — also a close, also fine
        Ok(n) => panic!("server replied {n} bytes to a malformed frame"),
    }
}

#[test]
fn malformed_frames_keep_node_serviceable() {
    let server_sys = ActorSystem::new(config(2));
    let _echo = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, &x: &u32| reply(x + 1)),
        SpawnOptions::named("echo"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    // zero-length frame
    assert_closed_without_reply(&addr, &0u32.to_le_bytes());
    // oversized frame announcement (would be a 4 GiB allocation unchecked)
    assert_closed_without_reply(&addr, &u32::MAX.to_le_bytes());
    // just past the cap
    assert_closed_without_reply(&addr, &((MAX_FRAME as u32) + 1).to_le_bytes());
    // unknown frame kind
    assert_closed_without_reply(&addr, &[1, 0, 0, 0, 200]);
    // REQUEST shorter than its mid
    assert_closed_without_reply(&addr, &[4, 0, 0, 0, 1, 9, 9, 9]);
    // REQUEST whose name_len points past the frame
    let mut f = vec![12u8, 0, 0, 0, 1];
    f.extend_from_slice(&7u64.to_le_bytes());
    f.extend_from_slice(&500u16.to_le_bytes());
    f.push(b'x');
    assert_closed_without_reply(&addr, &f);
    // truncated body: announce 100 bytes, send 3, hang up
    {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(&100u32.to_le_bytes()).unwrap();
        s.write_all(&[1, 2, 3]).unwrap();
        drop(s);
    }

    // after all of that, the node still serves well-formed traffic: no
    // handler thread panicked, the accept loop is alive
    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "echo").unwrap();
    let me = client_sys.scoped();
    let out: u32 = me.request(&remote, 41u32).receive(net_t()).unwrap();
    assert_eq!(out, 42);

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn malformed_request_payload_reports_to_requester() {
    // a parseable frame whose *payload* is garbage should answer the
    // waiting mid with an error instead of silently dropping it
    let server_sys = ActorSystem::new(config(2));
    spawn_blackhole(&server_sys, "w");
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let mut body = 9u64.to_le_bytes().to_vec(); // mid
    body.extend_from_slice(&1u16.to_le_bytes()); // name_len
    body.push(b'w');
    body.push(250); // unknown payload tag
    let mut frame = ((body.len() + 1) as u32).to_le_bytes().to_vec();
    frame.push(1); // KIND_REQUEST
    frame.extend_from_slice(&body);

    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&frame).unwrap();
    s.set_read_timeout(Some(net_t())).unwrap();
    let mut hdr = [0u8; 13]; // len + kind + mid of the REPLY
    s.read_exact(&mut hdr).unwrap();
    assert_eq!(hdr[4], 2, "frame kind must be REPLY");
    let mid = u64::from_le_bytes(hdr[5..13].try_into().unwrap());
    assert_eq!(mid, 9);

    server.stop();
    server_sys.shutdown();
}

// ---------------------------------------------------------------------------
// connection lifecycle
// ---------------------------------------------------------------------------

#[test]
fn proxies_to_same_peer_share_one_connection() {
    let server_sys = ActorSystem::new(config(2));
    let _a = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, &x: &u32| reply(x * 2)),
        SpawnOptions::named("double"),
    );
    let _b = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, &x: &u32| reply(x * 3)),
        SpawnOptions::named("triple"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let double = client.remote_actor(&addr.to_string(), "double").unwrap();
    let triple = client.remote_actor(&addr.to_string(), "triple").unwrap();
    assert_eq!(client.peer_count(), 1, "one link per peer address");

    let me = client_sys.scoped();
    let d: u32 = me.request(&double, 10u32).receive(net_t()).unwrap();
    let t: u32 = me.request(&triple, 10u32).receive(net_t()).unwrap();
    assert_eq!((d, t), (20, 30));

    // the server accepted exactly one connection for both proxies
    let deadline = Instant::now() + net_t();
    while server.served_count() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(server.served_count(), 1);

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn disconnect_fails_pending_requests_and_notifies_monitors() {
    let server_sys = ActorSystem::new(config(2));
    spawn_blackhole(&server_sys, "blackhole");
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "blackhole").unwrap();

    let me = client_sys.scoped();
    remote.monitor_with(me.me());
    let pending = me.request(&remote, 5u32);

    // killing the server tears down the served connection; the client
    // reader observes EOF, fails every pending request, and fires monitors
    server.stop();
    let t0 = Instant::now();
    let err = pending.receive_msg(net_t()).unwrap_err();
    assert!(
        err.reason.contains("disconnected") || err.reason.contains("timed out"),
        "unexpected reason: {}",
        err.reason
    );
    assert!(t0.elapsed() < net_t(), "must fail before the receive deadline");

    // the monitor sees Down { Unreachable } with the proxy's id
    let deadline = Instant::now() + net_t();
    let mut down: Option<Down> = None;
    while down.is_none() && Instant::now() < deadline {
        if let Some(env) = me.receive_any(Duration::from_millis(100)) {
            down = env.msg.downcast_ref::<Down>().cloned();
        }
    }
    let d = down.expect("monitor never received Down");
    assert_eq!(d.reason, ExitReason::Unreachable);
    assert_eq!(d.source, remote.id());

    // attaching to an already-dead proxy fires immediately
    remote.monitor_with(me.me());
    let env = me
        .receive_any(net_t())
        .expect("late monitor attach must fire immediately");
    assert!(env.msg.is::<Down>());

    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn unreachable_peer_fails_new_requests_instead_of_hanging() {
    let server_sys = ActorSystem::new(config(2));
    spawn_blackhole(&server_sys, "gone");
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "gone").unwrap();

    // peer disappears entirely (listener closed, connection torn down)
    server.stop();
    server_sys.shutdown();

    // reconnect-on-next-send finds nobody there: the request errors
    // instead of leaking a pending entry
    let me = client_sys.scoped();
    let err = me.request(&remote, 1u32).receive_msg(net_t()).unwrap_err();
    assert!(
        err.reason.contains("cannot reach")
            || err.reason.contains("disconnected")
            || err.reason.contains("failed"),
        "unexpected reason: {}",
        err.reason
    );

    client.stop();
    client_sys.shutdown();
}

#[test]
fn request_deadline_reaps_pending_entries() {
    let server_sys = ActorSystem::new(config(2));
    spawn_blackhole(&server_sys, "slow");
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    // client with a short remote_actor_timeout: an unanswered request must
    // come back as an error in ~the deadline, not hang until the receive
    // timeout (and the pending entry must not leak forever)
    let client_sys = ActorSystem::new(
        config(2).with_remote_timeout(Duration::from_millis(300)),
    );
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "slow").unwrap();
    let me = client_sys.scoped();
    let t0 = Instant::now();
    let err = me.request(&remote, 1u32).receive_msg(net_t()).unwrap_err();
    assert!(
        err.reason.contains("timed out"),
        "unexpected reason: {}",
        err.reason
    );
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "deadline took {:?}",
        t0.elapsed()
    );

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn reconnects_on_next_send_after_connection_loss() {
    let server_sys = ActorSystem::new(config(2));
    let _echo = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, &x: &u32| reply(x + 100)),
        SpawnOptions::named("echo"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "echo").unwrap();
    let me = client_sys.scoped();
    let a: u32 = me.request(&remote, 1u32).receive(net_t()).unwrap();
    assert_eq!(a, 101);

    // drop the client's side of the connection; the server keeps listening
    client.stop();

    // the proxy's link survives and re-establishes on the next request
    let b: u32 = me.request(&remote, 2u32).receive(net_t()).unwrap();
    assert_eq!(b, 102);

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn second_listen_rejected_until_stopped() {
    let sys = ActorSystem::new(config(2));
    let node = Node::new(&sys);
    let addr = node.listen("127.0.0.1:0").unwrap();
    assert_eq!(node.local_addr(), Some(addr));

    let err = node.listen("127.0.0.1:0").unwrap_err();
    assert!(err.to_string().contains("already listening"));

    node.stop();
    assert_eq!(node.local_addr(), None);
    // after a stop, listening again is fine
    node.listen("127.0.0.1:0").unwrap();
    node.stop();
    sys.shutdown();
}

#[test]
fn stop_tears_down_served_connections() {
    let server_sys = ActorSystem::new(config(2));
    spawn_blackhole(&server_sys, "sink");
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let _p = client.remote_actor(&addr.to_string(), "sink").unwrap();
    let deadline = Instant::now() + net_t();
    while server.served_count() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(server.served_count(), 1);

    server.stop();
    assert_eq!(
        server.served_count(),
        0,
        "stop() must close and join every served connection"
    );

    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

// ---------------------------------------------------------------------------
// async request futures over the wire: the exactly-once matrix. Every ask
// must resolve exactly once — as a reply, an error, a reaper timeout, or a
// disconnect failure — and late deliveries after the resolution must be
// ignored without panicking or double-firing hooks.
// ---------------------------------------------------------------------------

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

fn counting_hook(fut: &RequestFuture) -> Arc<AtomicUsize> {
    let fires = Arc::new(AtomicUsize::new(0));
    let f = fires.clone();
    fut.then(move |_| {
        f.fetch_add(1, Ordering::Relaxed);
    });
    fires
}

#[test]
fn ask_reply_resolves_future_exactly_once() {
    let server_sys = ActorSystem::new(config(2));
    let _echo = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, v: &Vec<u32>| reply(v.clone())),
        SpawnOptions::named("echo"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "echo").unwrap();

    let fut = remote.ask(vec![7u32; 16]);
    let fires = counting_hook(&fut);
    let typed = fut.map::<Vec<u32>>();
    assert_eq!(typed.wait(net_t()).unwrap(), vec![7u32; 16]);
    // waiting again returns the same resolution (idempotent)
    assert!(fut.wait(net_t()).is_ok());
    assert!(fut.is_resolved());
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(fires.load(Ordering::Relaxed), 1, "hook must fire exactly once");
    // a hook registered after resolution runs immediately, exactly once
    let late = counting_hook(&fut);
    assert_eq!(late.load(Ordering::Relaxed), 1);

    // a one-thread pipeline: many asks in flight through a bounded set
    let set = FutureSet::new(32);
    let futs: Vec<RequestFuture> = (0..256u32)
        .map(|i| {
            let f = remote.ask(vec![i; 8]);
            set.push(&f);
            f
        })
        .collect();
    let results = set.join_all(net_t());
    assert_eq!(results.len(), 256);
    assert!(results.iter().all(|r| r.is_ok()), "every pipelined ask must reply");
    assert!(futs.iter().all(|f| f.is_resolved()));

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn ask_error_resolves_future_exactly_once() {
    let server_sys = ActorSystem::new(config(2));
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "ghost").unwrap();

    let fut = remote.ask(1u32);
    let fires = counting_hook(&fut);
    let err = fut.wait(net_t()).unwrap_err();
    assert!(err.reason.contains("ghost"), "unexpected reason: {}", err.reason);
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(fires.load(Ordering::Relaxed), 1);

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn ask_timeout_resolves_future_and_ignores_the_late_reply() {
    let server_sys = ActorSystem::new(config(2));
    // replies after 900ms — far past the client's 250ms reaper deadline
    let _slow = server_sys.spawn_opts(
        |_| {
            Behavior::new().on_any(|ctx, m| {
                let p = ctx.make_promise();
                let m = m.clone();
                std::thread::spawn(move || {
                    std::thread::sleep(Duration::from_millis(900));
                    p.deliver_msg(m);
                });
                Reply::Promised
            })
        },
        SpawnOptions::named("slow"),
    );
    let _echo = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, &x: &u32| reply(x + 1)),
        SpawnOptions::named("echo"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys =
        ActorSystem::new(config(2).with_remote_timeout(Duration::from_millis(250)));
    let client = Node::new(&client_sys);
    let slow = client.remote_actor(&addr.to_string(), "slow").unwrap();

    let fut = slow.ask(5u32);
    let fires = counting_hook(&fut);
    let t0 = Instant::now();
    let err = fut.wait(net_t()).unwrap_err();
    assert!(err.reason.contains("timed out"), "unexpected reason: {}", err.reason);
    assert!(t0.elapsed() < Duration::from_secs(5));

    // the late REPLY lands after the reaper already failed the mid: it must
    // be ignored — no double resolution, no panic, connection intact
    std::thread::sleep(Duration::from_millis(900));
    assert_eq!(fires.load(Ordering::Relaxed), 1, "late reply must not re-fire");
    let echo = client.remote_actor(&addr.to_string(), "echo").unwrap();
    let out: u32 = client_sys.scoped().request(&echo, 41u32).receive(net_t()).unwrap();
    assert_eq!(out, 42, "connection must stay serviceable after a reaped mid");

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn ask_disconnect_fails_future_exactly_once() {
    let server_sys = ActorSystem::new(config(2));
    spawn_blackhole(&server_sys, "blackhole");
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "blackhole").unwrap();

    let fut = remote.ask(5u32);
    let fires = counting_hook(&fut);
    // tear the server down: the client reader observes EOF and fails every
    // pending entry, which resolves the future with an error
    server.stop();
    server_sys.shutdown();
    let err = fut.wait(net_t()).unwrap_err();
    assert!(
        err.reason.contains("disconnected") || err.reason.contains("timed out"),
        "unexpected reason: {}",
        err.reason
    );
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(fires.load(Ordering::Relaxed), 1);

    client.stop();
    client_sys.shutdown();
}

#[test]
fn ask_survives_client_node_stop_with_pending_future() {
    let server_sys = ActorSystem::new(config(2));
    spawn_blackhole(&server_sys, "blackhole");
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "blackhole").unwrap();

    let fut = remote.ask(5u32);
    let fires = counting_hook(&fut);
    // stopping the client node closes its side of the connection: the
    // pending future must fail instead of hanging forever
    client.stop();
    let err = fut.wait(net_t()).unwrap_err();
    assert!(!err.reason.is_empty());
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(fires.load(Ordering::Relaxed), 1);

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

#[test]
fn dropping_future_before_reply_is_safe() {
    let server_sys = ActorSystem::new(config(2));
    let _echo = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, v: &Vec<u32>| reply(v.clone())),
        SpawnOptions::named("echo"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "echo").unwrap();

    // the caller drops its handle before the reply arrives; the pending map
    // still owns the slot, so the reply resolves into it and is discarded —
    // no panic, no leak, no misdelivery
    drop(remote.ask(vec![3u32; 64]));
    for i in 0..20u32 {
        let out: Vec<u32> = client_sys
            .scoped()
            .request(&remote, vec![i; 8])
            .receive(net_t())
            .unwrap();
        assert_eq!(out, vec![i; 8]);
    }

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

// ---------------------------------------------------------------------------
// chunked continuation frames: messages past MAX_FRAME shard into
// CHUNK_START/CHUNK_CONT sequences and reassemble under MAX_CHUNKED;
// hostile chunk announcements close the connection without replying.
// ---------------------------------------------------------------------------

#[test]
fn oversized_messages_chunk_into_continuation_frames() {
    // 4.5M u32 = 18 MiB of element payload: both the request and the echoed
    // reply exceed MAX_FRAME (16 MiB) and must shard into continuation
    // frames, reassembling byte-for-byte on each side
    assert!(MAX_CHUNKED > MAX_FRAME);
    let elems = 4_500_000usize;
    assert!(elems * 4 > MAX_FRAME);

    let server_sys = ActorSystem::new(config(2));
    let _echo = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, v: &Vec<u32>| reply(v.clone())),
        SpawnOptions::named("echo"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "echo").unwrap();

    let payload: Vec<u32> = (0..elems as u32).collect();
    let me = client_sys.scoped();
    let out: Vec<u32> = me.request(&remote, payload.clone()).receive(net_t()).unwrap();
    assert_eq!(out.len(), payload.len());
    assert!(out == payload, "chunked roundtrip must be byte-faithful");

    // the async surface takes the same path
    let fut = remote.ask(payload.clone());
    let back = fut.map::<Vec<u32>>().wait(net_t()).unwrap();
    assert!(back == payload);

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

/// `len kind body` framing helper for hand-rolled hostile frames.
fn raw_frame(kind: u8, body: &[u8]) -> Vec<u8> {
    let mut f = ((body.len() + 1) as u32).to_le_bytes().to_vec();
    f.push(kind);
    f.extend_from_slice(body);
    f
}

/// `CHUNK_START` body: announced total, inner kind, carried data.
fn chunk_start_body(total: u64, inner: u8, data: &[u8]) -> Vec<u8> {
    let mut b = total.to_le_bytes().to_vec();
    b.push(inner);
    b.extend_from_slice(data);
    b
}

#[test]
fn hostile_chunk_frames_close_the_connection() {
    const KIND_REQUEST: u8 = 1;
    const KIND_CHUNK_START: u8 = 4;
    const KIND_CHUNK_CONT: u8 = 5;

    let server_sys = ActorSystem::new(config(2));
    let _echo = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, &x: &u32| reply(x + 1)),
        SpawnOptions::named("echo"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").unwrap();

    // continuation with no start
    assert_closed_without_reply(&addr, &raw_frame(KIND_CHUNK_CONT, &[0xAA; 8]));
    // start announcing more than the reassembly cap (a 256 MiB+ allocation
    // if the total were trusted)
    assert_closed_without_reply(
        &addr,
        &raw_frame(
            KIND_CHUNK_START,
            &chunk_start_body((MAX_CHUNKED as u64) + 1, KIND_REQUEST, &[0u8; 16]),
        ),
    );
    // hostile total at the extreme: u64::MAX must not preallocate
    assert_closed_without_reply(
        &addr,
        &raw_frame(
            KIND_CHUNK_START,
            &chunk_start_body(u64::MAX, KIND_REQUEST, &[0u8; 16]),
        ),
    );
    // nested chunk kinds
    assert_closed_without_reply(
        &addr,
        &raw_frame(
            KIND_CHUNK_START,
            &chunk_start_body(100, KIND_CHUNK_START, &[0u8; 8]),
        ),
    );
    // start shorter than its own header
    assert_closed_without_reply(&addr, &raw_frame(KIND_CHUNK_START, &[1, 2, 3, 4]));
    // start data already past the announced total
    assert_closed_without_reply(
        &addr,
        &raw_frame(
            KIND_CHUNK_START,
            &chunk_start_body(4, KIND_REQUEST, &[0u8; 8]),
        ),
    );
    // empty continuation (would loop forever if accepted)
    {
        let mut bytes =
            raw_frame(KIND_CHUNK_START, &chunk_start_body(100, KIND_REQUEST, &[0u8; 4]));
        bytes.extend_from_slice(&raw_frame(KIND_CHUNK_CONT, &[]));
        assert_closed_without_reply(&addr, &bytes);
    }
    // continuation overrunning the announced total
    {
        let mut bytes =
            raw_frame(KIND_CHUNK_START, &chunk_start_body(10, KIND_REQUEST, &[0u8; 4]));
        bytes.extend_from_slice(&raw_frame(KIND_CHUNK_CONT, &[0u8; 20]));
        assert_closed_without_reply(&addr, &bytes);
    }
    // non-continuation frame interleaved into a chunked message
    {
        let mut bytes =
            raw_frame(KIND_CHUNK_START, &chunk_start_body(10, KIND_REQUEST, &[0u8; 4]));
        bytes.extend_from_slice(&raw_frame(KIND_REQUEST, &[0u8; 9]));
        assert_closed_without_reply(&addr, &bytes);
    }

    // the node survived the whole barrage and still serves clean traffic
    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr.to_string(), "echo").unwrap();
    let out: u32 = client_sys.scoped().request(&remote, 41u32).receive(net_t()).unwrap();
    assert_eq!(out, 42);

    server.stop();
    client.stop();
    client_sys.shutdown();
    server_sys.shutdown();
}

// ---------------------------------------------------------------------------
// two-process smoke: the wire path against a *real* process boundary, not
// just two systems in one address space. The parent re-execs this test
// binary with NET_SMOKE_ROLE=server; the child publishes an echo actor and
// writes its ephemeral address to a file the parent polls.
// ---------------------------------------------------------------------------

fn run_smoke_server() {
    let sys = ActorSystem::new(config(2));
    let _echo = sys.spawn_opts(
        |_| Behavior::new().on(|_c, v: &Vec<u32>| reply(v.clone())),
        SpawnOptions::named("smoke-echo"),
    );
    let node = Node::new(&sys);
    let addr = node.listen("127.0.0.1:0").unwrap();
    let port_file = std::env::var("NET_SMOKE_PORT_FILE").unwrap();
    // write-then-rename so the parent never reads a half-written address
    let tmp = format!("{port_file}.tmp");
    std::fs::write(&tmp, addr.to_string()).unwrap();
    std::fs::rename(&tmp, &port_file).unwrap();
    // serve until the parent kills us; the ceiling keeps an orphaned child
    // from outliving a crashed parent
    std::thread::sleep(Duration::from_secs(60));
}

#[test]
fn two_process_smoke_over_subprocess() {
    if std::env::var("NET_SMOKE_ROLE").as_deref() == Ok("server") {
        run_smoke_server();
        return;
    }
    let port_file = std::env::temp_dir().join(format!(
        "caf-ocl-net-smoke-{}.addr",
        std::process::id()
    ));
    let _ = std::fs::remove_file(&port_file);
    let exe = std::env::current_exe().unwrap();
    let mut child = std::process::Command::new(exe)
        .args(["two_process_smoke_over_subprocess", "--exact", "--nocapture"])
        .env("NET_SMOKE_ROLE", "server")
        .env("NET_SMOKE_PORT_FILE", &port_file)
        .stdout(std::process::Stdio::null())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn server child");

    let deadline = Instant::now() + net_t();
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            if !s.trim().is_empty() {
                break s.trim().to_string();
            }
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            let _ = child.wait();
            panic!("server child never published its address");
        }
        std::thread::sleep(Duration::from_millis(20));
    };

    let client_sys = ActorSystem::new(config(2));
    let client = Node::new(&client_sys);
    let remote = client.remote_actor(&addr, "smoke-echo").unwrap();
    let me = client_sys.scoped();
    for i in 0..8u32 {
        let out: Vec<u32> = me.request(&remote, vec![i; 512]).receive(net_t()).unwrap();
        assert_eq!(out, vec![i; 512]);
    }
    // the async surface across the real process boundary
    let fut = remote.ask(vec![9u32; 512]);
    assert_eq!(fut.map::<Vec<u32>>().wait(net_t()).unwrap(), vec![9u32; 512]);

    client.stop();
    client_sys.shutdown();
    let _ = child.kill();
    let _ = child.wait();
    let _ = std::fs::remove_file(&port_file);
}
