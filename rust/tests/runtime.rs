//! Integration tests for the PJRT runtime substrate against real artifacts.
//! Requires `make artifacts` to have run (skipped gracefully otherwise).

use caf_ocl::runtime::*;
use std::time::Duration;

const T: Duration = Duration::from_secs(60);

fn manifest() -> Option<Manifest> {
    Manifest::load("artifacts").ok()
}

#[test]
fn manifest_loads_and_is_complete() {
    let Some(m) = manifest() else { return };
    assert!(m.len() >= 50, "expected >=50 artifacts, got {}", m.len());
    for name in ["matmul_256", "empty_1024", "wah_sort_4096", "wah_fused_4096"] {
        assert!(m.contains(name), "missing {name}");
    }
    let mm = m.get("matmul_256").unwrap();
    assert_eq!(mm.inputs.len(), 2);
    assert_eq!(mm.output.elems(), 256 * 256);
    assert_eq!(mm.output.dtype, Dtype::F32);
}

#[test]
fn emulated_kernel_executes_without_artifacts() {
    // the stub-backend path: no HLO on disk, kernels registered as HostOps
    let q = DeviceQueue::start("emu-test", None).unwrap();
    q.compile_emulated("copy", HostOp::Identity).wait(T).unwrap();
    q.compile_emulated("vadd", HostOp::Add).wait(T).unwrap();

    let a: Vec<u32> = (0..256).collect();
    let b: Vec<u32> = (0..256).map(|i| i * 10).collect();
    let (ba, ea) = q.upload(HostData::U32(a.clone()));
    let (bb, eb) = q.upload(HostData::U32(b.clone()));

    let (copy_out, copy_done) = q.execute("copy", vec![ba], Dtype::U32, vec![ea.clone()]);
    copy_done.wait(T).unwrap();
    assert_eq!(q.download(copy_out, T).unwrap().into_u32().unwrap(), a);

    let (add_out, add_done) = q.execute("vadd", vec![ba, bb], Dtype::U32, vec![ea, eb]);
    add_done.wait(T).unwrap();
    let sum: Vec<u32> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
    assert_eq!(q.download(add_out, T).unwrap().into_u32().unwrap(), sum);

    // shape/type mismatches surface as execution failures, not panics
    let (short, es) = q.upload(HostData::U32(vec![1, 2, 3]));
    let (_, bad) = q.execute("vadd", vec![ba, short], Dtype::U32, vec![es]);
    assert!(bad.wait(T).is_err());
    // dtype mismatch against the declared output
    let (_, bad2) = q.execute("copy", vec![ba], Dtype::F32, vec![]);
    assert!(bad2.wait(T).is_err());
    q.stop();
}

#[test]
fn compile_upload_execute_download_roundtrip() {
    let Some(m) = manifest() else { return };
    let q = DeviceQueue::start("test", None).unwrap();
    let meta = m.get("empty_1024").unwrap();
    q.compile(&meta.name, m.hlo_path(meta)).wait(T).unwrap();
    let data: Vec<u32> = (0..1024).collect();
    let (bid, up) = q.upload(HostData::U32(data.clone()));
    let (out, done) = q.execute(&meta.name, vec![bid], Dtype::U32, vec![up]);
    done.wait(T).unwrap();
    let back = q.download(out, T).unwrap().into_u32().unwrap();
    assert_eq!(back, data);
    q.stop();
}

#[test]
fn buffers_chain_across_executables_on_device() {
    // wah_sort -> wah_chunklit with the intermediate resident on device
    let Some(m) = manifest() else { return };
    let q = DeviceQueue::start("test2", None).unwrap();
    for k in ["wah_sort_4096", "wah_chunklit_4096"] {
        let meta = m.get(k).unwrap();
        q.compile(k, m.hlo_path(meta)).wait(T).unwrap();
    }
    let mut vals = vec![0u32; 4096];
    for (i, v) in vals.iter_mut().enumerate() {
        *v = (i as u32).wrapping_mul(2654435761) % 1023;
    }
    let (bid, up) = q.upload(HostData::U32(vals.clone()));
    let (sorted, e1) = q.execute("wah_sort_4096", vec![bid], Dtype::U32, vec![up]);
    let (cl, e2) = q.execute("wah_chunklit_4096", vec![sorted], Dtype::U32, vec![e1]);
    e2.wait(T).unwrap();
    let out = q.download(cl, T).unwrap().into_u32().unwrap();
    assert_eq!(out.len(), 2 * 4096);
    // spot-check: cids must be non-decreasing (values sorted, chunks sorted)
    let cids = &out[..4096];
    assert!(cids.windows(2).all(|w| w[0] <= w[1]), "cids not sorted");
    q.stop();
}

#[test]
fn matmul_artifact_computes_identity_product() {
    let Some(m) = manifest() else { return };
    let q = DeviceQueue::start("test3", None).unwrap();
    let meta = m.get("matmul_64").unwrap();
    q.compile(&meta.name, m.hlo_path(meta)).wait(T).unwrap();
    let n = 64usize;
    let mut eye = vec![0f32; n * n];
    for i in 0..n {
        eye[i * n + i] = 1.0;
    }
    let a: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32 * 0.25).collect();
    let (ba, e1) = q.upload(HostData::F32(a.clone()));
    let (be, e2) = q.upload(HostData::F32(eye));
    let (out, done) = q.execute(&meta.name, vec![ba, be], Dtype::F32, vec![e1, e2]);
    done.wait(T).unwrap();
    let got = q.download(out, T).unwrap().into_f32().unwrap();
    assert_eq!(got, a);
    q.stop();
}

#[test]
fn execute_unknown_kernel_fails_event() {
    let q = DeviceQueue::start("test4", None).unwrap();
    let (_, done) = q.execute("nope", vec![], Dtype::U32, vec![]);
    assert!(done.wait(T).is_err());
    q.stop();
}

#[test]
fn freed_buffer_is_gone() {
    let Some(m) = manifest() else { return };
    let q = DeviceQueue::start("test5", None).unwrap();
    let meta = m.get("empty_1024").unwrap();
    q.compile(&meta.name, m.hlo_path(meta)).wait(T).unwrap();
    let (bid, up) = q.upload(HostData::U32(vec![7; 1024]));
    up.wait(T).unwrap();
    q.free(bid);
    let (_, done) = q.execute(&meta.name, vec![bid], Dtype::U32, vec![]);
    assert!(done.wait(T).is_err(), "executing on freed buffer must fail");
    q.stop();
}

#[test]
fn pad_model_slows_down_device() {
    use caf_ocl::runtime::client::PadModel;
    let Some(m) = manifest() else { return };
    let meta = m.get("empty_1024").unwrap();
    // a "slow" simulated device: 1 MB/s transfers
    let slow = DeviceQueue::start(
        "slow",
        Some(PadModel {
            launch: Duration::from_millis(1),
            bytes_per_sec: 1e6,
            compute_scale: 1.0,
            busy_wait: false,
        }),
    )
    .unwrap();
    slow.compile(&meta.name, m.hlo_path(meta)).wait(T).unwrap();
    let t0 = std::time::Instant::now();
    let (bid, up) = slow.upload(HostData::U32(vec![1; 1024]));
    up.wait(T).unwrap();
    let elapsed = t0.elapsed();
    // 4096 bytes at 1 MB/s ≈ 4 ms + 1 ms launch
    assert!(elapsed >= Duration::from_millis(4), "pad not applied: {elapsed:?}");
    let _ = bid;
    slow.stop();
}

// --- buffer pool (no artifacts needed: upload/free/download run on the
// --- host-memory backend) ---------------------------------------------
//
// Tests asserting actual recycling are gated on `xla-stub`: without the
// stub's `buffer_from_host_buffer_reusing` hook the pool is force-disabled
// in queue_loop, so hits/returns are structurally zero there.

#[cfg(feature = "xla-stub")]
#[test]
fn buffer_pool_recycles_by_dtype_and_size_class() {
    let q = DeviceQueue::start("pool1", None).unwrap();
    let (a, ea) = q.upload(HostData::U32(vec![1; 1024]));
    ea.wait(T).unwrap();
    q.free(a);
    q.barrier(T).unwrap();
    let (hits, misses, returned, _) = q.stats().pool_snapshot();
    assert_eq!((hits, misses, returned), (0, 1, 1), "free must feed the pool");

    // same dtype + size class → recycled, and the data is the new upload's
    let (b, eb) = q.upload(HostData::U32(vec![2; 1000]));
    eb.wait(T).unwrap();
    let (hits, _, _, _) = q.stats().pool_snapshot();
    assert_eq!(hits, 1, "same-class upload must recycle");
    assert_eq!(q.download(b, T).unwrap().into_u32().unwrap(), vec![2; 1000]);

    // same byte class but different dtype → must not recycle
    q.free(b);
    q.barrier(T).unwrap();
    let (_, misses_before, _, _) = q.stats().pool_snapshot();
    let (c, ec) = q.upload(HostData::F32(vec![1.0; 1024]));
    ec.wait(T).unwrap();
    let (hits, misses_after, _, _) = q.stats().pool_snapshot();
    assert_eq!(hits, 1, "f32 upload must not recycle a u32 buffer");
    assert_eq!(misses_after, misses_before + 1);

    // different size class → miss as well
    let (d, ed) = q.upload(HostData::U32(vec![3; 4096]));
    ed.wait(T).unwrap();
    let (hits, _, _, _) = q.stats().pool_snapshot();
    assert_eq!(hits, 1);
    q.free(c);
    q.free(d);
    q.stop();
}

#[cfg(feature = "xla-stub")]
#[test]
fn pooled_buffer_not_reused_before_prior_commands_retire() {
    use caf_ocl::runtime::client::PadModel;
    // Slow device: free(A) and upload(B) are enqueued while A's upload
    // event is still pending. The in-order queue must retire
    // upload(A) -> free(A) -> upload(B), so the recycled storage can never
    // be handed out while a prior ready-event is pending.
    let slow = DeviceQueue::start(
        "pool-slow",
        Some(PadModel {
            launch: Duration::from_millis(2),
            bytes_per_sec: 1e6,
            compute_scale: 1.0,
            busy_wait: false,
        }),
    )
    .unwrap();
    let (a, ea) = slow.upload(HostData::U32(vec![7; 4096]));
    slow.free(a);
    let (b, eb) = slow.upload(HostData::U32(vec![8; 4096]));
    eb.wait(T).unwrap();
    assert!(
        ea.is_complete(),
        "B retired before A — in-order guarantee broken"
    );
    let (hits, _, returned, _) = slow.stats().pool_snapshot();
    assert_eq!(returned, 1);
    assert_eq!(hits, 1, "B must still recycle A's storage");
    assert_eq!(
        slow.download(b, T).unwrap().into_u32().unwrap(),
        vec![8; 4096]
    );
    slow.stop();
}

#[cfg(feature = "xla-stub")]
#[test]
fn pool_eviction_respects_caps() {
    let q = DeviceQueue::start_with(
        "pool-cap",
        None,
        PoolConfig {
            enabled: true,
            max_per_class: 1,
            max_bytes: 1 << 20,
        },
    )
    .unwrap();
    let (a, ea) = q.upload(HostData::U32(vec![1; 256]));
    let (b, eb) = q.upload(HostData::U32(vec![2; 256]));
    ea.wait(T).unwrap();
    eb.wait(T).unwrap();
    q.free(a);
    q.free(b);
    q.barrier(T).unwrap();
    let (_, _, returned, evicted) = q.stats().pool_snapshot();
    assert_eq!(returned, 1, "first free fits the per-class cap");
    assert_eq!(evicted, 1, "second free exceeds it and is dropped");
    q.stop();
}

#[test]
fn disabled_pool_never_recycles() {
    let q = DeviceQueue::start_with(
        "pool-off",
        None,
        PoolConfig {
            enabled: false,
            max_per_class: 8,
            max_bytes: 1 << 20,
        },
    )
    .unwrap();
    let (a, ea) = q.upload(HostData::U32(vec![1; 512]));
    ea.wait(T).unwrap();
    q.free(a);
    q.barrier(T).unwrap();
    let (b, eb) = q.upload(HostData::U32(vec![2; 512]));
    eb.wait(T).unwrap();
    let (hits, misses, returned, evicted) = q.stats().pool_snapshot();
    assert_eq!(hits, 0);
    assert_eq!(misses, 2);
    assert_eq!(returned, 0);
    assert_eq!(evicted, 1);
    let _ = b;
    q.stop();
}

#[test]
fn stats_accumulate() {
    let Some(m) = manifest() else { return };
    let q = DeviceQueue::start("test6", None).unwrap();
    let meta = m.get("empty_1024").unwrap();
    q.compile(&meta.name, m.hlo_path(meta)).wait(T).unwrap();
    let (bid, up) = q.upload(HostData::U32(vec![1; 1024]));
    let (out, done) = q.execute(&meta.name, vec![bid], Dtype::U32, vec![up]);
    done.wait(T).unwrap();
    let _ = q.download(out, T).unwrap();
    let (execs, t) = q.stats().snapshot();
    assert_eq!(execs, 1);
    assert!(t > Duration::ZERO);
    q.stop();
}
