//! Tier-1 soak gate: a reduced version of the soak & overload probe
//! (`cargo bench --bench soak`; methodology in PERF.md). An open-loop
//! mixed workload offers ~2x the simulated deployment's capacity while a
//! chaos schedule kills one replica mid-soak, once with admission control
//! ON (bounded + DropOldest + deadline) and once OFF. Records the
//! comparison in `BENCH_soak.json` (repo root) so the file refreshes on
//! every verified build.
//!
//! The default-on asserts are the robustness invariants, which hold on
//! any machine however noisy:
//!
//! - **exactly once** — every issued request resolves as a reply, a typed
//!   rejection, a shed, a deadline failure, or an error; the 30s hang
//!   detector never fires.
//! - **shedding engages** — under 2x overload the bounded arm rejects or
//!   sheds a nonzero number of requests.
//! - **chaos bites and heals** — at least one replica is killed and at
//!   least one respawn lands.
//! - **bounded beats unbounded** (comparative, wide-margin) — the shed
//!   arm's peak depth and admitted-request p99 do not exceed the
//!   unbounded arm's.
//!
//! The STRICT bounds (ratio + absolute) are opt-in via
//! `SOAK_ASSERT_BOUNDED=1` on a quiet machine, like
//! `DISPATCH_ASSERT_SPEEDUP` in perf_dispatch.

use caf_ocl::bench::{
    soak_closed_probe, soak_probe, write_soak_json, write_soak_manifest, SoakConfig, SoakRun,
};
use caf_ocl::workload::ClosedLoop;
use std::time::Duration;

fn assert_exactly_once(r: &SoakRun) {
    assert_eq!(
        r.issued,
        r.completed + r.rejected + r.shed + r.deadline + r.errors,
        "exactly-once ledger broken (shed {}): issued {} vs completed {} + \
         rejected {} + shed {} + deadline {} + errors {} (+ {} timeouts)",
        r.shedding,
        r.issued,
        r.completed,
        r.rejected,
        r.shed,
        r.deadline,
        r.errors,
        r.timeouts
    );
    assert_eq!(
        r.timeouts, 0,
        "a timeout means some request neither replied nor failed (shed {})",
        r.shedding
    );
}

#[test]
fn soak_resolves_every_request_and_shedding_bounds_the_tail() {
    let small_elems = 64;
    let batch_max_requests = 8;
    let large_elems = 1 << 16;
    // capacity math, so "2x overload" is checkable: 2 devices x 1/8ms =
    // 250 launches/s. The mix is ~70% small (batched up to 8-way: ~0.7
    // launches per 8 requests), ~20% large (1 launch each), ~10% pipeline
    // (2 launches each) — ~0.39 launches per offered request, so capacity
    // is ~640 req/s and 1280 req/s offered is ~2x
    let cfg = SoakConfig {
        devices: 2,
        launch: Duration::from_millis(8),
        bytes_per_sec: 4.0e9,
        duration: Duration::from_millis(1200),
        offered_rps: 1280.0,
        drivers: 32,
        small_elems,
        large_elems,
        batch_max_requests,
        batch_max_delay: Duration::from_millis(4),
        max_inflight: 8,
        max_queue_wait: Duration::from_millis(250),
        chaos_interval: Duration::from_millis(400),
        chaos_kills: 1,
        seed: 0x50a4,
        artifacts_dir: write_soak_manifest(
            "tier1",
            small_elems * batch_max_requests,
            large_elems,
        ),
    };
    let on = soak_probe(&cfg, true);
    let off = soak_probe(&cfg, false);
    // the closed-loop control arm (workload::ClosedLoop): bounded pressure
    // from the loop itself — each worker waits for its reply before
    // issuing the next request, so the backlog is capped by concurrency
    let closed_cfg = ClosedLoop {
        concurrency: 16,
        think: Duration::ZERO,
    };
    let closed = soak_closed_probe(&cfg, true, closed_cfg);

    // robustness invariant #1: no request is ever lost or double-resolved
    // — in ALL arms, under overload, with a replica chaos-killed mid-soak
    assert_exactly_once(&on);
    assert_exactly_once(&off);
    assert_exactly_once(&closed);
    assert!(
        closed.completed > 0,
        "the closed-loop arm never completed a request"
    );
    for r in [&on, &off] {
        assert!(
            r.issued > 100,
            "soak too small to mean anything: {} issued (shed {})",
            r.issued,
            r.shedding
        );
        assert!(
            r.completed > 0,
            "no request completed (shed {}) — the deployment never served",
            r.shedding
        );
    }

    // robustness invariant #2: under 2x overload the bounded arm must
    // actually engage its admission control
    assert!(
        on.rejected + on.shed + on.deadline > 0,
        "2x overload never tripped admission control: rejected {} shed {} deadline {}",
        on.rejected,
        on.shed,
        on.deadline
    );
    // ...and the unbounded arm must not reject anything (it has no bound)
    assert_eq!(
        off.rejected + off.shed, 0,
        "the unbounded arm rejected/shed requests: rejected {} shed {}",
        off.rejected,
        off.shed
    );

    // robustness invariant #3: chaos killed a replica and the Always
    // respawn policy brought one back
    for r in [&on, &off] {
        assert!(
            r.replica_kills >= 1,
            "chaos never killed a replica (shed {})",
            r.shedding
        );
        assert!(
            r.respawns >= 1,
            "no respawn landed after {} chaos kills (shed {})",
            r.replica_kills,
            r.shedding
        );
    }

    // comparative, wide-margin (default-on): bounding admitted work must
    // not make the backlog or the admitted tail WORSE than unbounded.
    // Under sustained 2x overload the unbounded arm's queues absorb every
    // driver, so its peak depth and lateness-inclusive p99 sit far above
    // the bounded arm's — a wide enough margin for noisy CI
    assert!(
        on.peak_depth <= off.peak_depth,
        "shedding must bound the depth gauge: peak {} (on) vs {} (off)",
        on.peak_depth,
        off.peak_depth
    );
    assert!(
        on.admitted_p99_ms <= off.admitted_p99_ms,
        "shedding must bound the admitted-request tail: p99 {:.1} ms (on) vs {:.1} ms (off)",
        on.admitted_p99_ms,
        off.admitted_p99_ms
    );

    let path = write_soak_json(
        &on,
        &off,
        &closed,
        &closed_cfg,
        &cfg,
        "cargo test --test perf_soak",
    )
    .expect("write BENCH_soak.json");
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"shed_on\""));
    assert!(written.contains("\"shed_off\""));
    assert!(written.contains("\"closed_loop\""));
    assert!(written.contains("\"closed_concurrency\""));
    assert!(written.contains("\"classes\""));
    assert!(written.contains("\"admitted_p99_ms\""));
    assert!(written.contains("\"small_val\""));
    assert!(written.contains("\"large_transfer\""));
    assert!(written.contains("\"pipeline\""));
    println!(
        "soak: shed ON  issued {} completed {} rejected {} shed {} deadline {} \
         peak_depth {} p99 {:.1} ms | shed OFF issued {} completed {} peak_depth {} \
         p99 {:.1} ms | kills {}+{} respawns {}+{} -> {}",
        on.issued,
        on.completed,
        on.rejected,
        on.shed,
        on.deadline,
        on.peak_depth,
        on.admitted_p99_ms,
        off.issued,
        off.completed,
        off.peak_depth,
        off.admitted_p99_ms,
        on.replica_kills,
        off.replica_kills,
        on.respawns,
        off.respawns,
        path.display()
    );

    // strict bounds, opt-in on a quiet machine: the bounded arm's tail is
    // not just "no worse" but decisively better, and its depth stays near
    // the configured bound (2x allows the one-mailbox-hop gauge lag of
    // batched occupancy documented on DevicePool::total_depth)
    if std::env::var_os("SOAK_ASSERT_BOUNDED").is_some() {
        assert!(
            on.admitted_p99_ms < 0.8 * off.admitted_p99_ms,
            "bounded p99 {:.1} ms should be well under unbounded {:.1} ms",
            on.admitted_p99_ms,
            off.admitted_p99_ms
        );
        assert!(
            on.peak_depth <= 2 * cfg.max_inflight + cfg.drivers as u64 / 4,
            "bounded peak depth {} strayed too far past max_inflight {}",
            on.peak_depth,
            cfg.max_inflight
        );
    }
}
