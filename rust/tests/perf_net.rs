//! Tier-1 remote-request gate: a reduced version of the blocking-vs-async
//! net probe (`cargo bench --bench net`; methodology in PERF.md). One
//! loopback echo actor, the full 1/64/4096 in-flight sweep, both arms at
//! each level. Records the comparison in `BENCH_net.json` (repo root) so
//! the file refreshes on every verified build.
//!
//! The default-on asserts are the structural invariants, which hold on
//! any machine however noisy:
//!
//! - **exactly once** — every issued request resolves as a reply or an
//!   error; over a healthy loopback, errors are zero. A hang would show
//!   as a ledger imbalance (the generous receive deadlines never fire).
//! - **bounded client pool** — the async arm drives 4096 concurrent
//!   requests from a fixed handful of threads, never a thread per
//!   request; the blocking arm's thread count equals its window, which is
//!   exactly the cost the futures surface removes.
//!
//! Relative throughput claims (async ≥ blocking) are left to the bench on
//! a quiet machine — CI thread scheduling makes them flaky.

use caf_ocl::bench::{net_probe, write_net_json, NetProbeConfig};

#[test]
fn net_futures_resolve_exactly_once_from_a_bounded_pool() {
    let cfg = NetProbeConfig {
        levels: vec![1, 64, 4096],
        requests: 4096,
        elems: 64,
        client_threads: 4,
    };
    let arms = net_probe(&cfg);
    assert_eq!(arms.len(), 2 * cfg.levels.len(), "two arms per level");

    for a in &arms {
        assert_eq!(
            a.issued,
            a.completed + a.errors,
            "exactly-once ledger broken ({} @ {}): issued {} vs completed {} + errors {}",
            a.mode,
            a.inflight,
            a.issued,
            a.completed,
            a.errors
        );
        assert_eq!(
            a.errors, 0,
            "{} arm @ {} in-flight errored over loopback",
            a.mode, a.inflight
        );
        assert!(
            a.completed > 0,
            "{} arm @ {} never completed a request",
            a.mode,
            a.inflight
        );
        match a.mode {
            "blocking" => assert_eq!(
                a.threads, a.inflight,
                "the blocking arm parks one thread per in-flight slot"
            ),
            "async" => assert!(
                a.threads <= cfg.client_threads,
                "async arm @ {} grew its pool: {} threads > {}",
                a.inflight,
                a.threads,
                cfg.client_threads
            ),
            other => panic!("unknown arm mode {other:?}"),
        }
    }

    // the acceptance shape: the async arm holds a 4096-request window from
    // a pool orders of magnitude smaller
    let wide = arms
        .iter()
        .find(|a| a.mode == "async" && a.inflight == 4096)
        .expect("async arm at 4096 in-flight");
    assert!(
        wide.threads * 100 <= wide.inflight,
        "async @ 4096 must not approach thread-per-request: {} threads",
        wide.threads
    );

    let path =
        write_net_json(&arms, &cfg, "cargo test --test perf_net").expect("write BENCH_net.json");
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"bench\": \"net\""));
    assert!(written.contains("\"inflight\": 4096"));
    assert!(written.contains("\"mode\": \"blocking\""));
    assert!(written.contains("\"mode\": \"async\""));
    for a in &arms {
        println!(
            "net: {:>8} @ {:>4} in-flight ({:>4} threads) {:>9.1} req/s p50 {:.3} ms p99 {:.3} ms",
            a.mode, a.inflight, a.threads, a.req_per_s, a.p50_ms, a.p99_ms
        );
    }
    println!("-> {}", path.display());
}
