//! Tier-1 perf probe: runs reduced versions of the two dispatch scenarios
//! (1-vs-N-device placement, batched vs unbatched sub-capacity requests)
//! and records the comparison in `BENCH_dispatch.json` (repo root), so the
//! file refreshes on every verified build. The full-size measurement is
//! `cargo bench --bench dispatch`; methodology in PERF.md.
//!
//! Like `perf_msgring`, the gate only sanity-checks the numbers: both
//! scenarios race other test binaries for cores inside a parallel `cargo
//! test`, so ratio asserts are opt-in (`DISPATCH_ASSERT_SPEEDUP=1` on a
//! quiet machine).

use caf_ocl::bench::{
    dispatch_batching_probe, dispatch_placement_probe, write_dispatch_json,
    write_dispatch_manifest, DispatchProbeConfig, DispatchResults,
};
use std::time::Duration;

#[test]
fn dispatch_records_placement_and_batching_throughput() {
    let cfg = DispatchProbeConfig {
        devices: 2,
        launch: Duration::from_millis(2),
        requests: 12,
        batch_requests: 16,
        request_elems: 128,
        capacity: 1024,
        artifacts_dir: write_dispatch_manifest("tier1", 1024),
    };
    let (one_device, n_device) = dispatch_placement_probe(&cfg);
    let (unbatched, batched) = dispatch_batching_probe(&cfg);
    for v in [one_device, n_device, unbatched, batched] {
        assert!(v.is_finite() && v > 0.0, "degenerate throughput {v}");
    }
    let results = DispatchResults {
        devices: cfg.devices,
        requests: cfg.requests,
        one_device_reqs_per_sec: one_device,
        n_device_reqs_per_sec: n_device,
        batch_requests: cfg.batch_requests,
        request_elems: cfg.request_elems,
        capacity: cfg.capacity,
        unbatched_reqs_per_sec: unbatched,
        batched_reqs_per_sec: batched,
    };
    let path = write_dispatch_json(&results, "cargo test --test perf_dispatch")
        .expect("write BENCH_dispatch.json");
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"placement\""));
    assert!(written.contains("\"batching\""));
    println!(
        "dispatch: placement {one_device:.1} -> {n_device:.1} req/s ({:.2}x), \
         batching {unbatched:.1} -> {batched:.1} req/s ({:.2}x) -> {}",
        n_device / one_device.max(1e-9),
        batched / unbatched.max(1e-9),
        path.display()
    );
    // Opt-in comparison bounds (see perf_msgring for why they are not in
    // the default gate): with a 2 ms launch pad the padded scenarios are
    // pad-dominated, so even a noisy machine should clear loose bounds.
    if std::env::var_os("DISPATCH_ASSERT_SPEEDUP").is_some() {
        assert!(
            n_device > one_device,
            "replication slower than one device: {n_device:.1} vs {one_device:.1} req/s"
        );
        assert!(
            batched > unbatched,
            "batching slower than per-request launches: {batched:.1} vs {unbatched:.1} req/s"
        );
    }
}
