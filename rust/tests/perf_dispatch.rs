//! Tier-1 perf probe: runs reduced versions of the dispatch scenarios
//! (1-vs-N-device placement, batched vs unbatched sub-capacity requests,
//! cost-aware vs round-robin steering on the Fig 7b pair, and the
//! placement-tier pipeline triple — composition overhead, stage
//! scheduling, stranded-ref recovery) and records the comparison in
//! `BENCH_dispatch.json` (repo root), so the file refreshes on every
//! verified build. The full-size measurement is
//! `cargo bench --bench dispatch`; methodology in PERF.md.
//!
//! Like `perf_msgring`, throughput-ratio asserts are opt-in
//! (`DISPATCH_ASSERT_SPEEDUP=1` on a quiet machine) — both scenarios race
//! other test binaries for cores inside a parallel `cargo test`. The
//! cost-aware *distribution* comparison (CostAware lands strictly less on
//! the slow device than RoundRobin) runs by default with a wide margin;
//! the strict zero-slow-launches form is opt-in because the EWMA service
//! gauge is wall-clock and sleep pads overshoot under load.

use caf_ocl::bench::{
    dispatch_batched_costaware_probe, dispatch_batching_probe, dispatch_costaware_probe,
    dispatch_pipeline_probe, dispatch_placement_probe, write_batched_costaware_manifest,
    write_costaware_manifest, write_dispatch_json, write_dispatch_manifest,
    BatchedCostAwareProbeConfig, CostAwareProbeConfig, DispatchProbeConfig, DispatchResults,
    PipelineProbeConfig,
};
use std::time::Duration;

#[test]
fn dispatch_records_placement_and_batching_throughput() {
    let cfg = DispatchProbeConfig {
        devices: 2,
        launch: Duration::from_millis(2),
        requests: 12,
        batch_requests: 16,
        request_elems: 128,
        capacity: 1024,
        artifacts_dir: write_dispatch_manifest("tier1", 1024),
    };
    let (one_device, n_device) = dispatch_placement_probe(&cfg);
    let (unbatched, batched) = dispatch_batching_probe(&cfg);
    // the small burst stays well below the ~(slow pad / fast service)
    // depth where spilling to the slow device becomes genuinely cheaper,
    // so the zero-slow-launches assert below is deterministic
    let ca_cfg = CostAwareProbeConfig {
        small_elems: 64,
        large_elems: 1 << 16,
        small_requests: 6,
        large_requests: 6,
        artifacts_dir: write_costaware_manifest("tier1", 64, 1 << 16),
    };
    let (ca_small, ca_large) = dispatch_costaware_probe(&ca_cfg);
    // batched steering pair: every replica fronts an adaptive batcher, so
    // routing must read the occupancy gauge (the routed estimate cannot
    // reconcile per-request routing against per-flush launches) — the
    // burst stays far below the depth where spilling to the slow device
    // becomes cheaper, so the comparative assert below is deterministic
    // (RoundRobin lands 6 requests = 3 windows on the slow device, so a
    // single noise-induced CostAware diversion cannot flip the comparison)
    let bc_cfg = BatchedCostAwareProbeConfig {
        request_elems: 64,
        requests: 12,
        batch_max_requests: 2,
        batch_max_delay: Duration::from_millis(100),
        alt_elems: 128,
        per_class: 3,
        artifacts_dir: write_batched_costaware_manifest("tier1", 1024),
    };
    let bc = dispatch_batched_costaware_probe(&bc_cfg);
    // placement-tier pipelines: composition overhead, stage scheduling,
    // and stranded-ref recovery on the same stub manifest
    let pipe_cfg = PipelineProbeConfig {
        launch: cfg.launch,
        requests: cfg.requests / 2,
        capacity: cfg.capacity,
        artifacts_dir: cfg.artifacts_dir.clone(),
    };
    let pipe = dispatch_pipeline_probe(&pipe_cfg);
    for v in [
        one_device,
        n_device,
        unbatched,
        batched,
        ca_small.costaware_reqs_per_sec,
        ca_small.round_robin_reqs_per_sec,
        ca_large.costaware_reqs_per_sec,
        ca_large.round_robin_reqs_per_sec,
        bc.costaware_reqs_per_sec,
        bc.round_robin_reqs_per_sec,
        pipe.monolithic_ms_per_req,
        pipe.composed_ms_per_req,
        pipe.interleaved_reqs_per_sec,
        pipe.lockstep_reqs_per_sec,
        pipe.migration_recovery_ms,
        pipe.reupload_recovery_ms,
    ] {
        assert!(v.is_finite() && v > 0.0, "degenerate measurement {v}");
    }
    // acceptance (deterministic, so default-on): lock-step serializes a
    // request end-to-end — its ExecStats high-water mark is pinned at one
    // in-flight stage launch — while interleaving overlaps stage launches
    // of different requests. The throughput ordering the overlap buys is
    // wall-clock and therefore opt-in below.
    assert_eq!(
        pipe.lockstep_inflight_peak, 1,
        "lock-step must never overlap stage launches"
    );
    assert!(
        pipe.interleaved_inflight_peak >= 2,
        "interleaving must overlap stage launches of different requests (peak {})",
        pipe.interleaved_inflight_peak
    );
    // acceptance: the migration arm recovered by an explicit
    // device-to-device transfer (counted on the source device), not by a
    // routed error + re-upload
    assert!(
        pipe.migrations >= 1,
        "the migration arm must count an explicit transfer"
    );
    // acceptance: the small burst under CostAware must land strictly less
    // work on the high-dispatch-cost device than RoundRobin (which pays
    // the pad on every second request by construction). The comparison is
    // default-on — a routing decision over a 20x pad gap, with RoundRobin
    // placing half the burst on the slow device, leaves a wide margin —
    // but the STRICT zero-slow-launches form is opt-in below: the EWMA
    // service gauge is a wall-clock measurement, and sleep overshoot on a
    // loaded box can nudge a single late request over the pad gap. (The
    // fully deterministic zero-launch assert lives in tests/placement.rs,
    // where requests are sequential and the cheap device has no pad.)
    assert!(
        ca_small.costaware_slow_launches < ca_small.round_robin_slow_launches,
        "CostAware must steer the small burst away from the Phi-like device \
         (CostAware slow={}, RoundRobin slow={})",
        ca_small.costaware_slow_launches,
        ca_small.round_robin_slow_launches
    );
    assert!(
        ca_small.round_robin_slow_launches > 0,
        "RoundRobin must (by construction) pay the Phi-like pad"
    );
    // acceptance: the steering survives batching. On a BATCHED replicated
    // pool, CostAware must land strictly fewer small-request launches on
    // the slow device than RoundRobin (comparative form, like the
    // unbatched gate above — launch counts here are per-flush).
    assert!(
        bc.costaware_slow_launches < bc.round_robin_slow_launches,
        "batched CostAware must steer the small burst away from the Phi-like \
         device (CostAware slow={}, RoundRobin slow={})",
        bc.costaware_slow_launches,
        bc.round_robin_slow_launches
    );
    assert!(
        bc.round_robin_slow_launches > 0,
        "batched RoundRobin must (by construction) flush windows on the slow device"
    );
    // acceptance: a multi-shape interleaved burst coalesces per class —
    // exactly one fused launch per shape class (count triggers fill both
    // windows deterministically), never one launch per request
    assert_eq!(
        bc.multishape_fused_launches, bc.multishape_classes as u64,
        "interleaved shape classes must fuse into one launch per class"
    );
    assert!(
        bc.multishape_coalescing_ratio > 1.0,
        "coalescing ratio must beat one request per launch (got {:.2})",
        bc.multishape_coalescing_ratio
    );
    let results = DispatchResults {
        devices: cfg.devices,
        requests: cfg.requests,
        one_device_reqs_per_sec: one_device,
        n_device_reqs_per_sec: n_device,
        batch_requests: cfg.batch_requests,
        request_elems: cfg.request_elems,
        capacity: cfg.capacity,
        unbatched_reqs_per_sec: unbatched,
        batched_reqs_per_sec: batched,
        cost_aware_small: ca_small,
        cost_aware_large: ca_large,
        batched_costaware: bc,
        pipeline: pipe,
    };
    let path = write_dispatch_json(&results, "cargo test --test perf_dispatch")
        .expect("write BENCH_dispatch.json");
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"placement\""));
    assert!(written.contains("\"batching\""));
    assert!(written.contains("\"cost_aware\""));
    assert!(written.contains("\"batched_costaware\""));
    assert!(written.contains("\"multishape\""));
    assert!(written.contains("\"pipeline\""));
    println!(
        "dispatch: placement {one_device:.1} -> {n_device:.1} req/s ({:.2}x), \
         batching {unbatched:.1} -> {batched:.1} req/s ({:.2}x), \
         costaware small fast/slow {}/{} vs RR {}/{}, \
         batched costaware fast/slow {}/{} vs RR {}/{}, \
         multishape {} reqs -> {} launches -> {}",
        n_device / one_device.max(1e-9),
        batched / unbatched.max(1e-9),
        ca_small.costaware_fast_launches,
        ca_small.costaware_slow_launches,
        ca_small.round_robin_fast_launches,
        ca_small.round_robin_slow_launches,
        bc.costaware_fast_launches,
        bc.costaware_slow_launches,
        bc.round_robin_fast_launches,
        bc.round_robin_slow_launches,
        bc.multishape_requests,
        bc.multishape_fused_launches,
        path.display()
    );
    println!(
        "pipeline: monolithic {:.2} ms/req vs composed {:.2} ms/req, \
         lockstep {:.1} req/s (peak {}) vs interleaved {:.1} req/s (peak {}), \
         recovery migrate {:.2} ms vs re-upload {:.2} ms",
        pipe.monolithic_ms_per_req,
        pipe.composed_ms_per_req,
        pipe.lockstep_reqs_per_sec,
        pipe.lockstep_inflight_peak,
        pipe.interleaved_reqs_per_sec,
        pipe.interleaved_inflight_peak,
        pipe.migration_recovery_ms,
        pipe.reupload_recovery_ms
    );
    // Opt-in comparison bounds (see perf_msgring for why they are not in
    // the default gate): with a 2 ms launch pad the padded scenarios are
    // pad-dominated, so even a noisy machine should clear loose bounds.
    if std::env::var_os("DISPATCH_ASSERT_SPEEDUP").is_some() {
        assert!(
            n_device > one_device,
            "replication slower than one device: {n_device:.1} vs {one_device:.1} req/s"
        );
        assert!(
            batched > unbatched,
            "batching slower than per-request launches: {batched:.1} vs {unbatched:.1} req/s"
        );
        assert!(
            ca_small.costaware_reqs_per_sec > ca_small.round_robin_reqs_per_sec,
            "steering around the Phi-like pad must beat rotating into it"
        );
        assert_eq!(
            ca_small.costaware_slow_launches, 0,
            "on a quiet machine the small burst avoids the slow device entirely"
        );
        assert!(
            bc.costaware_reqs_per_sec > bc.round_robin_reqs_per_sec,
            "batched steering around the Phi-like pad must beat rotating into it"
        );
        assert_eq!(
            bc.costaware_slow_launches, 0,
            "on a quiet machine the batched burst avoids the slow device entirely"
        );
        assert!(
            pipe.composed_ms_per_req > pipe.monolithic_ms_per_req,
            "three pad-bearing stage launches must cost more than one \
             ({:.2} vs {:.2} ms/req)",
            pipe.composed_ms_per_req,
            pipe.monolithic_ms_per_req
        );
        assert!(
            pipe.interleaved_reqs_per_sec > pipe.lockstep_reqs_per_sec,
            "overlapping stage launches must beat end-to-end serialization \
             ({:.1} vs {:.1} req/s)",
            pipe.interleaved_reqs_per_sec,
            pipe.lockstep_reqs_per_sec
        );
    }
}
