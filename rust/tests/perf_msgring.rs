//! Tier-1 perf probe: runs a reduced message ring on both the seed-style
//! locked runtime and the lock-free runtime, records the comparison in
//! `BENCH_msgring.json` (repo root), and sanity-checks the result. The
//! full-size measurement is `cargo bench --bench msgring`; methodology in
//! PERF.md.

use caf_ocl::bench::{msgring_lockfree, msgring_seed_style, write_msgring_json, RingConfig};

#[test]
fn msgring_records_before_after_throughput() {
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(2, 4);
    let cfg = RingConfig {
        workers,
        actors: 32,
        tokens: workers * 2,
        hops_per_token: 5_000,
    };
    // one warmup each, then measure
    let _ = msgring_seed_style(cfg);
    let _ = msgring_lockfree(cfg);
    let seed = msgring_seed_style(cfg);
    let lockfree = msgring_lockfree(cfg);

    assert!(seed.is_finite() && seed > 0.0);
    assert!(lockfree.is_finite() && lockfree > 0.0);

    let path = write_msgring_json(cfg, seed, lockfree, "cargo test --test perf_msgring")
        .expect("write BENCH_msgring.json");
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"speedup\""));
    println!(
        "msgring: seed {seed:.0} msgs/s, lockfree {lockfree:.0} msgs/s, \
         speedup {:.2}x -> {}",
        lockfree / seed.max(1e-9),
        path.display()
    );
    // The acceptance target (>= 2x, see ISSUE/PERF.md) comes from the
    // recorded JSON on a quiet machine. A ratio assert inside `cargo test`
    // is inherently flaky: the two timed runs happen at different moments
    // while other test binaries compete for the same cores, so even a
    // loose bound can fail a shared CI runner with no real regression.
    // The gate keeps only the finite/positive and JSON checks above;
    // quiet machines opt into the comparison bound explicitly.
    if std::env::var_os("MSGRING_ASSERT_SPEEDUP").is_some() {
        assert!(
            lockfree > seed * 0.5,
            "lock-free runtime dramatically slower than the locked seed: \
             {lockfree:.0} vs {seed:.0} msgs/s"
        );
    }
}
