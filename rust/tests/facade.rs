//! Integration tests: OpenCL actors end-to-end through the actor system —
//! value round-trips, mem_ref pipelines, composition, error paths.
//! Requires artifacts (`make artifacts`); tests no-op gracefully otherwise.

use caf_ocl::actor::*;
use caf_ocl::opencl::*;
use std::sync::Arc;
use std::time::Duration;

const T: Duration = Duration::from_secs(60);

fn system_with_opencl() -> Option<(ActorSystem, Arc<Manager>)> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        return None;
    }
    let sys = ActorSystem::new(SystemConfig::default().with_threads(4));
    let mgr = Manager::load(&sys);
    Some((sys, mgr))
}

fn teardown(sys: ActorSystem, mgr: Arc<Manager>) {
    mgr.stop_devices();
    sys.shutdown();
}

#[test]
fn matmul_value_roundtrip() {
    // paper Listing 2: spawn, request two matrices, receive the product
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let n = 64usize;
    let worker = mgr.spawn_simple("matmul_64", Mode::Val, Mode::Val).unwrap();
    let mut eye = vec![0f32; n * n];
    for i in 0..n {
        eye[i * n + i] = 1.0;
    }
    let a: Vec<f32> = (0..n * n).map(|i| (i % 31) as f32).collect();
    let me = sys.scoped();
    let out: Vec<f32> = me.request(&worker, (a.clone(), eye)).receive(T).unwrap();
    assert_eq!(out, a);
    teardown(sys, mgr);
}

#[test]
fn empty_kernel_roundtrip_and_stats() {
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let stats = Arc::new(FacadeStats::default());
    let program = mgr.create_kernel_program("empty_1024").unwrap();
    let worker = mgr
        .spawn_cl(
            KernelSpawn::new(program, "empty_1024")
                .range(NdRange::d1(1024))
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .with_stats(stats.clone()),
        )
        .unwrap();
    let me = sys.scoped();
    let data: Vec<u32> = (0..1024).collect();
    let out: Vec<u32> = me.request(&worker, data.clone()).receive(T).unwrap();
    assert_eq!(out, data);
    assert_eq!(
        stats.launched.load(std::sync::atomic::Ordering::Relaxed),
        1
    );
    assert!(stats.device_ns.load(std::sync::atomic::Ordering::Relaxed) > 0);
    teardown(sys, mgr);
}

#[test]
fn ref_output_returns_memref_before_read() {
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let worker = mgr.spawn_simple("empty_1024", Mode::Val, Mode::Ref).unwrap();
    let me = sys.scoped();
    let data: Vec<u32> = (0..1024).rev().collect();
    let r: MemRef = me.request(&worker, data.clone()).receive(T).unwrap();
    assert_eq!(r.len(), 1024);
    assert_eq!(r.read_u32(T).unwrap(), data);
    teardown(sys, mgr);
}

#[test]
fn memref_feeds_next_stage() {
    // two chained empty kernels: Val -> Ref -> Val
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let s1 = mgr.spawn_simple("empty_1024", Mode::Val, Mode::Ref).unwrap();
    let s2 = mgr.spawn_simple("empty_1024", Mode::Ref, Mode::Val).unwrap();
    let me = sys.scoped();
    let data: Vec<u32> = (0..1024).map(|i| i * 3).collect();
    let r: MemRef = me.request(&s1, data.clone()).receive(T).unwrap();
    let out: Vec<u32> = me.request(&s2, r).receive(T).unwrap();
    assert_eq!(out, data);
    teardown(sys, mgr);
}

#[test]
fn composed_pipeline_stays_on_device() {
    // sort -> chunklit as a composed actor; only MemRefs travel inside
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let dev = mgr.default_device().unwrap();
    let program = mgr
        .create_program(&dev, &["wah_sort_4096", "wah_chunklit_4096"])
        .unwrap();
    let (pipe, stages) = caf_ocl::opencl::stage::PipelineBuilder::new(&mgr, program)
        .stage("wah_sort_4096")
        .stage("wah_chunklit_4096")
        .collect()
        .build()
        .unwrap();
    assert_eq!(stages.len(), 2);
    let mut vals = vec![0u32; 4096];
    for (i, v) in vals.iter_mut().enumerate() {
        *v = (i as u32).wrapping_mul(2654435761) % 1023;
    }
    let me = sys.scoped();
    let out: Vec<u32> = me.request(&pipe, vals).receive(T).unwrap();
    assert_eq!(out.len(), 8192);
    let cids = &out[..4096];
    assert!(cids.windows(2).all(|w| w[0] <= w[1]));
    teardown(sys, mgr);
}

#[test]
fn wrong_arity_is_an_error() {
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let worker = mgr.spawn_simple("matmul_64", Mode::Val, Mode::Val).unwrap();
    let me = sys.scoped();
    // one matrix instead of two
    let r = me
        .request(&worker, vec![0f32; 64 * 64])
        .receive_msg(T);
    assert!(r.is_err());
    assert!(r.unwrap_err().reason.contains("expects 2 arguments"));
    teardown(sys, mgr);
}

#[test]
fn wrong_shape_is_an_error() {
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let worker = mgr.spawn_simple("matmul_64", Mode::Val, Mode::Val).unwrap();
    let me = sys.scoped();
    let r = me
        .request(&worker, (vec![0f32; 10], vec![0f32; 10]))
        .receive_msg(T);
    assert!(r.is_err());
    assert!(r.unwrap_err().reason.contains("elements"));
    teardown(sys, mgr);
}

#[test]
fn wrong_dtype_is_an_error() {
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let worker = mgr.spawn_simple("empty_1024", Mode::Val, Mode::Val).unwrap();
    let me = sys.scoped();
    let r = me.request(&worker, vec![0f32; 1024]).receive_msg(T);
    assert!(r.is_err());
    teardown(sys, mgr);
}

#[test]
fn unmatchable_message_is_an_error() {
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let worker = mgr.spawn_simple("empty_1024", Mode::Val, Mode::Val).unwrap();
    let me = sys.scoped();
    let r = me.request(&worker, "hello".to_string()).receive_msg(T);
    assert!(r.is_err());
    teardown(sys, mgr);
}

#[test]
fn pre_and_postprocess_functions() {
    // paper Listing 3: custom conversion around the kernel
    let Some((sys, mgr)) = system_with_opencl() else { return };
    #[derive(Clone)]
    struct Wrapped(Vec<u32>);
    let program = mgr.create_kernel_program("empty_1024").unwrap();
    let worker = mgr
        .spawn_cl(
            KernelSpawn::new(program, "empty_1024")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .preprocess(|msg| {
                    msg.downcast_ref::<Wrapped>()
                        .map(|w| vec![ArgValue::from(w.0.clone())])
                })
                .postprocess(|out, _inc| match out {
                    ArgValue::U32(v) => Message::new(Wrapped((*v).clone())),
                    other => Message::new(other),
                }),
        )
        .unwrap();
    let me = sys.scoped();
    let data: Vec<u32> = (100..1124).collect();
    let out: Wrapped = me.request(&worker, Wrapped(data.clone())).receive(T).unwrap();
    assert_eq!(out.0, data);
    teardown(sys, mgr);
}

#[test]
fn facade_is_monitorable_like_any_actor() {
    // "an OpenCL actor is not distinguishable from any other actor"
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let worker = mgr.spawn_simple("empty_1024", Mode::Val, Mode::Val).unwrap();
    // monitoring a live facade works through the same interface
    let probe = sys.scoped();
    worker.monitor_with(probe.me());
    // handle equality semantics hold
    assert_eq!(worker.clone(), worker);
    teardown(sys, mgr);
}

#[test]
fn default_device_selection_and_kinds() {
    let Some((sys, mgr)) = system_with_opencl() else { return };
    let dev = mgr.default_device().unwrap();
    assert_eq!(dev.id, 0);
    assert_eq!(dev.kind, DeviceKind::Cpu);
    assert!(mgr.platform().device_of_kind(DeviceKind::Gpu).is_none());
    teardown(sys, mgr);
}
