//! End-to-end indexing tests: the 8-stage device pipeline and the fused
//! variant must produce word-identical indexes to the CPU oracle, and the
//! decoded index must recover every value's positions exactly.

use caf_ocl::actor::*;
use caf_ocl::indexing::gpu_pipeline::{FusedIndexer, GpuIndexer, CARDINALITY, PAD_VALUE};
use caf_ocl::indexing::{CpuIndexer, WahIndex};
use caf_ocl::opencl::Manager;
use caf_ocl::util::Rng;
use caf_ocl::workload::ValueStream;
use std::sync::Arc;
use std::time::Duration;

const T: Duration = Duration::from_secs(120);

fn setup() -> Option<(ActorSystem, Arc<Manager>)> {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        return None;
    }
    let sys = ActorSystem::new(SystemConfig::default().with_threads(4));
    let mgr = Manager::load(&sys);
    Some((sys, mgr))
}

fn cpu_index_padded(values: &[u32], capacity: usize) -> WahIndex {
    // the CPU oracle over the same padded stream the GPU pipeline sees,
    // with the pad value's bitmap removed (reserved)
    let mut padded = values.to_vec();
    padded.resize(capacity, PAD_VALUE);
    let mut idx = CpuIndexer::new(CARDINALITY).index(&padded);
    // drop the pad bitmap: it is always last in the layout
    if idx.lut[PAD_VALUE as usize] != caf_ocl::indexing::INVALID {
        idx.words.truncate(idx.lut[PAD_VALUE as usize] as usize);
        idx.lut[PAD_VALUE as usize] = caf_ocl::indexing::INVALID;
        idx.n_distinct -= 1;
    }
    idx
}

#[test]
fn gpu_pipeline_matches_cpu_oracle_word_for_word() {
    let Some((sys, mgr)) = setup() else { return };
    let gpu = GpuIndexer::build(&mgr, 0, 4096).unwrap();
    let me = sys.scoped();
    for seed in [1u64, 2, 3] {
        let values = ValueStream::Uniform { cardinality: 256 }.generate(4096, seed);
        let got = gpu.index(&me, &values, T).unwrap();
        let want = cpu_index_padded(&values, 4096);
        assert_eq!(got.words, want.words, "seed {seed}: words differ");
        assert_eq!(got.lut, want.lut, "seed {seed}: lut differs");
        assert_eq!(got.n_distinct, want.n_distinct);
    }
    mgr.stop_devices();
    sys.shutdown();
}

#[test]
fn gpu_pipeline_verifies_against_raw_values() {
    let Some((sys, mgr)) = setup() else { return };
    let gpu = GpuIndexer::build(&mgr, 0, 4096).unwrap();
    let me = sys.scoped();
    // partial fill: 3000 of 4096 slots, Zipf-skewed
    let values = ValueStream::Zipf { cardinality: 512, s: 1.2 }.generate(3000, 9);
    let idx = gpu.index(&me, &values, T).unwrap();
    idx.verify(&values).unwrap();
    mgr.stop_devices();
    sys.shutdown();
}

#[test]
fn fused_indexer_matches_staged_pipeline() {
    let Some((sys, mgr)) = setup() else { return };
    let staged = GpuIndexer::build(&mgr, 0, 4096).unwrap();
    let fused = FusedIndexer::build(&mgr, 0, 4096).unwrap();
    let me = sys.scoped();
    let values = ValueStream::Runs { cardinality: 64, max_run: 40 }.generate(4096, 4);
    let a = staged.index(&me, &values, T).unwrap();
    let b = fused.index(&me, &values, T).unwrap();
    assert_eq!(a.words, b.words);
    assert_eq!(a.lut, b.lut);
    mgr.stop_devices();
    sys.shutdown();
}

#[test]
fn pipeline_rejects_out_of_range_values() {
    let Some((sys, mgr)) = setup() else { return };
    let gpu = GpuIndexer::build(&mgr, 0, 4096).unwrap();
    let me = sys.scoped();
    assert!(gpu.index(&me, &[PAD_VALUE], T).is_err());
    assert!(gpu
        .index(&me, &vec![0u32; 5000], T)
        .is_err(), "over capacity must fail");
    mgr.stop_devices();
    sys.shutdown();
}

#[test]
fn pipeline_handles_degenerate_streams() {
    let Some((sys, mgr)) = setup() else { return };
    let gpu = GpuIndexer::build(&mgr, 0, 4096).unwrap();
    let me = sys.scoped();
    // all-same value
    let same = vec![7u32; 4096];
    let idx = gpu.index(&me, &same, T).unwrap();
    idx.verify(&same).unwrap();
    assert_eq!(idx.n_distinct, 1);
    // single value in slot 0
    let single = vec![3u32];
    let idx = gpu.index(&me, &single, T).unwrap();
    idx.verify(&single).unwrap();
    mgr.stop_devices();
    sys.shutdown();
}

#[test]
fn pipeline_is_reusable_across_requests() {
    let Some((sys, mgr)) = setup() else { return };
    let gpu = GpuIndexer::build(&mgr, 0, 4096).unwrap();
    let me = sys.scoped();
    let mut rng = Rng::new(12);
    for _ in 0..5 {
        let n = rng.range(1, 4096) as usize;
        let values: Vec<u32> = (0..n).map(|_| rng.below(1000) as u32).collect();
        let idx = gpu.index(&me, &values, T).unwrap();
        let want = cpu_index_padded(&values, 4096);
        assert_eq!(idx.words, want.words);
    }
    mgr.stop_devices();
    sys.shutdown();
}

#[test]
fn larger_capacity_pipeline() {
    let Some((sys, mgr)) = setup() else { return };
    let gpu = GpuIndexer::build(&mgr, 0, 16384).unwrap();
    let me = sys.scoped();
    let values = ValueStream::Uniform { cardinality: 1000 }.generate(16384, 21);
    let got = gpu.index(&me, &values, T).unwrap();
    let want = cpu_index_padded(&values, 16384);
    assert_eq!(got.words, want.words);
    assert_eq!(got.lut, want.lut);
    mgr.stop_devices();
    sys.shutdown();
}

#[test]
fn bitonic_sort_artifact_matches_sort_stage() {
    // sort-stage ablation: the Pallas bitonic network must be a drop-in
    // replacement for the lax.sort artifact (stability included)
    use caf_ocl::runtime::*;
    let Some((sys, mgr)) = setup() else { return };
    let m = &mgr.platform().manifest;
    if !m.contains("wah_bitonic_4096") {
        sys.shutdown();
        return;
    }
    let q = DeviceQueue::start("bitonic-test", None).unwrap();
    for k in ["wah_sort_4096", "wah_bitonic_4096"] {
        q.compile(k, m.hlo_path(m.get(k).unwrap())).wait(T).unwrap();
    }
    let values = ValueStream::Zipf { cardinality: 700, s: 1.3 }.generate(4096, 5);
    let (b, e) = q.upload(HostData::U32(values));
    let (s1, e1) = q.execute("wah_sort_4096", vec![b], Dtype::U32, vec![e.clone()]);
    let (s2, e2) = q.execute("wah_bitonic_4096", vec![b], Dtype::U32, vec![e]);
    e1.wait(T).unwrap();
    e2.wait(T).unwrap();
    let a = q.download(s1, T).unwrap().into_u32().unwrap();
    let c = q.download(s2, T).unwrap().into_u32().unwrap();
    assert_eq!(a, c, "bitonic and lax.sort artifacts must agree");
    q.stop();
    mgr.stop_devices();
    sys.shutdown();
}
