//! Integration tests for the CAF-like actor substrate: spawning, messaging,
//! request/response, behavior changes, monitors/links, composition,
//! panic isolation, timeouts.

use caf_ocl::actor::*;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

const T: Duration = Duration::from_secs(5);

fn sys() -> ActorSystem {
    ActorSystem::new(SystemConfig::default().with_threads(4))
}

#[test]
fn ping_pong_request_response() {
    let sys = sys();
    let adder = sys.spawn(|_| {
        Behavior::new().on(|_ctx, &(a, b): &(i32, i32)| reply(a + b))
    });
    let me = sys.scoped();
    let r: i32 = me.request(&adder, (20, 22)).receive(T).unwrap();
    assert_eq!(r, 42);
    sys.shutdown();
}

#[test]
fn typed_dispatch_picks_matching_handler() {
    let sys = sys();
    let poly = sys.spawn(|_| {
        Behavior::new()
            .on(|_ctx, &x: &i32| reply(x * 2))
            .on(|_ctx, s: &String| reply(format!("<{s}>")))
    });
    let me = sys.scoped();
    assert_eq!(me.request(&poly, 21i32).receive::<i32>(T).unwrap(), 42);
    assert_eq!(
        me.request(&poly, "hi".to_string())
            .receive::<String>(T)
            .unwrap(),
        "<hi>"
    );
    sys.shutdown();
}

#[test]
fn void_handler_sends_unit_reply() {
    let sys = sys();
    let hits = Arc::new(AtomicUsize::new(0));
    let h = hits.clone();
    let sink = sys.spawn(move |_| {
        Behavior::new().on(move |_ctx, _: &u32| {
            h.fetch_add(1, Ordering::SeqCst);
            no_reply()
        })
    });
    let me = sys.scoped();
    let r = me.request(&sink, 7u32).receive_msg(T).unwrap();
    assert!(r.is::<caf_ocl::actor::message::UnitReply>());
    assert_eq!(hits.load(Ordering::SeqCst), 1);
    sys.shutdown();
}

#[test]
fn request_to_dead_actor_errors() {
    let sys = sys();
    let quitter = sys.spawn(|_| {
        Behavior::new().on(|ctx, _: &u32| {
            ctx.quit(ExitReason::Normal);
            no_reply()
        })
    });
    let me = sys.scoped();
    let _ = me.request(&quitter, 1u32).receive_msg(T).unwrap();
    // actor is now dead; the next request must produce an error
    std::thread::sleep(Duration::from_millis(50));
    let err = me.request(&quitter, 2u32).receive_msg(T);
    assert!(err.is_err(), "expected error, got {err:?}");
    sys.shutdown();
}

#[test]
fn behavior_change_unstashes() {
    let sys = sys();
    // starts only understanding `Go`, stashes u32s, then switches
    #[derive(Clone, Copy)]
    struct Go;
    let actor = sys.spawn(|_| {
        Behavior::new().on(move |ctx, _: &Go| {
            ctx.become_(Behavior::new().on(|_ctx, &x: &u32| reply(x + 1)));
            no_reply()
        })
    });
    let me = sys.scoped();
    let pending = me.request(&actor, 10u32); // stashed: no handler yet
    std::thread::sleep(Duration::from_millis(50));
    me.send(&actor, Go);
    // after the behavior change the stashed request is replayed
    assert_eq!(pending.receive::<u32>(T).unwrap(), 11);
    sys.shutdown();
}

/// Regression test for batched `resume` vs the stash contract: a behavior
/// change that replays stashed envelopes must run them before younger
/// messages that were already drained into the same batch snapshot. The
/// single worker is held busy so `1`, `Go`, `2` all land in one batch;
/// processing `Go` unstashes `1`, and the fix splices the remainder (`2`)
/// back behind it — without it, `2` runs before the replayed `1`.
#[test]
fn stash_replay_precedes_batch_remainder() {
    use std::sync::Mutex;
    use std::time::Instant;
    let sys = ActorSystem::new(SystemConfig::default().with_threads(1));
    #[derive(Clone, Copy)]
    struct Go;
    let seen = Arc::new(Mutex::new(Vec::<u32>::new()));
    let s = seen.clone();
    let actor = sys.spawn(move |_| {
        let s = s.clone();
        Behavior::new().on(move |ctx, _: &Go| {
            let s = s.clone();
            ctx.become_(Behavior::new().on(move |_ctx, &x: &u32| {
                s.lock().unwrap().push(x);
                no_reply()
            }));
            no_reply()
        })
    });
    let gate = sys.spawn(|_| {
        Behavior::new().on(|_ctx, &ms: &u64| {
            std::thread::sleep(Duration::from_millis(ms));
            no_reply()
        })
    });
    let me = sys.scoped();
    // occupy the lone worker so the three sends below queue up into a
    // single batch for the actor's next slice
    me.send(&gate, 200u64);
    std::thread::sleep(Duration::from_millis(50));
    me.send(&actor, 1u32); // no handler yet: stashed
    me.send(&actor, Go); // unstashes 1 mid-batch
    me.send(&actor, 2u32); // batch remainder — must run after the replay
    let deadline = Instant::now() + T;
    loop {
        let v = seen.lock().unwrap().clone();
        if v.len() == 2 {
            assert_eq!(v, vec![1, 2], "stash replay overtaken by younger batch message");
            break;
        }
        assert!(Instant::now() < deadline, "timed out waiting for both messages; saw {v:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
    sys.shutdown();
}

#[test]
fn monitor_receives_down() {
    let sys = sys();
    let victim = sys.spawn(|_| {
        Behavior::new().on(|ctx, _: &u32| {
            ctx.quit(ExitReason::Error("boom".into()));
            no_reply()
        })
    });
    let (tx, rx) = std::sync::mpsc::channel::<Down>();
    let v2 = victim.clone();
    let _watcher = sys.spawn(move |ctx| {
        ctx.monitor(&v2);
        Behavior::new().on(move |_ctx, d: &Down| {
            tx.send(d.clone()).unwrap();
            no_reply()
        })
    });
    std::thread::sleep(Duration::from_millis(50));
    let me = sys.scoped();
    me.send(&victim, 1u32);
    let down = rx.recv_timeout(T).unwrap();
    assert_eq!(down.source, victim.id());
    assert_eq!(down.reason, ExitReason::Error("boom".into()));
    sys.shutdown();
}

#[test]
fn link_propagates_abnormal_exit() {
    let sys = sys();
    let a = sys.spawn(|_| {
        Behavior::new().on(|ctx, _: &u32| {
            ctx.quit(ExitReason::Error("die".into()));
            no_reply()
        })
    });
    let a2 = a.clone();
    let b = sys.spawn(move |ctx| {
        ctx.link_to(&a2);
        Behavior::new().on(|_ctx, &x: &i64| reply(x))
    });
    std::thread::sleep(Duration::from_millis(50));
    let me = sys.scoped();
    me.send(&a, 1u32);
    std::thread::sleep(Duration::from_millis(100));
    // b should have died with its link partner
    let err = me.request(&b, 5i64).receive_msg(T);
    assert!(err.is_err(), "linked actor should be dead, got {err:?}");
    sys.shutdown();
}

#[test]
fn trapped_exit_is_delivered_as_message() {
    let sys = sys();
    let a = sys.spawn(|_| {
        Behavior::new().on(|ctx, _: &u32| {
            ctx.quit(ExitReason::Error("die".into()));
            no_reply()
        })
    });
    let (tx, rx) = std::sync::mpsc::channel::<Exit>();
    let a2 = a.clone();
    let _b = sys.spawn(move |ctx| {
        ctx.trap_exit(true);
        ctx.link_to(&a2);
        Behavior::new().on(move |_ctx, e: &Exit| {
            tx.send(e.clone()).unwrap();
            no_reply()
        })
    });
    std::thread::sleep(Duration::from_millis(50));
    sys.scoped().send(&a, 1u32);
    let exit = rx.recv_timeout(T).unwrap();
    assert_eq!(exit.reason, ExitReason::Error("die".into()));
    sys.shutdown();
}

#[test]
fn panicking_handler_terminates_actor_not_system() {
    let sys = sys();
    let bomb = sys.spawn(|_| {
        Behavior::new().on(|_ctx, _: &u32| -> Reply { panic!("kaboom") })
    });
    let me = sys.scoped();
    let r = me.request(&bomb, 1u32).receive_msg(T);
    // either the drained-request error or a broken-promise style error
    assert!(r.is_err());
    // the system still works
    let ok = sys.spawn(|_| Behavior::new().on(|_ctx, &x: &u32| reply(x)));
    assert_eq!(me.request(&ok, 9u32).receive::<u32>(T).unwrap(), 9);
    sys.shutdown();
}

#[test]
fn request_timeout_fires() {
    let sys = sys();
    let black_hole = sys.spawn(|_| {
        Behavior::new().on(|ctx, _: &u32| {
            let _silent = ctx.make_promise();
            // deliberately leak the request by delivering nothing and
            // keeping the promise alive forever
            std::mem::forget(_silent);
            Reply::Promised
        })
    });
    let (tx, rx) = std::sync::mpsc::channel::<bool>();
    let bh = black_hole.clone();
    let _asker = sys.spawn(move |ctx| {
        let tx = tx.clone();
        ctx.request(&bh, 1u32)
            .with_timeout(Duration::from_millis(50))
            .then(move |_ctx, res| {
                tx.send(res.is_err()).unwrap();
            });
        Behavior::new()
    });
    assert!(rx.recv_timeout(T).unwrap(), "timeout must surface as error");
    sys.shutdown();
}

#[test]
fn composition_chains_two_actors() {
    let sys = sys();
    let add_one = sys.spawn(|_| Behavior::new().on(|_c, &x: &i32| reply(x + 1)));
    let double = sys.spawn(|_| Behavior::new().on(|_c, &x: &i32| reply(x * 2)));
    // double ∘ add_one : x -> (x+1)*2
    let composed = compose(&sys, double, add_one);
    let me = sys.scoped();
    assert_eq!(me.request(&composed, 20i32).receive::<i32>(T).unwrap(), 42);
    sys.shutdown();
}

#[test]
fn pipeline_chains_many() {
    let sys = sys();
    let stages: Vec<ActorRef> = (1..=4)
        .map(|k| {
            sys.spawn(move |_| Behavior::new().on(move |_c, &x: &i64| reply(x + k)))
        })
        .collect();
    let p = pipeline(&sys, &stages);
    let me = sys.scoped();
    // 0 + 1 + 2 + 3 + 4
    assert_eq!(me.request(&p, 0i64).receive::<i64>(T).unwrap(), 10);
    sys.shutdown();
}

#[test]
fn composition_propagates_errors() {
    let sys = sys();
    let fine = sys.spawn(|_| Behavior::new().on(|_c, &x: &i32| reply(x)));
    let broken = sys.spawn(|_| {
        Behavior::new().on(|_c, _: &i32| reply_msg(Message::new(ErrorMsg::new("stage failed"))))
    });
    let composed = compose(&sys, fine, broken);
    let me = sys.scoped();
    let r = me.request(&composed, 1i32).receive_msg(T);
    assert!(r.is_err());
    assert!(r.unwrap_err().reason.contains("stage failed"));
    sys.shutdown();
}

#[test]
fn delegation_forwards_original_requester() {
    let sys = sys();
    let worker = sys.spawn(|_| Behavior::new().on(|_c, &x: &u32| reply(x * 10)));
    let w2 = worker.clone();
    let front = sys.spawn(move |_| {
        let w = w2.clone();
        Behavior::new().on(move |ctx, &x: &u32| {
            ctx.delegate(&w, Message::new(x + 1));
            Reply::Promised
        })
    });
    let me = sys.scoped();
    // front delegates to worker: (4+1)*10
    assert_eq!(me.request(&front, 4u32).receive::<u32>(T).unwrap(), 50);
    sys.shutdown();
}

#[test]
fn spawn_storm_and_fanin() {
    let sys = sys();
    let n = 500usize;
    let counter = Arc::new(AtomicUsize::new(0));
    let me = sys.scoped();
    let mut workers = Vec::new();
    for i in 0..n {
        let c = counter.clone();
        workers.push(sys.spawn(move |_| {
            let c = c.clone();
            Behavior::new().on(move |_ctx, &x: &usize| {
                c.fetch_add(1, Ordering::SeqCst);
                reply(x + i)
            })
        }));
    }
    let pending: Vec<_> = workers
        .iter()
        .map(|w| me.request(w, 1000usize))
        .collect();
    let mut sum = 0usize;
    for p in pending {
        sum += p.receive::<usize>(T).unwrap();
    }
    assert_eq!(counter.load(Ordering::SeqCst), n);
    assert_eq!(sum, n * 1000 + n * (n - 1) / 2);
    sys.shutdown();
}

#[test]
fn registry_roundtrip() {
    let sys = sys();
    let a = sys.spawn_opts(
        |_| Behavior::new().on(|_c, &x: &u8| reply(x)),
        SpawnOptions::named("echo"),
    );
    let found = sys.registry().get("echo").unwrap();
    assert_eq!(found.id(), a.id());
    assert!(sys.registry().get("nope").is_none());
    sys.shutdown();
}

#[test]
fn lazy_actors_initialize_on_first_message() {
    let sys = sys();
    let initialized = Arc::new(AtomicUsize::new(0));
    let i2 = initialized.clone();
    let lazy = sys.spawn_opts(
        move |_ctx| {
            i2.fetch_add(1, Ordering::SeqCst);
            Behavior::new().on(|_c, &x: &u32| reply(x))
        },
        SpawnOptions::lazy(),
    );
    std::thread::sleep(Duration::from_millis(50));
    assert_eq!(initialized.load(Ordering::SeqCst), 0, "must not init eagerly");
    let me = sys.scoped();
    assert_eq!(me.request(&lazy, 5u32).receive::<u32>(T).unwrap(), 5);
    assert_eq!(initialized.load(Ordering::SeqCst), 1);
    sys.shutdown();
}

#[test]
fn sequential_state_via_move_closure() {
    let sys = sys();
    // actors can hold state in their handler closures
    let counter_actor = sys.spawn(|_| {
        let mut count = 0u64;
        Behavior::new().on(move |_c, _: &()| {
            count += 1;
            reply(count)
        })
    });
    let me = sys.scoped();
    for expect in 1..=10u64 {
        assert_eq!(me.request(&counter_actor, ()).receive::<u64>(T).unwrap(), expect);
    }
    sys.shutdown();
}
