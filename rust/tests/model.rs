//! Deterministic model-checking suite for the unsafe messaging core.
//!
//! Every test explores *all* distinguishable interleavings (within the
//! checker's documented bounds — see STATIC_ANALYSIS.md) of a small model
//! program built from the production primitives, and asserts an invariant
//! that must hold on every schedule: no lost message, no lost wakeup, no
//! double delivery. Two kinds of test prove the checker itself works:
//! self-tests pinning exact exploration counts, and `#[should_panic]`
//! models with a deliberately weakened ordering whose counterexample the
//! checker must find.
//!
//! Historical bugs replayed here as checked models:
//! * the sender-`schedule()` vs `resume` Dekker handshake (the AcqRel CAS
//!   lost-wakeup fixed in PR 3 — `dekker_without_seqcst_fence_is_caught`
//!   proves the weakened ordering is caught, and the production ordering
//!   passes exhaustively);
//! * `Mailbox::close` vs in-flight `enqueue` (the close-snapshot drain);
//! * Chase–Lev `steal` vs `take` on a one-element deque and `steal` vs
//!   buffer growth;
//! * parker token loss (the seed scheduler's 10 ms-poll papered-over bug);
//! * `Event::poll`/`wait` lock-free fast path vs `complete`;
//! * `FutureSlot` reply-`resolve` vs reaper-timeout `resolve` — the
//!   Pending→Done claim must be atomic for exactly-once delivery.

#![cfg(feature = "model")]
// invariants below are written in their natural "never (bad shape)" form
#![allow(clippy::nonminimal_bool)]

use caf_ocl::actor::envelope::Envelope;
use caf_ocl::actor::mailbox::{EnqueueResult, Mailbox};
use caf_ocl::actor::message::Message;
use caf_ocl::concurrent::model::{self, Builder};
use caf_ocl::concurrent::{CountedQueue, Steal, WorkDeque};
use caf_ocl::loom_types::{fence, AtomicBool, AtomicU64, AtomicU8, Ordering};
use caf_ocl::runtime::event::Event;
use std::sync::{Arc, Mutex};

fn env(tag: u32) -> Envelope {
    Envelope::asynchronous(None, Message::new(tag))
}

fn tag(e: &Envelope) -> u32 {
    *e.msg.downcast_ref::<u32>().expect("test envelope carries a u32")
}

// ---------------------------------------------------------------------------
// Checker self-tests

/// Two threads, two (dependent) ops each: the schedule space is exactly
/// C(4,2) = 6 interleavings, and the checker must explore each exactly
/// once — no duplicates, nothing pruned (same-location ops never commute).
#[test]
fn self_test_two_threads_two_ops_is_exactly_six_interleavings() {
    let report = model::check(|| {
        let a = Arc::new(AtomicU64::new(0));
        let a2 = a.clone();
        let t = model::thread::spawn(move || {
            a2.store(1, Ordering::Relaxed);
            a2.store(2, Ordering::Relaxed);
        });
        a.store(3, Ordering::Relaxed);
        a.store(4, Ordering::Relaxed);
        t.join().expect("model thread");
    });
    assert_eq!(report.completed, 6, "expected exactly 6 interleavings");
    assert_eq!(report.pruned, 0, "dependent ops must not be pruned");
}

/// Stores to *independent* locations commute: of the two schedules, sleep
/// sets must prune one. With pruning disabled both run.
#[test]
fn self_test_sleep_sets_prune_independent_stores() {
    let run = |sleep_sets: bool| {
        let mut b = Builder::new();
        b.sleep_sets = sleep_sets;
        b.check(|| {
            let x = Arc::new(AtomicU64::new(0));
            let y = Arc::new(AtomicU64::new(0));
            let y2 = y.clone();
            let t = model::thread::spawn(move || {
                y2.store(1, Ordering::Relaxed);
            });
            x.store(1, Ordering::Relaxed);
            t.join().expect("model thread");
        })
    };
    let with = run(true);
    assert_eq!((with.completed, with.pruned), (1, 1));
    let without = run(false);
    assert_eq!((without.completed, without.pruned), (2, 0));
}

/// The happens-before vault must flag the textbook data race: two threads
/// mutating a plain cell with no synchronization at all.
#[test]
#[should_panic(expected = "data race")]
fn self_test_race_detector_flags_unsynchronized_counter() {
    use caf_ocl::loom_types::UnsafeCell;
    model::check(|| {
        let c = Arc::new(UnsafeCell::new(0u64));
        let c2 = c.clone();
        let t = model::thread::spawn(move || {
            c2.with_mut(|p| unsafe { *p += 1 });
        });
        c.with_mut(|p| unsafe { *p += 1 });
        t.join().expect("model thread");
    });
}

/// RMW atomicity: concurrent `fetch_add`s never lose an increment on any
/// schedule.
#[test]
fn rmw_increments_are_never_lost() {
    model::check(|| {
        let c = Arc::new(AtomicU64::new(0));
        let c2 = c.clone();
        let t = model::thread::spawn(move || {
            c2.fetch_add(1, Ordering::Relaxed);
            c2.fetch_add(1, Ordering::Relaxed);
        });
        c.fetch_add(1, Ordering::Relaxed);
        c.fetch_add(1, Ordering::Relaxed);
        t.join().expect("model thread");
        assert_eq!(c.load(Ordering::Relaxed), 4, "lost increment");
    });
}

/// Store-buffering litmus, relaxed: the checker's weak-memory modeling
/// must reach the (0, 0) outcome that SC interleaving alone cannot.
#[test]
fn store_buffering_relaxed_observes_both_zero() {
    let outcomes = Arc::new(Mutex::new(std::collections::HashSet::new()));
    let outcomes2 = outcomes.clone();
    model::check(move || {
        let x = Arc::new(AtomicU64::new(0));
        let y = Arc::new(AtomicU64::new(0));
        let (x2, y2) = (x.clone(), y.clone());
        let r1 = Arc::new(AtomicU64::new(u64::MAX));
        let r1w = r1.clone();
        let t = model::thread::spawn(move || {
            x2.store(1, Ordering::Relaxed);
            let v = y2.load(Ordering::Relaxed);
            r1w.store(v, Ordering::Relaxed);
        });
        y.store(1, Ordering::Relaxed);
        let r2 = x.load(Ordering::Relaxed);
        t.join().expect("model thread");
        let r1 = r1.load(Ordering::Relaxed);
        outcomes2
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert((r1, r2));
    });
    let seen = outcomes.lock().unwrap_or_else(|p| p.into_inner());
    assert!(
        seen.contains(&(0, 0)),
        "weak memory must allow the (0,0) store-buffering outcome; saw {seen:?}"
    );
}

/// Store-buffering litmus, SeqCst: the single total order forbids (0, 0).
#[test]
fn store_buffering_seqcst_forbids_both_zero() {
    model::check(|| {
        let x = Arc::new(AtomicU64::new(0));
        let y = Arc::new(AtomicU64::new(0));
        let (x2, y2) = (x.clone(), y.clone());
        let r1 = Arc::new(AtomicU64::new(u64::MAX));
        let r1w = r1.clone();
        let t = model::thread::spawn(move || {
            x2.store(1, Ordering::SeqCst);
            let v = y2.load(Ordering::SeqCst);
            r1w.store(v, Ordering::Relaxed);
        });
        y.store(1, Ordering::SeqCst);
        let r2 = x.load(Ordering::SeqCst);
        t.join().expect("model thread");
        let r1 = r1.load(Ordering::Relaxed);
        assert!(
            !(r1 == 0 && r2 == 0),
            "SeqCst store-buffering must not observe (0,0)"
        );
    });
}

// ---------------------------------------------------------------------------
// The Dekker handshake: sender `schedule()` vs consumer `resume` exit

const IDLE: u8 = 0;
const SCHEDULED: u8 = 1;
const RUNNING: u8 = 2;

/// One slice of the production protocol, inlined against a real [`Mailbox`]:
/// the consumer holds RUNNING, drains, stores IDLE, and re-checks behind a
/// SeqCst fence; the sender enqueues and CASes IDLE→SCHEDULED on
/// `NeedsSchedule`. `with_fence` toggles the production fence so the
/// weakened variant below can prove the checker finds the lost wakeup.
fn dekker_slice(with_fence: bool) {
    let mb = Arc::new(Mailbox::new());
    let state = Arc::new(AtomicU8::new(RUNNING));
    let (mb2, st2) = (mb.clone(), state.clone());
    let sender = model::thread::spawn(move || {
        if mb2.enqueue(env(7), false) == EnqueueResult::NeedsSchedule {
            // pairs with: cell.rs::resume (IDLE store → SeqCst fence →
            // recheck) — mirrored here from cell.rs::schedule
            let _ = st2.compare_exchange(IDLE, SCHEDULED, Ordering::SeqCst, Ordering::SeqCst);
        }
    });
    // consumer slice: drain, then the resume-exit protocol
    while mb.dequeue().is_some() {}
    if mb.is_empty() {
        state.store(IDLE, Ordering::Release);
        if with_fence {
            // pairs with: cell.rs::schedule (the sender's SeqCst CAS)
            fence(Ordering::SeqCst);
        }
        if !mb.is_empty() {
            let _ = state.compare_exchange(IDLE, SCHEDULED, Ordering::SeqCst, Ordering::SeqCst);
        }
    } else {
        state.store(SCHEDULED, Ordering::Release);
    }
    sender.join().expect("model thread");
    // the lost-wakeup shape: a message sits in the mailbox while the actor
    // is IDLE and nobody committed to scheduling it
    let pending = !mb.is_empty();
    let st = state.load(Ordering::SeqCst);
    assert!(
        !(pending && st == IDLE),
        "lost wakeup: message queued but actor IDLE and unscheduled"
    );
}

/// The production ordering (SeqCst CAS + SeqCst fence) survives every
/// interleaving — the PR 3 lost-wakeup fix, now pinned exhaustively.
#[test]
fn dekker_resume_schedule_handshake_never_loses_wakeup() {
    model::check(|| dekker_slice(true));
}

/// Dropping the fence re-introduces the bug: the consumer's recheck can
/// read a stale count of 0 while the sender's CAS reads RUNNING — neither
/// side schedules. The checker must produce a counterexample, proving the
/// suite has teeth (and that the SeqCst fence is load-bearing).
#[test]
#[should_panic(expected = "counterexample")]
fn dekker_without_seqcst_fence_is_caught() {
    model::check(|| dekker_slice(false));
}

// ---------------------------------------------------------------------------
// Mailbox close vs in-flight enqueue

/// A producer's accepted envelope is always drained by a racing `close`;
/// a rejected producer gets the envelope back and `close` drains nothing.
#[test]
fn mailbox_close_vs_enqueue_never_drops_accepted() {
    model::check(|| {
        let mb = Arc::new(Mailbox::new());
        let accepted = Arc::new(AtomicBool::new(false));
        let (mb2, acc2) = (mb.clone(), accepted.clone());
        let producer = model::thread::spawn(move || {
            let r = mb2.enqueue(env(7), false);
            acc2.store(r != EnqueueResult::Closed, Ordering::SeqCst);
        });
        let drained = mb.close();
        producer.join().expect("model thread");
        if accepted.load(Ordering::SeqCst) {
            assert_eq!(drained.len(), 1, "accepted envelope lost by close");
            assert_eq!(tag(&drained[0]), 7);
        } else {
            assert!(drained.is_empty(), "rejected envelope appeared in drain");
        }
        assert!(mb.is_empty(), "count leaked past close");
    });
}

// ---------------------------------------------------------------------------
// MPSC queue

/// Two producers, one consumer: every accepted value arrives exactly once,
/// across every interleaving of the two-step (swap, link) Vyukov push.
#[test]
fn mpsc_two_producers_deliver_exactly_once() {
    model::check(|| {
        let q = Arc::new(CountedQueue::new());
        let mut handles = Vec::new();
        for p in 0..2u64 {
            let q = q.clone();
            handles.push(model::thread::spawn(move || {
                q.push(p).expect("queue is not closed");
            }));
        }
        let mut got = [false; 2];
        let mut n = 0;
        while n < 2 {
            match q.pop() {
                Some(v) => {
                    assert!(!got[v as usize], "value {v} delivered twice");
                    got[v as usize] = true;
                    n += 1;
                }
                None => caf_ocl::loom_types::thread_yield(),
            }
        }
        for h in handles {
            h.join().expect("model thread");
        }
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    });
}

// ---------------------------------------------------------------------------
// Chase–Lev deque

/// The one-element endgame: owner `take` races a thief `steal`; exactly
/// one of them gets the element on every schedule.
#[test]
fn deque_take_vs_steal_one_element_exactly_once() {
    model::check(|| {
        let d = Arc::new(WorkDeque::with_capacity(2));
        // single-threaded setup: owner contract trivially holds
        unsafe { d.push(7u64) };
        let d2 = d.clone();
        let stole = Arc::new(AtomicBool::new(false));
        let stole2 = stole.clone();
        let thief = model::thread::spawn(move || {
            if let Steal::Success(v) = d2.steal() {
                assert_eq!(v, 7);
                stole2.store(true, Ordering::SeqCst);
            }
        });
        // main is the owner thread for the whole execution
        let took = unsafe { d.take() };
        thief.join().expect("model thread");
        let wins = took.is_some() as u32 + stole.load(Ordering::SeqCst) as u32;
        assert_eq!(wins, 1, "the last element must go to exactly one side");
        assert!(d.is_empty());
    });
}

/// `steal` racing the owner's buffer growth: the thief's in-flight pointer
/// into the old buffer stays valid (retire list) and no element is lost or
/// duplicated across the copy.
#[test]
fn deque_steal_vs_grow_loses_nothing() {
    model::check(|| {
        let d = Arc::new(WorkDeque::with_capacity(2));
        unsafe {
            d.push(0u64);
            d.push(1u64);
        }
        let d2 = d.clone();
        let stolen = Arc::new(AtomicU64::new(u64::MAX));
        let stolen2 = stolen.clone();
        let thief = model::thread::spawn(move || {
            if let Steal::Success(v) = d2.steal() {
                stolen2.store(v, Ordering::SeqCst);
            }
        });
        unsafe { d.push(2u64) }; // capacity 2 is full — this grows
        thief.join().expect("model thread");
        let mut seen = [0u32; 3];
        let s = stolen.load(Ordering::SeqCst);
        if s != u64::MAX {
            seen[s as usize] += 1;
        }
        while let Some(v) = unsafe { d.take() } {
            seen[v as usize] += 1;
        }
        assert_eq!(seen, [1, 1, 1], "element lost or duplicated across grow");
    });
}

// ---------------------------------------------------------------------------
// Parker

/// The token protocol: an unpark racing ahead of (or into) the park is
/// never lost — `park` always returns. A broken parker shows up as a
/// deadlock counterexample (main blocked forever after the child exits).
#[test]
fn parker_unpark_before_or_during_park_is_never_lost() {
    use caf_ocl::concurrent::Parker;
    model::check(|| {
        let p = Arc::new(Parker::new());
        let p2 = p.clone();
        let t = model::thread::spawn(move || {
            p2.unpark();
        });
        p.park(); // must consume the (possibly banked) token on every schedule
        t.join().expect("model thread");
    });
}

// ---------------------------------------------------------------------------
// FutureSlot: reply resolve vs reaper timeout

const PENDING: u8 = 0;
const DONE: u8 = 1;

/// One side's attempt to resolve the slot. The production shape
/// (`ask.rs::FutureSlot::resolve` — check-and-transition under one mutex
/// hold, modeled as a single CAS) claims atomically; the weakened twin
/// splits it into a check-then-store with a TOCTOU window.
fn future_slot_claim(atomic_claim: bool, state: &AtomicU8, delivered: &AtomicU64) {
    let won = if atomic_claim {
        state
            .compare_exchange(PENDING, DONE, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    } else if state.load(Ordering::Acquire) == PENDING {
        state.store(DONE, Ordering::Release);
        true
    } else {
        false
    };
    if won {
        delivered.fetch_add(1, Ordering::AcqRel);
    }
}

/// One slice of the `ask` endgame: the reply delivery and the
/// `PendingReaper`'s timeout failure race to transition the same slot
/// Pending→Done, and the loser must observe Done and back off — hooks run
/// once, `wait` wakes once.
fn future_slot_slice(atomic_claim: bool) {
    let state = Arc::new(AtomicU8::new(PENDING));
    let delivered = Arc::new(AtomicU64::new(0));
    let (st2, dl2) = (state.clone(), delivered.clone());
    let timeout = model::thread::spawn(move || {
        future_slot_claim(atomic_claim, &st2, &dl2);
    });
    // the reply side, on the main thread
    future_slot_claim(atomic_claim, &state, &delivered);
    timeout.join().expect("model thread");
    assert_eq!(state.load(Ordering::SeqCst), DONE, "slot left Pending");
    assert_eq!(
        delivered.load(Ordering::SeqCst),
        1,
        "FutureSlot must resolve exactly once: reply or timeout, never both"
    );
}

/// The production claim survives every interleaving: exactly one of
/// reply/timeout delivers, the other sees Done and backs off.
#[test]
fn future_slot_resolve_vs_timeout_exactly_once() {
    model::check(|| future_slot_slice(true));
}

/// Splitting the claim (dropping the mutex for a naive flag check) opens
/// the window where both sides observe Pending and both deliver. The
/// checker must find that double delivery, proving the atomic claim is
/// load-bearing.
#[test]
#[should_panic(expected = "counterexample")]
fn future_slot_split_claim_double_delivery_is_caught() {
    model::check(|| future_slot_slice(false));
}

// ---------------------------------------------------------------------------
// Event fast path

/// `poll`'s lock-free fast path vs a concurrent `complete`: whenever the
/// done flag is visible the result must already be consistent, and `wait`
/// always returns the completion (never times out, never hangs).
#[test]
fn event_poll_wait_fast_path_consistent() {
    model::check(|| {
        let e = Event::new();
        let e2 = e.clone();
        let t = model::thread::spawn(move || {
            e2.complete();
        });
        if let Some(r) = e.poll() {
            assert_eq!(r, Ok(()), "fast path saw done flag before the result");
        }
        let r = e.wait(std::time::Duration::from_secs(3600));
        assert_eq!(r, Ok(()), "wait missed the completion");
        t.join().expect("model thread");
    });
}
