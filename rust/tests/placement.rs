//! Placement & batching tests: replica fan-out across simulated devices,
//! affinity routing of device-resident refs, least-inflight and cost-aware
//! selection, batcher window triggers (count, capacity, timer, shutdown,
//! zero-delay synchronous flush), shape-classed sub-batching (interleaved
//! request shapes and genuinely multi-shape kernels coalesce per class
//! with exact slices), the batcher's occupancy gauge, the fallible
//! discovery paths (`try_platform`, empty inventory), and the
//! fault-injection suite — a replica killed mid-burst (batched or not)
//! must never lose a request (reply or routed error, exactly once), its
//! stale routed-depth estimate and occupancy must drain,
//! `RespawnPolicy::Always` must restore N-way distribution, and
//! `RespawnPolicy::Limited` must retire a crash-looping replica after its
//! budget.
//!
//! The pipeline section covers the placement tier's pipeline unit
//! (`PipelineSpawn`): whole-pipeline routing (a request's stage launches
//! never split across devices), per-request ref pairing (the `MemRefSlot`
//! regression), interleaved-vs-lock-step stage scheduling (via
//! `ExecStats::inflight_peak`), whole-replica supervision (one stage death
//! kills and respawns the entire replica pipeline), opt-in migration of
//! stranded refs off a dead replica, a mid-burst kill under mixed load
//! (exactly-once resolution), and the WAH indexing pipeline end-to-end
//! through the placement tier with a chaos kill.
//!
//! Everything runs on host-emulated kernels (`emu=` manifest extras) over
//! simulated devices, so the suite needs no artifacts and no real XLA
//! backend — it is tier-1 on both feature configurations.

use caf_ocl::actor::*;
use caf_ocl::opencl::*;
use caf_ocl::runtime::client::PadModel;
use std::sync::Arc;
use std::time::Duration;

const T: Duration = Duration::from_secs(30);
const CAP: usize = 1024;
/// Second-input capacity of the multi-shape `scale_copy_u32` kernel.
const HALF: usize = CAP / 2;

/// Write a stub-backend manifest (host-emulated kernels) into a per-test
/// temp dir.
fn stub_artifacts(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!(
        "caf-ocl-placement-{}-{tag}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        format!(
            "copy_u32|emu|u32:{CAP}|u32:{CAP}|emu=identity n={CAP}\n\
             vadd_u32|emu|u32:{CAP} u32:{CAP}|u32:{CAP}|emu=add n={CAP}\n\
             scale_copy_u32|emu|u32:{CAP} u32:{HALF}|u32:{CAP}|emu=identity n={CAP}\n"
        ),
    )
    .unwrap();
    dir.to_string_lossy().to_string()
}

fn sim_spec(name: &str, launch: Duration) -> DeviceSpec {
    DeviceSpec {
        name: name.to_string(),
        kind: DeviceKind::Gpu,
        info: DeviceInfo {
            compute_units: 4,
            max_work_items_per_cu: 1024,
        },
        pad: if launch.is_zero() {
            None
        } else {
            Some(PadModel {
                launch,
                bytes_per_sec: 0.0,
                compute_scale: 1.0,
                busy_wait: false,
            })
        },
    }
}

/// An actor system with `n` simulated devices and the stub manifest.
fn system(tag: &str, n: usize, launch: Duration) -> (ActorSystem, Arc<Manager>) {
    let sys = ActorSystem::new(
        SystemConfig::default()
            .with_threads(4)
            .with_artifacts_dir(stub_artifacts(tag)),
    );
    let specs = (0..n).map(|i| sim_spec(&format!("sim-{i}"), launch)).collect();
    let mgr = Manager::load_with(&sys, specs);
    (sys, mgr)
}

fn teardown(sys: ActorSystem, mgr: Arc<Manager>) {
    mgr.stop_devices();
    sys.shutdown();
}

fn launched_on(mgr: &Manager, dev: usize) -> u64 {
    mgr.device(dev).unwrap().queue.stats().launched()
}

fn spawn_copy(mgr: &Manager, placement: Placement) -> ActorRef {
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    mgr.spawn_cl(
        KernelSpawn::new(program, "copy_u32")
            .inputs(Mode::Val, 1)
            .output(Mode::Val)
            .placement(placement),
    )
    .unwrap()
}

// --- fallible discovery (satellites) -----------------------------------

#[test]
fn discovery_failure_is_an_err_not_an_abort() {
    let sys = ActorSystem::new(
        SystemConfig::default()
            .with_threads(2)
            .with_artifacts_dir("/nonexistent/caf-ocl-no-artifacts"),
    );
    let mgr = Manager::load(&sys);
    assert!(mgr.try_platform().is_err());
    assert!(!mgr.discovered());
    // every accessor surfaces the error instead of aborting the process
    assert!(mgr.default_device().is_err());
    assert!(mgr.device(0).is_err());
    assert!(mgr.spawn_simple("copy_u32", Mode::Val, Mode::Val).is_err());
    // a failed discovery is retryable, not latched
    assert!(mgr.try_platform().is_err());
    sys.shutdown();
}

#[test]
fn empty_device_inventory_is_a_clean_err() {
    let sys = ActorSystem::new(
        SystemConfig::default()
            .with_threads(2)
            .with_artifacts_dir(stub_artifacts("empty")),
    );
    let mgr = Manager::load_with(&sys, vec![]);
    // discovery itself succeeds (manifest is fine), the inventory is empty
    assert!(mgr.try_platform().is_ok());
    let e = mgr.default_device().unwrap_err();
    assert!(e.to_string().contains("empty"), "got: {e}");
    assert!(mgr.device(0).is_err());
    assert!(mgr.spawn_simple("copy_u32", Mode::Val, Mode::Val).is_err());
    teardown(sys, mgr);
}

#[test]
fn build_timeout_is_configurable() {
    let cfg = SystemConfig::default();
    assert_eq!(cfg.build_timeout, Duration::from_secs(300));
    let cfg = cfg.with_build_timeout(Duration::from_secs(5));
    assert_eq!(cfg.build_timeout, Duration::from_secs(5));
    let sys = ActorSystem::new(cfg.with_threads(2).with_artifacts_dir(stub_artifacts("bt")));
    let mgr = Manager::load(&sys);
    assert_eq!(mgr.build_timeout(), Duration::from_secs(5));
    // programs still build fine under the tighter deadline
    assert!(mgr.create_kernel_program("copy_u32").is_ok());
    teardown(sys, mgr);
}

// --- placement ---------------------------------------------------------

#[test]
fn pinned_device_placement_runs_there() {
    let (sys, mgr) = system("pinned", 2, Duration::ZERO);
    let worker = spawn_copy(&mgr, Placement::Device(1));
    let me = sys.scoped();
    let data: Vec<u32> = (0..CAP as u32).collect();
    let out: Vec<u32> = me.request(&worker, data.clone()).receive(T).unwrap();
    assert_eq!(out, data);
    assert_eq!(launched_on(&mgr, 0), 0);
    assert_eq!(launched_on(&mgr, 1), 1);
    teardown(sys, mgr);
}

#[test]
fn round_robin_distributes_requests() {
    let (sys, mgr) = system("rr", 2, Duration::ZERO);
    let worker = spawn_copy(&mgr, Placement::replicated(PlacementPolicy::RoundRobin));
    let me = sys.scoped();
    for i in 0..8u32 {
        let data = vec![i; CAP];
        let out: Vec<u32> = me.request(&worker, data.clone()).receive(T).unwrap();
        assert_eq!(out, data);
    }
    assert_eq!(launched_on(&mgr, 0), 4);
    assert_eq!(launched_on(&mgr, 1), 4);
    teardown(sys, mgr);
}

#[test]
fn least_inflight_spreads_a_burst_across_devices() {
    // acceptance: a burst through Replicated + least-inflight lands on
    // >= 2 simulated devices, asserted via per-device ExecStats.launched
    let (sys, mgr) = system("li", 2, Duration::from_millis(25));
    let worker = spawn_copy(&mgr, Placement::replicated(PlacementPolicy::LeastInflight));
    let me = sys.scoped();
    let pending: Vec<_> = (0..8u32)
        .map(|i| me.request(&worker, vec![i; CAP]))
        .collect();
    for (i, p) in pending.into_iter().enumerate() {
        let out: Vec<u32> = p.receive(T).unwrap();
        assert_eq!(out, vec![i as u32; CAP]);
    }
    let (l0, l1) = (launched_on(&mgr, 0), launched_on(&mgr, 1));
    assert_eq!(l0 + l1, 8, "every request must launch exactly once");
    assert!(
        l0 >= 2 && l1 >= 2,
        "burst must spread across both devices (got {l0}/{l1})"
    );
    teardown(sys, mgr);
}

#[test]
fn affinity_routes_ref_args_to_their_device() {
    // producer pinned to device 1 emits device-resident refs; the
    // replicated consumer must follow the data, never device 0
    let (sys, mgr) = system("affinity", 2, Duration::ZERO);
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let producer = mgr
        .spawn_cl(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Ref)
                .placement(Placement::Device(1)),
        )
        .unwrap();
    let consumer_prog = mgr.create_kernel_program("copy_u32").unwrap();
    let consumer = mgr
        .spawn_cl(
            KernelSpawn::new(consumer_prog, "copy_u32")
                .inputs(Mode::Ref, 1)
                .output(Mode::Val)
                .placement(Placement::replicated(PlacementPolicy::RoundRobin)),
        )
        .unwrap();
    let me = sys.scoped();
    for i in 0..6u32 {
        let data = vec![i; CAP];
        let r: MemRef = me.request(&producer, data.clone()).receive(T).unwrap();
        assert_eq!(r.device_id(), 1);
        let out: Vec<u32> = me.request(&consumer, r).receive(T).unwrap();
        assert_eq!(out, data);
    }
    // 6 producer launches + 6 affinity-routed consumer launches, all on 1
    assert_eq!(launched_on(&mgr, 0), 0, "affinity must never cross devices");
    assert_eq!(launched_on(&mgr, 1), 12);
    teardown(sys, mgr);
}

#[test]
fn refs_on_multiple_devices_are_a_routed_error() {
    let (sys, mgr) = system("multiref", 2, Duration::ZERO);
    let mk_producer = |dev: usize| {
        let program = mgr.create_kernel_program("copy_u32").unwrap();
        mgr.spawn_cl(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Ref)
                .placement(Placement::Device(dev)),
        )
        .unwrap()
    };
    let p0 = mk_producer(0);
    let p1 = mk_producer(1);
    let program = mgr.create_kernel_program("vadd_u32").unwrap();
    let adder = mgr
        .spawn_cl(
            KernelSpawn::new(program, "vadd_u32")
                .inputs(Mode::Ref, 2)
                .output(Mode::Val)
                .placement(Placement::replicated(PlacementPolicy::RoundRobin)),
        )
        .unwrap();
    let me = sys.scoped();
    let r0: MemRef = me.request(&p0, vec![1u32; CAP]).receive(T).unwrap();
    let r1: MemRef = me.request(&p1, vec![2u32; CAP]).receive(T).unwrap();
    // same-device pair works (affinity to device 1)
    let r1b: MemRef = me.request(&p1, vec![3u32; CAP]).receive(T).unwrap();
    let sum: Vec<u32> = me.request(&adder, (r1.clone(), r1b)).receive(T).unwrap();
    assert_eq!(sum, vec![5u32; CAP]);
    // cross-device pair is a routed error, not a wrong-device launch
    let err = me.request(&adder, (r0, r1)).receive_msg(T).unwrap_err();
    assert!(
        err.reason.contains("multiple devices"),
        "got: {}",
        err.reason
    );
    teardown(sys, mgr);
}

#[test]
fn replicated_pipeline_e2e_on_emulated_backend() {
    // Val -> Ref -> Val across two replicated stages: stage 1 rotates
    // devices, stage 2 follows each ref by affinity; both devices serve
    let (sys, mgr) = system("pipe", 2, Duration::ZERO);
    let p1 = mgr.create_kernel_program("copy_u32").unwrap();
    let s1 = mgr
        .spawn_cl(
            KernelSpawn::new(p1, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Ref)
                .placement(Placement::replicated(PlacementPolicy::RoundRobin)),
        )
        .unwrap();
    let p2 = mgr.create_kernel_program("copy_u32").unwrap();
    let s2 = mgr
        .spawn_cl(
            KernelSpawn::new(p2, "copy_u32")
                .inputs(Mode::Ref, 1)
                .output(Mode::Val)
                .placement(Placement::replicated(PlacementPolicy::RoundRobin)),
        )
        .unwrap();
    let me = sys.scoped();
    for i in 0..8u32 {
        let data: Vec<u32> = (0..CAP as u32).map(|x| x.wrapping_mul(i)).collect();
        let r: MemRef = me.request(&s1, data.clone()).receive(T).unwrap();
        let out: Vec<u32> = me.request(&s2, r).receive(T).unwrap();
        assert_eq!(out, data);
    }
    let (l0, l1) = (launched_on(&mgr, 0), launched_on(&mgr, 1));
    assert_eq!(l0 + l1, 16);
    assert!(l0 > 0 && l1 > 0, "both devices must serve ({l0}/{l1})");
    teardown(sys, mgr);
}

// --- fault tolerance ----------------------------------------------------

/// Inject a fault: a non-normal `Exit` terminates an actor that does not
/// trap exits, firing `Down` at its monitors — the canonical CAF failure
/// signal the dispatcher supervises replicas with.
fn kill(actor: &ActorRef) {
    actor.send_from(None, Message::new(Exit::fault("injected fault")));
}

/// Poll `f` until it holds or ~5 s elapse; returns the final verdict.
fn eventually(mut f: impl FnMut() -> bool) -> bool {
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while std::time::Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    f()
}

fn spawn_replicated_copy(mgr: &Manager, set: ReplicaSet) -> ReplicatedHandle {
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    mgr.spawn_cl_replicated(
        KernelSpawn::new(program, "copy_u32")
            .inputs(Mode::Val, 1)
            .output(Mode::Val)
            .placement(Placement::Replicated(set)),
    )
    .unwrap()
}

#[test]
fn replica_death_mid_burst_never_loses_a_request() {
    // acceptance: a replica Down never loses a routed request — every
    // request resolves with a reply or an error, exactly once, and never
    // by timeout
    let (sys, mgr) = system("death", 2, Duration::from_millis(10));
    let handle = spawn_replicated_copy(&mgr, ReplicaSet::new(PlacementPolicy::RoundRobin));
    let me = sys.scoped();
    let pending: Vec<_> = (0..16u32)
        .map(|i| me.request(&handle.actor, vec![i; CAP]))
        .collect();
    // kill replica 0 while the burst is in flight
    kill(&handle.pool.replicas()[0].facade());
    let (mut ok, mut errs) = (0usize, 0usize);
    for (i, p) in pending.into_iter().enumerate() {
        match p.receive_msg(T) {
            Ok(m) => {
                assert_eq!(m.downcast_ref::<Vec<u32>>(), Some(&vec![i as u32; CAP]));
                ok += 1;
            }
            Err(e) => {
                assert!(
                    !e.reason.contains("timed out"),
                    "request {i} was silently lost: {}",
                    e.reason
                );
                errs += 1;
            }
        }
    }
    assert_eq!(ok + errs, 16, "every request resolves exactly once");
    assert!(ok > 0, "the surviving replica must have served");
    // the dispatcher observes the Down: replica dead, depth drained
    assert!(
        eventually(|| !handle.pool.replicas()[0].is_alive()),
        "dispatcher must observe the Down"
    );
    assert_eq!(handle.pool.live_count(), 1);
    assert!(
        eventually(|| handle.pool.depth(0) == 0),
        "dead replica's stale routed count must drain (got {})",
        handle.pool.depth(0)
    );
    // post-mortem traffic routes exclusively to the survivor — no errors
    let dead_launches = launched_on(&mgr, 0);
    for i in 0..6u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    assert_eq!(
        launched_on(&mgr, 0),
        dead_launches,
        "a dead replica must stop receiving routed traffic"
    );
    teardown(sys, mgr);
}

#[test]
fn dead_replica_depth_estimate_drains_for_least_inflight() {
    // the ROADMAP bug: a dead replica's routed-but-never-launched messages
    // used to inflate its LeastInflight depth forever
    let (sys, mgr) = system("drain", 2, Duration::from_millis(5));
    let handle =
        spawn_replicated_copy(&mgr, ReplicaSet::new(PlacementPolicy::LeastInflight));
    let me = sys.scoped();
    let pending: Vec<_> = (0..8u32)
        .map(|i| me.request(&handle.actor, vec![i; CAP]))
        .collect();
    kill(&handle.pool.replicas()[0].facade());
    for p in pending {
        let _ = p.receive_msg(T); // reply or error, both fine here
    }
    assert!(eventually(|| !handle.pool.replicas()[0].is_alive()));
    assert!(
        eventually(|| handle.pool.depth(0) == 0),
        "stale routed counts must not survive the replica (got {})",
        handle.pool.depth(0)
    );
    // depth-based selection now sees a clean picture: the survivor serves
    let out: Vec<u32> = me.request(&handle.actor, vec![9; CAP]).receive(T).unwrap();
    assert_eq!(out, vec![9; CAP]);
    teardown(sys, mgr);
}

#[test]
fn respawn_restores_n_way_distribution() {
    let (sys, mgr) = system("respawn", 2, Duration::ZERO);
    let handle = spawn_replicated_copy(
        &mgr,
        ReplicaSet::new(PlacementPolicy::RoundRobin).respawn(RespawnPolicy::Always),
    );
    let me = sys.scoped();
    // pre-death sanity round
    for i in 0..4u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    let old_id = handle.pool.replicas()[0].facade().id();
    kill(&handle.pool.replicas()[0].facade());
    assert!(
        eventually(|| handle.pool.replicas()[0].respawns() >= 1),
        "RespawnPolicy::Always must rebuild the replica"
    );
    assert!(handle.pool.replicas()[0].is_alive());
    assert_ne!(
        handle.pool.replicas()[0].facade().id(),
        old_id,
        "the respawned facade is a fresh incarnation"
    );
    assert_eq!(handle.pool.live_count(), 2);
    // acceptance: respawn restores the full N-way rotation
    let (b0, b1) = (launched_on(&mgr, 0), launched_on(&mgr, 1));
    for i in 0..8u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    let (d0, d1) = (launched_on(&mgr, 0) - b0, launched_on(&mgr, 1) - b1);
    assert_eq!(d0 + d1, 8, "every request launches exactly once");
    assert_eq!(d0, 4, "respawned replica serves its full rotation share");
    assert_eq!(d1, 4);
    teardown(sys, mgr);
}

#[test]
fn stranded_refs_on_a_dead_replica_get_a_routed_error() {
    let (sys, mgr) = system("strand", 2, Duration::ZERO);
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let producer = mgr
        .spawn_cl(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Ref)
                .placement(Placement::Device(1)),
        )
        .unwrap();
    let handle = {
        let program = mgr.create_kernel_program("copy_u32").unwrap();
        mgr.spawn_cl_replicated(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Ref, 1)
                .output(Mode::Val)
                .placement(Placement::replicated(PlacementPolicy::RoundRobin)),
        )
        .unwrap()
    };
    let me = sys.scoped();
    let data = vec![5u32; CAP];
    let r: MemRef = me.request(&producer, data.clone()).receive(T).unwrap();
    assert_eq!(r.device_id(), 1);
    // affinity serves from device 1 while its replica lives
    let out: Vec<u32> = me.request(&handle.actor, r.clone()).receive(T).unwrap();
    assert_eq!(out, data);
    // kill device 1's replica: the ref is stranded on its device
    kill(&handle.pool.replicas()[1].facade());
    assert!(eventually(|| !handle.pool.replicas()[1].is_alive()));
    let err = me.request(&handle.actor, r).receive_msg(T).unwrap_err();
    assert!(err.reason.contains("down"), "got: {}", err.reason);
    // affinity-free traffic still flows via the survivor on device 0
    let out: Vec<u32> = me.request(&handle.actor, data.clone()).receive(T).unwrap();
    assert_eq!(out, data);
    teardown(sys, mgr);
}

#[test]
fn replica_subsets_span_only_the_chosen_devices() {
    let (sys, mgr) = system("subset", 3, Duration::ZERO);
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let handle = mgr
        .spawn_cl_replicated(
            KernelSpawn::new(program.clone(), "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .placement(Placement::Replicated(
                    ReplicaSet::new(PlacementPolicy::RoundRobin).on_devices(vec![0, 2]),
                )),
        )
        .unwrap();
    assert_eq!(handle.pool.replicas().len(), 2);
    let me = sys.scoped();
    for i in 0..8u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    assert_eq!(launched_on(&mgr, 1), 0, "device 1 is outside the subset");
    assert_eq!(launched_on(&mgr, 0) + launched_on(&mgr, 2), 8);
    // invalid subsets are clean spawn-time errors
    let bad = |ids: Vec<usize>| {
        mgr.spawn_cl(
            KernelSpawn::new(program.clone(), "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .placement(Placement::Replicated(
                    ReplicaSet::new(PlacementPolicy::RoundRobin).on_devices(ids),
                )),
        )
        .unwrap_err()
        .to_string()
    };
    assert!(bad(vec![7]).contains("not in the inventory"));
    assert!(bad(vec![]).contains("empty"));
    assert!(bad(vec![0, 0]).contains("twice"));
    teardown(sys, mgr);
}

#[test]
fn cost_aware_steers_small_requests_off_the_expensive_device() {
    // the Fig 7b lesson as a routed decision: device 1 carries a Phi-like
    // 30 ms dispatch pad, device 0 dispatches for free. RoundRobin pays
    // the pad on every second request; CostAware never does.
    let sys = ActorSystem::new(
        SystemConfig::default()
            .with_threads(4)
            .with_artifacts_dir(stub_artifacts("costaware")),
    );
    let specs = vec![
        sim_spec("fast", Duration::ZERO),
        sim_spec("phi-like", Duration::from_millis(30)),
    ];
    let mgr = Manager::load_with(&sys, specs);
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let worker = mgr
        .spawn_cl(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .placement(Placement::replicated(PlacementPolicy::CostAware)),
        )
        .unwrap();
    let me = sys.scoped();
    for i in 0..8u32 {
        let out: Vec<u32> = me.request(&worker, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    assert_eq!(launched_on(&mgr, 0), 8, "all requests go to the cheap device");
    assert_eq!(launched_on(&mgr, 1), 0, "the Phi-like pad is steered around");
    teardown(sys, mgr);
}

#[test]
fn empty_pipeline_build_is_an_err_not_a_panic() {
    let (sys, mgr) = system("empty-pipe", 1, Duration::ZERO);
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let err = caf_ocl::opencl::stage::PipelineBuilder::new(&mgr, program)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("at least one stage"), "got: {err}");
    teardown(sys, mgr);
}

// --- batching ----------------------------------------------------------

fn spawn_batched(
    mgr: &Manager,
    stats: Arc<FacadeStats>,
    max_requests: usize,
    max_delay: Duration,
) -> ActorRef {
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    mgr.spawn_cl(
        KernelSpawn::new(program, "copy_u32")
            .inputs(Mode::Val, 1)
            .output(Mode::Val)
            .with_stats(stats)
            .batched(BatchConfig {
                max_requests,
                max_delay,
            }),
    )
    .unwrap()
}

fn stat_launches(stats: &FacadeStats) -> u64 {
    stats.launched.load(std::sync::atomic::Ordering::Relaxed)
}

#[test]
fn batcher_coalesces_capacity_window_into_one_launch() {
    // acceptance: >= 4 sub-capacity requests fill the capacity and fuse
    // into a single launch; every requester gets its exact slice back
    let (sys, mgr) = system("batch-cap", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let worker = spawn_batched(&mgr, stats.clone(), 1000, Duration::from_secs(30));
    let me = sys.scoped();
    let quarter = CAP / 4;
    let payloads: Vec<Vec<u32>> = (0..4u32)
        .map(|i| (0..quarter as u32).map(|x| x + i * 10_000).collect())
        .collect();
    let pending: Vec<_> = payloads
        .iter()
        .map(|p| me.request(&worker, p.clone()))
        .collect();
    for (p, want) in pending.into_iter().zip(&payloads) {
        let out: Vec<u32> = p.receive(T).unwrap();
        assert_eq!(&out, want, "each requester gets its exact slice");
    }
    assert_eq!(stat_launches(&stats), 1, "4 requests must fuse into 1 launch");
    assert_eq!(launched_on(&mgr, 0), 1);
    teardown(sys, mgr);
}

#[test]
fn batcher_count_trigger_flushes_below_capacity() {
    let (sys, mgr) = system("batch-count", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let worker = spawn_batched(&mgr, stats.clone(), 3, Duration::from_secs(30));
    let me = sys.scoped();
    let payloads: Vec<Vec<u32>> = (0..3u32).map(|i| vec![i + 7; 64]).collect();
    let pending: Vec<_> = payloads
        .iter()
        .map(|p| me.request(&worker, p.clone()))
        .collect();
    for (p, want) in pending.into_iter().zip(&payloads) {
        let out: Vec<u32> = p.receive(T).unwrap();
        assert_eq!(&out, want);
    }
    assert_eq!(stat_launches(&stats), 1, "count trigger at 3 pending");
    teardown(sys, mgr);
}

#[test]
fn batcher_timer_trigger_flushes_a_partial_window() {
    let (sys, mgr) = system("batch-timer", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let worker = spawn_batched(&mgr, stats.clone(), 1000, Duration::from_millis(100));
    let me = sys.scoped();
    let a: Vec<u32> = (0..64).collect();
    let b: Vec<u32> = (100..164).collect();
    let pa = me.request(&worker, a.clone());
    let pb = me.request(&worker, b.clone());
    // neither count nor capacity triggers — only the timer can flush
    let out_a: Vec<u32> = pa.receive(T).unwrap();
    let out_b: Vec<u32> = pb.receive(T).unwrap();
    assert_eq!(out_a, a);
    assert_eq!(out_b, b);
    assert_eq!(stat_launches(&stats), 1, "timer flush must fuse both");
    teardown(sys, mgr);
}

#[test]
fn batcher_shutdown_flush_loses_no_promises() {
    let (sys, mgr) = system("batch-down", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    // window that cannot flush on its own within the test
    let worker = spawn_batched(&mgr, stats.clone(), 1000, Duration::from_secs(600));
    let me = sys.scoped();
    let a: Vec<u32> = (0..64).collect();
    let b: Vec<u32> = (200..264).collect();
    let pa = me.request(&worker, a.clone());
    let pb = me.request(&worker, b.clone());
    // let the facade admit both into the open window
    std::thread::sleep(Duration::from_millis(300));
    // terminate the facade: the dropped batcher must flush, not lose them
    worker.send_from(None, Message::new(Exit::fault("shutdown")));
    let out_a: Vec<u32> = pa.receive(T).expect("promise must survive shutdown");
    let out_b: Vec<u32> = pb.receive(T).expect("promise must survive shutdown");
    assert_eq!(out_a, a);
    assert_eq!(out_b, b);
    assert_eq!(stat_launches(&stats), 1);
    teardown(sys, mgr);
}

#[test]
fn batcher_rejects_oversized_and_mistyped_requests() {
    let (sys, mgr) = system("batch-err", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let worker = spawn_batched(&mgr, stats.clone(), 4, Duration::from_millis(20));
    let me = sys.scoped();
    let err = me
        .request(&worker, vec![0u32; CAP + 1])
        .receive_msg(T)
        .unwrap_err();
    assert!(err.reason.contains("exceeds kernel capacity"), "{}", err.reason);
    let err = me
        .request(&worker, vec![0f32; 64])
        .receive_msg(T)
        .unwrap_err();
    assert!(err.reason.contains("expected u32"), "{}", err.reason);
    // a full-capacity request still flushes alone and round-trips
    let data: Vec<u32> = (0..CAP as u32).collect();
    let out: Vec<u32> = me.request(&worker, data.clone()).receive(T).unwrap();
    assert_eq!(out, data);
    teardown(sys, mgr);
}

#[test]
fn batching_composes_with_replication() {
    let (sys, mgr) = system("batch-rep", 2, Duration::ZERO);
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let worker = mgr
        .spawn_cl(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .placement(Placement::replicated(PlacementPolicy::RoundRobin))
                .batched(BatchConfig {
                    max_requests: 2,
                    max_delay: Duration::from_millis(50),
                }),
        )
        .unwrap();
    let me = sys.scoped();
    let payloads: Vec<Vec<u32>> = (0..8u32).map(|i| vec![i; 128]).collect();
    let pending: Vec<_> = payloads
        .iter()
        .map(|p| me.request(&worker, p.clone()))
        .collect();
    for (p, want) in pending.into_iter().zip(&payloads) {
        let out: Vec<u32> = p.receive(T).unwrap();
        assert_eq!(&out, want);
    }
    // every batched launch accounted on some device, none lost
    let total = launched_on(&mgr, 0) + launched_on(&mgr, 1);
    assert!(total >= 1 && total <= 8, "got {total} launches for 8 requests");
    teardown(sys, mgr);
}

#[test]
fn batching_spawn_rejects_ref_modes() {
    let (sys, mgr) = system("batch-val-only", 1, Duration::ZERO);
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let r = mgr.spawn_cl(
        KernelSpawn::new(program, "copy_u32")
            .inputs(Mode::Ref, 1)
            .output(Mode::Val)
            .batched(BatchConfig::default()),
    );
    assert!(r.is_err());
    assert!(r.unwrap_err().to_string().contains("val-mode"));
    teardown(sys, mgr);
}

// --- shape-classed sub-batching ----------------------------------------

#[test]
fn multishape_interleaved_requests_coalesce_per_class_with_exact_slices() {
    // two request shapes interleave through ONE batched facade: each shape
    // class owns its own window, so the burst fuses into exactly one
    // launch per class — the old single-window batcher would have let one
    // shape's arrivals force-flush the other's half-filled window
    let (sys, mgr) = system("batch-multiclass", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let worker = spawn_batched(&mgr, stats.clone(), 3, Duration::from_secs(30));
    let me = sys.scoped();
    let payloads: Vec<Vec<u32>> = (0..6u32)
        .map(|i| {
            let len = if i % 2 == 0 { 64 } else { 128 };
            (0..len as u32).map(|x| x + i * 10_000).collect()
        })
        .collect();
    let pending: Vec<_> = payloads
        .iter()
        .map(|p| me.request(&worker, p.clone()))
        .collect();
    for (p, want) in pending.into_iter().zip(&payloads) {
        let out: Vec<u32> = p.receive(T).unwrap();
        assert_eq!(&out, want, "each requester gets its exact slice");
    }
    assert_eq!(
        stat_launches(&stats),
        2,
        "two interleaved classes -> exactly two fused launches"
    );
    assert_eq!(launched_on(&mgr, 0), 2);
    teardown(sys, mgr);
}

#[test]
fn multi_shape_kernel_batches_per_class_with_exact_slices() {
    // a kernel whose manifest inputs have DIFFERENT element counts
    // (1024 + 512, output 1024) could not batch at all before the
    // shape-class rewrite; each request must be a uniform scale-down of
    // the manifest shape, and same-scale requests coalesce per class
    let (sys, mgr) = system("batch-multishape", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let program = mgr.create_kernel_program("scale_copy_u32").unwrap();
    let worker = mgr
        .spawn_cl(
            KernelSpawn::new(program, "scale_copy_u32")
                .inputs(Mode::Val, 2)
                .output(Mode::Val)
                .with_stats(stats.clone())
                .batched(BatchConfig {
                    max_requests: 2,
                    max_delay: Duration::from_secs(30),
                }),
        )
        .unwrap();
    let me = sys.scoped();
    // two eighth-scale requests (128 + 64) and two quarter-scale requests
    // (256 + 128), interleaved — two classes, one fused launch each
    let mk = |scale_len: usize, seed: u32| -> (Vec<u32>, Vec<u32>) {
        (
            (0..scale_len as u32).map(|x| x + seed).collect(),
            vec![seed; scale_len / 2],
        )
    };
    let reqs = [mk(128, 1_000), mk(256, 2_000), mk(128, 3_000), mk(256, 4_000)];
    let pending: Vec<_> = reqs.iter().map(|r| me.request(&worker, r.clone())).collect();
    for (p, (a, _b)) in pending.into_iter().zip(&reqs) {
        // emu=identity: the output is input 0, so each requester's slice
        // must echo its first argument exactly
        let out: Vec<u32> = p.receive(T).unwrap();
        assert_eq!(&out, a, "exact output slice per requester");
    }
    assert_eq!(
        stat_launches(&stats),
        2,
        "two scale classes -> exactly two fused launches"
    );
    // a request whose arguments are NOT a uniform scale-down is a clean
    // per-request error, not a wrong launch
    let skewed: (Vec<u32>, Vec<u32>) = ((0..128).collect(), vec![7u32; 100]);
    let err = me.request(&worker, skewed).receive_msg(T).unwrap_err();
    assert!(err.reason.contains("scale"), "got: {}", err.reason);
    teardown(sys, mgr);
}

#[test]
fn zero_delay_batching_flushes_each_request_synchronously() {
    // BatchConfig { max_delay: 0 } used to schedule a FlushTick anyway, so
    // a lone request paid a full timer hop before launching; a zero delay
    // must flush inside admit
    let (sys, mgr) = system("batch-zerodelay", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let worker = spawn_batched(&mgr, stats.clone(), 1000, Duration::ZERO);
    let me = sys.scoped();
    for i in 0..3u32 {
        let data = vec![i; 64];
        let out: Vec<u32> = me.request(&worker, data.clone()).receive(T).unwrap();
        assert_eq!(out, data);
    }
    assert_eq!(
        stat_launches(&stats),
        3,
        "every admit must flush synchronously under a zero delay"
    );
    teardown(sys, mgr);
}

#[test]
fn batched_occupancy_gauge_rises_and_drains() {
    // the batcher publishes admitted-but-unretired requests into the
    // device's ExecStats — the depth signal batched placement reads
    let (sys, mgr) = system("batch-occupancy", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let worker = spawn_batched(&mgr, stats.clone(), 4, Duration::from_secs(30));
    let me = sys.scoped();
    let pending: Vec<_> = (0..3u32)
        .map(|i| me.request(&worker, vec![i; 64]))
        .collect();
    let dev = mgr.device(0).unwrap();
    assert!(
        eventually(|| dev.batch_occupancy() == 3),
        "open window must publish its occupancy (got {})",
        dev.batch_occupancy()
    );
    // the 4th request hits the count trigger and flushes the window
    let p4 = me.request(&worker, vec![9u32; 64]);
    for p in pending {
        let _: Vec<u32> = p.receive(T).unwrap();
    }
    let _: Vec<u32> = p4.receive(T).unwrap();
    assert!(
        eventually(|| dev.batch_occupancy() == 0),
        "retired launches must drain the gauge (got {})",
        dev.batch_occupancy()
    );
    assert_eq!(stat_launches(&stats), 1);
    teardown(sys, mgr);
}

// --- batching × replication fault injection ----------------------------

#[test]
fn batched_replica_death_mid_window_resolves_every_promise() {
    // kill a batched replica while windows are open: every admitted
    // promise resolves — a slice (the Drop-flush launched the window) or
    // an error (bounced from the closing mailbox) — exactly once, never a
    // timeout
    let (sys, mgr) = system("batch-death", 2, Duration::from_millis(5));
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let handle = mgr
        .spawn_cl_replicated(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .placement(Placement::replicated(PlacementPolicy::RoundRobin))
                .batched(BatchConfig {
                    max_requests: 1000,
                    max_delay: Duration::from_millis(200),
                }),
        )
        .unwrap();
    let me = sys.scoped();
    let pending: Vec<_> = (0..12u32)
        .map(|i| me.request(&handle.actor, vec![i; 64]))
        .collect();
    // kill replica 0's facade while the burst is mid-admission: its open
    // windows Drop-flush, its undelivered messages bounce
    kill(&handle.pool.replicas()[0].facade());
    let (mut ok, mut errs) = (0usize, 0usize);
    for (i, p) in pending.into_iter().enumerate() {
        match p.receive_msg(T) {
            Ok(m) => {
                assert_eq!(m.downcast_ref::<Vec<u32>>(), Some(&vec![i as u32; 64]));
                ok += 1;
            }
            Err(e) => {
                assert!(
                    !e.reason.contains("timed out"),
                    "request {i} was silently lost: {}",
                    e.reason
                );
                errs += 1;
            }
        }
    }
    assert_eq!(ok + errs, 12, "every request resolves exactly once");
    assert!(ok > 0, "the surviving replica's windows must flush");
    assert!(
        eventually(|| !handle.pool.replicas()[0].is_alive()),
        "dispatcher must observe the Down"
    );
    // the dead batcher's occupancy drained (Drop-flush retired it), so
    // depth-based routing sees a clean picture post-mortem
    let d0 = mgr.device(0).unwrap();
    assert!(
        eventually(|| d0.batch_occupancy() == 0),
        "a dead batcher must not leak occupancy (got {})",
        d0.batch_occupancy()
    );
    // post-mortem traffic flows via the survivor
    for i in 0..4u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; 64]).receive(T).unwrap();
        assert_eq!(out, vec![i; 64]);
    }
    teardown(sys, mgr);
}

#[test]
fn batched_drop_flush_on_a_closed_queue_fails_promises_cleanly() {
    // the hardest shutdown path: the device queue is ALREADY gone when the
    // dying batcher Drop-flushes. The refused launch must fail every
    // admitted promise with a real error — never a hang, never a leaked
    // occupancy count
    let (sys, mgr) = system("batch-closedq", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let worker = spawn_batched(&mgr, stats.clone(), 1000, Duration::from_secs(600));
    let me = sys.scoped();
    let pa = me.request(&worker, vec![1u32; 64]);
    let pb = me.request(&worker, vec![2u32; 64]);
    // let the facade admit both into the open window
    let dev = mgr.device(0).unwrap();
    assert!(eventually(|| dev.batch_occupancy() == 2));
    // stop the device, THEN terminate the facade: Drop-flush hits a closed
    // queue
    dev.queue.stop();
    worker.send_from(None, Message::new(Exit::fault("shutdown")));
    for p in [pa, pb] {
        let err = p.receive_msg(T).expect_err("closed queue cannot produce slices");
        assert!(
            !err.reason.contains("timed out"),
            "promise must fail fast, not time out: {}",
            err.reason
        );
        assert!(
            err.reason.contains("closed") || err.reason.contains("broken promise"),
            "got: {}",
            err.reason
        );
    }
    assert!(
        eventually(|| dev.batch_occupancy() == 0),
        "a refused flush must drain the occupancy gauge (got {})",
        dev.batch_occupancy()
    );
    teardown(sys, mgr);
}

// --- limited respawn ----------------------------------------------------

#[test]
fn limited_respawn_retires_a_crash_looping_replica() {
    // RespawnPolicy::Limited: a replica that keeps dying is rebuilt at
    // most `max` times (with backoff), then marked permanently dead — the
    // ROADMAP crash-loop item (Always recompiled forever)
    let (sys, mgr) = system("respawn-limited", 2, Duration::ZERO);
    let handle = spawn_replicated_copy(
        &mgr,
        ReplicaSet::new(PlacementPolicy::RoundRobin).respawn(RespawnPolicy::Limited {
            max: 2,
            backoff: Duration::from_millis(1),
        }),
    );
    let me = sys.scoped();
    // two deaths are rebuilt (with exponential backoff between attempts)
    for expected in 1..=2u64 {
        kill(&handle.pool.replicas()[0].facade());
        assert!(
            eventually(|| handle.pool.replicas()[0].respawns() >= expected),
            "death {expected} must rebuild (respawns={})",
            handle.pool.replicas()[0].respawns()
        );
        assert!(eventually(|| handle.pool.replicas()[0].is_alive()));
    }
    assert_eq!(handle.pool.replicas()[0].respawn_attempts(), 2);
    // the third death exhausts the budget: permanently dead, never rebuilt
    kill(&handle.pool.replicas()[0].facade());
    assert!(
        eventually(|| handle.pool.replicas()[0].is_retired()),
        "the third death must retire the replica"
    );
    assert!(!handle.pool.replicas()[0].is_alive());
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(
        handle.pool.replicas()[0].respawns(),
        2,
        "a retired replica must never be rebuilt again"
    );
    assert_eq!(handle.pool.live_count(), 1);
    // traffic keeps flowing via the survivor
    for i in 0..4u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    assert_eq!(launched_on(&mgr, 0), 0, "the retired replica must not serve");
    teardown(sys, mgr);
}

// --- admission control: overload, shedding, deadlines (tentpole) -------

fn spawn_replicated_batched_copy(
    mgr: &Manager,
    set: ReplicaSet,
    max_requests: usize,
    max_delay: Duration,
) -> ReplicatedHandle {
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    mgr.spawn_cl_replicated(
        KernelSpawn::new(program, "copy_u32")
            .inputs(Mode::Val, 1)
            .output(Mode::Val)
            .placement(Placement::Replicated(set))
            .batched(BatchConfig {
                max_requests,
                max_delay,
            }),
    )
    .unwrap()
}

#[test]
fn overload_past_max_inflight_is_a_typed_overloaded_rejection() {
    // an UNBATCHED pool makes the bound deterministic: the dispatcher's
    // routed-minus-retired depth updates synchronously at routing time,
    // so the third request observes exactly the two admitted ones
    let (sys, mgr) = system("overload", 1, Duration::from_millis(300));
    let handle = spawn_replicated_copy(
        &mgr,
        ReplicaSet::new(PlacementPolicy::RoundRobin).admission(AdmissionConfig::bounded(2)),
    );
    let me = sys.scoped();
    let r1 = me.request(&handle.actor, vec![1u32; CAP]);
    let r2 = me.request(&handle.actor, vec![2u32; CAP]);
    assert!(
        eventually(|| handle.pool.total_depth() == 2),
        "both requests must be admitted (depth={})",
        handle.pool.total_depth()
    );
    let err = me
        .request(&handle.actor, vec![3u32; CAP])
        .receive::<Vec<u32>>(T)
        .unwrap_err();
    assert_eq!(
        Rejection::of(&err),
        Some(Rejection::Overloaded),
        "past the bound the rejection must be typed: {}",
        err.reason
    );
    assert!(err.reason.contains("overloaded"), "{}", err.reason);
    assert_eq!(handle.admission.stats.overloaded_count(), 1);
    // the admitted requests are unaffected by the rejection
    assert_eq!(r1.receive::<Vec<u32>>(T).unwrap(), vec![1; CAP]);
    assert_eq!(r2.receive::<Vec<u32>>(T).unwrap(), vec![2; CAP]);
    // and once the backlog retires, admission reopens
    assert!(eventually(|| handle.pool.total_depth() == 0));
    let out: Vec<u32> = me
        .request(&handle.actor, vec![4u32; CAP])
        .receive(T)
        .unwrap();
    assert_eq!(out, vec![4; CAP]);
    assert_eq!(handle.admission.stats.overloaded_count(), 1);
    teardown(sys, mgr);
}

#[test]
fn drop_oldest_sheds_exactly_the_stalest_queued_request() {
    // A and B park in a batch window (count trigger 4, timer 1s); C
    // arrives past the bound of 2 — DropOldest must fail exactly A (the
    // stalest), admit C, and the eventual flush serves B and C intact
    let (sys, mgr) = system("dropoldest", 1, Duration::ZERO);
    let handle = spawn_replicated_batched_copy(
        &mgr,
        ReplicaSet::new(PlacementPolicy::RoundRobin)
            .admission(AdmissionConfig::bounded(2).shed(ShedPolicy::DropOldest)),
        4,
        Duration::from_secs(1),
    );
    let me = sys.scoped();
    let ra = me.request(&handle.actor, vec![1u32; 64]);
    let rb = me.request(&handle.actor, vec![2u32; 64]);
    assert!(
        eventually(|| handle.pool.total_depth() == 2),
        "A and B must occupy the window (depth={})",
        handle.pool.total_depth()
    );
    let rc = me.request(&handle.actor, vec![3u32; 64]);
    let err = ra.receive::<Vec<u32>>(T).unwrap_err();
    assert_eq!(
        Rejection::of(&err),
        Some(Rejection::Shed),
        "the stalest promise must fail with the typed shed error: {}",
        err.reason
    );
    assert!(err.reason.contains("shed"), "{}", err.reason);
    // B and C survive with their own slices — shedding A must not
    // disturb its window peers
    assert_eq!(rb.receive::<Vec<u32>>(T).unwrap(), vec![2; 64]);
    assert_eq!(rc.receive::<Vec<u32>>(T).unwrap(), vec![3; 64]);
    assert_eq!(handle.admission.stats.shed_count(), 1);
    assert_eq!(handle.admission.stats.overloaded_count(), 0);
    teardown(sys, mgr);
}

#[test]
fn expired_arrival_fails_fast_and_flushes_its_shape_class_early() {
    // a request that exceeded max_queue_wait before reaching the batcher
    // must fail with the typed deadline error AND early-flush its shape
    // class: its window peers have been waiting too, so holding them for
    // the timer only risks expiring them as well. The window's own timer
    // here is the 45s deadline clamp (0.75 x 60s budget, under a 600s
    // max_delay) — the fresh peer's reply arriving in seconds proves the
    // flush came from the expired arrival, not any timer.
    let (sys, mgr) = system("deadlineflush", 1, Duration::ZERO);
    let adm = Arc::new(Admission::new(
        AdmissionConfig::default().deadline(Duration::from_secs(60)),
    ));
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let facade = mgr
        .spawn_cl(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .batched(BatchConfig {
                    max_requests: 4,
                    max_delay: Duration::from_secs(600),
                })
                .admission(adm.clone()),
        )
        .unwrap();
    // a monotonic clock younger than the backdate would make the stamp
    // unrepresentable — vanishingly rare outside a just-booted VM
    let Some(stale) = std::time::Instant::now().checked_sub(Duration::from_secs(120)) else {
        return;
    };
    let me = sys.scoped();
    let t0 = std::time::Instant::now();
    let ra = me.request(&facade, vec![7u32; 64]);
    let rx = me.request_msg(
        &facade,
        Message::new(Stamped {
            at: stale,
            inner: Message::new(vec![9u32; 64]),
        }),
    );
    let err = rx.receive::<Vec<u32>>(T).unwrap_err();
    assert_eq!(
        Rejection::of(&err),
        Some(Rejection::Deadline),
        "an expired request must fail with the typed deadline error: {}",
        err.reason
    );
    assert!(err.reason.contains("deadline"), "{}", err.reason);
    // the half-filled window flushed early: the fresh peer replies in
    // seconds instead of waiting out the 45s clamp
    assert_eq!(ra.receive::<Vec<u32>>(T).unwrap(), vec![7; 64]);
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "peer reply took {:?} — the class was not early-flushed",
        t0.elapsed()
    );
    assert_eq!(adm.stats.deadline_count(), 1);
    teardown(sys, mgr);
}

#[test]
fn idle_class_flushes_near_synchronously_hot_class_holds_the_window() {
    // the adaptive time valve: a cold class pays the configured
    // max_delay once, but after a quiet period the class's EWMA arrival
    // gap exceeds the window and the next lone request flushes
    // synchronously instead of idling out the full timer again
    let (sys, mgr) = system("adaptdelay", 1, Duration::ZERO);
    let stats = Arc::new(FacadeStats::default());
    let facade = spawn_batched(&mgr, stats.clone(), 8, Duration::from_secs(1));
    let me = sys.scoped();
    let t0 = std::time::Instant::now();
    let out: Vec<u32> = me.request(&facade, vec![1u32; 64]).receive(T).unwrap();
    assert_eq!(out, vec![1; 64]);
    let cold = t0.elapsed();
    assert!(
        cold >= Duration::from_millis(600),
        "a cold class must pay the window timer, took {cold:?}"
    );
    // quiet period: the measured arrival gap now exceeds max_delay
    std::thread::sleep(Duration::from_millis(1200));
    let t1 = std::time::Instant::now();
    let out: Vec<u32> = me.request(&facade, vec![2u32; 64]).receive(T).unwrap();
    assert_eq!(out, vec![2; 64]);
    let idle = t1.elapsed();
    assert!(
        idle < Duration::from_millis(500),
        "an idle class must flush near-synchronously, took {idle:?}"
    );
    assert_eq!(stats.launched.load(std::sync::atomic::Ordering::Relaxed), 2);
    teardown(sys, mgr);
}

#[test]
fn chaos_kill_during_overload_never_loses_or_double_resolves() {
    // the soak invariant at test scale: a replica killed in the middle of
    // an over-admitted burst must not lose a single promise — every
    // request resolves exactly once as a reply, a typed rejection/shed/
    // deadline, or a routed error, and never by timeout
    let (sys, mgr) = system("chaosburst", 2, Duration::from_millis(10));
    let handle = spawn_replicated_batched_copy(
        &mgr,
        ReplicaSet::new(PlacementPolicy::LeastInflight)
            .respawn(RespawnPolicy::Always)
            .admission(
                AdmissionConfig::bounded(4)
                    .shed(ShedPolicy::DropOldest)
                    .deadline(Duration::from_millis(100)),
            ),
        4,
        Duration::from_millis(5),
    );
    let me = sys.scoped();
    const N: usize = 40;
    let pending: Vec<_> = (0..N)
        .map(|i| me.request(&handle.actor, vec![i as u32; 64]))
        .collect();
    // kill a replica while the burst is in flight
    kill(&handle.pool.replicas()[0].facade());
    let mut ok = 0;
    let mut failed = 0;
    for p in pending {
        match p.receive_msg(T) {
            Ok(_) => ok += 1,
            Err(e) => {
                assert!(
                    !e.reason.contains("timed out"),
                    "a request hung instead of resolving: {}",
                    e.reason
                );
                failed += 1;
            }
        }
    }
    assert_eq!(ok + failed, N, "every request resolves exactly once");
    assert!(ok > 0, "the surviving replica must keep serving");
    // Always-respawn brings the killed replica back
    assert!(
        eventually(|| handle.pool.replicas()[0].respawns() >= 1),
        "the killed replica must respawn"
    );
    teardown(sys, mgr);
}

// --- placement-tier pipelines ------------------------------------------

/// A 3-stage copy pipeline (Val -> Ref -> Ref -> Val): the smallest shape
/// that exercises device-resident hand-off between interior stages.
fn pipeline_3stage(mgr: &Manager, set: ReplicaSet, mode: PipelineMode) -> ReplicatedHandle {
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let stage = |in_mode: Mode, out: Mode| {
        KernelSpawn::new(program.clone(), "copy_u32")
            .inputs(in_mode, 1)
            .output(out)
    };
    mgr.spawn_pipeline_replicated(
        PipelineSpawn::new()
            .stage(stage(Mode::Val, Mode::Ref))
            .stage(stage(Mode::Ref, Mode::Ref))
            .stage(stage(Mode::Ref, Mode::Val))
            .placement(Placement::Replicated(set))
            .mode(mode),
    )
    .unwrap()
}

#[test]
fn pipeline_routes_as_a_unit_and_stays_device_resident() {
    let (sys, mgr) = system("pipe-unit", 2, Duration::ZERO);
    let handle = pipeline_3stage(
        &mgr,
        ReplicaSet::new(PlacementPolicy::RoundRobin),
        PipelineMode::Interleaved,
    );
    let me = sys.scoped();
    // one request: all three stage launches land on ONE device — the
    // intermediate refs never cross (the tentpole acceptance)
    let data: Vec<u32> = (0..CAP as u32).collect();
    let out: Vec<u32> = me.request(&handle.actor, data.clone()).receive(T).unwrap();
    assert_eq!(out, data);
    let (l0, l1) = (launched_on(&mgr, 0), launched_on(&mgr, 1));
    assert_eq!(l0 + l1, 3, "three stages launch exactly once each");
    assert!(
        l0 == 3 || l1 == 3,
        "a request's stages must not split across devices (got {l0}/{l1})"
    );
    // a burst rotates whole pipelines: every device's launch count stays a
    // multiple of the stage count, and both replicas serve
    for i in 0..8u32 {
        let data = vec![i; CAP];
        let out: Vec<u32> = me.request(&handle.actor, data.clone()).receive(T).unwrap();
        assert_eq!(out, data);
    }
    let (l0, l1) = (launched_on(&mgr, 0), launched_on(&mgr, 1));
    assert_eq!(l0 + l1, 27, "9 requests x 3 stages, each exactly once");
    assert_eq!(l0 % 3, 0, "whole pipelines only (got {l0}/{l1})");
    assert_eq!(l1 % 3, 0, "whole pipelines only (got {l0}/{l1})");
    assert!(l0 >= 12 && l1 >= 12, "round-robin rotates replicas ({l0}/{l1})");
    teardown(sys, mgr);
}

#[test]
fn pipeline_pairs_refs_per_request_not_per_process() {
    // the MemRefSlot regression: stage 2 pairs its output with a ref from
    // ITS OWN incoming request. Concurrent requests through one replica
    // must never observe each other's references — with the old shared
    // slot, whichever request wrote last clobbered both pairings.
    let (sys, mgr) = system("pipe-pair", 1, Duration::from_millis(5));
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let vadd = mgr.create_kernel_program("vadd_u32").unwrap();
    let driver = mgr
        .spawn_pipeline(
            PipelineSpawn::new()
                .stage(
                    KernelSpawn::new(program.clone(), "copy_u32")
                        .inputs(Mode::Val, 1)
                        .output(Mode::Ref),
                )
                .stage(
                    KernelSpawn::new(program, "copy_u32")
                        .inputs(Mode::Ref, 1)
                        .output(Mode::Ref)
                        .postprocess(post_pair_from(0)),
                )
                .stage(
                    KernelSpawn::new(vadd, "vadd_u32")
                        .inputs(Mode::Ref, 2)
                        .output(Mode::Val),
                )
                .placement(Placement::Device(0)),
        )
        .unwrap();
    let me = sys.scoped();
    // interleaved driver keeps both requests in flight at once
    let pending: Vec<_> = (1..=4u32)
        .map(|i| me.request(&driver, vec![i; CAP]))
        .collect();
    for (i, p) in pending.into_iter().enumerate() {
        let want = (i as u32 + 1) * 2;
        let out: Vec<u32> = p.receive(T).unwrap();
        assert_eq!(
            out,
            vec![want; CAP],
            "request {i} must pair its OWN refs (copy + copy = 2x its data)"
        );
    }
    teardown(sys, mgr);
}

#[test]
fn interleaved_stages_overlap_where_lockstep_serializes() {
    // acceptance: stage interleaving yields more in-flight stage launches
    // than lock-step composition on the same device, asserted via the
    // ExecStats high-water mark
    let run = |tag: &str, mode: PipelineMode| -> u64 {
        let (sys, mgr) = system(tag, 1, Duration::from_millis(10));
        let handle =
            pipeline_3stage(&mgr, ReplicaSet::new(PlacementPolicy::RoundRobin), mode);
        let me = sys.scoped();
        let pending: Vec<_> = (0..4u32)
            .map(|i| me.request(&handle.actor, vec![i; CAP]))
            .collect();
        for (i, p) in pending.into_iter().enumerate() {
            let out: Vec<u32> = p.receive(T).unwrap();
            assert_eq!(out, vec![i as u32; CAP]);
        }
        let peak = mgr.device(0).unwrap().queue.stats().inflight_peak();
        teardown(sys, mgr);
        peak
    };
    let lock = run("pipe-lock", PipelineMode::LockStep);
    let inter = run("pipe-inter", PipelineMode::Interleaved);
    assert_eq!(
        lock, 1,
        "lock-step runs one request end-to-end at a time: stage launches never overlap"
    );
    assert!(
        inter >= 2,
        "interleaving must overlap stage launches of different requests (peak {inter})"
    );
}

#[test]
fn stage_death_kills_and_respawns_the_whole_replica_pipeline() {
    let (sys, mgr) = system("pipe-respawn", 2, Duration::ZERO);
    let handle = pipeline_3stage(
        &mgr,
        ReplicaSet::new(PlacementPolicy::RoundRobin).respawn(RespawnPolicy::Always),
        PipelineMode::Interleaved,
    );
    let me = sys.scoped();
    for i in 0..4u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    let old_driver = handle.pool.replicas()[0].facade().id();
    let old_members = handle.pool.replicas()[0].members();
    assert_eq!(old_members.len(), 3, "the roster exposes every stage");
    // kill a MIDDLE STAGE, not the driver: supervision must take the whole
    // replica pipeline down (no half-pipeline serves continuations against
    // a dead peer) and respawn recompiles all stages
    kill(&old_members[1]);
    assert!(
        eventually(|| handle.pool.replicas()[0].respawns() >= 1),
        "a stage death must trigger a whole-pipeline respawn"
    );
    assert!(eventually(|| handle.pool.replicas()[0].is_alive()));
    assert_ne!(
        handle.pool.replicas()[0].facade().id(),
        old_driver,
        "the driver is a fresh incarnation"
    );
    let fresh = handle.pool.replicas()[0].members();
    assert_eq!(fresh.len(), 3);
    for s in &fresh {
        assert!(
            old_members.iter().all(|o| o.id() != s.id()),
            "every stage facade must be a fresh incarnation"
        );
    }
    // the respawned replica pipeline rejoins the full rotation
    let (b0, b1) = (launched_on(&mgr, 0), launched_on(&mgr, 1));
    for i in 0..8u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    let (d0, d1) = (launched_on(&mgr, 0) - b0, launched_on(&mgr, 1) - b1);
    assert_eq!(d0 + d1, 24, "8 requests x 3 stages after the respawn");
    assert_eq!(d0, 12, "respawned replica serves its full rotation share");
    assert_eq!(d1, 12);
    teardown(sys, mgr);
}

#[test]
fn migration_reroutes_stranded_refs_instead_of_erroring() {
    // the stranded-ref scenario of `stranded_refs_on_a_dead_replica_...`,
    // with `ReplicaSet::migrate(true)`: instead of the routed error, the
    // dispatcher device-to-device-copies the ref to a live replica and
    // reschedules — the request succeeds
    let (sys, mgr) = system("pipe-migrate", 2, Duration::ZERO);
    let program = mgr.create_kernel_program("copy_u32").unwrap();
    let producer = mgr
        .spawn_cl(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Ref)
                .placement(Placement::Device(1)),
        )
        .unwrap();
    let handle = {
        let program = mgr.create_kernel_program("copy_u32").unwrap();
        mgr.spawn_cl_replicated(
            KernelSpawn::new(program, "copy_u32")
                .inputs(Mode::Ref, 1)
                .output(Mode::Val)
                .placement(Placement::Replicated(
                    ReplicaSet::new(PlacementPolicy::RoundRobin).migrate(true),
                )),
        )
        .unwrap()
    };
    let me = sys.scoped();
    let data = vec![5u32; CAP];
    let r: MemRef = me.request(&producer, data.clone()).receive(T).unwrap();
    assert_eq!(r.device_id(), 1);
    // kill device 1's replica: the ref is stranded there
    kill(&handle.pool.replicas()[1].facade());
    assert!(eventually(|| !handle.pool.replicas()[1].is_alive()));
    let before = launched_on(&mgr, 0);
    let out: Vec<u32> = me.request(&handle.actor, r).receive(T).unwrap();
    assert_eq!(out, data, "migration must reroute, not error");
    assert_eq!(
        launched_on(&mgr, 0),
        before + 1,
        "the rerouted request launches on the survivor"
    );
    assert!(
        mgr.device(1).unwrap().queue.stats().migrations() >= 1,
        "the source device counts the explicit transfer"
    );
    teardown(sys, mgr);
}

#[test]
fn pipeline_kill_mid_burst_resolves_every_request_exactly_once() {
    // acceptance: a replicated pipeline under mixed-request load with one
    // mid-burst stage kill — every request resolves reply-or-error exactly
    // once, never by timeout, and Always-respawn restores service
    let (sys, mgr) = system("pipe-chaos", 2, Duration::from_millis(5));
    let handle = pipeline_3stage(
        &mgr,
        ReplicaSet::new(PlacementPolicy::RoundRobin).respawn(RespawnPolicy::Always),
        PipelineMode::Interleaved,
    );
    let me = sys.scoped();
    let pending: Vec<_> = (0..16u32)
        .map(|i| me.request(&handle.actor, vec![i; CAP]))
        .collect();
    // mid-burst: a stage of replica 0 dies while requests are in flight
    kill(&handle.pool.replicas()[0].members()[1]);
    let (mut ok, mut errs) = (0usize, 0usize);
    for (i, p) in pending.into_iter().enumerate() {
        match p.receive_msg(T) {
            Ok(m) => {
                assert_eq!(m.downcast_ref::<Vec<u32>>(), Some(&vec![i as u32; CAP]));
                ok += 1;
            }
            Err(e) => {
                assert!(
                    !e.reason.contains("timed out"),
                    "request {i} was silently lost: {}",
                    e.reason
                );
                errs += 1;
            }
        }
    }
    assert_eq!(ok + errs, 16, "every request resolves exactly once");
    assert!(ok > 0, "the surviving replica pipeline must have served");
    assert!(
        eventually(|| handle.pool.replicas()[0].respawns() >= 1),
        "the killed replica pipeline must respawn"
    );
    assert!(eventually(|| handle.pool.replicas()[0].is_alive()));
    // post-mortem traffic flows on both replicas again
    for i in 0..4u32 {
        let out: Vec<u32> = me.request(&handle.actor, vec![i; CAP]).receive(T).unwrap();
        assert_eq!(out, vec![i; CAP]);
    }
    teardown(sys, mgr);
}

// --- the WAH indexing pipeline through the placement tier ---------------

/// Manifest with host-emulated stand-ins for the eight WAH stage kernels
/// at capacity 4096 (identity semantics: the structure of the pipeline —
/// context threading, Ref-mode hand-off, stage count — is real; the
/// arithmetic is not, which is exactly what the placement-tier assertions
/// need on the stub backend).
fn wah_artifacts(tag: &str) -> String {
    const N: usize = 4096;
    let dir = std::env::temp_dir().join(format!(
        "caf-ocl-placement-{}-{tag}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let mut manifest = String::new();
    for (stage, n_in) in [
        ("sort", 1),
        ("chunklit", 1),
        ("fillslit", 1),
        ("interleave", 1),
        ("count", 1),
        ("scan", 1),
        ("move", 2),
        ("lut", 2),
    ] {
        let ins = vec![format!("u32:{N}"); n_in].join(" ");
        manifest.push_str(&format!(
            "wah_{stage}_{N}|emu|{ins}|u32:{N}|emu=identity n={N}\n"
        ));
    }
    std::fs::write(dir.join("manifest.txt"), manifest).unwrap();
    dir.to_string_lossy().to_string()
}

#[test]
fn wah_pipeline_replicates_and_survives_a_chaos_kill() {
    use caf_ocl::sim::{ChaosConfig, ChaosFault, ChaosSchedule};
    const N: usize = 4096;
    let sys = ActorSystem::new(
        SystemConfig::default()
            .with_threads(4)
            .with_artifacts_dir(wah_artifacts("wah")),
    );
    let specs = vec![
        sim_spec("wah-0", Duration::from_millis(2)),
        sim_spec("wah-1", Duration::from_millis(2)),
    ];
    let mgr = Manager::load_with(&sys, specs);
    let spawn = caf_ocl::indexing::pipeline_spawn(
        &mgr,
        0,
        N,
        Placement::Replicated(
            ReplicaSet::new(PlacementPolicy::RoundRobin).respawn(RespawnPolicy::Always),
        ),
    )
    .unwrap();
    assert_eq!(spawn.stages.len(), 8, "the WAH build is eight stages");
    let handle = mgr.spawn_pipeline_replicated(spawn).unwrap();
    assert_eq!(handle.pool.replicas()[0].members().len(), 8);
    let me = sys.scoped();
    let pending: Vec<_> = (0..8u32)
        .map(|i| {
            let mut values = vec![i; N / 2];
            values.resize(N, 1023); // pad like GpuIndexer::index
            me.request(&handle.actor, values)
        })
        .collect();
    // exactly one chaos kill mid-burst, through the production schedule
    let chaos = ChaosSchedule::start(
        handle.pool.clone(),
        ChaosConfig {
            interval: Duration::from_millis(10),
            max_kills: 1,
            seed: 42,
            fault: ChaosFault::Kill,
        },
    );
    let (mut ok, mut errs) = (0usize, 0usize);
    for (i, p) in pending.into_iter().enumerate() {
        match p.receive_msg(T) {
            Ok(m) => {
                // [moved, lut]: two device refs, resident on ONE device
                let ctx = m.downcast_ref::<Vec<ArgValue>>().unwrap();
                assert_eq!(ctx.len(), 2, "the WAH pipeline returns (index, LUT)");
                let ids: Vec<usize> = ctx
                    .iter()
                    .map(|a| match a {
                        ArgValue::Ref(r) => r.device_id(),
                        other => panic!("expected device refs, got {other:?}"),
                    })
                    .collect();
                assert_eq!(ids[0], ids[1], "outputs must share one device");
                ok += 1;
            }
            Err(e) => {
                assert!(
                    !e.reason.contains("timed out"),
                    "request {i} was silently lost: {}",
                    e.reason
                );
                errs += 1;
            }
        }
    }
    assert_eq!(chaos.stop(), 1, "exactly one kill was scheduled");
    assert_eq!(ok + errs, 8, "every request resolves exactly once");
    assert!(ok > 0, "the surviving replica must have served");
    teardown(sys, mgr);
}
