//! Fig 3: "Runtime for building a WAH index as a function of index size —
//! comparing GPU with CPU performance." (paper §4.2)
//!
//! Paper setup: 10k..20M values, Tesla C2075 vs 24-core server, log-log,
//! means of 10. Here: the AOT pipeline capacities (4k..1M), the CPU
//! streaming indexer as baseline, and two device series — real PJRT
//! wall-clock, and the Tesla cost model applied to the measured kernel time
//! (launch + PCIe transfer + 0.5x compute; see sim::devices).
//!
//! Expected shape: both linear; device sub-linear at small N (dispatch
//! dominated). NOTE an honest inversion: the paper's GPU wins by ~2x; our
//! "device" is the same CPU running the O(N log N) sort-based GPU
//! algorithm, so the O(N) CPU encoder keeps winning in wall-clock — the
//! modeled-Tesla series shows what the cost structure gives real silicon.
//! Run with CAF_OCL_BENCH_FULL=1 for the full size sweep + 10 samples.

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::bench::{sample, samples_per_point, Series};
use caf_ocl::indexing::gpu_pipeline::GpuIndexer;
use caf_ocl::indexing::CpuIndexer;
use caf_ocl::opencl::{DeviceSpec, Manager};
use caf_ocl::util::stats::linear_fit;
use caf_ocl::workload::ValueStream;
use std::sync::atomic::Ordering;
use std::time::Duration;

const T: Duration = Duration::from_secs(600);

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("fig3: artifacts missing — run `make artifacts`");
        return;
    }
    let full = caf_ocl::bench::full_mode();
    let sizes: &[usize] = if full {
        &[4096, 16384, 65536, 262144, 1048576]
    } else {
        &[4096, 16384, 65536]
    };
    let n_samples = samples_per_point(3, 10);
    let tesla = caf_ocl::sim::tesla_c2075();
    let tesla_pad = tesla.pad.unwrap();

    let sys = ActorSystem::new(SystemConfig::default());
    let mngr = Manager::load_with(&sys, vec![DeviceSpec::host()]);
    let me = sys.scoped();

    let mut cpu_s = Series::new("fig3_cpu");
    let mut gpu_s = Series::new("fig3_gpu_real");
    let mut tesla_s = Series::new("fig3_gpu_tesla_model");

    for &n in sizes {
        let values = ValueStream::Zipf {
            cardinality: 512,
            s: 1.1,
        }
        .generate(n, 0xF163 + n as u64);
        let cpu = CpuIndexer::new(1024);
        cpu_s.push(
            n as f64,
            "cpu",
            &sample(1, n_samples, || {
                std::hint::black_box(cpu.index(&values));
            }),
        );

        let gpu = GpuIndexer::build(&mngr, 0, n).expect("pipeline");
        let _ = gpu.index(&me, &values, T).unwrap(); // warm
        let device = mngr.default_device().unwrap();
        let stats = device.queue.stats();
        let exec_ns_before = stats.exec_ns.load(Ordering::Relaxed);
        let samples_gpu = sample(0, n_samples, || {
            std::hint::black_box(gpu.index(&me, &values, T).unwrap());
        });
        let exec_s = (stats.exec_ns.load(Ordering::Relaxed) - exec_ns_before) as f64
            / n_samples as f64
            / 1e9;
        gpu_s.push(n as f64, "pjrt-real", &samples_gpu);
        // Tesla model: dispatch per stage (8) + up/down transfers + 0.5x exec
        let bytes = (n * 4 + (2 * n + 1024 + 16) * 4) as f64;
        let modeled = 8.0 * tesla_pad.launch.as_secs_f64()
            + bytes / tesla_pad.bytes_per_sec
            + exec_s * tesla_pad.compute_scale;
        tesla_s.push(n as f64, "tesla-modeled", &[modeled]);
    }

    cpu_s.finish("N values", "s");
    gpu_s.finish("N values", "s");
    tesla_s.finish("N values", "s");

    // slopes (paper: "the GPU also exhibits linear scaling with about half
    // the slope" — report ours)
    let xs: Vec<f64> = sizes.iter().map(|&n| n as f64).collect();
    let cpu_y: Vec<f64> = cpu_s.rows.iter().map(|r| r.summary.mean).collect();
    let gpu_y: Vec<f64> = gpu_s.rows.iter().map(|r| r.summary.mean).collect();
    let (_, cpu_b) = linear_fit(&xs, &cpu_y);
    let (_, gpu_b) = linear_fit(&xs, &gpu_y);
    println!(
        "\nslopes [ns/value]: cpu {:.2}, device-real {:.2} (ratio {:.2})",
        cpu_b * 1e9,
        gpu_b * 1e9,
        gpu_b / cpu_b
    );

    mngr.stop_devices();
    sys.shutdown();
}
