//! Soak & overload: the robustness probe (PERF.md).
//!
//! An open-loop Poisson arrival process offers a mixed workload — batched
//! small val-mode requests, large transfer-bound requests, and two-stage
//! pipelines — at roughly 2x the simulated deployment's capacity while a
//! chaos schedule kills replicas on a timer. The same scenario runs twice:
//!
//! - **shed on** — `AdmissionConfig` bounds inflight depth (`DropOldest`
//!   sheds the stalest queued request past the bound) and every routed
//!   request carries a queue-wait deadline.
//! - **shed off** — unbounded admission, the control arm whose queues are
//!   free to grow.
//!
//! The probe's two claims: every request resolves exactly once (reply,
//! typed rejection, shed, or deadline — never a hang), and shedding keeps
//! the admitted-request p99 bounded where the unbounded arm's tail grows
//! with the backlog.
//!
//! Writes `BENCH_soak.json` at the repository root. Smoke mode for CI:
//! `SOAK_BENCH_SMOKE=1` shrinks the soak to ~1s arms so the harness cannot
//! bit-rot without burning runner minutes; `CAF_OCL_BENCH_FULL=1` is the
//! other direction — the minutes-long full-mode soak that is the
//! documented release ritual (PERF.md "Release ritual"; CI runs it as an
//! advisory artifact-upload job on pushes to main). The reduced tier-1
//! twin is `cargo test --test perf_soak`.

use caf_ocl::bench::{
    soak_closed_probe, soak_probe, write_soak_json, write_soak_manifest, SoakConfig, SoakRun,
};
use caf_ocl::workload::ClosedLoop;
use std::time::Duration;

fn print_run(r: &SoakRun) {
    println!(
        "  shed {}: issued {} -> completed {} rejected {} shed {} deadline {} \
         errors {} timeouts {}",
        if r.shedding { "ON " } else { "OFF" },
        r.issued,
        r.completed,
        r.rejected,
        r.shed,
        r.deadline,
        r.errors,
        r.timeouts
    );
    println!(
        "           goodput {:.1} req/s  peak depth {}  admitted p99 {:.1} ms  \
         kills {}  respawns {}",
        r.goodput_rps, r.peak_depth, r.admitted_p99_ms, r.replica_kills, r.respawns
    );
    for c in &r.classes {
        println!(
            "           {:>14}: n={:<5} p50 {:.1} ms  p99 {:.1} ms  p999 {:.1} ms",
            c.class, c.n, c.p50_ms, c.p99_ms, c.p999_ms
        );
    }
}

fn main() {
    let smoke = std::env::var("SOAK_BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    // the release ritual (PERF.md "Release ritual"): a minutes-long soak
    // with a full chaos budget. Smoke wins when both are set, so CI smoke
    // jobs stay cheap no matter the environment.
    let full = !smoke
        && std::env::var("CAF_OCL_BENCH_FULL")
            .map(|v| v == "1")
            .unwrap_or(false);
    let small_elems = 64;
    let batch_max_requests = 8;
    let large_elems = 1 << 18;
    let devices = 2;
    let launch = Duration::from_millis(4);
    // capacity math (documented so the "2x overload" claim is checkable):
    // each device serves ~1/launch = 250 launches/s; with two devices and
    // up-to-8-way batching of the ~70% small class, the deployment absorbs
    // on the order of 500-1500 req/s — offering ~2000 req/s (smoke: the
    // same ratio at a shorter duration) is solidly past saturation
    let cfg = SoakConfig {
        devices,
        launch,
        bytes_per_sec: 4.0e9,
        duration: Duration::from_millis(if smoke {
            1000
        } else if full {
            60_000
        } else {
            8000
        }),
        offered_rps: 2000.0,
        drivers: 32,
        small_elems,
        large_elems,
        batch_max_requests,
        batch_max_delay: Duration::from_millis(4),
        max_inflight: 16,
        max_queue_wait: Duration::from_millis(250),
        chaos_interval: Duration::from_millis(if smoke { 400 } else { 1500 }),
        chaos_kills: if smoke {
            1
        } else if full {
            32
        } else {
            4
        },
        seed: 0x50a4,
        artifacts_dir: write_soak_manifest(
            "bench",
            small_elems * batch_max_requests,
            large_elems,
        ),
    };
    println!(
        "soak: {} devices, {:?} launch pad, {:?} soak, {:.0} req/s offered, \
         {} drivers, chaos every {:?} (budget {}){}",
        cfg.devices,
        cfg.launch,
        cfg.duration,
        cfg.offered_rps,
        cfg.drivers,
        cfg.chaos_interval,
        cfg.chaos_kills,
        if smoke {
            " (smoke)"
        } else if full {
            " (full-mode release ritual)"
        } else {
            ""
        }
    );

    let on = soak_probe(&cfg, true);
    print_run(&on);
    let off = soak_probe(&cfg, false);
    print_run(&off);
    // the closed-loop control arm: bounded pressure from the loop itself
    // (each worker waits for its reply before issuing the next request)
    let closed_cfg = ClosedLoop {
        concurrency: 16,
        think: Duration::ZERO,
    };
    let closed = soak_closed_probe(&cfg, true, closed_cfg);
    println!("  closed loop ({} workers):", closed_cfg.concurrency);
    print_run(&closed);

    let lost = |r: &SoakRun| {
        r.issued != r.completed + r.rejected + r.shed + r.deadline + r.errors || r.timeouts != 0
    };
    if lost(&on) || lost(&off) || lost(&closed) {
        eprintln!("!! exactly-once violated: some request neither replied nor failed");
        std::process::exit(1);
    }

    match write_soak_json(
        &on,
        &off,
        &closed,
        &closed_cfg,
        &cfg,
        "cargo bench --bench soak",
    ) {
        Ok(p) => println!("-> {}", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
