//! Message-ring throughput: the before/after probe for the lock-free
//! mailbox + sharded work-stealing scheduler (PERF.md).
//!
//! Runs the same token ring twice — once on a faithful miniature of the
//! seed's locked runtime (Mutex<VecDeque> mailboxes, locked injector,
//! 10 ms condvar poll), once on the real lock-free actor system — and
//! writes the machine-readable comparison to `BENCH_msgring.json` at the
//! repository root.

use caf_ocl::bench::{
    full_mode, msgring_lockfree, msgring_seed_style, write_msgring_json, RingConfig,
};

fn main() {
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(8);
    let cfg = if full_mode() {
        RingConfig {
            workers,
            actors: 256,
            tokens: workers * 4,
            hops_per_token: 200_000,
        }
    } else {
        RingConfig {
            workers,
            actors: 64,
            tokens: workers * 2,
            hops_per_token: 20_000,
        }
    };

    println!("msgring: {cfg:?} ({} messages per run)", cfg.messages());

    // warmup + 3 samples each, keep the best (throughput benches are
    // noise-floor bound, max is the honest summary)
    let mut seed = 0f64;
    let mut lockfree = 0f64;
    let _ = msgring_seed_style(cfg);
    let _ = msgring_lockfree(cfg);
    for _ in 0..3 {
        seed = seed.max(msgring_seed_style(cfg));
        lockfree = lockfree.max(msgring_lockfree(cfg));
    }

    println!("seed-style locked runtime : {seed:>12.0} msgs/s");
    println!("lock-free runtime         : {lockfree:>12.0} msgs/s");
    println!("speedup                   : {:>12.2}x", lockfree / seed.max(1e-9));

    match write_msgring_json(cfg, seed, lockfree, "cargo bench --bench msgring") {
        Ok(p) => println!("-> {}", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
