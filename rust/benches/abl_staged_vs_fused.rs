//! Ablation A (paper §3.6 design discussion): composed per-kernel actors —
//! "an interface that integrates into the actor model and allows for
//! composition" — versus "an actor that handles multiple kernel stages"
//! (our monolithic fused artifact), which "removes the need for message
//! passing between kernel executions and could prevent idling of the
//! OpenCL device".
//!
//! Both build identical WAH indexes (asserted); the delta quantifies the
//! price of stage-wise composition.

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::bench::{sample, samples_per_point, Series};
use caf_ocl::indexing::gpu_pipeline::{FusedIndexer, GpuIndexer};
use caf_ocl::opencl::Manager;
use caf_ocl::workload::ValueStream;
use std::time::Duration;

const T: Duration = Duration::from_secs(600);

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("abl_staged_vs_fused: artifacts missing — run `make artifacts`");
        return;
    }
    let sizes: &[usize] = if caf_ocl::bench::full_mode() {
        &[4096, 16384, 65536, 262144]
    } else {
        &[4096, 16384, 65536]
    };
    let n_samples = samples_per_point(3, 10);

    let sys = ActorSystem::new(SystemConfig::default());
    let mngr = Manager::load(&sys);
    let me = sys.scoped();

    let mut staged_s = Series::new("abl_staged");
    let mut fused_s = Series::new("abl_fused");

    for &n in sizes {
        let values = ValueStream::Uniform { cardinality: 512 }.generate(n, 77 + n as u64);
        let staged = GpuIndexer::build(&mngr, 0, n).unwrap();
        let fused = FusedIndexer::build(&mngr, 0, n).unwrap();
        // warm + correctness cross-check
        let a = staged.index(&me, &values, T).unwrap();
        let b = fused.index(&me, &values, T).unwrap();
        assert_eq!(a.words, b.words, "ablation variants must agree");

        staged_s.push(n as f64, "8 composed actors", &sample(0, n_samples, || {
            std::hint::black_box(staged.index(&me, &values, T).unwrap());
        }));
        fused_s.push(n as f64, "1 fused actor", &sample(0, n_samples, || {
            std::hint::black_box(fused.index(&me, &values, T).unwrap());
        }));
    }

    staged_s.finish("N values", "s");
    fused_s.finish("N values", "s");

    println!("\ncomposition cost (staged vs fused):");
    for (s, f) in staged_s.rows.iter().zip(&fused_s.rows) {
        println!(
            "  N={:>8}: staged {:.3} ms, fused {:.3} ms ({:+.1}%)",
            s.x,
            s.summary.mean * 1e3,
            f.summary.mean * 1e3,
            (s.summary.mean / f.summary.mean - 1.0) * 100.0
        );
    }

    mngr.stop_devices();
    sys.shutdown();
}
