//! Ablation B: the cost of the actor-composition operator itself (§3.5's
//! "downside of this approach is the messaging overhead [to] pass memory
//! references from actor to actor") — measured with pure CPU actors so no
//! device time obscures the messaging.
//!
//! Three ways to run a K-stage increment chain: a composed actor
//! (`compose` fold), explicit sequential requests from the driver, and a
//! single actor doing all K increments (the no-messaging floor).

use caf_ocl::actor::*;
use caf_ocl::bench::{sample, samples_per_point, Series};
use std::time::Duration;

const T: Duration = Duration::from_secs(60);

fn main() {
    let n_samples = samples_per_point(300, 2000);
    let sys = ActorSystem::new(SystemConfig::default().with_threads(4));
    let me = sys.scoped();

    let mut composed_s = Series::new("ablB_composed");
    let mut manual_s = Series::new("ablB_manual");
    let mut single_s = Series::new("ablB_single");

    for k in [2usize, 4, 8, 16] {
        let stages: Vec<ActorRef> = (0..k)
            .map(|_| sys.spawn(|_| Behavior::new().on(|_c, &x: &u64| reply(x + 1))))
            .collect();
        let composed = pipeline(&sys, &stages);
        let all_in_one = {
            let k = k as u64;
            sys.spawn(move |_| Behavior::new().on(move |_c, &x: &u64| reply(x + k)))
        };
        // warm
        let _: u64 = me.request(&composed, 0u64).receive(T).unwrap();

        composed_s.push(k as f64, "composed", &sample(20, n_samples, || {
            let r: u64 = me.request(&composed, 0u64).receive(T).unwrap();
            assert_eq!(r, k as u64);
        }));
        manual_s.push(k as f64, "manual chain", &sample(20, n_samples, || {
            let mut x = 0u64;
            for s in &stages {
                x = me.request(s, x).receive(T).unwrap();
            }
            assert_eq!(x, k as u64);
        }));
        single_s.push(k as f64, "single actor", &sample(20, n_samples, || {
            let r: u64 = me.request(&all_in_one, 0u64).receive(T).unwrap();
            assert_eq!(r, k as u64);
        }));
    }

    composed_s.finish("stages", "s");
    manual_s.finish("stages", "s");
    single_s.finish("stages", "s");

    println!("\nper-stage messaging cost [us]:");
    for ((c, m), s) in composed_s.rows.iter().zip(&manual_s.rows).zip(&single_s.rows) {
        let k = c.x;
        println!(
            "  K={:>2}: composed {:.2}, manual {:.2}, floor {:.2}",
            k,
            (c.summary.mean - s.summary.mean) / k * 1e6,
            (m.summary.mean - s.summary.mean) / k * 1e6,
            s.summary.mean * 1e6
        );
    }

    sys.shutdown();
}
