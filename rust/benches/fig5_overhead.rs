//! Fig 5: "Overhead of the CAF messaging when multiplying N x N matrices."
//! (paper §5.2)
//!
//! Two measurements per problem size: (a) the whole calculation, from
//! sending the message to receiving the answer; (b) the time from enqueuing
//! the kernel until the completion callback (data transfer + execution).
//! Fig 5(b) plots the difference — the paper found a flat 5.7–8.6 ms with
//! "no discernible slope", i.e. actor overhead independent of problem size.
//!
//! Paper sizes 1000..12000 (GTX 780M); ours 64..512 (interpret-mode PJRT).

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::bench::{samples_per_point, Series};
use caf_ocl::opencl::{FacadeStats, KernelSpawn, Manager, Mode, NdRange};
use caf_ocl::util::Rng;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

const T: Duration = Duration::from_secs(300);

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("fig5: artifacts missing — run `make artifacts`");
        return;
    }
    let sizes: &[usize] = &[64, 128, 256, 384, 512];
    let n_samples = samples_per_point(10, 50);

    let sys = ActorSystem::new(SystemConfig::default());
    let mngr = Manager::load(&sys);
    let me = sys.scoped();

    let mut total_s = Series::new("fig5a_total");
    let mut device_s = Series::new("fig5a_device");
    let mut diff_s = Series::new("fig5b_difference");

    for &n in sizes {
        let kernel = format!("matmul_{n}");
        let stats = Arc::new(FacadeStats::default());
        let program = mngr.create_kernel_program(&kernel).unwrap();
        let worker = mngr
            .spawn_cl(
                KernelSpawn::new(program, &kernel)
                    .range(NdRange::d2(n, n))
                    .inputs(Mode::Val, 2)
                    .output(Mode::Val)
                    .with_stats(stats.clone()),
            )
            .unwrap();
        let mut rng = Rng::new(n as u64);
        let a = rng.fill_f32(n * n);
        let b = rng.fill_f32(n * n);
        // one message, cheaply cloned per request (Arc payload) — keeps
        // payload construction out of the measured window, like the paper's
        // pre-allocated matrices
        let msg = caf_ocl::actor::Message::new(vec![
            caf_ocl::opencl::ArgValue::from(a),
            caf_ocl::opencl::ArgValue::from(b),
        ]);
        let _ = me.request_msg(&worker, msg.clone()).receive_msg(T).unwrap();

        let mut totals = Vec::new();
        let mut devices = Vec::new();
        let mut diffs = Vec::new();
        for _ in 0..n_samples {
            let dev_before = stats.device_ns.load(Ordering::Relaxed);
            let t0 = Instant::now();
            let out = me.request_msg(&worker, msg.clone()).receive_msg(T).unwrap();
            assert!(out.is::<Vec<f32>>());
            let total = t0.elapsed().as_secs_f64();
            let device =
                (stats.device_ns.load(Ordering::Relaxed) - dev_before) as f64 / 1e9;
            totals.push(total);
            devices.push(device);
            diffs.push(total - device);
        }
        total_s.push(n as f64, "request->reply", &totals);
        device_s.push(n as f64, "enqueue->callback", &devices);
        diff_s.push(n as f64, "difference", &diffs);
    }

    total_s.finish("N (matrix dim)", "s");
    device_s.finish("N (matrix dim)", "s");
    diff_s.finish("N (matrix dim)", "s");

    // the Fig 5b check: the difference must not grow with the problem size
    let first = diff_s.rows.first().unwrap().summary.mean;
    let last = diff_s.rows.last().unwrap().summary.mean;
    println!(
        "\nFig5b flatness: difference at N=64: {:.3} ms, at N=512: {:.3} ms",
        first * 1e3,
        last * 1e3
    );

    mngr.stop_devices();
    sys.shutdown();
}
