//! Fig 8: "Moving large workloads to OpenCL devices." (paper §5.4)
//!
//! Same sweep as Fig 7 with a drastically larger image so the offload cost
//! amortizes. Paper: 16000x16000, (a) 100 and (b) 1000 iterations; at 100
//! iterations the optimum sits around 80% (Tesla) / 60% (Phi); at 1000
//! iterations "the Phi and Tesla perform equally well" — the Phi's
//! transfer penalty vanishes when compute dominates.
//!
//! Ours: 2048x2040; quick mode runs it100 with a coarse sweep, full mode
//! (CAF_OCL_BENCH_FULL=1) adds it1000 and all 11 steps.

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::bench::{full_mode, hetero_step, Series};
use caf_ocl::opencl::{Manager, Mode};
use caf_ocl::sim::{tesla_c2075, xeon_phi_5110p};

const W: usize = 2048;
const H: usize = 2040;
const CHUNK: usize = 204;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("fig8: artifacts missing — run `make artifacts`");
        return;
    }
    let full = full_mode();
    let iters_list: &[u32] = if full { &[100, 1000] } else { &[100] };
    let steps: Vec<usize> = if full {
        (0..=10).collect()
    } else {
        vec![0, 2, 4, 6, 8, 10]
    };
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    for &iters in iters_list {
        let kernel = format!("mandel_w{W}_h{H}_c{CHUNK}_it{iters}");
        for (tag, spec) in [("tesla", tesla_c2075()), ("phi", xeon_phi_5110p())] {
            let sys = ActorSystem::new(SystemConfig::default());
            let mngr = Manager::load_with(&sys, vec![spec]);
            let device_actor = mngr.spawn_simple(&kernel, Mode::Val, Mode::Val).unwrap();
            let me = sys.scoped();
            let _ = hetero_step(&me, &device_actor, W, H, CHUNK, iters, 1, threads);

            let mut total_s = Series::new(format!("fig8_it{iters}_{tag}_total"));
            let mut best = (0usize, f64::INFINITY);
            for &step in &steps {
                let (t, c, d) =
                    hetero_step(&me, &device_actor, W, H, CHUNK, iters, step, threads);
                total_s.push((step * 10) as f64, "total", &[t]);
                if t < best.1 {
                    best = (step * 10, t);
                }
                println!(
                    "it{iters} {tag}: offload {:>3}% -> total {:8.1} ms (cpu {:8.1}, dev {:8.1})",
                    step * 10,
                    t * 1e3,
                    c * 1e3,
                    d * 1e3
                );
            }
            total_s.finish("offload %", "s");
            println!(
                "it{iters} {tag}: best split {}% at {:.1} ms\n",
                best.0,
                best.1 * 1e3
            );
            mngr.stop_devices();
            sys.shutdown();
        }
    }
}
