//! Fig 7: "Moving a small workload to OpenCL devices." (paper §5.4)
//!
//! Mandelbrot of the inner cut, offloaded to (a) the Tesla and (b) the
//! Xeon Phi in 10% steps. Paper: 1920x1080, 100 iterations; Tesla declines
//! monotonically to its minimum at 100% offload, while the Phi's dispatch +
//! transfer overhead makes *any* offload of this small problem a loss
//! ("the total execution time doubles when offloading 10%").
//!
//! Ours: 960x540 @ 100 iterations on the simulated device profiles.

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::bench::{hetero_step, samples_per_point, Series};
use caf_ocl::opencl::{Manager, Mode};
use caf_ocl::sim::{tesla_c2075, xeon_phi_5110p};
use caf_ocl::util::stats::summarize;

const W: usize = 960;
const H: usize = 540;
const CHUNK: usize = 54;
const ITERS: u32 = 100;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("fig7: artifacts missing — run `make artifacts`");
        return;
    }
    let n_samples = samples_per_point(3, 10);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let kernel = format!("mandel_w{W}_h{H}_c{CHUNK}_it{ITERS}");

    for (tag, spec) in [("tesla", tesla_c2075()), ("phi", xeon_phi_5110p())] {
        let sys = ActorSystem::new(SystemConfig::default());
        let mngr = Manager::load_with(&sys, vec![spec]);
        let device_actor = mngr.spawn_simple(&kernel, Mode::Val, Mode::Val).unwrap();
        let me = sys.scoped();
        // warm the device path
        let _ = hetero_step(&me, &device_actor, W, H, CHUNK, ITERS, 1, threads);

        let mut total_s = Series::new(format!("fig7_{tag}_total"));
        let mut cpu_s = Series::new(format!("fig7_{tag}_cpu"));
        let mut dev_s = Series::new(format!("fig7_{tag}_device"));
        for step in 0..=10usize {
            let mut totals = Vec::new();
            let mut cpus = Vec::new();
            let mut devs = Vec::new();
            for _ in 0..n_samples {
                let (t, c, d) =
                    hetero_step(&me, &device_actor, W, H, CHUNK, ITERS, step, threads);
                totals.push(t);
                cpus.push(c);
                devs.push(d);
            }
            let x = (step * 10) as f64;
            total_s.push(x, "total", &totals);
            cpu_s.push(x, "cpu-part", &cpus);
            dev_s.push(x, "device-part", &devs);
            let s = summarize(&totals);
            println!("{tag}: offload {:>3}% -> total {:.2} ms", x, s.mean * 1e3);
        }
        total_s.finish("offload %", "s");
        cpu_s.finish("offload %", "s");
        dev_s.finish("offload %", "s");

        // shape checks from the paper
        let t0 = total_s.rows[0].summary.mean;
        let t100 = total_s.rows[10].summary.mean;
        let min = total_s
            .rows
            .iter()
            .map(|r| r.summary.mean)
            .fold(f64::INFINITY, f64::min);
        println!(
            "{tag}: total(0%)={:.1} ms, total(100%)={:.1} ms, min={:.1} ms\n",
            t0 * 1e3,
            t100 * 1e3,
            min * 1e3
        );
        mngr.stop_devices();
        sys.shutdown();
    }
}
