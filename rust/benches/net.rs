//! Remote request path: blocking vs async futures over loopback (PERF.md).
//!
//! One published echo actor, one proxy connection, a sweep over in-flight
//! windows (1 / 64 / 4096). At each window the same request budget runs
//! twice:
//!
//! - **blocking** — one OS thread per in-flight slot (small stacks), each
//!   parked in `ScopedActor::request(..).receive_msg(..)`: the
//!   pre-futures baseline whose client-side cost is the thread army.
//! - **async** — a fixed pool of a few client threads drives the whole
//!   window via `ActorRef::ask` + a bounded `FutureSet`; completion hooks
//!   record latency on the resolver thread, and nothing parks per
//!   request.
//!
//! Both arms are closed loops at their window size: latencies are
//! issue→resolve service times and req/s is reported over the whole batch
//! (see PERF.md on coordinated omission). The bench exits nonzero if the
//! exactly-once ledger breaks — every issued request must resolve as a
//! reply or an error, never hang.
//!
//! Writes `BENCH_net.json` at the repository root. Smoke mode for CI:
//! `NET_BENCH_SMOKE=1` shrinks the request budget so the harness cannot
//! bit-rot without burning runner minutes. The reduced tier-1 twin is
//! `cargo test --test perf_net`.

use caf_ocl::bench::{full_mode, net_probe, write_net_json, NetArm, NetProbeConfig};

fn print_arm(a: &NetArm) {
    println!(
        "  {:>8} @ {:>4} in-flight ({:>4} threads): {:>7} issued  \
         {:>9.1} req/s  p50 {:>8.3} ms  p99 {:>8.3} ms  errors {}",
        a.mode, a.inflight, a.threads, a.issued, a.req_per_s, a.p50_ms, a.p99_ms, a.errors
    );
}

fn main() {
    let smoke = std::env::var("NET_BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let requests = if smoke {
        4096
    } else if full_mode() {
        65536
    } else {
        16384
    };
    let cfg = NetProbeConfig {
        levels: vec![1, 64, 4096],
        requests,
        elems: if smoke { 64 } else { 256 },
        client_threads: 4,
    };
    println!(
        "net: levels {:?}, {} requests/arm, {} u32/request, {} async client threads{}",
        cfg.levels,
        cfg.requests,
        cfg.elems,
        cfg.client_threads,
        if smoke { " (smoke)" } else { "" }
    );

    let arms = net_probe(&cfg);
    for a in &arms {
        print_arm(a);
    }

    // exactly-once: each arm's ledger must balance, and an async arm must
    // never have grown a thread per request
    let mut broken = false;
    for a in &arms {
        if a.issued != a.completed + a.errors {
            eprintln!(
                "!! exactly-once violated ({} @ {}): issued {} != completed {} + errors {}",
                a.mode, a.inflight, a.issued, a.completed, a.errors
            );
            broken = true;
        }
        if a.mode == "async" && a.threads > cfg.client_threads {
            eprintln!(
                "!! async arm @ {} grew its pool: {} threads > {}",
                a.inflight, a.threads, cfg.client_threads
            );
            broken = true;
        }
    }
    if broken {
        std::process::exit(1);
    }

    match write_net_json(&arms, &cfg, "cargo bench --bench net") {
        Ok(p) => println!("-> {}", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
