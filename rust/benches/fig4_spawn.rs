//! Fig 4: "Comparing the wall-clock time for spawning OpenCL versus
//! event-based actors." (paper §5.1)
//!
//! Paper setup: spawn 1..N actors in a loop, then send a message to the
//! last one and await the response to ensure all are live; event-based
//! actors use lazy_init for a fair comparison; means of 50 with 95% CIs.
//! Expected shape: both linear in N, OpenCL actors with the larger slope.

use caf_ocl::actor::{no_reply, ActorSystem, Behavior, SpawnOptions, SystemConfig};
use caf_ocl::bench::{sample, samples_per_point, Series};
use caf_ocl::opencl::{KernelSpawn, Manager, Mode};
use std::time::Duration;

const T: Duration = Duration::from_secs(60);

fn main() {
    let full = caf_ocl::bench::full_mode();
    let counts: &[usize] = if full {
        &[250, 500, 1000, 2000, 4000]
    } else {
        &[100, 250, 500, 1000]
    };
    let n_samples = samples_per_point(5, 50);
    let have_artifacts = std::path::Path::new("artifacts/manifest.txt").exists();

    let mut ev_s = Series::new("fig4_event_based");
    let mut cl_s = Series::new("fig4_opencl");

    for &k in counts {
        // event-based actors, lazy_init (the paper's setup)
        ev_s.push(
            k as f64,
            "event-based",
            &sample(1, n_samples, || {
                let sys = ActorSystem::new(SystemConfig::default().with_threads(4));
                let mut last = None;
                for _ in 0..k {
                    last = Some(sys.spawn_opts(
                        |_| Behavior::new().on(|_c, _: &u32| no_reply()),
                        SpawnOptions::lazy(),
                    ));
                }
                // confirm liveness through the last actor
                let me = sys.scoped();
                let _ = me.request(&last.unwrap(), 1u32).receive_msg(T).unwrap();
                sys.shutdown();
            }),
        );

        if have_artifacts {
            cl_s.push(
                k as f64,
                "opencl",
                &sample(1, n_samples, || {
                    let sys = ActorSystem::new(SystemConfig::default().with_threads(4));
                    let mngr = Manager::load(&sys);
                    // program creation (kernel compilation) happens once,
                    // inside the measured window — like the OpenCL runtime
                    // init in the paper's measurement
                    let program = mngr.create_kernel_program("empty_1024").unwrap();
                    let mut last = None;
                    for _ in 0..k {
                        last = Some(
                            mngr.spawn_cl(
                                KernelSpawn::new(program.clone(), "empty_1024")
                                    .inputs(Mode::Val, 1)
                                    .output(Mode::Val),
                            )
                            .unwrap(),
                        );
                    }
                    let me = sys.scoped();
                    let data: Vec<u32> = vec![0; 1024];
                    let _: Vec<u32> = me.request(&last.unwrap(), data).receive(T).unwrap();
                    mngr.stop_devices();
                    sys.shutdown();
                }),
            );
        }
    }

    ev_s.finish("actors", "s");
    if have_artifacts {
        cl_s.finish("actors", "s");
        let per_ev = ev_s.rows.last().unwrap().summary.mean / *counts.last().unwrap() as f64;
        let per_cl = cl_s.rows.last().unwrap().summary.mean / *counts.last().unwrap() as f64;
        println!(
            "\nper-actor spawn cost: event-based {:.2} us, opencl {:.2} us (x{:.1})",
            per_ev * 1e6,
            per_cl * 1e6,
            per_cl / per_ev
        );
    }
}
