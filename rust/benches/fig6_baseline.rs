//! Fig 6: "Comparing the runtime of iterated tasks in CAF versus native
//! OpenCL." (paper §5.3)
//!
//! A sequence of dependent matmuls: the CAF variant issues the next request
//! when the previous response arrives; the native variant drives the device
//! queue directly (upload/execute/download, next task from the completion
//! callback) without any actor messaging. Paper: both linear, CAF 8.3%
//! over native at 1000 iterations decaying to 7.4% at 10000.
//!
//! Paper: 1000x1000 matrices, 1000..10000 iterations; ours: 256x256,
//! 100..1000 (quick: 100..500).

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::bench::{samples_per_point, Series};
use caf_ocl::opencl::{Manager, Mode};
use caf_ocl::runtime::{Dtype, HostData};
use caf_ocl::util::Rng;
use std::time::{Duration, Instant};

const T: Duration = Duration::from_secs(600);
const N: usize = 256;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("fig6: artifacts missing — run `make artifacts`");
        return;
    }
    let full = caf_ocl::bench::full_mode();
    let iters: Vec<usize> = if full {
        (1..=10).map(|k| k * 100).collect()
    } else {
        (1..=5).map(|k| k * 100).collect()
    };
    let n_samples = samples_per_point(3, 10);

    let sys = ActorSystem::new(SystemConfig::default());
    let mngr = Manager::load(&sys);
    let me = sys.scoped();
    let kernel = format!("matmul_{N}");
    let worker = mngr.spawn_simple(&kernel, Mode::Val, Mode::Val).unwrap();
    let queue = mngr.default_device().unwrap().queue.clone();

    let mut rng = Rng::new(6);
    let a = rng.fill_f32(N * N);
    let b = rng.fill_f32(N * N);
    // warm both paths
    let _: Vec<f32> = me.request(&worker, (a.clone(), b.clone())).receive(T).unwrap();

    let mut caf_s = Series::new("fig6_caf");
    let mut native_s = Series::new("fig6_native");

    for &k in &iters {
        let mut caf = Vec::new();
        let mut native = Vec::new();
        for _ in 0..n_samples {
            // CAF path: sequential requests through the actor
            let t0 = Instant::now();
            for _ in 0..k {
                let _: Vec<f32> = me
                    .request(&worker, (a.clone(), b.clone()))
                    .receive(T)
                    .unwrap();
            }
            caf.push(t0.elapsed().as_secs_f64());

            // native path: the device queue without actors
            let t0 = Instant::now();
            for _ in 0..k {
                let (ba, e1) = queue.upload(HostData::F32(a.clone()));
                let (bb, e2) = queue.upload(HostData::F32(b.clone()));
                let (out, done) = queue.execute(&kernel, vec![ba, bb], Dtype::F32, vec![e1, e2]);
                queue.free(ba);
                queue.free(bb);
                done.wait(T).map_err(|e| e.to_string()).unwrap();
                let _ = queue.download(out, T).unwrap();
                queue.free(out);
            }
            native.push(t0.elapsed().as_secs_f64());
        }
        caf_s.push(k as f64, "caf", &caf);
        native_s.push(k as f64, "native", &native);
    }

    caf_s.finish("iterations", "s");
    native_s.finish("iterations", "s");

    println!("\nrelative overhead of the actor path (paper: 8.3% -> 7.4%):");
    for (c, n) in caf_s.rows.iter().zip(&native_s.rows) {
        println!(
            "  {:>6} iterations: {:+.2}%",
            c.x,
            (c.summary.mean / n.summary.mean - 1.0) * 100.0
        );
    }

    mngr.stop_devices();
    sys.shutdown();
}
