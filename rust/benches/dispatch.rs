//! Dispatch & batching: the placement-tier probe (PERF.md).
//!
//! Four comparisons over host-emulated kernels on simulated sub-second
//! devices (per-command launch padding, no artifacts or XLA backend
//! needed, so this runs everywhere — including the `--no-default-features`
//! CI config):
//!
//! 1. **Placement** — a burst of full-capacity requests against one pinned
//!    facade vs the same burst against `Placement::Replicated` +
//!    least-inflight over N devices.
//! 2. **Batching** — sub-capacity requests launched one-per-message
//!    (caller pads to capacity, the status quo) vs the adaptive batcher
//!    coalescing them into padded fused launches.
//! 3. **Cost-aware steering** (Fig 7b) — the same burst under
//!    `PlacementPolicy::CostAware` vs `RoundRobin` on a fast/Phi-like
//!    device pair: small requests must route around the 20x dispatch pad,
//!    large (transfer-dominated) ones may spill onto it.
//! 4. **Placement-tier pipelines** — composed 3-stage pipelines vs one
//!    monolithic launch (latency), interleaved vs lock-step stage
//!    scheduling (throughput + in-flight peaks), and stranded-ref
//!    recovery by device-to-device migration vs host re-upload.
//!
//! Writes `BENCH_dispatch.json` at the repository root. Smoke mode for CI:
//! `DISPATCH_BENCH_SMOKE=1` runs one tiny iteration of each scenario so
//! the harness cannot bit-rot without burning runner minutes. The reduced
//! tier-1 twin is `cargo test --test perf_dispatch`.

use caf_ocl::bench::{
    dispatch_batched_costaware_probe, dispatch_batching_probe, dispatch_costaware_probe,
    dispatch_pipeline_probe, dispatch_placement_probe, write_batched_costaware_manifest,
    write_costaware_manifest, write_dispatch_json, write_dispatch_manifest,
    BatchedCostAwareProbeConfig, CostAwareProbeConfig, DispatchProbeConfig, DispatchResults,
    PipelineProbeConfig,
};
use std::time::Duration;

fn main() {
    let smoke = std::env::var("DISPATCH_BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let cfg = DispatchProbeConfig {
        devices: 3,
        launch: Duration::from_millis(if smoke { 1 } else { 3 }),
        requests: if smoke { 4 } else { 96 },
        batch_requests: if smoke { 8 } else { 256 },
        request_elems: 64,
        capacity: 1024,
        artifacts_dir: write_dispatch_manifest("bench", 1024),
    };
    println!(
        "dispatch: {} simulated devices, {:?} launch pad, {} placement requests, \
         {} batching requests{}",
        cfg.devices,
        cfg.launch,
        cfg.requests,
        cfg.batch_requests,
        if smoke { " (smoke)" } else { "" }
    );

    let (one_device, n_device) = dispatch_placement_probe(&cfg);
    println!(
        "placement: 1 device {one_device:>9.1} req/s  |  {} devices {n_device:>9.1} req/s  ({:.2}x)",
        cfg.devices,
        n_device / one_device.max(1e-9)
    );

    let (unbatched, batched) = dispatch_batching_probe(&cfg);
    println!(
        "batching : unbatched {unbatched:>9.1} req/s  |  batched {batched:>9.1} req/s  ({:.2}x)",
        batched / unbatched.max(1e-9)
    );

    // cost-aware steering (Fig 7b): CostAware must keep the small burst
    // off the Phi-like device entirely, while RoundRobin pays its pad on
    // every second request; large requests are transfer-dominated, where
    // spilling onto the slow device beats queueing on the fast one
    let ca_cfg = CostAwareProbeConfig {
        // the small burst stays below the ~(slow pad / fast service) depth
        // where spilling to the slow device becomes genuinely cheaper, so
        // "CostAware avoids the Phi-like device" is a property, not a race
        small_elems: 64,
        large_elems: 1 << 20,
        small_requests: if smoke { 6 } else { 8 },
        large_requests: if smoke { 4 } else { 16 },
        artifacts_dir: write_costaware_manifest("bench", 64, 1 << 20),
    };
    let (ca_small, ca_large) = dispatch_costaware_probe(&ca_cfg);
    for (tag, s) in [("small", &ca_small), ("large", &ca_large)] {
        println!(
            "costaware {tag:>5}: CostAware fast/slow {}/{} @ {:>8.1} req/s  |  \
             RoundRobin fast/slow {}/{} @ {:>8.1} req/s",
            s.costaware_fast_launches,
            s.costaware_slow_launches,
            s.costaware_reqs_per_sec,
            s.round_robin_fast_launches,
            s.round_robin_slow_launches,
            s.round_robin_reqs_per_sec
        );
    }

    // batched steering (occupancy-gauge routing) + multi-shape coalescing:
    // the same Fig 7b pair, but every replica fronts an adaptive batcher —
    // launch counts are per-flush, and the dispatcher's depth signal is
    // the occupancy gauge the batchers publish
    let bc_cfg = BatchedCostAwareProbeConfig {
        request_elems: 64,
        requests: if smoke { 6 } else { 8 },
        batch_max_requests: 2,
        batch_max_delay: Duration::from_millis(100),
        alt_elems: 128,
        per_class: if smoke { 3 } else { 4 },
        artifacts_dir: write_batched_costaware_manifest("bench", 1024),
    };
    let bc = dispatch_batched_costaware_probe(&bc_cfg);
    println!(
        "batched costaware: CostAware fast/slow {}/{} @ {:>8.1} req/s  |  \
         RoundRobin fast/slow {}/{} @ {:>8.1} req/s  |  \
         multishape {} reqs -> {} fused launches ({:.2} reqs/launch)",
        bc.costaware_fast_launches,
        bc.costaware_slow_launches,
        bc.costaware_reqs_per_sec,
        bc.round_robin_fast_launches,
        bc.round_robin_slow_launches,
        bc.round_robin_reqs_per_sec,
        bc.multishape_requests,
        bc.multishape_fused_launches,
        bc.multishape_coalescing_ratio
    );

    // placement-tier pipelines: composition overhead, stage scheduling,
    // and stranded-ref recovery (migration vs host re-upload)
    let pipe_cfg = PipelineProbeConfig {
        launch: cfg.launch,
        requests: if smoke { 4 } else { 24 },
        capacity: cfg.capacity,
        artifacts_dir: cfg.artifacts_dir.clone(),
    };
    let pipe = dispatch_pipeline_probe(&pipe_cfg);
    println!(
        "pipeline : monolithic {:.2} ms/req | composed {:.2} ms/req ({:.2}x)  |  \
         lockstep {:>8.1} req/s (peak {}) | interleaved {:>8.1} req/s (peak {})  |  \
         recovery: migrate {:.2} ms vs re-upload {:.2} ms ({} transfers)",
        pipe.monolithic_ms_per_req,
        pipe.composed_ms_per_req,
        pipe.composed_ms_per_req / pipe.monolithic_ms_per_req.max(1e-9),
        pipe.lockstep_reqs_per_sec,
        pipe.lockstep_inflight_peak,
        pipe.interleaved_reqs_per_sec,
        pipe.interleaved_inflight_peak,
        pipe.migration_recovery_ms,
        pipe.reupload_recovery_ms,
        pipe.migrations
    );

    let results = DispatchResults {
        devices: cfg.devices,
        requests: cfg.requests,
        one_device_reqs_per_sec: one_device,
        n_device_reqs_per_sec: n_device,
        batch_requests: cfg.batch_requests,
        request_elems: cfg.request_elems,
        capacity: cfg.capacity,
        unbatched_reqs_per_sec: unbatched,
        batched_reqs_per_sec: batched,
        cost_aware_small: ca_small,
        cost_aware_large: ca_large,
        batched_costaware: bc,
        pipeline: pipe,
    };
    match write_dispatch_json(&results, "cargo bench --bench dispatch") {
        Ok(p) => println!("-> {}", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
