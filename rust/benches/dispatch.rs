//! Dispatch & batching: the placement-tier probe (PERF.md).
//!
//! Two comparisons over host-emulated kernels on simulated sub-second
//! devices (per-command launch padding, no artifacts or XLA backend
//! needed, so this runs everywhere — including the `--no-default-features`
//! CI config):
//!
//! 1. **Placement** — a burst of full-capacity requests against one pinned
//!    facade vs the same burst against `Placement::Replicated` +
//!    least-inflight over N devices.
//! 2. **Batching** — sub-capacity requests launched one-per-message
//!    (caller pads to capacity, the status quo) vs the adaptive batcher
//!    coalescing them into padded fused launches.
//!
//! Writes `BENCH_dispatch.json` at the repository root. Smoke mode for CI:
//! `DISPATCH_BENCH_SMOKE=1` runs one tiny iteration of each scenario so
//! the harness cannot bit-rot without burning runner minutes. The reduced
//! tier-1 twin is `cargo test --test perf_dispatch`.

use caf_ocl::bench::{
    dispatch_batching_probe, dispatch_placement_probe, write_dispatch_json,
    write_dispatch_manifest, DispatchProbeConfig, DispatchResults,
};
use std::time::Duration;

fn main() {
    let smoke = std::env::var("DISPATCH_BENCH_SMOKE")
        .map(|v| v == "1")
        .unwrap_or(false);
    let cfg = DispatchProbeConfig {
        devices: 3,
        launch: Duration::from_millis(if smoke { 1 } else { 3 }),
        requests: if smoke { 4 } else { 96 },
        batch_requests: if smoke { 8 } else { 256 },
        request_elems: 64,
        capacity: 1024,
        artifacts_dir: write_dispatch_manifest("bench", 1024),
    };
    println!(
        "dispatch: {} simulated devices, {:?} launch pad, {} placement requests, \
         {} batching requests{}",
        cfg.devices,
        cfg.launch,
        cfg.requests,
        cfg.batch_requests,
        if smoke { " (smoke)" } else { "" }
    );

    let (one_device, n_device) = dispatch_placement_probe(&cfg);
    println!(
        "placement: 1 device {one_device:>9.1} req/s  |  {} devices {n_device:>9.1} req/s  ({:.2}x)",
        cfg.devices,
        n_device / one_device.max(1e-9)
    );

    let (unbatched, batched) = dispatch_batching_probe(&cfg);
    println!(
        "batching : unbatched {unbatched:>9.1} req/s  |  batched {batched:>9.1} req/s  ({:.2}x)",
        batched / unbatched.max(1e-9)
    );

    let results = DispatchResults {
        devices: cfg.devices,
        requests: cfg.requests,
        one_device_reqs_per_sec: one_device,
        n_device_reqs_per_sec: n_device,
        batch_requests: cfg.batch_requests,
        request_elems: cfg.request_elems,
        capacity: cfg.capacity,
        unbatched_reqs_per_sec: unbatched,
        batched_reqs_per_sec: batched,
    };
    match write_dispatch_json(&results, "cargo bench --bench dispatch") {
        Ok(p) => println!("-> {}", p.display()),
        Err(e) => eprintln!("(json write failed: {e})"),
    }
}
