//! §3.6's stage-messaging estimate: "we created an actor with an empty
//! kernel and passed it a memory reference to execute its kernel. Measuring
//! the time from sending the message to receiving an answer should give an
//! estimate of the baseline required to process an 'empty' stage. ...the
//! measurements mainly remain below 1 ms. Looking only at the time between
//! the mapping functions ... the measurements remain around a few
//! microseconds."

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::bench::{sample, samples_per_point, Series};
use caf_ocl::opencl::{FacadeStats, KernelSpawn, Manager, MemRef, Mode};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

const T: Duration = Duration::from_secs(60);

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        eprintln!("tbl_stage_latency: artifacts missing — run `make artifacts`");
        return;
    }
    let n = samples_per_point(200, 1000);
    let sys = ActorSystem::new(SystemConfig::default());
    let mngr = Manager::load(&sys);
    let me = sys.scoped();

    // producer puts data on the device once; the empty stage consumes the
    // reference and answers with a fresh reference
    let producer = mngr.spawn_simple("empty_1024", Mode::Val, Mode::Ref).unwrap();
    let stats = Arc::new(FacadeStats::default());
    let program = mngr.create_kernel_program("empty_1024").unwrap();
    let empty_stage = mngr
        .spawn_cl(
            KernelSpawn::new(program, "empty_1024")
                .inputs(Mode::Ref, 1)
                .output(Mode::Ref)
                .with_stats(stats.clone()),
        )
        .unwrap();

    let data: Vec<u32> = (0..1024).collect();
    let seed: MemRef = me.request(&producer, data).receive(T).unwrap();
    seed.ready_event().wait(T).unwrap();

    // keep the returned refs alive until after each measurement
    let hold: Mutex<Option<MemRef>> = Mutex::new(None);
    let roundtrip = sample(50, n, || {
        let r: MemRef = me.request(&empty_stage, seed.clone()).receive(T).unwrap();
        *hold.lock().unwrap() = Some(r);
    });

    let mut s = Series::new("tbl_stage_latency");
    s.push(0.0, "empty-stage round-trip", &roundtrip);
    let launched = stats.launched.load(Ordering::Relaxed).max(1);
    let device_mean = stats.device_ns.load(Ordering::Relaxed) as f64 / launched as f64 / 1e9;
    s.push(1.0, "device enqueue->complete", &[device_mean]);
    let msg_only: Vec<f64> = roundtrip.iter().map(|t| (t - device_mean).max(0.0)).collect();
    s.push(2.0, "actor messaging only", &msg_only);
    s.finish("row", "s");

    let mean_ms = s.rows[0].summary.mean * 1e3;
    println!(
        "\npaper bound: < 1 ms per empty stage; measured {:.3} ms ({})",
        mean_ms,
        if mean_ms < 1.0 { "PASS" } else { "above bound on this testbed" }
    );
    println!(
        "messaging-only (mapper-to-mapper analog): {:.1} us",
        s.rows[2].summary.mean * 1e6
    );

    mngr.stop_devices();
    sys.shutdown();
}
