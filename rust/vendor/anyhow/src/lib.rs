//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access, so this vendored crate
//! implements exactly the surface the repository uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros, and the [`Context`]
//! extension trait. Error values carry a message plus an optional context
//! chain; `?` works on any `std::error::Error` source because `Error`
//! itself deliberately does *not* implement `std::error::Error` (the same
//! trick the real crate uses to keep the blanket `From` impl coherent).

use std::fmt;

/// A string-backed error with an optional chain of context frames.
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error {
            msg: m.to_string(),
            context: Vec::new(),
        }
    }

    /// Attach a context frame (outermost first when displayed).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.context.push(c.to_string());
        self
    }

    /// The root message, without context frames.
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.context.last() {
            Some(c) => write!(f, "{c}: {}", self.msg),
            None => write!(f, "{}", self.msg),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        for c in &self.context {
            write!(f, "\n  context: {c}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Attach context to errors, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/file")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_and_context() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert!(e.to_string().contains("bad value 3"));
        let e2: Result<()> = Err(anyhow!("inner")).context("outer");
        let msg = format!("{}", e2.unwrap_err());
        assert!(msg.contains("outer") && msg.contains("inner"), "{msg}");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(from_string.to_string(), "plain");
    }

    fn bails(flag: bool) -> Result<u32> {
        if flag {
            bail!("flagged {}", 7);
        }
        Ok(1)
    }

    #[test]
    fn bail_returns_early() {
        assert!(bails(true).is_err());
        assert_eq!(bails(false).unwrap(), 1);
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert!(none.context("missing").is_err());
        assert_eq!(Some(5u32).context("missing").unwrap(), 5);
    }
}
