//! Host-memory stub of the PJRT/XLA bindings.
//!
//! The build environment has neither network access nor the XLA C++
//! toolchain, so this vendored crate mirrors the small API surface
//! `caf_ocl::runtime::client` uses and keeps device buffers in host
//! memory:
//!
//! * client creation, upload, download, free, and buffer recycling work
//!   fully — which is what the actor substrate, the device command queues,
//!   and the buffer-pool tests exercise;
//! * `compile` records the artifact, but `execute_b` returns an error,
//!   because interpreting HLO is out of scope for a stub. Machines with
//!   the real XLA stack can point the `xla` dependency in
//!   `rust/Cargo.toml` at the real bindings and build with
//!   `--no-default-features`: the one stub-only API,
//!   `buffer_from_host_buffer_reusing`, is gated behind the `xla-stub`
//!   feature in `runtime::client` and degrades to a plain allocation when
//!   the feature is off, so caller code compiles against both backends.

use std::fmt;

/// Error type matching the real crate's shape (Display + std::error::Error).
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// XLA primitive element types (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrimitiveType {
    U32,
    F32,
}

/// Element types storable in device buffers.
pub trait ArrayElement: Copy {
    const PRIMITIVE: PrimitiveType;
    fn write_le(self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

impl ArrayElement for u32 {
    const PRIMITIVE: PrimitiveType = PrimitiveType::U32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl ArrayElement for f32 {
    const PRIMITIVE: PrimitiveType = PrimitiveType::F32;
    fn write_le(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

/// Placeholder for the real crate's device handle.
pub struct PjRtDevice;

/// A "device" buffer (host memory in the stub).
pub struct PjRtBuffer {
    bytes: Vec<u8>,
    dims: Vec<usize>,
    prim: PrimitiveType,
}

impl PjRtBuffer {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn primitive_type(&self) -> PrimitiveType {
        self.prim
    }

    /// Bytes of backing storage currently reserved (pool diagnostics).
    pub fn byte_capacity(&self) -> usize {
        self.bytes.capacity()
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal {
            bytes: self.bytes.clone(),
            prim: self.prim,
        })
    }
}

/// Host copy of a buffer.
pub struct Literal {
    bytes: Vec<u8>,
    prim: PrimitiveType,
}

impl Literal {
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        if self.prim != T::PRIMITIVE {
            return Err(Error::new(format!(
                "literal holds {:?}, requested a different element type",
                self.prim
            )));
        }
        Ok(self.bytes.chunks_exact(4).map(T::read_le).collect())
    }
}

/// Parsed HLO module (the stub only retains the source text).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _text_len: usize,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _text_len: proto.text.len(),
        }
    }
}

/// A "compiled" executable. The stub cannot interpret HLO, so execution
/// reports an error; everything up to that point behaves like the real
/// bindings.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(
            "xla stub: kernel execution needs the real XLA backend \
             (point rust/Cargo.toml's `xla` dependency at the real bindings)",
        ))
    }
}

/// A PJRT client; the stub's "device memory" is host memory.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }

    pub fn buffer_from_host_buffer<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        self.buffer_from_host_buffer_reusing(data, dims, None)
    }

    /// Upload that recycles a freed buffer's backing storage when one is
    /// supplied (the device-side buffer pool's allocation-avoidance hook;
    /// real-XLA builds ignore `recycled` and allocate fresh).
    pub fn buffer_from_host_buffer_reusing<T: ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
        recycled: Option<PjRtBuffer>,
    ) -> Result<PjRtBuffer> {
        let expected: usize = dims.iter().product();
        if expected != data.len() {
            return Err(Error::new(format!(
                "dims {:?} describe {expected} elements but data has {}",
                dims,
                data.len()
            )));
        }
        let mut bytes = match recycled {
            Some(b) => {
                let mut v = b.bytes;
                v.clear();
                v
            }
            None => Vec::new(),
        };
        bytes.reserve(data.len() * 4);
        for &x in data {
            x.write_le(&mut bytes);
        }
        Ok(PjRtBuffer {
            bytes,
            dims: dims.to_vec(),
            prim: T::PRIMITIVE,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upload_download_roundtrip() {
        let c = PjRtClient::cpu().unwrap();
        let data: Vec<u32> = (0..128).collect();
        let b = c.buffer_from_host_buffer(&data, &[128], None).unwrap();
        assert_eq!(b.element_count(), 128);
        let back: Vec<u32> = b.to_literal_sync().unwrap().to_vec().unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn recycling_reuses_allocation() {
        let c = PjRtClient::cpu().unwrap();
        let first = vec![1.5f32; 1024];
        let b = c.buffer_from_host_buffer(&first, &[1024], None).unwrap();
        let ptr_before = b.bytes.as_ptr();
        let cap_before = b.bytes.capacity();
        let second = vec![2.5f32; 1000];
        let b2 = c
            .buffer_from_host_buffer_reusing(&second, &[1000], Some(b))
            .unwrap();
        assert_eq!(b2.bytes.as_ptr(), ptr_before, "storage must be reused");
        assert_eq!(b2.bytes.capacity(), cap_before);
        let back: Vec<f32> = b2.to_literal_sync().unwrap().to_vec().unwrap();
        assert_eq!(back.len(), 1000);
        assert!(back.iter().all(|&x| x == 2.5));
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        let data = vec![1u32; 4];
        let b = c.buffer_from_host_buffer(&data, &[4], None).unwrap();
        assert!(b.to_literal_sync().unwrap().to_vec::<f32>().is_err());
    }

    #[test]
    fn execution_reports_stub() {
        let c = PjRtClient::cpu().unwrap();
        let exe = c
            .compile(&XlaComputation::from_proto(&HloModuleProto {
                text: String::new(),
            }))
            .unwrap();
        let r = exe.execute_b::<&PjRtBuffer>(&[]);
        assert!(r.is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let c = PjRtClient::cpu().unwrap();
        let data = vec![1u32; 4];
        assert!(c.buffer_from_host_buffer(&data, &[5], None).is_err());
    }
}
