//! Minimal offline stand-in for the `log` facade.
//!
//! `warn!`/`error!` write to stderr; `info!`/`debug!`/`trace!` are
//! compiled but silent unless `CAF_OCL_LOG=1` is set. No global logger
//! registration — this is deliberately tiny.

use std::fmt;

#[doc(hidden)]
pub fn __emit(level: &str, always: bool, args: fmt::Arguments<'_>) {
    if always || std::env::var_os("CAF_OCL_LOG").is_some() {
        eprintln!("[{level}] {args}");
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit("ERROR", true, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit("WARN", true, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit("INFO", false, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit("DEBUG", false, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit("TRACE", false, format_args!($($arg)*)) };
}
