//! Minimal offline stand-in for `once_cell`, backed by `std::sync::OnceLock`.

pub mod sync {
    /// Drop-in subset of `once_cell::sync::OnceCell`.
    #[derive(Debug, Default)]
    pub struct OnceCell<T> {
        inner: std::sync::OnceLock<T>,
    }

    impl<T> OnceCell<T> {
        pub const fn new() -> OnceCell<T> {
            OnceCell {
                inner: std::sync::OnceLock::new(),
            }
        }

        pub fn get(&self) -> Option<&T> {
            self.inner.get()
        }

        pub fn set(&self, value: T) -> Result<(), T> {
            self.inner.set(value)
        }

        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.inner.get_or_init(f)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::OnceCell;

    #[test]
    fn init_once() {
        let c: OnceCell<u32> = OnceCell::new();
        assert!(c.get().is_none());
        assert_eq!(*c.get_or_init(|| 7), 7);
        assert_eq!(*c.get_or_init(|| 8), 7);
        assert_eq!(c.get(), Some(&7));
        assert!(c.set(9).is_err());
    }
}
