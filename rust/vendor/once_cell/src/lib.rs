//! Minimal offline stand-in for `once_cell`, backed by `std::sync::OnceLock`.

pub mod sync {
    /// Drop-in subset of `once_cell::sync::OnceCell`.
    #[derive(Debug, Default)]
    pub struct OnceCell<T> {
        inner: std::sync::OnceLock<T>,
        /// Serializes fallible initializers (`get_or_try_init`): `OnceLock`
        /// has no stable fallible entry point, so without this two racing
        /// callers could both run the initializer and one side's value
        /// (with whatever resources it acquired) would be dropped.
        init_lock: std::sync::Mutex<()>,
    }

    impl<T> OnceCell<T> {
        pub const fn new() -> OnceCell<T> {
            OnceCell {
                inner: std::sync::OnceLock::new(),
                init_lock: std::sync::Mutex::new(()),
            }
        }

        pub fn get(&self) -> Option<&T> {
            self.inner.get()
        }

        pub fn set(&self, value: T) -> Result<(), T> {
            self.inner.set(value)
        }

        pub fn get_or_init<F: FnOnce() -> T>(&self, f: F) -> &T {
            self.inner.get_or_init(f)
        }

        /// Fallible initialization (real `once_cell` API): the initializer
        /// runs at most once at a time; a failure leaves the cell empty so
        /// a later call can retry.
        pub fn get_or_try_init<F, E>(&self, f: F) -> Result<&T, E>
        where
            F: FnOnce() -> Result<T, E>,
        {
            if let Some(v) = self.inner.get() {
                return Ok(v);
            }
            let _g = self
                .init_lock
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            if let Some(v) = self.inner.get() {
                return Ok(v);
            }
            let v = f()?;
            Ok(self.inner.get_or_init(|| v))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::sync::OnceCell;

    #[test]
    fn init_once() {
        let c: OnceCell<u32> = OnceCell::new();
        assert!(c.get().is_none());
        assert_eq!(*c.get_or_init(|| 7), 7);
        assert_eq!(*c.get_or_init(|| 8), 7);
        assert_eq!(c.get(), Some(&7));
        assert!(c.set(9).is_err());
    }

    #[test]
    fn try_init_failure_leaves_cell_retryable() {
        let c: OnceCell<u32> = OnceCell::new();
        let r: Result<&u32, &'static str> = c.get_or_try_init(|| Err("nope"));
        assert_eq!(r, Err("nope"));
        assert!(c.get().is_none(), "failed init must leave the cell empty");
        assert_eq!(c.get_or_try_init(|| Ok::<u32, &'static str>(3)), Ok(&3));
        assert_eq!(c.get_or_try_init(|| Err("late")), Ok(&3));
    }
}
