//! Statistics for the bench harness: mean, standard deviation, 95%
//! confidence intervals — the paper plots "means of 10–50 runs with error
//! bars showing the 95% confidence intervals" (Figs 3–8).

/// Summary of a sample of measurements (seconds or any unit).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub sd: f64,
    /// Half-width of the 95% confidence interval of the mean.
    pub ci95: f64,
    pub min: f64,
    pub max: f64,
}

/// Two-sided t critical values (df -> t_{0.975}); interpolated tail.
fn t975(df: usize) -> f64 {
    const TABLE: [(usize, f64); 14] = [
        (1, 12.706),
        (2, 4.303),
        (3, 3.182),
        (4, 2.776),
        (5, 2.571),
        (6, 2.447),
        (7, 2.365),
        (8, 2.306),
        (9, 2.262),
        (10, 2.228),
        (15, 2.131),
        (20, 2.086),
        (30, 2.042),
        (60, 2.000),
    ];
    if df == 0 {
        return f64::NAN;
    }
    for (d, t) in TABLE {
        if df <= d {
            return t;
        }
    }
    1.96
}

/// Summarize a sample; `ci95` uses the t distribution.
pub fn summarize(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary {
            n: 0,
            mean: f64::NAN,
            sd: f64::NAN,
            ci95: f64::NAN,
            min: f64::NAN,
            max: f64::NAN,
        };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let sd = var.sqrt();
    let ci95 = if n > 1 {
        t975(n - 1) * sd / (n as f64).sqrt()
    } else {
        0.0
    };
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    Summary {
        n,
        mean,
        sd,
        ci95,
        min,
        max,
    }
}

/// Percentile of a sample by the nearest-rank method (`p` in `[0, 1]`,
/// e.g. 0.5 / 0.99 / 0.999). NaN on an empty sample. Used by the soak
/// harness for the per-request-class p50/p99/p999 latency report — tail
/// percentiles, not means, are what overload behavior is judged by.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let p = p.clamp(0.0, 1.0);
    // nearest rank: ceil(p * n), 1-based; p = 0 maps to the minimum
    let rank = (p * sorted.len() as f64).ceil() as usize;
    sorted[rank.max(1) - 1]
}

/// Ordinary least squares fit `y = a + b x`; returns `(a, b)`. Used to
/// report slopes ("the GPU exhibits linear scaling with about half the
/// slope", Fig 3).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = if den == 0.0 { 0.0 } else { num / den };
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constants() {
        let s = summarize(&[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.sd, 0.0);
        assert_eq!(s.ci95, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_known_values() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.sd - 1.0).abs() < 1e-12);
        // t(2) = 4.303 -> ci = 4.303 / sqrt(3)
        assert!((s.ci95 - 4.303 / 3f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn fit_recovers_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_sample() {
        assert!(summarize(&[]).mean.is_nan());
    }
}
