//! A small property-testing framework (crates.io `proptest` is unavailable
//! offline; see DESIGN.md §3). Deterministic seeded generation, a failure
//! report carrying the reproducing seed, and size-based shrinking for the
//! common case of `Vec` inputs.
//!
//! Used for the coordinator invariants: mailbox ordering, routing, WAH
//! round-trips, compaction properties.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        // CI-friendly default; override via CAF_OCL_PROP_CASES
        let cases = std::env::var("CAF_OCL_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        PropConfig { cases, seed: 0xCAF0 }
    }
}

/// Run `prop` over `cases` generated inputs; panics with the reproducing
/// seed and (shrunken, when possible) input on failure.
pub fn check<T, G, P>(cfg: PropConfig, mut generate: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = generate(&mut rng);
        if let Err(why) = prop(&input) {
            panic!(
                "property failed (case {case}, seed {case_seed:#x}): {why}\ninput: {input:?}"
            );
        }
    }
}

/// Like [`check`] but shrinks `Vec` inputs by halving before reporting.
pub fn check_vec<T, G, P>(cfg: PropConfig, mut generate: G, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut Rng) -> Vec<T>,
    P: FnMut(&[T]) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let input = generate(&mut rng);
        if let Err(why) = prop(&input) {
            // shrink: repeatedly try dropping halves while the failure holds
            let mut best = input.clone();
            let mut why_best = why;
            loop {
                let n = best.len();
                if n <= 1 {
                    break;
                }
                let halves = [best[..n / 2].to_vec(), best[n / 2..].to_vec()];
                let mut shrunk = false;
                for h in halves {
                    if let Err(w) = prop(&h) {
                        best = h;
                        why_best = w;
                        shrunk = true;
                        break;
                    }
                }
                if !shrunk {
                    break;
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x}): {why_best}\nshrunk input ({} elems): {best:?}",
                best.len()
            );
        }
    }
}

/// Convenience assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_eq<A: PartialEq + std::fmt::Debug>(a: A, b: A) -> Result<(), String> {
    if a == b {
        Ok(())
    } else {
        Err(format!("{a:?} != {b:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            PropConfig { cases: 32, seed: 1 },
            |r| r.below(100),
            |&x| ensure(x < 100, "bound"),
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports_seed() {
        check(
            PropConfig { cases: 32, seed: 2 },
            |r| r.below(100),
            |&x| ensure(x < 50, "too big"),
        );
    }

    #[test]
    #[should_panic(expected = "shrunk input")]
    fn vec_failures_shrink() {
        check_vec(
            PropConfig { cases: 8, seed: 3 },
            |r| (0..64).map(|_| r.below(100) as u32).collect(),
            |xs| ensure(xs.iter().all(|&x| x < 90), "found >= 90"),
        );
    }
}
