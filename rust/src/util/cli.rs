//! Minimal CLI argument parsing (clap is unavailable offline; DESIGN.md §3).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args —
//! enough for the `repro` launcher and every bench/example binary.

use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args` (skipping the binary name).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("run --n 42 --mode=fast pos1 pos2 --verbose");
        assert_eq!(a.positional, vec!["run", "pos1", "pos2"]);
        assert_eq!(a.usize("n", 0), 42);
        assert_eq!(a.get("mode"), Some("fast"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize("n", 7), 7);
        assert_eq!(a.f64("x", 1.5), 1.5);
        assert_eq!(a.get_or("mode", "slow"), "slow");
    }

    #[test]
    fn flag_before_positional() {
        // a value-less -- option followed by a positional is ambiguous;
        // we treat the next non-`--` token as its value by design
        let a = parse("--k v rest");
        assert_eq!(a.get("k"), Some("v"));
        assert_eq!(a.positional, vec!["rest"]);
    }
}
