//! Deterministic PRNG (SplitMix64 core + convenience distributions).
//!
//! Used by workload generators, the property-test framework, and benches;
//! seeds are always explicit so every experiment is reproducible.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`; `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // multiply-shift rejection-free mapping (slight bias is fine here)
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Zipf-ish rank distribution over `[0, n)` with exponent `s` (used by
    /// the indexing workload generator — skewed value frequencies are the
    /// realistic case for bitmap indexes).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        // inverse-CDF on a truncated power law; cheap and good enough
        let u = self.f64().max(1e-12);
        let x = ((n as f64).powf(1.0 - s) * u + (1.0 - u)).powf(1.0 / (1.0 - s));
        (x.floor() as u64).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    pub fn fill_u32(&mut self, n: usize, bound: u32) -> Vec<u32> {
        (0..n).map(|_| self.below(bound as u64) as u32).collect()
    }

    pub fn fill_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32() * 2.0 - 1.0).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_in_range_and_skewed() {
        let mut r = Rng::new(3);
        let mut head = 0;
        for _ in 0..10_000 {
            let v = r.zipf(1000, 1.2);
            assert!(v < 1000);
            if v < 10 {
                head += 1;
            }
        }
        // strongly skewed towards small ranks
        assert!(head > 4_000, "zipf head mass too small: {head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
