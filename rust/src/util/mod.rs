//! Substrate utilities: deterministic PRNG, a property-testing
//! mini-framework (no crates.io proptest in this offline environment — see
//! DESIGN.md §3), statistics for the bench harness, and a small CLI parser.

pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
