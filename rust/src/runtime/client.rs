//! The device command-queue thread: owns a `PjRtClient`, compiled
//! executables, and device-resident buffers; processes commands in order
//! (OpenCL's default in-order command queue).
//!
//! Freed upload buffers are recycled through a per-device [`BufferPool`]
//! keyed by `(dtype, size class)` — see [`PoolConfig`] — so steady-state
//! pipelines stop allocating device memory per stage.
//!
//! Simulated device profiles (Tesla/Phi, DESIGN.md §2) inject their transfer
//! and compute cost model here as sleep padding, so end-to-end measurements
//! through the actor system reproduce the paper's heterogeneous-offload
//! behavior on hardware we do not have.

use super::artifact::Dtype;
use super::chan::Chan;
use super::event::Event;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Host-side tensor data (one flat array; shapes live in the manifest).
#[derive(Clone, Debug, PartialEq)]
pub enum HostData {
    U32(Vec<u32>),
    F32(Vec<f32>),
}

impl HostData {
    pub fn len(&self) -> usize {
        match self {
            HostData::U32(v) => v.len(),
            HostData::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostData::U32(_) => Dtype::U32,
            HostData::F32(_) => Dtype::F32,
        }
    }

    pub fn bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn into_u32(self) -> Result<Vec<u32>> {
        match self {
            HostData::U32(v) => Ok(v),
            _ => Err(anyhow!("expected u32 data")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostData::F32(v) => Ok(v),
            _ => Err(anyhow!("expected f32 data")),
        }
    }
}

/// Cost model of a simulated device (the Tesla / Xeon Phi stand-ins).
/// `None` paddings mean "the real PJRT CPU device".
#[derive(Clone, Copy, Debug, Default)]
pub struct PadModel {
    /// Fixed per-command dispatch latency (PCIe round trip, driver).
    pub launch: Duration,
    /// Host<->device copy bandwidth in bytes/second (0 = infinite).
    pub bytes_per_sec: f64,
    /// Kernel time multiplier relative to the real PJRT execution
    /// (0.5 = twice as fast as the host; 1.0 = same; >1 slower).
    pub compute_scale: f64,
    /// Burn a core while padding instead of sleeping — models drivers whose
    /// offload runtime busy-polls the host (the Xeon Phi's MPSS stack; this
    /// is what makes Phi offload hurt the host side in Fig 7b).
    pub busy_wait: bool,
}

impl PadModel {
    /// Modeled cost of moving `bytes` across the host↔device boundary:
    /// the fixed dispatch latency plus the bandwidth term. Public because
    /// the cost-aware placement policy uses it to estimate a request's
    /// dispatch+transfer cost *before* routing (the Fig 7b steering
    /// input); the queue thread uses the same number as its sleep pad, so
    /// the estimate and the simulation cannot drift apart.
    pub fn transfer_time(&self, bytes: usize) -> Duration {
        let mut d = self.launch;
        if self.bytes_per_sec > 0.0 {
            d += Duration::from_secs_f64(bytes as f64 / self.bytes_per_sec);
        }
        d
    }

    fn pad_for(&self, d: Duration) {
        if self.busy_wait {
            let deadline = Instant::now() + d;
            while Instant::now() < deadline {
                std::hint::spin_loop();
            }
        } else {
            std::thread::sleep(d);
        }
    }

    fn compute_pad(&self, real: Duration) -> Duration {
        let scaled = if self.compute_scale > 0.0 {
            real.mul_f64(self.compute_scale)
        } else {
            real
        };
        self.launch + scaled.saturating_sub(real)
    }
}

type DownloadCb = Box<dyn FnOnce(Result<HostData, String>) + Send>;

/// Upload source: owned host data or an Arc shared with actor messages —
/// the copy into the device happens on the queue thread either way (the
/// `clEnqueueWriteBuffer` model), so senders never pre-copy payloads.
#[derive(Clone, Debug)]
pub enum UploadSrc {
    Owned(HostData),
    SharedU32(Arc<Vec<u32>>),
    SharedF32(Arc<Vec<f32>>),
}

impl UploadSrc {
    pub fn bytes(&self) -> usize {
        match self {
            UploadSrc::Owned(d) => d.bytes(),
            UploadSrc::SharedU32(v) => v.len() * 4,
            UploadSrc::SharedF32(v) => v.len() * 4,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            UploadSrc::Owned(d) => d.dtype(),
            UploadSrc::SharedU32(_) => Dtype::U32,
            UploadSrc::SharedF32(_) => Dtype::F32,
        }
    }
}

impl From<HostData> for UploadSrc {
    fn from(d: HostData) -> Self {
        UploadSrc::Owned(d)
    }
}

/// Host-side emulation of a kernel's semantics, for backends that cannot
/// execute HLO (the vendored `xla` stub). A manifest entry carrying
/// `emu=<op>` in its extras field is registered through
/// [`DeviceQueue::compile_emulated`] instead of the HLO compile path; the
/// queue thread then computes the output from the (host-memory) input
/// buffers. This keeps the full facade pipeline — upload, execute,
/// download, events, buffer pool, sim padding — exercisable in
/// environments without the real PJRT backend, e.g. the distributed
/// integration tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HostOp {
    /// Output = first input, verbatim (the paper's `empty_*` kernels).
    Identity,
    /// Elementwise sum across all inputs (`u32` wraps).
    Add,
}

impl HostOp {
    /// Parse a manifest `emu=` value.
    pub fn parse(s: &str) -> Option<HostOp> {
        match s {
            "identity" => Some(HostOp::Identity),
            "add" => Some(HostOp::Add),
            _ => None,
        }
    }

    /// Whether every input must share one element count. Exhaustive on
    /// purpose: a new op must decide explicitly instead of inheriting a
    /// fail-open default.
    fn requires_uniform_shapes(self) -> bool {
        match self {
            // passes input 0 through verbatim; trailing inputs may be
            // differently shaped (multi-shape kernels batch per class and
            // land here with proportional, not equal, lengths)
            HostOp::Identity => false,
            // elementwise fold across all inputs
            HostOp::Add => true,
        }
    }

    fn apply(self, inputs: &[HostData], out_dtype: Dtype) -> Result<HostData, String> {
        let first = inputs
            .first()
            .ok_or_else(|| "emulated kernel needs at least one input".to_string())?;
        for (i, d) in inputs.iter().enumerate() {
            if d.dtype() != out_dtype {
                return Err(format!(
                    "input {i} is {:?}, output wants {:?}",
                    d.dtype(),
                    out_dtype
                ));
            }
            if self.requires_uniform_shapes() && d.len() != first.len() {
                return Err(format!(
                    "input {i} has {} elements, input 0 has {}",
                    d.len(),
                    first.len()
                ));
            }
        }
        match self {
            HostOp::Identity => Ok(first.clone()),
            HostOp::Add => match first {
                HostData::U32(_) => {
                    let mut acc = vec![0u32; first.len()];
                    for d in inputs {
                        if let HostData::U32(v) = d {
                            for (a, x) in acc.iter_mut().zip(v) {
                                *a = a.wrapping_add(*x);
                            }
                        }
                    }
                    Ok(HostData::U32(acc))
                }
                HostData::F32(_) => {
                    let mut acc = vec![0f32; first.len()];
                    for d in inputs {
                        if let HostData::F32(v) = d {
                            for (a, x) in acc.iter_mut().zip(v) {
                                *a += *x;
                            }
                        }
                    }
                    Ok(HostData::F32(acc))
                }
            },
        }
    }
}

/// Commands of the in-order device queue.
pub enum QueueCmd {
    /// Compile the HLO-text artifact at `path` and cache it under `name`.
    Compile {
        name: String,
        path: PathBuf,
        done: Event,
    },
    /// Register a host-emulated kernel under `name` (no HLO involved).
    CompileEmu {
        name: String,
        op: HostOp,
        done: Event,
    },
    /// Copy host data into a fresh device buffer `id`.
    Upload {
        id: u64,
        data: UploadSrc,
        done: Event,
    },
    /// Run executable `exec` over buffer args; result becomes buffer `out`.
    /// Waits for `deps` (cross-queue dependencies) first.
    Execute {
        exec: String,
        args: Vec<u64>,
        out: u64,
        out_dtype: Dtype,
        deps: Vec<Event>,
        done: Event,
    },
    /// Fused upload+execute: stage every input from host data and run
    /// `exec` over the staged buffers in ONE queue command. Batched and
    /// all-`Val` launches use this so a request traverses the command
    /// channel once instead of once per argument plus once for the kernel;
    /// the staged inputs die with the invocation (their storage returns to
    /// the buffer pool on the queue thread).
    FusedExec {
        exec: String,
        inputs: Vec<UploadSrc>,
        out: u64,
        out_dtype: Dtype,
        done: Event,
    },
    /// Read a buffer back; `and_then` runs on the queue thread.
    Download { id: u64, and_then: DownloadCb },
    /// Release a device buffer.
    Free { id: u64 },
    /// Completes when every previously enqueued command retired (clFinish).
    Barrier { done: Event },
    /// Fault injection: the queue thread sleeps for the duration, stalling
    /// every later command behind it — a slow/hung device, as opposed to
    /// `Stop`'s clean death. Only the chaos harness pushes this.
    Stall { dur: Duration },
    Stop,
}

/// Execution statistics of one device queue (metrics for Figs 5/6 and the
/// placement tier's queue-depth gauge).
#[derive(Default)]
pub struct ExecStats {
    /// Kernel launches *submitted* to this queue (`Execute` + `FusedExec`),
    /// counted at enqueue time — the per-device distribution metric the
    /// placement tests assert on.
    pub launched: AtomicU64,
    /// Launches submitted but not yet retired: the queue-depth gauge that
    /// feeds [`least-inflight placement`](crate::opencl::PlacementPolicy).
    pub inflight: AtomicU64,
    /// Exponentially weighted moving average of per-launch service time in
    /// nanoseconds (α = 1/8), sampled on the queue thread as each launch
    /// retires — wall time including the simulated transfer/compute pads.
    /// Feeds the cost-aware policy's "queue depth × mean service time"
    /// term. Single-writer (the queue thread); 0 until the first launch
    /// retires.
    pub ewma_service_ns: AtomicU64,
    /// Occupancy published by val-mode batchers bound to this device, in
    /// REQUESTS: window entries admitted but not yet flushed, plus
    /// flushed-but-unretired launches scaled by their request count. This
    /// is the placement tier's queue-depth signal for *batched* replicas —
    /// the dispatcher counts routed messages per request but a batcher
    /// launches once per flush, so its routed-minus-retired estimate can
    /// never reconcile there, and `launched`/`inflight` alone undercount a
    /// window that has not flushed yet.
    pub batch_pending: AtomicU64,
    /// Occupancy published by pipeline drivers bound to this device, in
    /// REQUESTS: requests admitted into any stage of a device-resident
    /// pipeline and not yet resolved (reply or error). This is the
    /// placement tier's queue-depth signal for *pipeline* replicas — a
    /// request routed once fans out into one launch per stage, so the
    /// dispatcher's routed-minus-retired estimate and the raw
    /// `launched`/`inflight` gauges both miscount pipeline depth.
    pub pipe_pending: AtomicU64,
    /// EWMA of end-to-end pipeline service time in nanoseconds (α = 1/8),
    /// sampled by the pipeline driver as each request's final stage
    /// resolves — the `depth × service` term of cost-aware steering for
    /// pipeline replicas. Single-writer (the driver's mailbox serializes
    /// its continuations); 0 until the first request resolves.
    pub pipe_ewma_ns: AtomicU64,
    /// High-water mark of `inflight` (updated via `fetch_max` at submit
    /// time): how many launches this queue ever held concurrently. The
    /// stage-interleaving gate asserts on it — lock-step composition can
    /// never push it past 1, interleaved stages of different requests can.
    pub inflight_peak: AtomicU64,
    /// Buffers migrated OFF this device by the dispatcher's explicit
    /// device-to-device transfer path (download-from-src + upload-to-dst).
    pub migrations: AtomicU64,
    /// Requests bound to this device that the admission layer failed
    /// fast for exceeding their `max_queue_wait` deadline (from a batch
    /// window or a facade mailbox) — per-device counterpart of the
    /// pool-level [`AdmissionStats`](crate::opencl::AdmissionStats).
    pub deadline_failed: AtomicU64,
    /// Requests bound to this device that `ShedPolicy::DropOldest`
    /// dropped from a batch window to admit newer work.
    pub shed: AtomicU64,
    pub execs: AtomicU64,
    pub exec_ns: AtomicU64,
    pub uploads: AtomicU64,
    pub upload_bytes: AtomicU64,
    pub downloads: AtomicU64,
    pub download_bytes: AtomicU64,
    pub compiles: AtomicU64,
    /// Uploads served by recycling a pooled buffer's storage.
    pub pool_hits: AtomicU64,
    /// Uploads that had to allocate fresh storage.
    pub pool_misses: AtomicU64,
    /// Freed buffers returned to the pool.
    pub pool_returned: AtomicU64,
    /// Freed buffers dropped because the pool was full/disabled.
    pub pool_evicted: AtomicU64,
}

impl ExecStats {
    /// Current queue depth: launches submitted but not yet retired.
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Total launches submitted to this queue.
    pub fn launched(&self) -> u64 {
        self.launched.load(Ordering::Relaxed)
    }

    /// EWMA of per-launch service time (zero until a launch retired).
    pub fn ewma_service(&self) -> Duration {
        Duration::from_nanos(self.ewma_service_ns.load(Ordering::Relaxed))
    }

    /// Batcher-published occupancy in requests (see [`ExecStats::batch_pending`]).
    pub fn batch_occupancy(&self) -> u64 {
        self.batch_pending.load(Ordering::Relaxed)
    }

    /// Record `n` requests admitted into a batching window on this device.
    pub(crate) fn note_batch_admitted(&self, n: u64) {
        self.batch_pending.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` batched requests retired (their flush completed, failed,
    /// or was refused by a closed queue). Saturating: the gauge is a
    /// routing heuristic, and wrapping it to u64::MAX on an accounting bug
    /// would freeze a replica out of rotation forever.
    pub(crate) fn note_batch_retired(&self, n: u64) {
        let _ = self
            .batch_pending
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
    }

    /// Pipeline-driver-published occupancy in requests (see
    /// [`ExecStats::pipe_pending`]).
    pub fn pipe_occupancy(&self) -> u64 {
        self.pipe_pending.load(Ordering::Relaxed)
    }

    /// Record `n` requests admitted into a pipeline replica on this device.
    pub(crate) fn note_pipe_admitted(&self, n: u64) {
        self.pipe_pending.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` pipeline requests resolved (reply or error). Saturating
    /// for the same reason as [`ExecStats::note_batch_retired`]: the gauge
    /// is a routing heuristic, and wrapping it on an accounting bug would
    /// freeze a replica out of rotation forever.
    pub(crate) fn note_pipe_retired(&self, n: u64) {
        let _ = self
            .pipe_pending
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(n))
            });
    }

    /// EWMA of end-to-end pipeline service time (zero until a pipeline
    /// request resolved).
    pub fn pipe_ewma(&self) -> Duration {
        Duration::from_nanos(self.pipe_ewma_ns.load(Ordering::Relaxed))
    }

    /// Fold one resolved pipeline request's end-to-end time into the
    /// pipeline EWMA. Single logical writer: the owning driver's mailbox
    /// serializes its continuations, so load/store suffices (same
    /// justification as [`ExecStats::note_service`]).
    pub(crate) fn note_pipe_service(&self, d: Duration) {
        let sample = (d.as_nanos() as u64).max(1);
        let old = self.pipe_ewma_ns.load(Ordering::Relaxed);
        let new = if old == 0 {
            sample
        } else {
            (old.saturating_mul(7).saturating_add(sample) / 8).max(1)
        };
        self.pipe_ewma_ns.store(new, Ordering::Relaxed);
    }

    /// High-water mark of concurrent launches on this queue.
    pub fn inflight_peak(&self) -> u64 {
        self.inflight_peak.load(Ordering::Relaxed)
    }

    /// Buffers migrated off this device so far.
    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    /// Record one buffer migrated off this device.
    pub(crate) fn note_migration(&self) {
        self.migrations.fetch_add(1, Ordering::Relaxed);
    }

    /// Requests failed fast on this device by the deadline check.
    pub fn deadline_failed(&self) -> u64 {
        self.deadline_failed.load(Ordering::Relaxed)
    }

    /// Requests shed from this device's batch windows by `DropOldest`.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Record `n` requests failed fast by the deadline check.
    pub(crate) fn note_deadline_failed(&self, n: u64) {
        self.deadline_failed.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` requests shed from a batch window.
    pub(crate) fn note_shed(&self, n: u64) {
        self.shed.fetch_add(n, Ordering::Relaxed);
    }

    /// Fold one retired launch's service time into the EWMA (queue-thread
    /// only — single writer, so plain load/store suffices). The first
    /// sample seeds the average; later samples blend at α = 1/8. Clamped
    /// to ≥ 1 ns so a seeded gauge never reads as "no samples yet".
    pub(crate) fn note_service(&self, d: Duration) {
        let sample = (d.as_nanos() as u64).max(1);
        let old = self.ewma_service_ns.load(Ordering::Relaxed);
        let new = if old == 0 {
            sample
        } else {
            (old.saturating_mul(7).saturating_add(sample) / 8).max(1)
        };
        self.ewma_service_ns.store(new, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> (u64, Duration) {
        (
            self.execs.load(Ordering::Relaxed),
            Duration::from_nanos(self.exec_ns.load(Ordering::Relaxed)),
        )
    }

    /// (hits, misses, returned, evicted) of the device buffer pool.
    pub fn pool_snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.pool_hits.load(Ordering::Relaxed),
            self.pool_misses.load(Ordering::Relaxed),
            self.pool_returned.load(Ordering::Relaxed),
            self.pool_evicted.load(Ordering::Relaxed),
        )
    }
}

/// Configuration of the device-side buffer pool.
///
/// Freed upload buffers are recycled by `(dtype, size class)` — size class
/// is the next power of two of the byte length — instead of allocating
/// fresh device memory on every `upload`, so multi-stage pipelines
/// (`gpu_pipeline`, `fig3_wah_index`) stop paying an allocation per stage.
///
/// Pool entries are inserted when the `Free` command *retires* on the
/// in-order queue thread, which is what guarantees a recycled buffer is
/// never handed out while a prior command's ready-event is still pending:
/// every command that references the buffer was enqueued before the `Free`
/// and has therefore already completed.
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    pub enabled: bool,
    /// Max buffers kept per (dtype, size-class) bucket.
    pub max_per_class: usize,
    /// Cap on total pooled bytes (counted as each entry's size-class lower
    /// bound, `1 << class_filled(bytes)`).
    pub max_bytes: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            enabled: true,
            max_per_class: 8,
            max_bytes: 256 << 20,
        }
    }
}

/// log2 size class *covering* `bytes` (round up) — the lookup key for an
/// upload of that many bytes.
fn class_covering(bytes: usize) -> u32 {
    bytes.max(1).next_power_of_two().trailing_zeros()
}

/// log2 size class a buffer of `bytes` bytes *fills* (round down) — the
/// filing key for a freed buffer. Rounding the two keys in opposite
/// directions guarantees every hit's storage is at least as large as the
/// request, so a recycled buffer never has to reallocate (a same-class-by-
/// round-up match could otherwise be smaller than the request and count a
/// pool hit that still pays the allocation).
fn class_filled(bytes: usize) -> u32 {
    usize::BITS - 1 - bytes.max(1).leading_zeros()
}

/// Freed-buffer pool living on the queue thread (single-threaded — the
/// in-order queue is the synchronization).
struct BufferPool {
    cfg: PoolConfig,
    classes: HashMap<(Dtype, u32), Vec<xla::PjRtBuffer>>,
    bytes: usize,
}

impl BufferPool {
    fn new(cfg: PoolConfig) -> BufferPool {
        BufferPool {
            cfg,
            classes: HashMap::new(),
            bytes: 0,
        }
    }

    /// Take a recyclable buffer for an upload of `bytes` bytes of `dtype`.
    fn take(&mut self, dtype: Dtype, bytes: usize) -> Option<xla::PjRtBuffer> {
        let class = class_covering(bytes);
        let bucket = self.classes.get_mut(&(dtype, class))?;
        let buf = bucket.pop()?;
        self.bytes = self.bytes.saturating_sub(1usize << class);
        Some(buf)
    }

    /// Return a freed buffer of `bytes` bytes; returns false when the
    /// buffer was evicted instead (pool full or disabled).
    fn put(&mut self, dtype: Dtype, bytes: usize, buf: xla::PjRtBuffer) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        let class = class_filled(bytes);
        let class_bytes = 1usize << class;
        if self.bytes + class_bytes > self.cfg.max_bytes {
            return false;
        }
        let bucket = self.classes.entry((dtype, class)).or_default();
        if bucket.len() >= self.cfg.max_per_class {
            return false;
        }
        bucket.push(buf);
        self.bytes += class_bytes;
        true
    }
}

/// Handle to a device command-queue thread.
pub struct DeviceQueue {
    name: String,
    cmds: Chan<QueueCmd>,
    next_buf: AtomicU64,
    stats: Arc<ExecStats>,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl DeviceQueue {
    /// Start the queue thread with the default buffer pool; fails if the
    /// PJRT client cannot be created.
    pub fn start(name: impl Into<String>, pad: Option<PadModel>) -> Result<Arc<DeviceQueue>> {
        Self::start_with(name, pad, PoolConfig::default())
    }

    /// Start with an explicit buffer-pool configuration.
    pub fn start_with(
        name: impl Into<String>,
        pad: Option<PadModel>,
        pool: PoolConfig,
    ) -> Result<Arc<DeviceQueue>> {
        let name = name.into();
        let cmds: Chan<QueueCmd> = Chan::new();
        let stats = Arc::new(ExecStats::default());
        let (init_tx, init_rx) = std::sync::mpsc::channel::<Result<(), String>>();
        let thread_cmds = cmds.clone();
        let thread_stats = stats.clone();
        let tname = format!("device-{name}");
        let worker = std::thread::Builder::new()
            .name(tname)
            .spawn(move || queue_loop(thread_cmds, thread_stats, pad, pool, init_tx))?;
        init_rx
            .recv()
            .map_err(|_| anyhow!("device thread died during init"))?
            .map_err(|e| anyhow!("PJRT init failed: {e}"))?;
        Ok(Arc::new(DeviceQueue {
            name,
            cmds,
            next_buf: AtomicU64::new(1),
            stats,
            worker: Mutex::new(Some(worker)),
        }))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn stats(&self) -> &ExecStats {
        &self.stats
    }

    pub fn fresh_buffer_id(&self) -> u64 {
        self.next_buf.fetch_add(1, Ordering::Relaxed)
    }

    fn push(&self, cmd: QueueCmd) -> bool {
        let ok = self.cmds.push(cmd);
        if !ok {
            log::warn!("device queue {} is closed; command dropped", self.name);
        }
        ok
    }

    /// Fail `done` when the closed queue refused the command that carried
    /// it: the command will never run, so a waiter must error out now
    /// instead of sitting in `Event::wait` for its full timeout (e.g. a
    /// replica respawn racing device shutdown would otherwise block its
    /// helper thread for the whole `build_timeout`).
    fn push_or_fail(&self, cmd: QueueCmd, done: &Event) -> bool {
        let ok = self.push(cmd);
        if !ok {
            done.fail(format!("device queue {} is closed", self.name));
        }
        ok
    }

    /// Account a kernel submission on the launch counter and queue-depth
    /// gauge. Must run *before* the push: the queue thread decrements
    /// `inflight` when the launch retires, so incrementing after the push
    /// could race a fast retirement into an underflow.
    fn pre_launch(&self) {
        self.stats.launched.fetch_add(1, Ordering::Relaxed);
        let depth = self.stats.inflight.fetch_add(1, Ordering::Relaxed) + 1;
        self.stats.inflight_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// Undo the accounting for a submission the closed queue refused: the
    /// command will never execute, so it must count neither as a launch
    /// (placement's distribution metric) nor as queue depth.
    fn launch_refused(&self) {
        self.stats.launched.fetch_sub(1, Ordering::Relaxed);
        self.stats.inflight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Compile an artifact (idempotent per name).
    pub fn compile(&self, name: impl Into<String>, path: PathBuf) -> Event {
        let done = Event::new();
        done.mark_enqueued();
        self.push_or_fail(
            QueueCmd::Compile {
                name: name.into(),
                path,
                done: done.clone(),
            },
            &done,
        );
        done
    }

    /// Register a host-emulated kernel (idempotent per name) — the stub
    /// backend's stand-in for compilation; see [`HostOp`].
    pub fn compile_emulated(&self, name: impl Into<String>, op: HostOp) -> Event {
        let done = Event::new();
        done.mark_enqueued();
        self.push_or_fail(
            QueueCmd::CompileEmu {
                name: name.into(),
                op,
                done: done.clone(),
            },
            &done,
        );
        done
    }

    /// Asynchronously copy host data to the device; returns (buffer id,
    /// completion event).
    pub fn upload(&self, data: impl Into<UploadSrc>) -> (u64, Event) {
        self.upload_src(data.into())
    }

    fn upload_src(&self, data: UploadSrc) -> (u64, Event) {
        let id = self.fresh_buffer_id();
        let done = Event::new();
        done.mark_enqueued();
        self.push_or_fail(
            QueueCmd::Upload {
                id,
                data,
                done: done.clone(),
            },
            &done,
        );
        (id, done)
    }

    /// Enqueue a kernel execution; returns (output buffer id, event).
    pub fn execute(
        &self,
        exec: impl Into<String>,
        args: Vec<u64>,
        out_dtype: Dtype,
        deps: Vec<Event>,
    ) -> (u64, Event) {
        let out = self.fresh_buffer_id();
        let done = Event::new();
        done.mark_enqueued();
        self.pre_launch();
        if !self.push_or_fail(
            QueueCmd::Execute {
                exec: exec.into(),
                args,
                out,
                out_dtype,
                deps,
                done: done.clone(),
            },
            &done,
        ) {
            self.launch_refused();
        }
        (out, done)
    }

    /// Fused upload+execute: stage `inputs` and run the kernel over them in
    /// a single queue command (one channel traversal for the whole launch —
    /// the submission path of batched and all-`Val` requests). Returns
    /// (output buffer id, completion event); the staged inputs are internal
    /// to the invocation and recycled on the queue thread.
    pub fn execute_fused(
        &self,
        exec: impl Into<String>,
        inputs: Vec<UploadSrc>,
        out_dtype: Dtype,
    ) -> (u64, Event) {
        let out = self.fresh_buffer_id();
        let done = Event::new();
        done.mark_enqueued();
        self.pre_launch();
        if !self.push_or_fail(
            QueueCmd::FusedExec {
                exec: exec.into(),
                inputs,
                out,
                out_dtype,
                done: done.clone(),
            },
            &done,
        ) {
            self.launch_refused();
        }
        (out, done)
    }

    /// Asynchronous download; the callback runs on the queue thread (the
    /// OpenCL completion-callback pattern — never call blocking queue ops
    /// from inside it). Returns whether the command was accepted: a closed
    /// queue refuses it and DROPS the callback un-run (any promises it
    /// captured resolve through their own drop path), so callers that keep
    /// side accounting — e.g. the batcher's occupancy gauge — must settle
    /// it when this returns `false`.
    pub fn download_with<F>(&self, id: u64, f: F) -> bool
    where
        F: FnOnce(Result<HostData, String>) + Send + 'static,
    {
        self.push(QueueCmd::Download {
            id,
            and_then: Box::new(f),
        })
    }

    /// Blocking download (must not be called from the queue thread itself).
    pub fn download(&self, id: u64, timeout: Duration) -> Result<HostData> {
        let reply: Chan<Result<HostData, String>> = Chan::new();
        let r2 = reply.clone();
        if !self.download_with(id, move |res| {
            r2.push(res);
        }) {
            // refused by the closed queue: the callback will never run, so
            // fail now instead of sitting out the whole timeout
            bail!("device queue {} is closed", self.name);
        }
        reply
            .pop_timeout(timeout)
            .ok_or_else(|| anyhow!("download timed out"))?
            .map_err(|e| anyhow!(e))
    }

    /// Explicit device-to-device transfer: download buffer `id` from this
    /// queue and upload the bytes into a fresh buffer on `dst`. Returns the
    /// destination buffer id and the completion event of the *upload* —
    /// wait on (or chain from) that event before using the new buffer.
    ///
    /// The hop is staged through host memory (download-from-src +
    /// upload-to-dst), which is what both the stub and emulated backends
    /// can do; a real backend with peer-to-peer copies would hook in here,
    /// gated like the rest of the backend surface. Cost-wise the hop pays
    /// both queues' [`PadModel::transfer_time`] pads, exactly the terms the
    /// cost-aware policy prices a cross-device move at.
    ///
    /// The download rides this in-order queue, so it observes every
    /// previously enqueued command on the source buffer (a producer that
    /// failed propagates its error through the download). The upload is
    /// pushed from the source queue thread's completion callback — a
    /// lock-free channel push, never a blocking wait.
    pub fn transfer_to(&self, id: u64, dst: &Arc<DeviceQueue>) -> (u64, Event) {
        let new_id = dst.fresh_buffer_id();
        let done = Event::new();
        done.mark_enqueued();
        self.stats.note_migration();
        let ev = done.clone();
        let dst = dst.clone();
        let accepted = self.download_with(id, move |res| match res {
            Ok(host) => {
                // push_or_fail fails `ev` itself if dst closed meanwhile
                dst.push_or_fail(
                    QueueCmd::Upload {
                        id: new_id,
                        data: UploadSrc::Owned(host),
                        done: ev.clone(),
                    },
                    &ev,
                );
            }
            Err(e) => ev.fail(format!("migration download failed: {e}")),
        });
        if !accepted {
            // closed source queue dropped the callback un-run
            done.fail(format!("device queue {} is closed", self.name));
        }
        (new_id, done)
    }

    /// Fault injection for the chaos harness: stall the queue thread for
    /// `dur`, delaying every command enqueued behind the stall (a slow
    /// replica, not a dead one). Returns whether the queue accepted it.
    pub fn inject_stall(&self, dur: Duration) -> bool {
        self.push(QueueCmd::Stall { dur })
    }

    pub fn free(&self, id: u64) {
        self.push(QueueCmd::Free { id });
    }

    /// clFinish: block until all previously enqueued commands retired.
    pub fn barrier(&self, timeout: Duration) -> Result<()> {
        let done = Event::new();
        self.push_or_fail(QueueCmd::Barrier { done: done.clone() }, &done);
        done.wait(timeout).map_err(|e| anyhow!(e))
    }

    /// Stop the queue thread (drains remaining commands first).
    pub fn stop(&self) {
        self.push(QueueCmd::Stop);
        if let Some(w) = self.worker.lock().unwrap_or_else(|p| p.into_inner()).take() {
            let _ = w.join();
        }
        self.cmds.close();
    }
}

impl Drop for DeviceQueue {
    fn drop(&mut self) {
        // best-effort: release the thread if the owner forgot to stop
        self.cmds.push(QueueCmd::Stop);
        self.cmds.close();
        if let Some(w) = self.worker.lock().unwrap_or_else(|p| p.into_inner()).take() {
            let _ = w.join();
        }
    }
}

struct Buffer {
    buf: xla::PjRtBuffer,
    dtype: Dtype,
    /// Byte size (size-class key on free); keying on bytes rather than an
    /// element count keeps the pool correct for any future element width.
    bytes: usize,
    /// Upload-originated buffers can be recycled; executable outputs come
    /// from the backend and cannot back a future upload.
    poolable: bool,
}

/// Upload adapter over the two `xla` backends. The vendored host-memory
/// stub exposes `buffer_from_host_buffer_reusing` (the buffer pool's
/// allocation-avoidance hook); the real PJRT bindings do not. The
/// `xla-stub` feature (on by default) selects the recycling call; builds
/// that point `xla` at the real bindings (`--no-default-features`) drop the
/// recycled buffer and allocate fresh, so the crate compiles against both.
#[cfg(feature = "xla-stub")]
fn upload_host_buffer<T: xla::ArrayElement>(
    client: &xla::PjRtClient,
    data: &[T],
    dims: &[usize],
    recycled: Option<xla::PjRtBuffer>,
) -> xla::Result<xla::PjRtBuffer> {
    client.buffer_from_host_buffer_reusing(data, dims, recycled)
}

#[cfg(not(feature = "xla-stub"))]
fn upload_host_buffer<T: xla::ArrayElement>(
    client: &xla::PjRtClient,
    data: &[T],
    dims: &[usize],
    recycled: Option<xla::PjRtBuffer>,
) -> xla::Result<xla::PjRtBuffer> {
    drop(recycled); // no recycling hook in the real bindings
    client.buffer_from_host_buffer(data, dims, None)
}

/// How long the in-order queue blocks on one cross-queue dependency.
const DEP_WAIT: Duration = Duration::from_secs(300);

/// Take host data out of an upload source (unwraps shared `Arc`s when this
/// is the last owner, clones otherwise).
fn src_to_host(data: UploadSrc) -> HostData {
    match data {
        UploadSrc::Owned(d) => d,
        UploadSrc::SharedU32(v) => {
            HostData::U32(Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone()))
        }
        UploadSrc::SharedF32(v) => {
            HostData::F32(Arc::try_unwrap(v).unwrap_or_else(|a| (*a).clone()))
        }
    }
}

/// Block the in-order queue on cross-queue dependencies.
fn wait_deps(deps: &[Event]) -> Result<(), String> {
    for d in deps {
        d.wait(DEP_WAIT)
            .map_err(|e| format!("dependency failed: {e}"))?;
    }
    Ok(())
}

/// The queue thread's owned state: PJRT client, compiled executables,
/// resident buffers, and the buffer pool. Extracted from the former
/// monolithic `queue_loop` match so the per-command operations (upload,
/// execute, download, free) compose — `FusedExec` reuses them to run a
/// whole launch off one command-channel traversal.
struct QueueState {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    emus: HashMap<String, HostOp>,
    buffers: HashMap<u64, Buffer>,
    pool: BufferPool,
    pad: Option<PadModel>,
    stats: Arc<ExecStats>,
}

impl QueueState {
    /// Stage a host slice into a device buffer, recycling pooled storage
    /// when a same-class buffer is available (hit/miss accounted).
    fn stage_slice<T: xla::ArrayElement>(
        &mut self,
        data: &[T],
        dtype: Dtype,
    ) -> Result<Buffer, String> {
        let byte_len = data.len() * 4;
        let recycled = self.pool.take(dtype, byte_len);
        if recycled.is_some() {
            self.stats.pool_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.pool_misses.fetch_add(1, Ordering::Relaxed);
        }
        upload_host_buffer(&self.client, data, &[data.len()], recycled)
            .map(|buf| Buffer {
                buf,
                dtype,
                bytes: byte_len,
                poolable: true,
            })
            .map_err(|e| e.to_string())
    }

    /// Stage owned host data (the emulated-execution output path: pool
    /// recycling, but no transfer accounting — the data never crossed the
    /// host boundary).
    fn stage_host(&mut self, data: &HostData) -> Result<Buffer, String> {
        match data {
            HostData::U32(v) => self.stage_slice(&v[..], Dtype::U32),
            HostData::F32(v) => self.stage_slice(&v[..], Dtype::F32),
        }
    }

    fn stage_src(&mut self, data: &UploadSrc) -> Result<Buffer, String> {
        match data {
            UploadSrc::Owned(HostData::U32(v)) => self.stage_slice(&v[..], Dtype::U32),
            UploadSrc::SharedU32(v) => self.stage_slice(&v[..], Dtype::U32),
            UploadSrc::Owned(HostData::F32(v)) => self.stage_slice(&v[..], Dtype::F32),
            UploadSrc::SharedF32(v) => self.stage_slice(&v[..], Dtype::F32),
        }
    }

    /// Account + pad one host→device transfer. Every input of a fused
    /// launch goes through here exactly like a standalone `Upload`, so the
    /// simulated devices charge the same PCIe cost on both paths.
    fn account_transfer(&self, bytes: usize) {
        self.stats.uploads.fetch_add(1, Ordering::Relaxed);
        self.stats
            .upload_bytes
            .fetch_add(bytes as u64, Ordering::Relaxed);
        if let Some(p) = &self.pad {
            p.pad_for(p.transfer_time(bytes));
        }
    }

    /// `Upload`: stage into the resident-buffer map under `id`.
    fn upload(&mut self, id: u64, data: &UploadSrc) -> Result<(), String> {
        self.account_transfer(data.bytes());
        let buf = self.stage_src(data).map_err(|e| format!("upload: {e}"))?;
        self.buffers.insert(id, buf);
        Ok(())
    }

    /// Account a finished kernel run: exec counters + simulated compute pad.
    fn account_exec(&self, real: Duration) {
        self.stats.execs.fetch_add(1, Ordering::Relaxed);
        self.stats
            .exec_ns
            .fetch_add(real.as_nanos() as u64, Ordering::Relaxed);
        if let Some(p) = &self.pad {
            p.pad_for(p.compute_pad(real));
        }
    }

    /// Run a host-emulated kernel over host inputs; the output is staged
    /// like an upload (pool-recycled) under `out`.
    fn run_emulated(
        &mut self,
        op: HostOp,
        exec: &str,
        inputs: &[HostData],
        out: u64,
        out_dtype: Dtype,
    ) -> Result<(), String> {
        let t0 = Instant::now();
        let host = op
            .apply(inputs, out_dtype)
            .map_err(|e| format!("emulated {exec}: {e}"))?;
        self.account_exec(t0.elapsed());
        let buf = self
            .stage_host(&host)
            .map_err(|e| format!("emulated {exec}: staging output: {e}"))?;
        self.buffers.insert(out, buf);
        Ok(())
    }

    /// File a real-backend execution result as the (non-poolable) output.
    fn finish_hlo(
        &mut self,
        buf: xla::PjRtBuffer,
        real: Duration,
        out: u64,
        out_dtype: Dtype,
    ) -> Result<(), String> {
        self.account_exec(real);
        self.buffers.insert(
            out,
            Buffer {
                buf,
                dtype: out_dtype,
                bytes: 0,
                poolable: false, // backend-owned output
            },
        );
        Ok(())
    }

    /// `Execute`: run a kernel over buffers already resident on the device.
    fn execute_resident(
        &mut self,
        exec: &str,
        args: &[u64],
        out: u64,
        out_dtype: Dtype,
    ) -> Result<(), String> {
        if let Some(op) = self.emus.get(exec).copied() {
            let mut inputs = Vec::with_capacity(args.len());
            for a in args {
                let b = self
                    .buffers
                    .get(a)
                    .ok_or_else(|| format!("buffer {a} not resident on device"))?;
                inputs.push(
                    download_buffer(b)
                        .map_err(|e| format!("emulated {exec}: reading arg {a}: {e}"))?,
                );
            }
            return self.run_emulated(op, exec, &inputs, out, out_dtype);
        }
        let t0 = Instant::now();
        let mut res = {
            let exe = self
                .execs
                .get(exec)
                .ok_or_else(|| format!("executable {exec:?} not compiled on this device"))?;
            let mut arg_bufs = Vec::with_capacity(args.len());
            for a in args {
                arg_bufs.push(
                    &self
                        .buffers
                        .get(a)
                        .ok_or_else(|| format!("buffer {a} not resident on device"))?
                        .buf,
                );
            }
            exe.execute_b::<&xla::PjRtBuffer>(&arg_bufs)
                .map_err(|e| format!("execute {exec}: {e}"))?
        };
        self.finish_hlo(res.remove(0).remove(0), t0.elapsed(), out, out_dtype)
    }

    /// `FusedExec`: stage every input and run the kernel, all in one
    /// command. Emulated kernels skip device staging entirely (the inputs
    /// are already host data — only the simulated transfer cost is
    /// charged); real executables stage through the pool and return the
    /// staged storage to it when the launch retires, the same lifecycle as
    /// the unfused `Upload`/`Execute`/`Free` triple.
    fn execute_fused(
        &mut self,
        exec: &str,
        inputs: Vec<UploadSrc>,
        out: u64,
        out_dtype: Dtype,
    ) -> Result<(), String> {
        if let Some(op) = self.emus.get(exec).copied() {
            let mut host = Vec::with_capacity(inputs.len());
            for d in inputs {
                self.account_transfer(d.bytes());
                host.push(src_to_host(d));
            }
            return self.run_emulated(op, exec, &host, out, out_dtype);
        }
        let mut staged = Vec::with_capacity(inputs.len());
        for d in &inputs {
            self.account_transfer(d.bytes());
            let buf = self
                .stage_src(d)
                .map_err(|e| format!("fused {exec}: staging input: {e}"))?;
            staged.push(buf);
        }
        let t0 = Instant::now();
        let run = {
            let exe = self
                .execs
                .get(exec)
                .ok_or_else(|| format!("executable {exec:?} not compiled on this device"))?;
            let arg_bufs: Vec<&xla::PjRtBuffer> = staged.iter().map(|b| &b.buf).collect();
            exe.execute_b::<&xla::PjRtBuffer>(&arg_bufs)
                .map_err(|e| format!("execute {exec}: {e}"))
        };
        let real = t0.elapsed();
        // the invocation's staged inputs die here whether it succeeded or not
        for b in staged {
            self.recycle(b);
        }
        let mut res = run?;
        self.finish_hlo(res.remove(0).remove(0), real, out, out_dtype)
    }

    /// Return a dead buffer's storage to the pool (`Free` semantics).
    fn recycle(&mut self, b: Buffer) {
        if b.poolable {
            if self.pool.put(b.dtype, b.bytes, b.buf) {
                self.stats.pool_returned.fetch_add(1, Ordering::Relaxed);
            } else {
                self.stats.pool_evicted.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn free(&mut self, id: u64) {
        if let Some(b) = self.buffers.remove(&id) {
            self.recycle(b);
        }
    }

    fn download(&mut self, id: u64) -> Result<HostData, String> {
        let b = self
            .buffers
            .get(&id)
            .ok_or_else(|| format!("buffer {id} not resident on device"))?;
        let d = download_buffer(b).map_err(|e| e.to_string())?;
        self.stats.downloads.fetch_add(1, Ordering::Relaxed);
        self.stats
            .download_bytes
            .fetch_add(d.bytes() as u64, Ordering::Relaxed);
        if let Some(p) = &self.pad {
            p.pad_for(p.transfer_time(d.bytes()));
        }
        Ok(d)
    }
}

fn queue_loop(
    cmds: Chan<QueueCmd>,
    stats: Arc<ExecStats>,
    pad: Option<PadModel>,
    pool_cfg: PoolConfig,
    init_tx: std::sync::mpsc::Sender<Result<(), String>>,
) {
    // silence TfrtCpuClient created/destroyed info spam
    if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
    }
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = init_tx.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = init_tx.send(Err(e.to_string()));
            return;
        }
    };
    // Without the stub's recycling hook the pool could never hand a buffer
    // back to an upload — retaining freed buffers would pin device memory
    // (up to max_bytes) and report pool hits that save nothing.
    #[cfg(not(feature = "xla-stub"))]
    let pool_cfg = PoolConfig {
        enabled: false,
        ..pool_cfg
    };
    let mut st = QueueState {
        client,
        execs: HashMap::new(),
        emus: HashMap::new(),
        buffers: HashMap::new(),
        pool: BufferPool::new(pool_cfg),
        pad,
        stats,
    };

    while let Some(cmd) = cmds.pop() {
        match cmd {
            QueueCmd::Compile { name, path, done } => {
                if st.execs.contains_key(&name) {
                    done.complete();
                    continue;
                }
                st.stats.compiles.fetch_add(1, Ordering::Relaxed);
                match compile_artifact(&st.client, &path) {
                    Ok(exe) => {
                        st.execs.insert(name, exe);
                        done.complete();
                    }
                    Err(e) => done.fail(format!("compile {name}: {e}")),
                }
            }
            QueueCmd::CompileEmu { name, op, done } => {
                st.stats.compiles.fetch_add(1, Ordering::Relaxed);
                st.emus.insert(name, op);
                done.complete();
            }
            QueueCmd::Upload { id, data, done } => match st.upload(id, &data) {
                Ok(()) => done.complete(),
                Err(e) => done.fail(e),
            },
            QueueCmd::Execute {
                exec,
                args,
                out,
                out_dtype,
                deps,
                done,
            } => {
                // cross-queue dependencies block this in-order queue first;
                // the service sample starts after them — waiting on another
                // queue is not this device's own occupancy
                let res = wait_deps(&deps).and_then(|()| {
                    let t0 = Instant::now();
                    let r = st.execute_resident(&exec, &args, out, out_dtype);
                    st.stats.note_service(t0.elapsed());
                    r
                });
                st.stats.inflight.fetch_sub(1, Ordering::Relaxed);
                match res {
                    Ok(()) => done.complete(),
                    Err(e) => done.fail(e),
                }
            }
            QueueCmd::FusedExec {
                exec,
                inputs,
                out,
                out_dtype,
                done,
            } => {
                let t0 = Instant::now();
                let res = st.execute_fused(&exec, inputs, out, out_dtype);
                st.stats.note_service(t0.elapsed());
                st.stats.inflight.fetch_sub(1, Ordering::Relaxed);
                match res {
                    Ok(()) => done.complete(),
                    Err(e) => done.fail(e),
                }
            }
            QueueCmd::Download { id, and_then } => and_then(st.download(id)),
            QueueCmd::Free { id } => st.free(id),
            QueueCmd::Barrier { done } => done.complete(),
            QueueCmd::Stall { dur } => std::thread::sleep(dur),
            QueueCmd::Stop => break,
        }
    }
}

fn compile_artifact(
    client: &xla::PjRtClient,
    path: &std::path::Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let path_str = path
        .to_str()
        .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
    let proto = xla::HloModuleProto::from_text_file(path_str)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn download_buffer(b: &Buffer) -> Result<HostData> {
    let lit = b.buf.to_literal_sync()?;
    Ok(match b.dtype {
        Dtype::U32 => HostData::U32(lit.to_vec::<u32>()?),
        Dtype::F32 => HostData::F32(lit.to_vec::<f32>()?),
    })
}
