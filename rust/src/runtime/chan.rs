//! The device command channel: lock-free producers, one parked consumer.
//!
//! Actors enqueue device commands on every upload/execute/download — this
//! is squarely on the Fig 5 hot path — so `push` is a wait-free Vyukov
//! MPSC push plus one atomic RMW; no mutex is ever taken by producers.
//! The consumer side (the device queue thread) parks on a token instead of
//! polling. A small consumer-side mutex *only* serializes concurrent
//! poppers to uphold the MPSC single-consumer contract; with the one
//! dedicated queue thread per device it is never contended.

use crate::concurrent::{CountedQueue, Parker};
use std::sync::atomic::{fence, AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

struct Inner<T> {
    queue: CountedQueue<T>,
    /// Serializes poppers (correctness belt for the single-consumer
    /// contract; uncontended in the one-queue-thread-per-device design).
    consumer: Mutex<()>,
    /// True while the consumer is committing to park (Dekker flag).
    waiting: AtomicBool,
    parker: Parker,
}

/// Unbounded channel handle: any number of lock-free producers, one
/// (serialized) consumer.
pub struct Chan<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Chan<T> {
    fn clone(&self) -> Self {
        Chan {
            inner: self.inner.clone(),
        }
    }
}

impl<T> Default for Chan<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Chan<T> {
    pub fn new() -> Chan<T> {
        Chan {
            inner: Arc::new(Inner {
                queue: CountedQueue::new(),
                consumer: Mutex::new(()),
                waiting: AtomicBool::new(false),
                parker: Parker::new(),
            }),
        }
    }

    /// Push an item; returns false if the channel is closed. Lock-free.
    pub fn push(&self, item: T) -> bool {
        if self.inner.queue.push(item).is_err() {
            return false;
        }
        // Dekker handshake with the consumer's announce-then-recheck: if
        // the consumer missed this element, it must see `waiting` → we see
        // it here and hand over a token.
        // pairs with: chan.rs::pop (waiting-store → fence → is_empty recheck)
        fence(Ordering::SeqCst);
        if self.inner.waiting.load(Ordering::SeqCst) {
            self.inner.parker.unpark();
        }
        true
    }

    /// Pop, blocking until an item arrives or the channel closes empty.
    pub fn pop(&self) -> Option<T> {
        let _guard = self.inner.consumer.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(v) = self.inner.queue.pop() {
                return Some(v);
            }
            if self.inner.queue.is_closed() {
                return None;
            }
            self.inner.waiting.store(true, Ordering::SeqCst);
            // pairs with: chan.rs::push (push → fence → waiting load)
            fence(Ordering::SeqCst);
            if self.inner.queue.is_empty() && !self.inner.queue.is_closed() {
                self.inner.parker.park();
            }
            self.inner.waiting.store(false, Ordering::SeqCst);
        }
    }

    /// Pop with timeout.
    pub fn pop_timeout(&self, d: Duration) -> Option<T> {
        let deadline = Instant::now() + d;
        let _guard = self.inner.consumer.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(v) = self.inner.queue.pop() {
                return Some(v);
            }
            if self.inner.queue.is_closed() {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.inner.waiting.store(true, Ordering::SeqCst);
            // pairs with: chan.rs::push (push → fence → waiting load)
            fence(Ordering::SeqCst);
            if self.inner.queue.is_empty() && !self.inner.queue.is_closed() {
                self.inner.parker.park_timeout(deadline - now);
            }
            self.inner.waiting.store(false, Ordering::SeqCst);
        }
    }

    /// Close: pending items still drain, new pushes fail.
    pub fn close(&self) {
        self.inner.queue.close();
        // wake a parked consumer so it observes the close
        self.inner.parker.unpark();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_close() {
        let c = Chan::new();
        assert!(c.push(1));
        assert!(c.push(2));
        assert_eq!(c.pop(), Some(1));
        c.close();
        assert!(!c.push(3));
        assert_eq!(c.pop(), Some(2)); // drains
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn cross_thread() {
        let c = Chan::new();
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                c2.push(i);
            }
            c2.close();
        });
        let mut got = Vec::new();
        while let Some(x) = c.pop() {
            got.push(x);
        }
        h.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn pop_timeout_expires() {
        let c: Chan<u32> = Chan::new();
        assert_eq!(c.pop_timeout(Duration::from_millis(10)), None);
    }

    #[test]
    fn parked_consumer_wakes_on_push() {
        let c: Chan<u32> = Chan::new();
        let c2 = c.clone();
        let h = std::thread::spawn(move || c2.pop());
        std::thread::sleep(Duration::from_millis(30)); // let it park
        assert!(c.push(42));
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn many_producers_one_consumer() {
        let c: Chan<u64> = Chan::new();
        let producers = 6;
        let per = 2000u64;
        let mut handles = Vec::new();
        for _ in 0..producers {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    assert!(c.push(i));
                }
            }));
        }
        let mut sum = 0u64;
        for _ in 0..(producers as u64 * per) {
            sum += c.pop().unwrap();
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sum, producers as u64 * (per * (per - 1) / 2));
    }
}
