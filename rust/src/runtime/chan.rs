//! A small MPMC channel (std's `mpsc::Sender` is `!Sync`, which would
//! poison every structure embedding it; this one is `Send + Sync + Clone`).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

struct Inner<T> {
    queue: Mutex<(VecDeque<T>, bool)>, // (items, closed)
    cv: Condvar,
}

/// Unbounded MPMC channel handle.
pub struct Chan<T> {
    inner: Arc<Inner<T>>,
}

impl<T> Clone for Chan<T> {
    fn clone(&self) -> Self {
        Chan {
            inner: self.inner.clone(),
        }
    }
}

impl<T> Default for Chan<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Chan<T> {
    pub fn new() -> Chan<T> {
        Chan {
            inner: Arc::new(Inner {
                queue: Mutex::new((VecDeque::new(), false)),
                cv: Condvar::new(),
            }),
        }
    }

    /// Push an item; returns false if the channel is closed.
    pub fn push(&self, item: T) -> bool {
        let mut q = self.inner.queue.lock().unwrap();
        if q.1 {
            return false;
        }
        q.0.push_back(item);
        self.inner.cv.notify_one();
        true
    }

    /// Pop, blocking until an item arrives or the channel closes empty.
    pub fn pop(&self) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(x) = q.0.pop_front() {
                return Some(x);
            }
            if q.1 {
                return None;
            }
            q = self.inner.cv.wait(q).unwrap();
        }
    }

    /// Pop with timeout.
    pub fn pop_timeout(&self, d: Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + d;
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(x) = q.0.pop_front() {
                return Some(x);
            }
            if q.1 {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (g, _) = self.inner.cv.wait_timeout(q, deadline - now).unwrap();
            q = g;
        }
    }

    /// Close: pending items still drain, new pushes fail.
    pub fn close(&self) {
        let mut q = self.inner.queue.lock().unwrap();
        q.1 = true;
        self.inner.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_close() {
        let c = Chan::new();
        assert!(c.push(1));
        assert!(c.push(2));
        assert_eq!(c.pop(), Some(1));
        c.close();
        assert!(!c.push(3));
        assert_eq!(c.pop(), Some(2)); // drains
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn cross_thread() {
        let c = Chan::new();
        let c2 = c.clone();
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                c2.push(i);
            }
            c2.close();
        });
        let mut got = Vec::new();
        while let Some(x) = c.pop() {
            got.push(x);
        }
        h.join().unwrap();
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn pop_timeout_expires() {
        let c: Chan<u32> = Chan::new();
        assert_eq!(c.pop_timeout(Duration::from_millis(10)), None);
    }
}
