//! PJRT runtime substrate: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py` and executes them on per-device command-queue
//! threads.
//!
//! The `xla` crate's PJRT wrappers are not `Send`, so every device owns a
//! dedicated thread holding its `PjRtClient`, compiled executables, and
//! device-resident buffers; all operations are commands on an in-order
//! queue with completion events — which is *exactly* OpenCL's command-queue
//! + event model the paper builds on (DESIGN.md §2).

pub mod artifact;
pub mod chan;
pub mod client;
pub mod event;

pub use artifact::{ArtifactMeta, Dtype, Manifest, TensorSpec};
pub use chan::Chan;
pub use client::{DeviceQueue, ExecStats, HostData, HostOp, PoolConfig, QueueCmd, UploadSrc};
pub use event::Event;
