//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust coordinator. Line format (see aot.py docstring):
//!
//! ```text
//! name|file|in_dtype:shape[ in_dtype:shape...]|out_dtype:shape|k=v k=v
//! ```

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Element type of a kernel operand (subset of XLA's primitive types that
/// the kernels use).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    U32,
    F32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "u32" => Ok(Dtype::U32),
            "f32" => Ok(Dtype::F32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::U32 => "u32",
            Dtype::F32 => "f32",
        }
    }

    pub fn byte_size(self) -> usize {
        4
    }
}

/// Shape + dtype of one kernel operand.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    /// Parse `"u32:256x128"`.
    pub fn parse(s: &str) -> Result<TensorSpec> {
        let (d, dims) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("malformed tensor spec {s:?}"))?;
        let dims = dims
            .split('x')
            .map(|t| t.parse::<usize>().context("bad dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            dtype: Dtype::parse(d)?,
            dims,
        })
    }

    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.byte_size()
    }
}

/// One compiled kernel artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    /// Free-form metadata from aot.py (`n`, `range`, `group`, ...).
    pub extras: HashMap<String, String>,
}

impl ArtifactMeta {
    pub fn extra_usize(&self, key: &str) -> Option<usize> {
        self.extras.get(key).and_then(|v| v.parse().ok())
    }

    fn parse(line: &str) -> Result<ArtifactMeta> {
        let parts: Vec<&str> = line.split('|').collect();
        if parts.len() != 5 {
            bail!("manifest line must have 5 fields, got {}: {line:?}", parts.len());
        }
        let inputs = parts[2]
            .split_whitespace()
            .map(TensorSpec::parse)
            .collect::<Result<Vec<_>>>()?;
        let output = TensorSpec::parse(parts[3])?;
        let mut extras = HashMap::new();
        for kv in parts[4].split_whitespace() {
            if let Some((k, v)) = kv.split_once('=') {
                extras.insert(k.to_string(), v.to_string());
            }
        }
        Ok(ArtifactMeta {
            name: parts[0].to_string(),
            file: parts[1].to_string(),
            inputs,
            output,
            extras,
        })
    }
}

/// The full artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    by_name: HashMap<String, ArtifactMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let mut by_name = HashMap::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let meta = ArtifactMeta::parse(line)?;
            by_name.insert(meta.name.clone(), meta);
        }
        Ok(Manifest { dir, by_name })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.by_name
            .get(name)
            .ok_or_else(|| anyhow!("unknown kernel {name:?} (not in manifest)"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.by_name.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tensor_spec() {
        let t = TensorSpec::parse("f32:256x128").unwrap();
        assert_eq!(t.dtype, Dtype::F32);
        assert_eq!(t.dims, vec![256, 128]);
        assert_eq!(t.elems(), 32768);
        assert_eq!(t.bytes(), 131072);
        assert!(TensorSpec::parse("f32").is_err());
        assert!(TensorSpec::parse("q8:4").is_err());
    }

    #[test]
    fn parse_manifest_line() {
        let m = ArtifactMeta::parse(
            "wah_move_4096|wah_move_4096.hlo.txt|u32:8192 u32:136|u32:8200|n=4096 group=128",
        )
        .unwrap();
        assert_eq!(m.name, "wah_move_4096");
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.output.elems(), 8200);
        assert_eq!(m.extra_usize("group"), Some(128));
        assert_eq!(m.extra_usize("nope"), None);
    }

    #[test]
    fn malformed_lines_rejected() {
        assert!(ArtifactMeta::parse("too|few|fields").is_err());
    }
}
