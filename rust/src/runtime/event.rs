//! Completion events: the OpenCL `cl_event` analog (paper Listing 4).
//!
//! Commands on a device queue produce an [`Event`]; other commands may list
//! events as dependencies, and callbacks can be attached
//! (`clSetEventCallback`) — which is how the actor facade turns kernel
//! completion into a response message without blocking any scheduler thread.

use crate::loom_types::{AtomicBool, Condvar, Mutex, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

type Callback = Box<dyn FnOnce(&Result<(), String>) + Send>;

#[derive(Default)]
struct State {
    done: bool,
    error: Option<String>,
    callbacks: Vec<Callback>,
    /// Timing of the producing command (Fig 5: enqueue -> completion).
    enqueued_at: Option<Instant>,
    completed_at: Option<Instant>,
}

struct Inner {
    state: Mutex<State>,
    cv: Condvar,
    /// Lock-free completion flags: events sit on the per-command hot path
    /// (every upload/execute checks its dependencies), so the common
    /// "already complete, succeeded" case must not take the mutex.
    done_flag: AtomicBool,
    failed_flag: AtomicBool,
}

/// A shareable completion event.
#[derive(Clone)]
pub struct Event {
    inner: Arc<Inner>,
}

impl Default for Event {
    fn default() -> Self {
        Self::new()
    }
}

impl Event {
    pub fn new() -> Event {
        Event {
            inner: Arc::new(Inner {
                state: Mutex::new(State::default()),
                cv: Condvar::new(),
                done_flag: AtomicBool::new(false),
                failed_flag: AtomicBool::new(false),
            }),
        }
    }

    /// An event that is already complete (for constant/ready inputs).
    pub fn ready() -> Event {
        let e = Event::new();
        e.complete();
        e
    }

    pub fn mark_enqueued(&self) {
        self.inner.state.lock().unwrap_or_else(|p| p.into_inner()).enqueued_at = Some(Instant::now());
    }

    /// Signal successful completion; fires callbacks in registration order.
    pub fn complete(&self) {
        self.finish(Ok(()))
    }

    /// Signal failure.
    pub fn fail(&self, why: impl Into<String>) {
        self.finish(Err(why.into()))
    }

    fn finish(&self, result: Result<(), String>) {
        let callbacks = {
            let mut st = self.inner.state.lock().unwrap_or_else(|p| p.into_inner());
            if st.done {
                return;
            }
            st.done = true;
            st.completed_at = Some(Instant::now());
            st.error = result.as_ref().err().cloned();
            // publish the lock-free view while still holding the lock so
            // flag readers can trust the mutex state afterwards
            self.inner
                .failed_flag
                .store(st.error.is_some(), Ordering::Release);
            self.inner.done_flag.store(true, Ordering::Release);
            std::mem::take(&mut st.callbacks)
        };
        self.inner.cv.notify_all();
        let res = self.result_now();
        for cb in callbacks {
            cb(&res);
        }
    }

    fn result_now(&self) -> Result<(), String> {
        let st = self.inner.state.lock().unwrap_or_else(|p| p.into_inner());
        match &st.error {
            Some(e) => Err(e.clone()),
            None => Ok(()),
        }
    }

    pub fn is_complete(&self) -> bool {
        self.inner.done_flag.load(Ordering::Acquire)
    }

    /// Non-blocking, lock-free in the success case: `None` while pending,
    /// `Some(result)` once complete. Lets command enqueue skip dependency
    /// events that already retired.
    pub fn poll(&self) -> Option<Result<(), String>> {
        if !self.inner.done_flag.load(Ordering::Acquire) {
            return None;
        }
        if !self.inner.failed_flag.load(Ordering::Acquire) {
            return Some(Ok(()));
        }
        Some(self.result_now())
    }

    /// Attach a completion callback; fires immediately if already done.
    pub fn on_complete<F>(&self, f: F)
    where
        F: FnOnce(&Result<(), String>) + Send + 'static,
    {
        let run_now = {
            let mut st = self.inner.state.lock().unwrap_or_else(|p| p.into_inner());
            if st.done {
                true
            } else {
                st.callbacks.push(Box::new(f));
                return;
            }
        };
        if run_now {
            f(&self.result_now());
        }
    }

    /// Block until complete or timeout; `Ok(())` on success. Lock-free
    /// when the event already completed successfully.
    pub fn wait(&self, timeout: Duration) -> Result<(), String> {
        if let Some(r) = self.poll() {
            return r;
        }
        let deadline = Instant::now() + timeout;
        let mut st = self.inner.state.lock().unwrap_or_else(|p| p.into_inner());
        while !st.done {
            let now = Instant::now();
            if now >= deadline {
                return Err("event wait timed out".to_string());
            }
            let (g, _) = self.inner.cv.wait_timeout(st, deadline - now).unwrap_or_else(|p| p.into_inner());
            st = g;
        }
        match &st.error {
            Some(e) => Err(e.clone()),
            None => Ok(()),
        }
    }

    /// Enqueue-to-completion duration of the producing command, if both
    /// timestamps were recorded (the Fig 5 "kernel time" measurement).
    pub fn device_duration(&self) -> Option<Duration> {
        let st = self.inner.state.lock().unwrap_or_else(|p| p.into_inner());
        match (st.enqueued_at, st.completed_at) {
            (Some(a), Some(b)) => Some(b.duration_since(a)),
            _ => None,
        }
    }
}

impl std::fmt::Debug for Event {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Event(done={})", self.is_complete())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn complete_fires_callbacks_once() {
        let e = Event::new();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        e.on_complete(move |r| {
            assert!(r.is_ok());
            h.fetch_add(1, Ordering::SeqCst);
        });
        e.complete();
        e.complete(); // idempotent
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn late_callback_fires_immediately() {
        let e = Event::ready();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = hits.clone();
        e.on_complete(move |_| {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn wait_sees_failure() {
        let e = Event::new();
        let e2 = e.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            e2.fail("kernel exploded");
        });
        let r = e.wait(Duration::from_secs(5));
        assert_eq!(r.unwrap_err(), "kernel exploded");
    }

    #[test]
    fn wait_timeout() {
        let e = Event::new();
        assert!(e.wait(Duration::from_millis(20)).is_err());
    }

    #[test]
    fn poll_reports_states() {
        let e = Event::new();
        assert!(e.poll().is_none());
        e.complete();
        assert_eq!(e.poll(), Some(Ok(())));
        let f = Event::new();
        f.fail("nope");
        assert_eq!(f.poll(), Some(Err("nope".to_string())));
        // wait() takes the lock-free fast path once complete
        assert!(e.wait(Duration::ZERO).is_ok());
    }

    #[test]
    fn timing_recorded() {
        let e = Event::new();
        e.mark_enqueued();
        std::thread::sleep(Duration::from_millis(5));
        e.complete();
        assert!(e.device_duration().unwrap() >= Duration::from_millis(4));
    }
}
