//! caf_ocl — "OpenCL Actors" (CAF, Agere 2017) reproduced on a Rust + JAX +
//! Pallas (AOT via PJRT) stack. See DESIGN.md for the architecture map.
//!
//! Layer map:
//! * [`actor`]    — the CAF-like substrate (scheduler, mailboxes, messaging,
//!   monitors, composition).
//! * [`concurrent`] — lock-free primitives under the hot path (Vyukov MPSC
//!   queues, Chase–Lev work-stealing deques, token parkers).
//! * [`opencl`]   — the paper's contribution: OpenCL actors on top of the
//!   PJRT runtime (manager/platform/device/program/mem_ref/actor_facade).
//! * [`runtime`]  — PJRT command-queue threads executing AOT HLO artifacts.
//! * [`indexing`] — the WAH bitmap-index use case (§4), CPU + device.
//! * [`workload`] — native baselines and generators for the benchmarks.
//! * [`sim`]      — simulated Tesla/Phi device profiles (DESIGN.md §2).
//! * [`net`]      — network-transparent messaging between nodes.
//! * [`bench`]    — the measurement harness used by `cargo bench`.
//! * [`util`]     — PRNG, property testing, stats, CLI.
pub mod actor;
pub mod bench;
pub mod concurrent;
pub mod indexing;
pub mod loom_types;
pub mod net;
pub mod opencl;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;
