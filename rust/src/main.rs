//! `repro` — the launcher binary.
//!
//! ```text
//! repro info                          platform / device / kernel inventory
//! repro index  [--n 65536] [--dist zipf|uniform|runs] [--device 0]
//! repro mandel [--device tesla|phi|host] [--offload 50]
//! repro serve  [--addr 127.0.0.1:7000] [--kernel empty_1024]
//! repro client --addr <addr> [--name device-worker]
//! ```

use caf_ocl::actor::{ActorSystem, SystemConfig};
use caf_ocl::bench::hetero_step;
use caf_ocl::indexing::gpu_pipeline::GpuIndexer;
use caf_ocl::indexing::CpuIndexer;
use caf_ocl::net::Node;
use caf_ocl::opencl::{DeviceSpec, Manager, Mode, OpenClSystemExt};
use caf_ocl::sim::{tesla_c2075, xeon_phi_5110p};
use caf_ocl::util::cli::Args;
use caf_ocl::workload::ValueStream;
use std::time::Duration;

const T: Duration = Duration::from_secs(600);

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("info") => info(&args),
        Some("index") => index(&args),
        Some("mandel") => mandel(&args),
        Some("serve") => serve(&args),
        Some("client") => client(&args),
        _ => {
            eprintln!(
                "usage: repro <info|index|mandel|serve|client> [--options]\n\
                 see rust/src/main.rs for per-command flags"
            );
            Ok(())
        }
    }
}

fn devices_from(args: &Args) -> Vec<DeviceSpec> {
    let mut specs = vec![DeviceSpec::host()];
    if args.flag("sim-devices") {
        specs.push(tesla_c2075());
        specs.push(xeon_phi_5110p());
    }
    specs
}

fn info(args: &Args) -> anyhow::Result<()> {
    let sys = ActorSystem::new(SystemConfig::default());
    let mngr = Manager::load_with(&sys, devices_from(args));
    let platform = mngr.platform();
    println!("platform: {platform:?}");
    for d in &platform.devices {
        println!("  {d:?}");
    }
    let mut names = platform.manifest.names();
    names.sort();
    println!("kernels ({}):", names.len());
    for n in names {
        let meta = platform.manifest.get(n).unwrap(); // lint-ok: n comes from manifest.keys()
        println!(
            "  {:32} in: {:40} out: {}",
            n,
            meta.inputs
                .iter()
                .map(|s| format!("{}[{}]", s.dtype.name(), s.elems()))
                .collect::<Vec<_>>()
                .join(", "),
            format_args!("{}[{}]", meta.output.dtype.name(), meta.output.elems()),
        );
    }
    mngr.stop_devices();
    sys.shutdown();
    Ok(())
}

fn index(args: &Args) -> anyhow::Result<()> {
    let n = args.usize("n", 65536);
    let device = args.usize("device", 0);
    let dist = match args.get_or("dist", "zipf") {
        "uniform" => ValueStream::Uniform { cardinality: 512 },
        "runs" => ValueStream::Runs {
            cardinality: 512,
            max_run: 64,
        },
        _ => ValueStream::Zipf {
            cardinality: 512,
            s: 1.1,
        },
    };
    let sys = ActorSystem::new(SystemConfig::default());
    let mngr = Manager::load_with(&sys, devices_from(args));
    let me = sys.scoped();
    let values = dist.generate(n, args.u64("seed", 42));
    let capacity = caf_ocl::indexing::gpu_pipeline::CAPACITIES
        .iter()
        .copied()
        .find(|&c| c >= n)
        .ok_or_else(|| anyhow::anyhow!("n too large; max 1048576"))?;
    let gpu = GpuIndexer::build(&mngr, device, capacity)?;
    let t0 = std::time::Instant::now();
    let idx = gpu.index(&me, &values, T)?;
    let dt = t0.elapsed();
    idx.verify(&values).map_err(|e| anyhow::anyhow!(e))?;
    let cpu = CpuIndexer::new(1024);
    let t1 = std::time::Instant::now();
    let _ = cpu.index(&values);
    let cpu_dt = t1.elapsed();
    println!(
        "indexed {n} values on device {} in {:.3} ms (cpu: {:.3} ms)",
        device,
        dt.as_secs_f64() * 1e3,
        cpu_dt.as_secs_f64() * 1e3
    );
    println!(
        "index: {} words, {} distinct values, compression x{:.2}, verified OK",
        idx.words.len(),
        idx.n_distinct,
        idx.compression_ratio(n)
    );
    mngr.stop_devices();
    sys.shutdown();
    Ok(())
}

fn mandel(args: &Args) -> anyhow::Result<()> {
    let spec = match args.get_or("device", "tesla") {
        "phi" => xeon_phi_5110p(),
        "host" => DeviceSpec::host(),
        _ => tesla_c2075(),
    };
    let offload = args.usize("offload", 50).min(100) / 10;
    let (w, h, chunk, iters) = (960usize, 540usize, 54usize, 100u32);
    let sys = ActorSystem::new(SystemConfig::default());
    println!("rendering {w}x{h} it{iters}, {}% on {}", offload * 10, spec.name);
    let mngr = Manager::load_with(&sys, vec![spec]);
    let kernel = format!("mandel_w{w}_h{h}_c{chunk}_it{iters}");
    let device_actor = mngr.spawn_simple(&kernel, Mode::Val, Mode::Val)?;
    let me = sys.scoped();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let (total, cpu, dev) = hetero_step(&me, &device_actor, w, h, chunk, iters, offload, threads);
    println!(
        "total {:.2} ms (cpu part {:.2} ms, device part {:.2} ms)",
        total * 1e3,
        cpu * 1e3,
        dev * 1e3
    );
    mngr.stop_devices();
    sys.shutdown();
    Ok(())
}

fn serve(args: &Args) -> anyhow::Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7000").to_string();
    let kernel = args.get_or("kernel", "empty_1024").to_string();
    let sys = ActorSystem::new(SystemConfig::default());
    Manager::load(&sys);
    let mngr = sys.opencl_manager();
    let worker = mngr.spawn_simple(&kernel, Mode::Val, Mode::Val)?;
    sys.registry().put("device-worker", worker);
    let node = Node::new(&sys);
    let bound = node.listen(&addr)?;
    println!("serving kernel {kernel:?} as 'device-worker' at {bound} — ctrl-c to stop");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn client(args: &Args) -> anyhow::Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("--addr required"))?;
    let name = args.get_or("name", "device-worker");
    let sys = ActorSystem::new(SystemConfig::default());
    let node = Node::new(&sys);
    let remote = node.remote_actor(addr, name)?;
    let me = sys.scoped();
    let data: Vec<u32> = (0..1024).collect();
    let t0 = std::time::Instant::now();
    let out: Vec<u32> = me
        .request(&remote, data.clone())
        .receive(T)
        .map_err(|e| anyhow::anyhow!(e.reason))?;
    println!(
        "remote round-trip: {} words in {:.2} ms (payload intact: {})",
        out.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        out == data
    );
    sys.shutdown();
    Ok(())
}
