//! Switchable sync-primitive aliases for the unsafe messaging core.
//!
//! Every file the model checker covers (`concurrent::{mpsc, deque,
//! parker}`, `actor::{mailbox, cell, scheduler}`, `runtime::event`) imports
//! its atomics, cells, locks, and spin hooks from here instead of
//! `std::sync`. In a normal build these are plain re-exports plus
//! `#[repr(transparent)]` `#[inline(always)]` wrappers — codegen is
//! byte-identical to using std directly. Under `--features model` the same
//! names resolve to the instrumented types in
//! [`crate::concurrent::model::sync`], which record every operation and
//! hand scheduling control to the model explorer.
//!
//! The linter (`python/lints/check.py`, rule R6) enforces that the covered
//! files never import `std::sync::atomic` / `std::cell::UnsafeCell`
//! directly, so coverage cannot silently rot.

#[cfg(not(feature = "model"))]
mod imp {
    pub use std::sync::atomic::{
        fence, AtomicBool, AtomicIsize, AtomicPtr, AtomicU32, AtomicU64, AtomicU8, AtomicUsize,
        Ordering,
    };
    pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

    /// Transparent `UnsafeCell` with access-intent methods. The methods
    /// exist so model builds can race-check each access; here they compile
    /// to the raw pointer use with no overhead.
    #[repr(transparent)]
    #[derive(Default)]
    pub struct UnsafeCell<T: ?Sized>(std::cell::UnsafeCell<T>);

    impl<T> UnsafeCell<T> {
        #[inline(always)]
        pub const fn new(v: T) -> UnsafeCell<T> {
            UnsafeCell(std::cell::UnsafeCell::new(v))
        }

        #[inline(always)]
        pub fn into_inner(self) -> T {
            self.0.into_inner()
        }
    }

    impl<T: ?Sized> UnsafeCell<T> {
        /// Declare a read access (race-checked under the model).
        #[inline(always)]
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Declare a write access (race-checked under the model).
        #[inline(always)]
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }

        /// Declare a deliberately racy read — a checked exemption from the
        /// model's race detector. Cite the reason in an adjacent comment.
        #[inline(always)]
        pub fn with_racy<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Raw pointer without an access declaration — single-threaded
        /// setup/teardown only (constructors, `Drop`).
        #[inline(always)]
        pub fn get(&self) -> *mut T {
            self.0.get()
        }
    }

    /// Spin-backoff hook; a demoting model yield under `--features model`.
    #[inline(always)]
    pub fn thread_yield() {
        std::thread::yield_now();
    }

    /// CPU-relax hook; a demoting model yield under `--features model`.
    #[inline(always)]
    pub fn cpu_relax() {
        std::hint::spin_loop();
    }
}

#[cfg(feature = "model")]
mod imp {
    pub use crate::concurrent::model::sync::{
        fence, Arc, AtomicBool, AtomicIsize, AtomicPtr, AtomicU32, AtomicU64, AtomicU8,
        AtomicUsize, Condvar, Mutex, MutexGuard, Ordering, UnsafeCell, WaitTimeoutResult,
    };

    /// Spin-backoff hook: under the model this demotes the spinner so spin
    /// loops neither explode the schedule space nor starve their writer.
    #[inline]
    pub fn thread_yield() {
        crate::concurrent::model::sync::yield_now();
    }

    /// CPU-relax hook; same demotion semantics as [`thread_yield`].
    #[inline]
    pub fn cpu_relax() {
        crate::concurrent::model::sync::spin_loop();
    }
}

pub use imp::*;
