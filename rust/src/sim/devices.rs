//! Concrete device profiles, calibrated to reproduce the *shapes* of the
//! paper's figures (absolute times are testbed-specific and not targets).
//!
//! Calibration rationale:
//!
//! * **Tesla C2075** (Fig 3, 7a, 8a): asymptotically ~2x faster than the
//!   host path on the indexing workload ("execution times on the CPU are
//!   about twice as large as on the GPU"), cheap dispatch, healthy PCIe
//!   bandwidth. `compute_scale = 0.5` halves effective kernel time;
//!   transfers at ~4 GB/s with ~0.15 ms launch cost give the sub-linear
//!   start for small problems.
//! * **Xeon Phi 5110P** (Fig 7b, 8): the paper found offloading *small*
//!   problems counterproductive — "the total execution time doubles when
//!   offloading 10% of work to the Phi" and even 100% stays slower than
//!   CPU-only; with large compute-heavy workloads it approaches the Tesla
//!   (Fig 8b). That is a transfer/dispatch-dominated device: high per-
//!   command latency (~3 ms, the unoptimized driver stack) and ~0.8 GB/s
//!   effective transfer rate, with compute itself competitive
//!   (`compute_scale = 0.55`).
//! * **GTX 780M** (Figs 4-6 testbed): like the Tesla but with laptop-grade
//!   transfer characteristics; used by the overhead benches where only
//!   relative CAF-vs-native numbers matter.

use crate::opencl::{DeviceInfo, DeviceKind, DeviceSpec};
use crate::runtime::client::PadModel;
use std::time::Duration;

/// NVIDIA Tesla C2075 (paper: 14 CUs x 1024 work items = 14336 concurrent).
pub fn tesla_c2075() -> DeviceSpec {
    DeviceSpec {
        name: "tesla-c2075".to_string(),
        kind: DeviceKind::Gpu,
        info: DeviceInfo {
            compute_units: 14,
            max_work_items_per_cu: 1024,
        },
        pad: Some(PadModel {
            launch: Duration::from_micros(150),
            bytes_per_sec: 4.0e9,
            compute_scale: 0.5,
            busy_wait: false,
        }),
    }
}

/// Intel Xeon Phi 5110P (paper: 60 cores x 4 threads = 240 threads).
pub fn xeon_phi_5110p() -> DeviceSpec {
    DeviceSpec {
        name: "xeon-phi-5110p".to_string(),
        kind: DeviceKind::Accelerator,
        info: DeviceInfo {
            compute_units: 60,
            max_work_items_per_cu: 4,
        },
        pad: Some(PadModel {
            launch: Duration::from_millis(20),
            bytes_per_sec: 0.5e9,
            compute_scale: 0.55,
            busy_wait: true,
        }),
    }
}

/// NVIDIA GeForce GTX 780M (the paper's iMac testbed GPU).
pub fn gtx_780m() -> DeviceSpec {
    DeviceSpec {
        name: "gtx-780m".to_string(),
        kind: DeviceKind::Gpu,
        info: DeviceInfo {
            compute_units: 8,
            max_work_items_per_cu: 1024,
        },
        pad: Some(PadModel {
            launch: Duration::from_micros(200),
            bytes_per_sec: 2.5e9,
            compute_scale: 0.7,
            busy_wait: false,
        }),
    }
}

/// The Fig 7b *steering* pair: a cheap-dispatch device and a Phi-like
/// high-dispatch-cost device that are otherwise identical (same transfer
/// bandwidth, same compute scale, no busy-wait), so the only dimension the
/// cost-aware placement policy can separate them on is the per-command
/// dispatch pad — exactly the effect the paper isolates in Fig 7b, where
/// offloading *small* duties to the Phi doubles total runtime while the
/// Tesla still wins. Used by the `dispatch` bench's cost-aware probe and
/// the placement tests; the 20x launch gap mirrors the calibrated
/// Tesla-vs-Phi profiles above without the Phi's core-burning busy-wait
/// (CI runners share cores).
pub fn steering_pair() -> (DeviceSpec, DeviceSpec) {
    let fast = DeviceSpec {
        name: "steer-fast".to_string(),
        kind: DeviceKind::Gpu,
        info: DeviceInfo {
            compute_units: 8,
            max_work_items_per_cu: 1024,
        },
        pad: Some(PadModel {
            launch: Duration::from_micros(500),
            bytes_per_sec: 2.0e9,
            compute_scale: 1.0,
            busy_wait: false,
        }),
    };
    let phi_like = DeviceSpec {
        name: "steer-phi".to_string(),
        kind: DeviceKind::Accelerator,
        info: DeviceInfo {
            compute_units: 60,
            max_work_items_per_cu: 4,
        },
        pad: Some(PadModel {
            launch: Duration::from_millis(10),
            bytes_per_sec: 2.0e9,
            compute_scale: 1.0,
            busy_wait: false,
        }),
    };
    (fast, phi_like)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_have_expected_structure() {
        let t = tesla_c2075();
        assert_eq!(t.kind, DeviceKind::Gpu);
        assert_eq!(t.info.max_concurrency(), 14_336);
        let p = xeon_phi_5110p();
        assert_eq!(p.kind, DeviceKind::Accelerator);
        assert_eq!(p.info.max_concurrency(), 240);
        // the Phi's dispatch cost dominates the Tesla's by design
        assert!(p.pad.unwrap().launch > t.pad.unwrap().launch * 10);
        assert!(p.pad.unwrap().busy_wait && !t.pad.unwrap().busy_wait);
    }

    #[test]
    fn steering_pair_differs_only_in_dispatch_cost() {
        let (fast, slow) = steering_pair();
        let (f, s) = (fast.pad.unwrap(), slow.pad.unwrap());
        assert!(s.launch >= f.launch * 20, "the dispatch gap IS the scenario");
        assert_eq!(s.bytes_per_sec, f.bytes_per_sec);
        assert_eq!(s.compute_scale, f.compute_scale);
        assert!(!f.busy_wait && !s.busy_wait);
    }
}
