//! Concrete device profiles, calibrated to reproduce the *shapes* of the
//! paper's figures (absolute times are testbed-specific and not targets).
//!
//! Calibration rationale:
//!
//! * **Tesla C2075** (Fig 3, 7a, 8a): asymptotically ~2x faster than the
//!   host path on the indexing workload ("execution times on the CPU are
//!   about twice as large as on the GPU"), cheap dispatch, healthy PCIe
//!   bandwidth. `compute_scale = 0.5` halves effective kernel time;
//!   transfers at ~4 GB/s with ~0.15 ms launch cost give the sub-linear
//!   start for small problems.
//! * **Xeon Phi 5110P** (Fig 7b, 8): the paper found offloading *small*
//!   problems counterproductive — "the total execution time doubles when
//!   offloading 10% of work to the Phi" and even 100% stays slower than
//!   CPU-only; with large compute-heavy workloads it approaches the Tesla
//!   (Fig 8b). That is a transfer/dispatch-dominated device: high per-
//!   command latency (~3 ms, the unoptimized driver stack) and ~0.8 GB/s
//!   effective transfer rate, with compute itself competitive
//!   (`compute_scale = 0.55`).
//! * **GTX 780M** (Figs 4-6 testbed): like the Tesla but with laptop-grade
//!   transfer characteristics; used by the overhead benches where only
//!   relative CAF-vs-native numbers matter.

use crate::opencl::{DeviceInfo, DeviceKind, DeviceSpec};
use crate::runtime::client::PadModel;
use std::time::Duration;

/// NVIDIA Tesla C2075 (paper: 14 CUs x 1024 work items = 14336 concurrent).
pub fn tesla_c2075() -> DeviceSpec {
    DeviceSpec {
        name: "tesla-c2075".to_string(),
        kind: DeviceKind::Gpu,
        info: DeviceInfo {
            compute_units: 14,
            max_work_items_per_cu: 1024,
        },
        pad: Some(PadModel {
            launch: Duration::from_micros(150),
            bytes_per_sec: 4.0e9,
            compute_scale: 0.5,
            busy_wait: false,
        }),
    }
}

/// Intel Xeon Phi 5110P (paper: 60 cores x 4 threads = 240 threads).
pub fn xeon_phi_5110p() -> DeviceSpec {
    DeviceSpec {
        name: "xeon-phi-5110p".to_string(),
        kind: DeviceKind::Accelerator,
        info: DeviceInfo {
            compute_units: 60,
            max_work_items_per_cu: 4,
        },
        pad: Some(PadModel {
            launch: Duration::from_millis(20),
            bytes_per_sec: 0.5e9,
            compute_scale: 0.55,
            busy_wait: true,
        }),
    }
}

/// NVIDIA GeForce GTX 780M (the paper's iMac testbed GPU).
pub fn gtx_780m() -> DeviceSpec {
    DeviceSpec {
        name: "gtx-780m".to_string(),
        kind: DeviceKind::Gpu,
        info: DeviceInfo {
            compute_units: 8,
            max_work_items_per_cu: 1024,
        },
        pad: Some(PadModel {
            launch: Duration::from_micros(200),
            bytes_per_sec: 2.5e9,
            compute_scale: 0.7,
            busy_wait: false,
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_have_expected_structure() {
        let t = tesla_c2075();
        assert_eq!(t.kind, DeviceKind::Gpu);
        assert_eq!(t.info.max_concurrency(), 14_336);
        let p = xeon_phi_5110p();
        assert_eq!(p.kind, DeviceKind::Accelerator);
        assert_eq!(p.info.max_concurrency(), 240);
        // the Phi's dispatch cost dominates the Tesla's by design
        assert!(p.pad.unwrap().launch > t.pad.unwrap().launch * 10);
        assert!(p.pad.unwrap().busy_wait && !t.pad.unwrap().busy_wait);
    }
}
