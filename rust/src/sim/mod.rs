//! Simulated device profiles: the paper evaluates on an NVIDIA Tesla C2075,
//! an Intel Xeon Phi 5110P, and a GeForce GTX 780M — hardware this
//! environment does not have. Per the substitution rule (DESIGN.md §2),
//! each becomes a [`DeviceSpec`] whose [`PadModel`] injects the device's
//! *cost structure* (dispatch latency, PCIe transfer bandwidth, relative
//! compute speed) on top of real PJRT executions, so the heterogeneous
//! benchmarks (Figs 7/8) reproduce the paper's qualitative behavior:
//! crossover points, transfer-bound regimes, and scaling shapes.
//!
//! The [`chaos`] submodule adds timed fault injection for the soak
//! harness: a [`ChaosSchedule`] kills — or, with [`ChaosFault::Stall`],
//! wedges the device queue of — random live replicas of a replicated
//! deployment on an interval, exercising the monitor/respawn path (and
//! the grey-failure paths supervision cannot see) under live load.
//!
//! [`ChaosFault::Stall`]: chaos::ChaosFault::Stall
//!
//! [`DeviceSpec`]: crate::opencl::DeviceSpec
//! [`PadModel`]: crate::runtime::client::PadModel

pub mod chaos;
pub mod devices;

pub use chaos::{ChaosConfig, ChaosFault, ChaosSchedule};
pub use devices::{gtx_780m, steering_pair, tesla_c2075, xeon_phi_5110p};
