//! Chaos schedule: timed replica kills against a replicated deployment.
//!
//! The soak harness (ISSUE: "a chaos schedule kills/respawns replicas on a
//! timer") needs fault injection that runs *concurrently* with an offered
//! load, not the synchronous kill-then-assert style of the placement
//! tests. [`ChaosSchedule::start`] spawns a background thread that, every
//! `interval`, picks a live replica from a [`DevicePool`] uniformly at
//! random and sends its facade the same `Exit::fault` the fault-injection
//! tests use. The dispatcher's monitor/respawn machinery does the rest —
//! chaos only *creates* faults, it never touches pool bookkeeping, so the
//! kill path through `Down` → `mark_dead` → respawn is exactly the
//! production one.
//!
//! Determinism: victim choice uses the seeded [`Rng`], so a given
//! `(pool size, seed, liveness history)` picks the same victims. Timing is
//! wall-clock and therefore not deterministic — the schedule is a soak
//! tool, not a replay log.
//!
//! Clean kills are not the only failure mode worth soaking: a wedged
//! driver or a thermally-throttled device *stalls* without dying, and no
//! `Down` ever fires. [`ChaosFault::Stall`] injects exactly that — the
//! victim's device-queue thread sleeps for the configured duration, the
//! replica stays "alive", and recovery must come from deadlines,
//! cost-aware steering away from the ballooning queue, or migration —
//! never from the supervisor.

use crate::actor::{Exit, Message};
use crate::opencl::placement::DevicePool;
use crate::util::Rng;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// The fault one chaos tick injects into its victim.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ChaosFault {
    /// Send the victim's facade `Exit::fault("chaos kill")` — a clean
    /// actor death the dispatcher's monitor/respawn machinery observes
    /// and recovers from.
    #[default]
    Kill,
    /// Put the victim's *device queue* to sleep for the given duration:
    /// the replica stays alive (no `Down` fires), it just stops making
    /// progress — the grey failure supervision cannot see.
    Stall(Duration),
}

/// Knobs for a chaos run.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// Gap between fault injections. The first fault fires one `interval`
    /// after [`ChaosSchedule::start`], not immediately — the soak gets a
    /// healthy warm-up window.
    pub interval: Duration,
    /// Stop after this many injected faults; `0` means unlimited (run
    /// until [`ChaosSchedule::stop`]).
    pub max_kills: u64,
    /// Seed for victim selection.
    pub seed: u64,
    /// What each tick does to its victim.
    pub fault: ChaosFault,
}

impl Default for ChaosConfig {
    fn default() -> ChaosConfig {
        ChaosConfig {
            interval: Duration::from_millis(500),
            max_kills: 0,
            seed: 0x9e3779b97f4a7c15,
            fault: ChaosFault::Kill,
        }
    }
}

/// A running chaos schedule. Dropping it (or calling [`stop`]) halts the
/// kill thread; kills already sent stay sent.
///
/// [`stop`]: ChaosSchedule::stop
pub struct ChaosSchedule {
    stop: Arc<AtomicBool>,
    kills: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl ChaosSchedule {
    /// Start killing replicas of `pool` on a timer.
    pub fn start(pool: Arc<DevicePool>, cfg: ChaosConfig) -> ChaosSchedule {
        let stop = Arc::new(AtomicBool::new(false));
        let kills = Arc::new(AtomicU64::new(0));
        let thread_stop = stop.clone();
        let thread_kills = kills.clone();
        let handle = std::thread::Builder::new()
            .name("chaos-schedule".into())
            .spawn(move || {
                let mut rng = Rng::new(cfg.seed);
                loop {
                    // sleep in short slices so stop() returns promptly even
                    // with a long interval
                    let mut slept = Duration::ZERO;
                    while slept < cfg.interval {
                        if thread_stop.load(Ordering::Acquire) {
                            return;
                        }
                        let slice = (cfg.interval - slept).min(Duration::from_millis(5));
                        std::thread::sleep(slice);
                        slept += slice;
                    }
                    if thread_stop.load(Ordering::Acquire) {
                        return;
                    }
                    let replicas = pool.replicas();
                    let live: Vec<usize> = (0..replicas.len())
                        .filter(|&i| replicas[i].is_alive())
                        .collect();
                    if live.is_empty() {
                        // everything is down (or respawning); try again next
                        // interval rather than burning a kill on nothing
                        continue;
                    }
                    let victim = live[rng.below(live.len() as u64) as usize];
                    let injected = match cfg.fault {
                        ChaosFault::Kill => {
                            replicas[victim]
                                .facade()
                                .send_from(None, Message::new(Exit::fault("chaos kill")));
                            true
                        }
                        ChaosFault::Stall(dur) => {
                            // false only if the queue already shut down —
                            // nothing was stalled, don't count it
                            replicas[victim].device.queue.inject_stall(dur)
                        }
                    };
                    if !injected {
                        continue;
                    }
                    let n = thread_kills.fetch_add(1, Ordering::AcqRel) + 1;
                    log::info!(
                        "chaos: {:?} on replica {victim} (fault #{n} of {})",
                        cfg.fault,
                        if cfg.max_kills == 0 {
                            "unlimited".to_string()
                        } else {
                            cfg.max_kills.to_string()
                        }
                    );
                    if cfg.max_kills != 0 && n >= cfg.max_kills {
                        return;
                    }
                }
            })
            .expect("spawn chaos-schedule thread"); // lint-ok: fail-fast at harness startup
        ChaosSchedule {
            stop,
            kills,
            handle: Some(handle),
        }
    }

    /// Faults injected so far (kills sent or stalls landed).
    pub fn kill_count(&self) -> u64 {
        self.kills.load(Ordering::Acquire)
    }

    /// Halt the schedule and return the total kill count.
    pub fn stop(mut self) -> u64 {
        self.halt();
        self.kill_count()
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosSchedule {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::{ActorSystem, Behavior, Reply, SystemConfig};
    use crate::opencl::device::{Device, DeviceInfo, DeviceKind};
    use crate::opencl::placement::{PlacementPolicy, Replica};

    fn test_pool(sys: &ActorSystem, n: usize) -> Arc<DevicePool> {
        let replicas = (0..n)
            .map(|id| {
                let dev = Device::start(
                    id,
                    &format!("chaos-test-{id}"),
                    DeviceKind::Cpu,
                    DeviceInfo {
                        compute_units: 1,
                        max_work_items_per_cu: 1,
                    },
                    None,
                )
                .unwrap();
                let facade = sys.spawn(|_| Behavior::new().on_any(|_c, _m| Reply::Promised));
                Replica::new(dev, facade)
            })
            .collect();
        Arc::new(DevicePool::new(replicas, PlacementPolicy::RoundRobin).unwrap())
    }

    fn eventually(mut cond: impl FnMut() -> bool, budget: Duration) -> bool {
        let start = std::time::Instant::now();
        while start.elapsed() < budget {
            if cond() {
                return true;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        cond()
    }

    #[test]
    fn max_kills_bounds_the_schedule_and_stop_reports_the_count() {
        let sys = ActorSystem::new(SystemConfig::default());
        let pool = test_pool(&sys, 2);
        let chaos = ChaosSchedule::start(
            pool,
            ChaosConfig {
                interval: Duration::from_millis(5),
                max_kills: 2,
                seed: 7,
                fault: ChaosFault::Kill,
            },
        );
        assert!(
            eventually(|| chaos.kill_count() >= 2, Duration::from_secs(5)),
            "chaos schedule never reached its kill budget"
        );
        let total = chaos.stop();
        assert_eq!(total, 2, "max_kills must cap the schedule exactly");
        sys.shutdown();
    }

    #[test]
    fn stall_faults_wedge_the_device_queue_without_killing_the_replica() {
        let sys = ActorSystem::new(SystemConfig::default());
        let pool = test_pool(&sys, 1);
        let stall = Duration::from_millis(60);
        let chaos = ChaosSchedule::start(
            pool.clone(),
            ChaosConfig {
                interval: Duration::from_millis(5),
                max_kills: 1,
                seed: 3,
                fault: ChaosFault::Stall(stall),
            },
        );
        assert!(
            eventually(|| chaos.kill_count() >= 1, Duration::from_secs(5)),
            "stall fault never landed"
        );
        // the replica is stalled, not dead: supervision sees nothing...
        assert!(pool.replicas()[0].is_alive(), "a stall must not kill");
        // ...but the queue thread is asleep — a barrier enqueued behind
        // the stall waits it out
        let t0 = std::time::Instant::now();
        pool.replicas()[0]
            .device
            .queue
            .barrier(Duration::from_secs(5))
            .expect("barrier after stall"); // lint-ok: test asserts queue drains
        assert!(
            t0.elapsed() >= stall / 2,
            "barrier returned before the stall elapsed — fault not injected?"
        );
        chaos.stop();
        sys.shutdown();
    }

    #[test]
    fn stop_halts_an_unlimited_schedule_promptly() {
        let sys = ActorSystem::new(SystemConfig::default());
        let pool = test_pool(&sys, 1);
        let chaos = ChaosSchedule::start(
            pool,
            ChaosConfig {
                interval: Duration::from_secs(3600),
                max_kills: 0,
                seed: 1,
                fault: ChaosFault::Kill,
            },
        );
        let start = std::time::Instant::now();
        let kills = chaos.stop();
        assert!(
            start.elapsed() < Duration::from_secs(2),
            "stop() must not wait out the full interval"
        );
        assert_eq!(kills, 0);
        sys.shutdown();
    }
}
