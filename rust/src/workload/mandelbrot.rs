//! Native CPU Mandelbrot (paper §5.4): renders the same inner cut
//! `[-0.5 - 0.7375i, 0.1 - 0.1375i]` as the device kernel
//! (`python/compile/kernels/mandelbrot.py`), bit-identically — both sides
//! iterate in f32 with the same escape rule, so CPU/device splits can be
//! verified by equality.

/// (x0, x1, y0, y1) of the rendered region.
pub const MANDEL_REGION: (f32, f32, f32, f32) = (-0.5, 0.1, -0.7375, -0.1375);

/// Render `rows` rows starting at `y_start` of a `width x height` image;
/// returns iteration counts row-major.
pub fn mandelbrot_rows(
    width: usize,
    height: usize,
    y_start: usize,
    rows: usize,
    iters: u32,
) -> Vec<u32> {
    let (x0, x1, y0, y1) = MANDEL_REGION;
    let mut out = vec![0u32; rows * width];
    for r in 0..rows {
        let cy = y0 + (y1 - y0) * ((y_start + r) as f32) / (height as f32);
        for c in 0..width {
            let cx = x0 + (x1 - x0) * (c as f32) / (width as f32);
            let mut zx = 0f32;
            let mut zy = 0f32;
            let mut count = 0u32;
            for _ in 0..iters {
                if zx * zx + zy * zy > 4.0 {
                    break;
                }
                count += 1;
                let nzx = zx * zx - zy * zy + cx;
                zy = 2.0 * zx * zy + cy;
                zx = nzx;
            }
            out[r * width + c] = count;
        }
    }
    out
}

/// Multi-threaded render (the CPU actors of Fig 7 split the image in row
/// bands; this is the equivalent dense loop for baseline timing).
pub fn mandelbrot_rows_parallel(
    width: usize,
    height: usize,
    y_start: usize,
    rows: usize,
    iters: u32,
    threads: usize,
) -> Vec<u32> {
    let threads = threads.max(1).min(rows.max(1));
    if threads <= 1 || rows == 0 {
        return mandelbrot_rows(width, height, y_start, rows, iters);
    }
    let mut out = vec![0u32; rows * width];
    let band = rows.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, chunk) in out.chunks_mut(band * width).enumerate() {
            let begin = y_start + t * band;
            let n = chunk.len() / width;
            s.spawn(move || {
                let part = mandelbrot_rows(width, height, begin, n, iters);
                chunk.copy_from_slice(&part);
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_bounded() {
        let img = mandelbrot_rows(32, 32, 0, 32, 20);
        assert_eq!(img.len(), 32 * 32);
        assert!(img.iter().all(|&c| c <= 20));
        // the cut contains both interior and escaping points
        assert!(img.iter().any(|&c| c == 20));
        assert!(img.iter().any(|&c| c < 20));
    }

    #[test]
    fn parallel_equals_sequential() {
        let a = mandelbrot_rows(64, 64, 8, 40, 30);
        let b = mandelbrot_rows_parallel(64, 64, 8, 40, 30, 4);
        assert_eq!(a, b);
    }

    #[test]
    fn chunks_tile_image() {
        let whole = mandelbrot_rows(16, 32, 0, 32, 15);
        let mut tiled = Vec::new();
        for y in (0..32).step_by(8) {
            tiled.extend(mandelbrot_rows(16, 32, y, 8, 15));
        }
        assert_eq!(whole, tiled);
    }
}
