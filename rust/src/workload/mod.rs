//! Benchmark workloads: native CPU implementations (the "CPU side" of the
//! heterogeneous benchmarks and the baselines of Figs 3/7/8) plus synthetic
//! data generators.

pub mod gen;
pub mod mandelbrot;
pub mod matmul;

pub use gen::ValueStream;
pub use mandelbrot::{mandelbrot_rows, mandelbrot_rows_parallel, MANDEL_REGION};
pub use matmul::matmul_naive;
