//! Benchmark workloads: native CPU implementations (the "CPU side" of the
//! heterogeneous benchmarks and the baselines of Figs 3/7/8), synthetic
//! data generators, and the arrival/mix generators that drive the soak
//! harness (see [`gen`]).

pub mod gen;
pub mod mandelbrot;
pub mod matmul;

pub use gen::{ClassMix, ClosedLoop, OpenLoop, RequestClass, ValueStream};
pub use mandelbrot::{mandelbrot_rows, mandelbrot_rows_parallel, MANDEL_REGION};
pub use matmul::matmul_naive;
