//! Synthetic workload generators.
//!
//! Two layers: *what* the requests carry — [`ValueStream`] value
//! distributions for the indexing benchmarks (Fig 3; the paper's VAST
//! domain has skewed port/address-byte frequencies, so uniform and
//! Zipf-like modes) — and *when/which* requests arrive, for the soak
//! harness:
//!
//! - [`RequestClass`] names the three soak request shapes (batched small
//!   val-mode, large transfer-bound, multi-stage pipeline) and
//!   [`ClassMix`] draws among them by weight.
//! - [`OpenLoop`] precomputes a Poisson arrival schedule at a target
//!   offered rate — arrivals do **not** slow down when the system backs
//!   up, which is exactly what makes overload reachable.
//! - [`ClosedLoop`] describes the classic N-outstanding-requests driver
//!   whose offered rate self-throttles to system speed (the control
//!   arm: a closed loop can saturate but never truly overload).

use crate::util::Rng;
use std::time::Duration;

/// A soak request class: which kernel shape a generated request exercises.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RequestClass {
    /// Sub-capacity val-mode request against the batched small kernel —
    /// exercises window coalescing, adaptive delay, and shed-from-window.
    SmallVal,
    /// Full-size request against the transfer-bound large kernel —
    /// exercises per-request dispatch, routing, and deadline-in-mailbox.
    LargeTransfer,
    /// Two chained requests (large stage feeding a small stage) —
    /// exercises cross-class latency coupling under overload.
    Pipeline,
}

impl RequestClass {
    pub const ALL: [RequestClass; 3] = [
        RequestClass::SmallVal,
        RequestClass::LargeTransfer,
        RequestClass::Pipeline,
    ];

    /// Stable name used in reports (`BENCH_soak.json` class keys).
    pub fn name(&self) -> &'static str {
        match self {
            RequestClass::SmallVal => "small_val",
            RequestClass::LargeTransfer => "large_transfer",
            RequestClass::Pipeline => "pipeline",
        }
    }
}

/// Weighted mix over request classes.
#[derive(Clone, Debug)]
pub struct ClassMix {
    /// `(class, weight)`; weights need not sum to 1 — draws normalize.
    pub weights: Vec<(RequestClass, f64)>,
}

impl ClassMix {
    /// The soak default: mostly small batched requests, a transfer-bound
    /// minority, and a trickle of pipelines.
    pub fn soak_default() -> ClassMix {
        ClassMix {
            weights: vec![
                (RequestClass::SmallVal, 0.7),
                (RequestClass::LargeTransfer, 0.2),
                (RequestClass::Pipeline, 0.1),
            ],
        }
    }

    /// Draw one class. Zero/negative weights are never picked; an empty
    /// or all-zero mix falls back to `SmallVal`.
    pub fn pick(&self, rng: &mut Rng) -> RequestClass {
        let total: f64 = self.weights.iter().map(|(_, w)| w.max(0.0)).sum();
        if total <= 0.0 {
            return RequestClass::SmallVal;
        }
        let mut x = rng.f64() * total;
        for (class, w) in &self.weights {
            let w = w.max(0.0);
            if x < w {
                return *class;
            }
            x -= w;
        }
        self.weights.last().map(|(c, _)| *c).unwrap_or(RequestClass::SmallVal)
    }
}

/// Open-loop (Poisson) arrival process at a fixed offered rate.
///
/// The schedule is materialized up front as offsets from the run start, so
/// driver threads can share one schedule through an atomic cursor and the
/// offered load stays independent of how slowly requests complete.
#[derive(Clone, Copy, Debug)]
pub struct OpenLoop {
    /// Offered arrival rate, requests per second.
    pub rps: f64,
}

impl OpenLoop {
    /// Poisson arrival offsets within `[0, duration)`, sorted ascending.
    /// Deterministic per seed; empty when `rps <= 0` or the duration is
    /// zero.
    pub fn schedule(&self, duration: Duration, seed: u64) -> Vec<Duration> {
        if self.rps <= 0.0 || duration.is_zero() {
            return Vec::new();
        }
        let mut rng = Rng::new(seed);
        let mut out = Vec::with_capacity((self.rps * duration.as_secs_f64()) as usize + 1);
        let mut t = 0.0f64;
        let end = duration.as_secs_f64();
        loop {
            // exponential inter-arrival gap with mean 1/rps
            let u = rng.f64();
            t += -((1.0 - u).max(1e-12)).ln() / self.rps;
            if t >= end {
                break;
            }
            out.push(Duration::from_secs_f64(t));
        }
        out
    }
}

/// Closed-loop driver shape: `concurrency` workers, each issuing its next
/// request `think` after the previous reply. Offered rate self-throttles
/// to completion rate, so this arm saturates without overloading —
/// the soak uses it as the bounded-pressure control.
#[derive(Clone, Copy, Debug)]
pub struct ClosedLoop {
    /// Outstanding requests held open at all times.
    pub concurrency: usize,
    /// Pause between a reply and the worker's next request.
    pub think: Duration,
}

/// Distribution of a generated value stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ValueStream {
    /// Uniform over `[0, cardinality)`.
    Uniform { cardinality: u32 },
    /// Zipf-ranked over `[0, cardinality)` with exponent `s`.
    Zipf { cardinality: u32, s: f64 },
    /// Runs of repeated values (favourable for fills — compression's best
    /// case; run lengths uniform in `[1, max_run]`).
    Runs { cardinality: u32, max_run: u32 },
}

impl ValueStream {
    /// Generate `n` values with the stream's distribution.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        match *self {
            ValueStream::Uniform { cardinality } => {
                (0..n).map(|_| rng.below(cardinality as u64) as u32).collect()
            }
            ValueStream::Zipf { cardinality, s } => {
                (0..n).map(|_| rng.zipf(cardinality as u64, s) as u32).collect()
            }
            ValueStream::Runs {
                cardinality,
                max_run,
            } => {
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    let v = rng.below(cardinality as u64) as u32;
                    let run = rng.range(1, max_run as u64 + 1) as usize;
                    for _ in 0..run.min(n - out.len()) {
                        out.push(v);
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_range_and_deterministic() {
        let s = ValueStream::Uniform { cardinality: 100 };
        let a = s.generate(1000, 7);
        let b = s.generate(1000, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| v < 100));
    }

    #[test]
    fn zipf_is_skewed() {
        let s = ValueStream::Zipf {
            cardinality: 1000,
            s: 1.2,
        };
        let v = s.generate(10_000, 3);
        let head = v.iter().filter(|&&x| x < 10).count();
        assert!(head > 3_000);
    }

    #[test]
    fn runs_have_requested_length() {
        let s = ValueStream::Runs {
            cardinality: 8,
            max_run: 50,
        };
        let v = s.generate(5_000, 1);
        assert_eq!(v.len(), 5_000);
        // should contain some long runs
        let mut best = 1;
        let mut cur = 1;
        for w in v.windows(2) {
            if w[0] == w[1] {
                cur += 1;
                best = best.max(cur);
            } else {
                cur = 1;
            }
        }
        assert!(best >= 10, "expected long runs, best={best}");
    }

    #[test]
    fn open_loop_hits_the_offered_rate_and_is_deterministic() {
        let gen = OpenLoop { rps: 500.0 };
        let a = gen.schedule(Duration::from_secs(2), 11);
        let b = gen.schedule(Duration::from_secs(2), 11);
        assert_eq!(a, b, "same seed must give the same schedule");
        // Poisson count over 2s at 500 rps: mean 1000, sd ~32 — a ±20%
        // band is ~6 sigma
        assert!(
            (800..=1200).contains(&a.len()),
            "expected ~1000 arrivals, got {}",
            a.len()
        );
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "offsets must be sorted");
        assert!(a.iter().all(|t| *t < Duration::from_secs(2)));
        let c = gen.schedule(Duration::from_secs(2), 12);
        assert_ne!(a, c, "different seeds must give different schedules");
    }

    #[test]
    fn open_loop_degenerate_inputs_give_empty_schedules() {
        assert!(OpenLoop { rps: 0.0 }
            .schedule(Duration::from_secs(1), 1)
            .is_empty());
        assert!(OpenLoop { rps: -5.0 }
            .schedule(Duration::from_secs(1), 1)
            .is_empty());
        assert!(OpenLoop { rps: 100.0 }.schedule(Duration::ZERO, 1).is_empty());
    }

    #[test]
    fn class_mix_respects_weights_and_skips_zero_weight_classes() {
        let mix = ClassMix {
            weights: vec![
                (RequestClass::SmallVal, 0.75),
                (RequestClass::LargeTransfer, 0.25),
                (RequestClass::Pipeline, 0.0),
            ],
        };
        let mut rng = Rng::new(42);
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            match mix.pick(&mut rng) {
                RequestClass::SmallVal => counts[0] += 1,
                RequestClass::LargeTransfer => counts[1] += 1,
                RequestClass::Pipeline => counts[2] += 1,
            }
        }
        assert_eq!(counts[2], 0, "zero-weight class must never be drawn");
        assert!(
            counts[0] > 2 * counts[1],
            "0.75/0.25 split should skew ~3:1, got {counts:?}"
        );
        assert!(counts[1] > 500, "minority class must still appear: {counts:?}");
    }

    #[test]
    fn class_mix_empty_or_all_zero_falls_back_to_small_val() {
        let mut rng = Rng::new(1);
        let empty = ClassMix { weights: Vec::new() };
        assert_eq!(empty.pick(&mut rng), RequestClass::SmallVal);
        let zeros = ClassMix {
            weights: vec![(RequestClass::Pipeline, 0.0)],
        };
        assert_eq!(zeros.pick(&mut rng), RequestClass::SmallVal);
    }

    #[test]
    fn request_class_names_are_stable_report_keys() {
        let names: Vec<&str> = RequestClass::ALL.iter().map(|c| c.name()).collect();
        assert_eq!(names, vec!["small_val", "large_transfer", "pipeline"]);
    }
}
