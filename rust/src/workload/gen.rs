//! Synthetic value streams for the indexing benchmarks (Fig 3). The paper's
//! domain is network forensics (VAST): indexed fields like ports and
//! address bytes have skewed frequency distributions, so the generator
//! offers uniform and Zipf-like modes.

use crate::util::Rng;

/// Distribution of a generated value stream.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ValueStream {
    /// Uniform over `[0, cardinality)`.
    Uniform { cardinality: u32 },
    /// Zipf-ranked over `[0, cardinality)` with exponent `s`.
    Zipf { cardinality: u32, s: f64 },
    /// Runs of repeated values (favourable for fills — compression's best
    /// case; run lengths uniform in `[1, max_run]`).
    Runs { cardinality: u32, max_run: u32 },
}

impl ValueStream {
    /// Generate `n` values with the stream's distribution.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        match *self {
            ValueStream::Uniform { cardinality } => {
                (0..n).map(|_| rng.below(cardinality as u64) as u32).collect()
            }
            ValueStream::Zipf { cardinality, s } => {
                (0..n).map(|_| rng.zipf(cardinality as u64, s) as u32).collect()
            }
            ValueStream::Runs {
                cardinality,
                max_run,
            } => {
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    let v = rng.below(cardinality as u64) as u32;
                    let run = rng.range(1, max_run as u64 + 1) as usize;
                    for _ in 0..run.min(n - out.len()) {
                        out.push(v);
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_range_and_deterministic() {
        let s = ValueStream::Uniform { cardinality: 100 };
        let a = s.generate(1000, 7);
        let b = s.generate(1000, 7);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| v < 100));
    }

    #[test]
    fn zipf_is_skewed() {
        let s = ValueStream::Zipf {
            cardinality: 1000,
            s: 1.2,
        };
        let v = s.generate(10_000, 3);
        let head = v.iter().filter(|&&x| x < 10).count();
        assert!(head > 3_000);
    }

    #[test]
    fn runs_have_requested_length() {
        let s = ValueStream::Runs {
            cardinality: 8,
            max_run: 50,
        };
        let v = s.generate(5_000, 1);
        assert_eq!(v.len(), 5_000);
        // should contain some long runs
        let mut best = 1;
        let mut cur = 1;
        for w in v.windows(2) {
            if w[0] == w[1] {
                cur += 1;
                best = best.max(cur);
            } else {
                cur = 1;
            }
        }
        assert!(best >= 10, "expected long runs, best={best}");
    }
}
