//! Native CPU square matmul — the host-side comparator for the overhead
//! benches (the paper's kernels compute in f32; so do we).

/// `a @ b` for row-major `n x n` f32 matrices (ikj loop order for cache
/// friendliness; good enough as a baseline, not a BLAS).
pub fn matmul_naive(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n * n);
    let mut out = vec![0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            if aik == 0.0 {
                continue;
            }
            let brow = &b[k * n..(k + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn identity() {
        let n = 16;
        let mut eye = vec![0f32; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let mut r = Rng::new(5);
        let a = r.fill_f32(n * n);
        assert_eq!(matmul_naive(&a, &eye, n), a);
        assert_eq!(matmul_naive(&eye, &a, n), a);
    }

    #[test]
    fn small_known_product() {
        // [1 2; 3 4] * [5 6; 7 8] = [19 22; 43 50]
        let got = matmul_naive(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2);
        assert_eq!(got, vec![19.0, 22.0, 43.0, 50.0]);
    }
}
