//! PLWAH (Position List Word Aligned Hybrid, Deliège & Pedersen, EDBT'10):
//! the paper's future-work item (2) — "extend the use case of indexing on
//! GPUs to common indexing algorithms such as PLWAH".
//!
//! PLWAH improves WAH by piggybacking a *nearly-empty* literal onto the
//! preceding fill: if the literal after a fill has exactly one set bit, its
//! 5-bit position is stored in the fill word itself. Word layout (32-bit):
//!
//! * literal: MSB clear, 31 payload bits (same as WAH);
//! * fill:    MSB set | position(5 bits) << 25 | run length (25 bits);
//!   position 0 = no piggybacked bit, 1..=31 = bit (position-1) of the
//!   chunk following the run.
//!
//! The encoder consumes WAH-identical per-value position streams, so CPU
//! WAH and PLWAH indexes are directly comparable in the ablation bench.

use super::CHUNK_BITS;

pub const FILL_FLAG: u32 = 1 << 31;
const POS_SHIFT: u32 = 25;
const LEN_MASK: u32 = (1 << POS_SHIFT) - 1;

/// Encode ascending set-bit positions into PLWAH words.
pub fn plwah_encode_positions(positions: &[u32], out: &mut Vec<u32>) {
    // gather per-chunk literals first (same walk as WAH)
    let mut chunks: Vec<(u32, u32)> = Vec::new(); // (chunk, literal)
    for &pos in positions {
        let chunk = pos / CHUNK_BITS as u32;
        let bit = pos % CHUNK_BITS as u32;
        match chunks.last_mut() {
            Some((c, lit)) if *c == chunk => *lit |= 1 << bit,
            _ => chunks.push((chunk, 1 << bit)),
        }
    }
    let mut prev: i64 = -1;
    let mut i = 0;
    while i < chunks.len() {
        let (chunk, lit) = chunks[i];
        let gap = chunk as i64 - prev - 1;
        if gap > 0 {
            debug_assert!((gap as u32) <= LEN_MASK, "run too long for 25 bits");
            if lit.count_ones() == 1 {
                // piggyback the lone bit onto the fill
                let bit = lit.trailing_zeros(); // 0..=30
                out.push(FILL_FLAG | ((bit + 1) << POS_SHIFT) | gap as u32);
                prev = chunk as i64;
                i += 1;
                continue;
            }
            out.push(FILL_FLAG | gap as u32);
        }
        out.push(lit);
        prev = chunk as i64;
        i += 1;
    }
}

/// Decode PLWAH words back into set-bit positions.
pub fn plwah_decode(words: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let mut chunk = 0u32;
    for &w in words {
        if w & FILL_FLAG != 0 {
            chunk += w & LEN_MASK;
            let pos = (w >> POS_SHIFT) & 0x1F;
            if pos != 0 {
                out.push(chunk * CHUNK_BITS as u32 + (pos - 1));
                chunk += 1;
            }
        } else {
            for b in 0..CHUNK_BITS as u32 {
                if w & (1 << b) != 0 {
                    out.push(chunk * CHUNK_BITS as u32 + b);
                }
            }
            chunk += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::indexing::wah;
    use crate::util::prop::{check_vec, ensure, ensure_eq, PropConfig};
    use crate::util::Rng;

    fn roundtrip(pos: &[u32]) -> Vec<u32> {
        let mut words = Vec::new();
        plwah_encode_positions(pos, &mut words);
        plwah_decode(&words)
    }

    #[test]
    fn lone_bit_after_fill_is_piggybacked() {
        // position 1000: WAH needs fill + literal, PLWAH needs one word
        let mut w = Vec::new();
        plwah_encode_positions(&[1000], &mut w);
        assert_eq!(w.len(), 1);
        assert_eq!(roundtrip(&[1000]), vec![1000]);
    }

    #[test]
    fn dense_literal_not_piggybacked() {
        let pos: Vec<u32> = vec![100, 101];
        let mut w = Vec::new();
        plwah_encode_positions(&pos, &mut w);
        assert_eq!(w.len(), 2); // fill + 2-bit literal
        assert_eq!(roundtrip(&pos), pos);
    }

    #[test]
    fn prop_roundtrip() {
        check_vec(
            PropConfig::default(),
            |r: &mut Rng| {
                let n = r.range(0, 150) as usize;
                let mut pos: Vec<u32> = (0..n).map(|_| r.below(50_000) as u32).collect();
                pos.sort_unstable();
                pos.dedup();
                pos
            },
            |pos| ensure_eq(roundtrip(pos), pos.to_vec()),
        );
    }

    #[test]
    fn prop_plwah_never_longer_than_wah() {
        check_vec(
            PropConfig::default(),
            |r: &mut Rng| {
                let n = r.range(1, 200) as usize;
                let mut pos: Vec<u32> = (0..n).map(|_| r.below(100_000) as u32).collect();
                pos.sort_unstable();
                pos.dedup();
                pos
            },
            |pos| {
                let mut w = Vec::new();
                wah::wah_encode_positions(pos, &mut w);
                let mut p = Vec::new();
                plwah_encode_positions(pos, &mut p);
                ensure(
                    p.len() <= w.len(),
                    format!("PLWAH {} words > WAH {}", p.len(), w.len()),
                )
            },
        );
    }
}
