//! CPU bitmap-index builder: the Fig 3 baseline ("comparing GPU with CPU
//! performance") and the correctness oracle for the device pipeline.
//!
//! Single pass, O(N): one streaming WAH encoder state per distinct value,
//! flushed value-by-value into the same concatenated layout the GPU
//! pipeline emits (ascending value order + offset LUT), so the two indexes
//! compare word-for-word.

use super::wah::{FILL_FLAG, INVALID};
use super::CHUNK_BITS;

/// The index layout shared by CPU and GPU builders: concatenated per-value
/// WAH bitmaps + a value→offset lookup table.
#[derive(Clone, Debug, PartialEq)]
pub struct WahIndex {
    /// Concatenated WAH words, ascending value order.
    pub words: Vec<u32>,
    /// `lut[v]` = offset of value v's bitmap in `words`, or INVALID.
    pub lut: Vec<u32>,
    /// Distinct values present.
    pub n_distinct: u32,
}

impl WahIndex {
    /// Decode the positions of one value.
    pub fn positions_of(&self, v: u32) -> Vec<u32> {
        let off = self.lut[v as usize];
        if off == INVALID {
            return Vec::new();
        }
        let end = self.end_of(v);
        super::wah::wah_decode(&self.words[off as usize..end])
    }

    fn end_of(&self, v: u32) -> usize {
        let off = self.lut[v as usize];
        // the next valid offset after `off`, else the end of `words`
        self.lut
            .iter()
            .filter(|&&o| o != INVALID && o > off)
            .min()
            .map(|&o| o as usize)
            .unwrap_or(self.words.len())
    }

    /// Verify against the raw value stream: each value's decoded positions
    /// must be exactly its occurrences (the end-to-end invariant).
    pub fn verify(&self, values: &[u32]) -> Result<(), String> {
        for v in 0..self.lut.len() as u32 {
            let expect: Vec<u32> = values
                .iter()
                .enumerate()
                .filter(|(_, &x)| x == v)
                .map(|(i, _)| i as u32)
                .collect();
            let got = self.positions_of(v);
            if got != expect {
                return Err(format!(
                    "value {v}: decoded {} positions, expected {}",
                    got.len(),
                    expect.len()
                ));
            }
        }
        Ok(())
    }

    /// Compression ratio vs verbatim bitmaps (diagnostics).
    pub fn compression_ratio(&self, n_values: usize) -> f64 {
        let verbatim = self.n_distinct as usize * n_values.div_ceil(CHUNK_BITS);
        if self.words.is_empty() {
            return f64::INFINITY;
        }
        verbatim as f64 / self.words.len() as f64
    }
}

/// Streaming per-value WAH encoder state.
#[derive(Clone, Copy)]
struct ValueState {
    prev_chunk: i64,
    literal: u32,
}

/// The CPU indexer.
pub struct CpuIndexer {
    cardinality: usize,
}

impl CpuIndexer {
    pub fn new(cardinality: usize) -> CpuIndexer {
        CpuIndexer { cardinality }
    }

    /// Build the index over `values` (all `< cardinality`).
    pub fn index(&self, values: &[u32]) -> WahIndex {
        let c = self.cardinality;
        let mut states = vec![
            ValueState {
                prev_chunk: -1,
                literal: 0,
            };
            c
        ];
        // per-value word vectors; flushed into the shared layout at the end
        let mut parts: Vec<Vec<u32>> = vec![Vec::new(); c];
        for (i, &v) in values.iter().enumerate() {
            debug_assert!((v as usize) < c, "value {v} exceeds cardinality {c}");
            let st = &mut states[v as usize];
            let chunk = (i / CHUNK_BITS) as i64;
            let bit = i % CHUNK_BITS;
            if chunk != st.prev_chunk {
                if st.prev_chunk >= 0 {
                    parts[v as usize].push(st.literal);
                }
                let gap = chunk - st.prev_chunk - 1;
                if gap > 0 {
                    parts[v as usize].push(FILL_FLAG | gap as u32);
                }
                st.prev_chunk = chunk;
                st.literal = 0;
            }
            st.literal |= 1 << bit;
        }
        let mut words = Vec::new();
        let mut lut = vec![INVALID; c];
        let mut n_distinct = 0;
        for v in 0..c {
            if states[v].prev_chunk >= 0 {
                parts[v].push(states[v].literal);
            }
            if !parts[v].is_empty() {
                lut[v] = words.len() as u32;
                words.extend_from_slice(&parts[v]);
                n_distinct += 1;
            }
        }
        WahIndex {
            words,
            lut,
            n_distinct,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_vec, PropConfig};
    use crate::util::Rng;

    #[test]
    fn tiny_example_by_hand() {
        // values: [1, 0, 1, 1] -> value 0 at pos 1; value 1 at 0,2,3
        let idx = CpuIndexer::new(4).index(&[1, 0, 1, 1]);
        assert_eq!(idx.n_distinct, 2);
        assert_eq!(idx.positions_of(0), vec![1]);
        assert_eq!(idx.positions_of(1), vec![0, 2, 3]);
        assert!(idx.positions_of(2).is_empty());
        idx.verify(&[1, 0, 1, 1]).unwrap();
    }

    #[test]
    fn sparse_values_compress() {
        let mut values = vec![0u32; 10_000];
        values[9_999] = 7; // one lone occurrence far out
        let idx = CpuIndexer::new(8).index(&values);
        // value 7's bitmap: fill + one literal = 2 words
        let off = idx.lut[7] as usize;
        assert_eq!(idx.words.len() - off, 2);
        assert_eq!(idx.positions_of(7), vec![9_999]);
    }

    #[test]
    fn prop_index_roundtrips_any_stream() {
        check_vec(
            PropConfig::default(),
            |r: &mut Rng| {
                let n = r.range(1, 512) as usize;
                (0..n).map(|_| r.below(32) as u32).collect::<Vec<u32>>()
            },
            |values| {
                let idx = CpuIndexer::new(32).index(values);
                idx.verify(values).map_err(|e| e.to_string())
            },
        );
    }

    #[test]
    fn prop_zipf_streams() {
        check_vec(
            PropConfig { cases: 16, ..Default::default() },
            |r: &mut Rng| {
                (0..1024).map(|_| r.zipf(64, 1.1) as u32).collect::<Vec<u32>>()
            },
            |values| {
                let idx = CpuIndexer::new(64).index(values);
                idx.verify(values).map_err(|e| e.to_string())
            },
        );
    }
}
