//! The paper's use case (§4): building WAH-compressed bitmap indexes for
//! high-volume value streams (VAST-style network forensics), both on the
//! CPU (the Fig 3 baseline + correctness oracle) and as a multi-stage
//! OpenCL-actor pipeline on the device (Fusco et al.'s algorithm).

pub mod cpu_index;
pub mod gpu_pipeline;
pub mod plwah;
pub mod wah;

pub use cpu_index::{CpuIndexer, WahIndex};
pub use gpu_pipeline::{pipeline_spawn, FusedIndexer, GpuIndexer};
pub use wah::{wah_decode, wah_encode_positions, FILL_FLAG, INVALID};

/// Config-prefix length shared with the Python kernels (DESIGN.md §5).
pub const CFG: usize = 8;
/// Work-group size of the stream compaction (paper §4.1: groups of 128).
pub const GROUP: usize = 128;
/// Bit positions per WAH chunk (31-bit literal payload).
pub const CHUNK_BITS: usize = 31;
