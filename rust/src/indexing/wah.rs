//! Word-Aligned Hybrid (WAH) compression primitives (Wu et al., TODS'06):
//! 32-bit words that are either a *literal* (MSB clear, 31 payload bits) or
//! a *fill* (MSB set, run length of empty 31-bit chunks — this index only
//! produces zero-fills).
//!
//! The encoding here is bit-identical to the Python oracle
//! (`python/compile/kernels/ref.py`) and to what the device pipeline
//! produces, so CPU and GPU indexes can be compared word-for-word.

use super::CHUNK_BITS;

pub const FILL_FLAG: u32 = 1 << 31;
pub const INVALID: u32 = 0xFFFF_FFFF;

/// Encode an ascending list of set-bit positions into WAH words.
pub fn wah_encode_positions(positions: &[u32], out: &mut Vec<u32>) {
    let mut prev_chunk: i64 = -1;
    let mut literal: u32 = 0;
    for &pos in positions {
        let chunk = (pos as usize / CHUNK_BITS) as i64;
        let bit = pos as usize % CHUNK_BITS;
        if chunk != prev_chunk {
            if prev_chunk >= 0 {
                out.push(literal);
            }
            let gap = chunk - prev_chunk - 1;
            if gap > 0 {
                out.push(FILL_FLAG | gap as u32);
            }
            prev_chunk = chunk;
            literal = 0;
        }
        literal |= 1 << bit;
    }
    if prev_chunk >= 0 {
        out.push(literal);
    }
}

/// Decode WAH words back into set-bit positions.
pub fn wah_decode(words: &[u32]) -> Vec<u32> {
    let mut out = Vec::new();
    let mut chunk = 0usize;
    for &w in words {
        if w & FILL_FLAG != 0 {
            chunk += (w & 0x3FFF_FFFF) as usize;
        } else {
            for b in 0..CHUNK_BITS {
                if w & (1 << b) != 0 {
                    out.push((chunk * CHUNK_BITS + b) as u32);
                }
            }
            chunk += 1;
        }
    }
    out
}

/// Number of words a literal+fill encoding of `positions` occupies without
/// compression context (diagnostics for compression-ratio reports).
pub fn uncompressed_words(max_pos: u32) -> usize {
    (max_pos as usize + CHUNK_BITS) / CHUNK_BITS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_vec, ensure_eq, PropConfig};
    use crate::util::Rng;

    fn roundtrip(positions: &[u32]) -> Vec<u32> {
        let mut words = Vec::new();
        wah_encode_positions(positions, &mut words);
        wah_decode(&words)
    }

    #[test]
    fn empty() {
        assert!(roundtrip(&[]).is_empty());
    }

    #[test]
    fn single_bit_far_out() {
        let pos = vec![1000];
        let mut words = Vec::new();
        wah_encode_positions(&pos, &mut words);
        // 1000/31 = chunk 32 -> one fill of 32, one literal
        assert_eq!(words.len(), 2);
        assert_eq!(words[0], FILL_FLAG | 32);
        assert_eq!(roundtrip(&pos), pos);
    }

    #[test]
    fn dense_chunk() {
        let pos: Vec<u32> = (0..31).collect();
        let mut words = Vec::new();
        wah_encode_positions(&pos, &mut words);
        assert_eq!(words, vec![(1 << 31) - 1]);
    }

    #[test]
    fn chunk_boundaries() {
        let pos = vec![30, 31, 61, 62, 92];
        assert_eq!(roundtrip(&pos), pos);
    }

    #[test]
    fn prop_roundtrip_random_position_sets() {
        check_vec(
            PropConfig::default(),
            |r: &mut Rng| {
                let n = r.range(0, 200) as usize;
                let mut pos: Vec<u32> =
                    (0..n).map(|_| r.below(10_000) as u32).collect();
                pos.sort_unstable();
                pos.dedup();
                pos
            },
            |pos| ensure_eq(roundtrip(pos), pos.to_vec()),
        );
    }

    #[test]
    fn prop_compression_never_exceeds_two_words_per_bit() {
        check_vec(
            PropConfig::default(),
            |r: &mut Rng| {
                let n = r.range(1, 100) as usize;
                let mut pos: Vec<u32> =
                    (0..n).map(|_| r.below(100_000) as u32).collect();
                pos.sort_unstable();
                pos.dedup();
                pos
            },
            |pos| {
                let mut words = Vec::new();
                wah_encode_positions(pos, &mut words);
                crate::util::prop::ensure(
                    words.len() <= 2 * pos.len(),
                    format!("{} words for {} positions", words.len(), pos.len()),
                )
            },
        );
    }
}
