//! The device-side WAH index builder (paper §4.1): eight kernel stages —
//! sort, chunk-literals, fills, interleave (`prepare_index`), compaction
//! count/scan/move (`count_elements` / `move_valid_elements`, work-groups
//! of 128), and the lookup table — each wrapped in an OpenCL actor and
//! composed into a single pipeline actor.
//!
//! Messages between stages carry a *context vector* of `MemRef`s; each
//! stage's preprocess selects its kernel operands from the context and its
//! postprocess re-packs what downstream stages still need (paper §3.5: the
//! mappers "add, remove or configure the arguments for the execution").
//! Data stays device-resident end to end; the requester reads the final
//! (index, LUT) references back explicitly.

use super::cpu_index::WahIndex;
use super::{CFG, INVALID};
use crate::actor::{compose, ActorRef, Message, ScopedActor};
use crate::opencl::{ArgValue, KernelSpawn, Manager, Mode, Placement, PipelineSpawn, Program};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;
use std::time::Duration;

/// Supported pipeline capacities (fixed AOT shapes; see aot.py WAH_SIZES).
pub const CAPACITIES: [usize; 5] = [4096, 16384, 65536, 262144, 1048576];
/// Value cardinality of the shipped artifacts (aot.py WAH_CARD).
pub const CARDINALITY: usize = 1024;
/// The reserved padding value.
pub const PAD_VALUE: u32 = (CARDINALITY - 1) as u32;

/// Select context entries as kernel operands.
fn pre_select(idxs: &'static [usize]) -> impl Fn(&Message) -> Option<Vec<ArgValue>> + Send + Sync {
    move |msg| {
        let ctx = msg.downcast_ref::<Vec<ArgValue>>()?;
        idxs.iter()
            .map(|&i| ctx.get(i).cloned())
            .collect::<Option<Vec<_>>>()
    }
}

/// Build the next context: the stage output (first unless `out_last`),
/// then the kept incoming-context entries.
fn post_ctx(
    keep: &'static [usize],
    out_last: bool,
) -> impl Fn(ArgValue, &Message) -> Message + Send + Sync {
    move |out, inc| {
        let kept: Vec<ArgValue> = inc
            .downcast_ref::<Vec<ArgValue>>()
            .map(|ctx| {
                keep.iter()
                    .filter_map(|&i| ctx.get(i).cloned())
                    .collect()
            })
            .unwrap_or_default();
        let mut next = Vec::with_capacity(kept.len() + 1);
        if out_last {
            next.extend(kept);
            next.push(out);
        } else {
            next.push(out);
            next.extend(kept);
        }
        Message::new(next)
    }
}

/// The per-stage spawn configs — kernel names, argument modes, and the
/// context-threading pre/post mappers — shared by the composed
/// [`GpuIndexer::build`] baseline and the placement-tier
/// [`pipeline_spawn`] constructor. One table, two deployment shapes.
fn stage_specs(program: &Arc<Program>, names: &[String]) -> Vec<KernelSpawn> {
    let mk = |kernel: &str| KernelSpawn::new(program.clone(), kernel).output(Mode::Ref);
    // context evolution:            incoming ctx          -> outgoing ctx
    vec![
        // 1 sort: Vec<u32> values   []                    -> [sorted]
        mk(&names[0])
            .inputs(Mode::Val, 1)
            .postprocess(post_ctx(&[], false)),
        // 2 chunklit                [sorted]              -> [cl, sorted]
        mk(&names[1])
            .inputs(Mode::Ref, 1)
            .preprocess(pre_select(&[0]))
            .postprocess(post_ctx(&[0], false)),
        // 3 fillslit                [cl, sorted]          -> [fl, sorted]
        mk(&names[2])
            .inputs(Mode::Ref, 1)
            .preprocess(pre_select(&[0]))
            .postprocess(post_ctx(&[1], false)),
        // 4 interleave              [fl, sorted]          -> [idx, fl, sorted]
        mk(&names[3])
            .inputs(Mode::Ref, 1)
            .preprocess(pre_select(&[0]))
            .postprocess(post_ctx(&[0, 1], false)),
        // 5 count                   [idx, fl, sorted]     -> [counts, idx, fl, sorted]
        mk(&names[4])
            .inputs(Mode::Ref, 1)
            .preprocess(pre_select(&[0]))
            .postprocess(post_ctx(&[0, 1, 2], false)),
        // 6 scan                    [counts, idx, fl, sorted] -> [scan, idx, fl, sorted]
        mk(&names[5])
            .inputs(Mode::Ref, 1)
            .preprocess(pre_select(&[0]))
            .postprocess(post_ctx(&[1, 2, 3], false)),
        // 7 move(idx, scan)         [scan, idx, fl, sorted] -> [moved, fl, sorted]
        mk(&names[6])
            .inputs(Mode::Ref, 2)
            .preprocess(pre_select(&[1, 0]))
            .postprocess(post_ctx(&[2, 3], false)),
        // 8 lut(fl, sorted)         [moved, fl, sorted]   -> [moved, lut]
        mk(&names[7])
            .inputs(Mode::Ref, 2)
            .preprocess(pre_select(&[1, 2]))
            .postprocess(post_ctx(&[0], true)),
    ]
}

/// Package the 8-stage WAH build as a placement-tier [`PipelineSpawn`]:
/// routed as one unit, replicable per device, stages interleaving across
/// concurrent index builds, and (with `ReplicaSet::migrate`) movable off a
/// dead replica mid-build. The program is compiled against `device_id`;
/// replicated placement recompiles per replica device.
///
/// Drive the returned spawn through `Manager::spawn_pipeline` /
/// `spawn_pipeline_replicated` with `Vec<u32>` values padded to
/// `capacity` (see [`GpuIndexer::index`] for the padding rules).
pub fn pipeline_spawn(
    manager: &Arc<Manager>,
    device_id: usize,
    capacity: usize,
    placement: Placement,
) -> Result<PipelineSpawn> {
    if !CAPACITIES.contains(&capacity) {
        bail!("unsupported capacity {capacity}; artifacts exist for {CAPACITIES:?}");
    }
    let device = manager.device(device_id)?;
    let names = GpuIndexer::kernel_names(capacity);
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let program = manager.create_program(&device, &name_refs)?;
    let mut spawn = PipelineSpawn::new().placement(placement);
    for cfg in stage_specs(&program, &names) {
        spawn = spawn.stage(cfg);
    }
    Ok(spawn)
}

/// The composed 8-stage device pipeline for one capacity.
pub struct GpuIndexer {
    pub capacity: usize,
    pipe: ActorRef,
    /// Stage actors in flow order (exposed for monitoring / reuse).
    pub stages: Vec<ActorRef>,
}

impl GpuIndexer {
    /// Stage kernel names at a capacity.
    pub fn kernel_names(n: usize) -> Vec<String> {
        ["sort", "chunklit", "fillslit", "interleave", "count", "scan", "move", "lut"]
            .iter()
            .map(|s| format!("wah_{s}_{n}"))
            .collect()
    }

    /// Build the pipeline on `manager`'s device `device_id`.
    pub fn build(manager: &Arc<Manager>, device_id: usize, capacity: usize) -> Result<GpuIndexer> {
        if !CAPACITIES.contains(&capacity) {
            bail!("unsupported capacity {capacity}; artifacts exist for {CAPACITIES:?}");
        }
        let device = manager.device(device_id)?;
        let names = Self::kernel_names(capacity);
        let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
        let program = manager.create_program(&device, &name_refs)?;
        let sys = manager.system_handle();

        let mut actors = Vec::new();
        for cfg in stage_specs(&program, &names) {
            actors.push(manager.spawn_cl(cfg)?);
        }
        let mut it = actors.iter().cloned();
        let first = it.next().unwrap(); // lint-ok: guarded by emptiness check above
        let pipe = it.fold(first, |acc, next| compose(&sys, next, acc));
        Ok(GpuIndexer {
            capacity,
            pipe,
            stages: actors,
        })
    }

    /// The composed pipeline actor (send it `Vec<u32>` values directly).
    pub fn actor(&self) -> &ActorRef {
        &self.pipe
    }

    /// Build an index: pads `values` to capacity with [`PAD_VALUE`],
    /// drives the pipeline, reads the (index, LUT) references back.
    pub fn index(&self, me: &ScopedActor, values: &[u32], timeout: Duration) -> Result<WahIndex> {
        if values.len() > self.capacity {
            bail!(
                "{} values exceed pipeline capacity {}",
                values.len(),
                self.capacity
            );
        }
        if let Some(v) = values.iter().find(|&&v| v >= PAD_VALUE) {
            bail!("value {v} out of range (cardinality {CARDINALITY}, top value reserved)");
        }
        let mut padded = values.to_vec();
        padded.resize(self.capacity, PAD_VALUE);
        let ctx: Vec<ArgValue> = me
            .request(&self.pipe, padded)
            .receive(timeout)
            .map_err(|e| anyhow!("pipeline failed: {}", e.reason))?;
        let [moved, lut] = ctx.as_slice() else {
            bail!("pipeline returned {} refs, expected 2", ctx.len());
        };
        let (ArgValue::Ref(moved), ArgValue::Ref(lut)) = (moved, lut) else {
            bail!("pipeline must return device references");
        };
        let moved = moved.read_u32(timeout)?;
        let lut_raw = lut.read_u32(timeout)?;
        Ok(assemble_index(&moved, &lut_raw))
    }
}

/// Parse (move-stage output, lut-stage output) into the shared layout.
fn assemble_index(moved: &[u32], lut_raw: &[u32]) -> WahIndex {
    let n_distinct = lut_raw[0];
    let words_real = lut_raw[1] as usize;
    let mut lut = lut_raw[CFG..].to_vec();
    lut[CARDINALITY - 1] = INVALID; // the pad value is reserved
    WahIndex {
        words: moved[CFG..CFG + words_real].to_vec(),
        lut,
        n_distinct,
    }
}

/// The monolithic single-actor variant (ablation A, design §3.6): the whole
/// algorithm as ONE kernel artifact wrapped in ONE OpenCL actor — no
/// inter-stage messaging, but also no stage reuse.
pub struct FusedIndexer {
    pub capacity: usize,
    actor: ActorRef,
}

impl FusedIndexer {
    pub fn build(manager: &Arc<Manager>, device_id: usize, capacity: usize) -> Result<FusedIndexer> {
        let device = manager.device(device_id)?;
        let kernel = format!("wah_fused_{capacity}");
        let program = manager.create_program(&device, &[kernel.as_str()])?;
        let actor = manager.spawn_cl(
            KernelSpawn::new(program, &kernel)
                .inputs(Mode::Val, 1)
                .output(Mode::Val),
        )?;
        Ok(FusedIndexer { capacity, actor })
    }

    pub fn actor(&self) -> &ActorRef {
        &self.actor
    }

    pub fn index(&self, me: &ScopedActor, values: &[u32], timeout: Duration) -> Result<WahIndex> {
        if values.len() > self.capacity {
            bail!("{} values exceed capacity {}", values.len(), self.capacity);
        }
        let mut padded = values.to_vec();
        padded.resize(self.capacity, PAD_VALUE);
        let out: Vec<u32> = self
            .actor
            .pipe_request(me, padded, timeout)?;
        // layout: cfg ++ compacted[2N] ++ lut[C]
        let words_real = out[1] as usize;
        let n_distinct = out[3];
        let body = &out[CFG..CFG + 2 * self.capacity];
        let mut lut = out[CFG + 2 * self.capacity..].to_vec();
        lut[CARDINALITY - 1] = INVALID;
        Ok(WahIndex {
            words: body[..words_real].to_vec(),
            lut,
            n_distinct,
        })
    }
}

/// Small extension so indexers read like the paper's request/receive flow.
trait PipeRequest {
    fn pipe_request<Req, Resp>(
        &self,
        me: &ScopedActor,
        req: Req,
        timeout: Duration,
    ) -> Result<Resp>
    where
        Req: std::any::Any + Send + Sync,
        Resp: std::any::Any + Clone;
}

impl PipeRequest for ActorRef {
    fn pipe_request<Req, Resp>(&self, me: &ScopedActor, req: Req, timeout: Duration) -> Result<Resp>
    where
        Req: std::any::Any + Send + Sync,
        Resp: std::any::Any + Clone,
    {
        me.request(self, req)
            .receive::<Resp>(timeout)
            .map_err(|e| anyhow!("{}", e.reason))
    }
}
