//! Measurement harness for the figure-regeneration benches (criterion is
//! unavailable offline; DESIGN.md §3). Mirrors the paper's methodology:
//! warmup, N samples, mean ± 95% CI, plus CSV emission so the series can be
//! plotted alongside the paper's figures.

use crate::util::stats::{summarize, Summary};
use std::io::Write;
use std::time::Instant;

/// Run `f` `warmup + n` times; return per-run seconds for the measured `n`.
pub fn sample<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// One row of a figure series.
#[derive(Clone, Debug)]
pub struct Row {
    pub x: f64,
    pub label: String,
    pub summary: Summary,
}

/// A figure series under construction.
pub struct Series {
    pub name: String,
    pub rows: Vec<Row>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Series {
        Series {
            name: name.into(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, label: impl Into<String>, samples: &[f64]) {
        self.rows.push(Row {
            x,
            label: label.into(),
            summary: summarize(samples),
        });
    }

    /// Print the paper-style table to stdout.
    pub fn print(&self, x_name: &str, unit: &str) {
        println!("\n== {} ==", self.name);
        println!(
            "{:>14}  {:>24}  {:>12}  {:>12}  {:>4}",
            x_name, "label", &format!("mean [{unit}]"), &format!("ci95 [{unit}]"), "n"
        );
        for r in &self.rows {
            println!(
                "{:>14}  {:>24}  {:>12.6}  {:>12.6}  {:>4}",
                r.x, r.label, r.summary.mean, r.summary.ci95, r.summary.n
            );
        }
    }

    /// Write `target/bench-results/<name>.csv`.
    pub fn write_csv(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/bench-results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "x,label,mean,sd,ci95,min,max,n")?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{}",
                r.x,
                r.label,
                r.summary.mean,
                r.summary.sd,
                r.summary.ci95,
                r.summary.min,
                r.summary.max,
                r.summary.n
            )?;
        }
        Ok(path)
    }

    /// Finish: print + CSV + provenance line.
    pub fn finish(&self, x_name: &str, unit: &str) {
        self.print(x_name, unit);
        match self.write_csv() {
            Ok(p) => println!("   -> {}", p.display()),
            Err(e) => eprintln!("   (csv write failed: {e})"),
        }
    }
}

/// One offload step of the heterogeneous Mandelbrot sweep (Figs 7/8):
/// `device_chunks` tenths of the image run on the device actor, the rest on
/// a native CPU render; returns (total, cpu-part, device-part) seconds.
///
/// Matches the paper's setup: "each graph displays the runtime for the CPU
/// and OpenCL device calculations separately ... since calculations are
/// performed in parallel, the total runtime is not a sum of the separate
/// runtimes, but measured independently."
#[allow(clippy::too_many_arguments)]
pub fn hetero_step(
    me: &crate::actor::ScopedActor,
    device_actor: &crate::actor::ActorRef,
    width: usize,
    height: usize,
    chunk_rows: usize,
    iters: u32,
    device_chunks: usize,
    cpu_threads: usize,
) -> (f64, f64, f64) {
    use std::sync::atomic::{AtomicU64, Ordering};
    let timeout = std::time::Duration::from_secs(1800);
    let cpu_rows = height - device_chunks * chunk_rows;
    let t0 = Instant::now();
    let pending: Vec<_> = (0..device_chunks)
        .map(|k| {
            let y0 = (cpu_rows + k * chunk_rows) as u32;
            me.request(device_actor, vec![y0])
        })
        .collect();
    let cpu_ns = AtomicU64::new(0);
    let dev_ns = AtomicU64::new(0);
    std::thread::scope(|s| {
        if cpu_rows > 0 {
            s.spawn(|| {
                let t = Instant::now();
                std::hint::black_box(crate::workload::mandelbrot_rows_parallel(
                    width,
                    height,
                    0,
                    cpu_rows,
                    iters,
                    cpu_threads,
                ));
                cpu_ns.store(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            });
        }
        s.spawn(|| {
            let t = Instant::now();
            for p in pending {
                let _: Vec<u32> = p.receive(timeout).expect("device chunk");
            }
            dev_ns.store(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        });
    });
    let total = t0.elapsed().as_secs_f64();
    (
        total,
        cpu_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e9,
        dev_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e9,
    )
}

/// Quick/full switch: benches default to a fast sweep; set
/// `CAF_OCL_BENCH_FULL=1` for the paper-scale version.
pub fn full_mode() -> bool {
    std::env::var("CAF_OCL_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Samples per point, honouring the quick/full switch.
pub fn samples_per_point(quick: usize, full: usize) -> usize {
    if full_mode() {
        full
    } else {
        quick
    }
}
