//! Measurement harness for the figure-regeneration benches (criterion is
//! unavailable offline; DESIGN.md §3). Mirrors the paper's methodology:
//! warmup, N samples, mean ± 95% CI, plus CSV emission so the series can be
//! plotted alongside the paper's figures.

use crate::util::stats::{summarize, Summary};
use std::io::Write;
use std::time::Instant;

/// Run `f` `warmup + n` times; return per-run seconds for the measured `n`.
pub fn sample<F: FnMut()>(warmup: usize, n: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64());
    }
    out
}

/// One row of a figure series.
#[derive(Clone, Debug)]
pub struct Row {
    pub x: f64,
    pub label: String,
    pub summary: Summary,
}

/// A figure series under construction.
pub struct Series {
    pub name: String,
    pub rows: Vec<Row>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Series {
        Series {
            name: name.into(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, x: f64, label: impl Into<String>, samples: &[f64]) {
        self.rows.push(Row {
            x,
            label: label.into(),
            summary: summarize(samples),
        });
    }

    /// Print the paper-style table to stdout.
    pub fn print(&self, x_name: &str, unit: &str) {
        println!("\n== {} ==", self.name);
        println!(
            "{:>14}  {:>24}  {:>12}  {:>12}  {:>4}",
            x_name, "label", &format!("mean [{unit}]"), &format!("ci95 [{unit}]"), "n"
        );
        for r in &self.rows {
            println!(
                "{:>14}  {:>24}  {:>12.6}  {:>12.6}  {:>4}",
                r.x, r.label, r.summary.mean, r.summary.ci95, r.summary.n
            );
        }
    }

    /// Write `target/bench-results/<name>.csv`.
    pub fn write_csv(&self) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("target/bench-results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.name));
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "x,label,mean,sd,ci95,min,max,n")?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{}",
                r.x,
                r.label,
                r.summary.mean,
                r.summary.sd,
                r.summary.ci95,
                r.summary.min,
                r.summary.max,
                r.summary.n
            )?;
        }
        Ok(path)
    }

    /// Finish: print + CSV + provenance line.
    pub fn finish(&self, x_name: &str, unit: &str) {
        self.print(x_name, unit);
        match self.write_csv() {
            Ok(p) => println!("   -> {}", p.display()),
            Err(e) => eprintln!("   (csv write failed: {e})"),
        }
    }
}

/// One offload step of the heterogeneous Mandelbrot sweep (Figs 7/8):
/// `device_chunks` tenths of the image run on the device actor, the rest on
/// a native CPU render; returns (total, cpu-part, device-part) seconds.
///
/// Matches the paper's setup: "each graph displays the runtime for the CPU
/// and OpenCL device calculations separately ... since calculations are
/// performed in parallel, the total runtime is not a sum of the separate
/// runtimes, but measured independently."
#[allow(clippy::too_many_arguments)]
pub fn hetero_step(
    me: &crate::actor::ScopedActor,
    device_actor: &crate::actor::ActorRef,
    width: usize,
    height: usize,
    chunk_rows: usize,
    iters: u32,
    device_chunks: usize,
    cpu_threads: usize,
) -> (f64, f64, f64) {
    use std::sync::atomic::{AtomicU64, Ordering};
    let timeout = std::time::Duration::from_secs(1800);
    let cpu_rows = height - device_chunks * chunk_rows;
    let t0 = Instant::now();
    let pending: Vec<_> = (0..device_chunks)
        .map(|k| {
            let y0 = (cpu_rows + k * chunk_rows) as u32;
            me.request(device_actor, vec![y0])
        })
        .collect();
    let cpu_ns = AtomicU64::new(0);
    let dev_ns = AtomicU64::new(0);
    std::thread::scope(|s| {
        if cpu_rows > 0 {
            s.spawn(|| {
                let t = Instant::now();
                std::hint::black_box(crate::workload::mandelbrot_rows_parallel(
                    width,
                    height,
                    0,
                    cpu_rows,
                    iters,
                    cpu_threads,
                ));
                cpu_ns.store(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
            });
        }
        s.spawn(|| {
            let t = Instant::now();
            for p in pending {
                let _: Vec<u32> = p.receive(timeout).expect("device chunk");
            }
            dev_ns.store(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        });
    });
    let total = t0.elapsed().as_secs_f64();
    (
        total,
        cpu_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e9,
        dev_ns.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e9,
    )
}

// ---------------------------------------------------------------------------
// Message-ring throughput (PERF.md): the before/after probe for the
// lock-free mailbox + scheduler work. `msgring_lockfree` drives the real
// actor system; `msgring_seed_style` drives a faithful miniature of the
// seed's Mutex<VecDeque> mailboxes + locked injector + 10 ms condvar-poll
// scheduler, so the comparison isolates exactly the contention that was
// removed.
// ---------------------------------------------------------------------------

/// Ring parameters for [`msgring_lockfree`] / [`msgring_seed_style`].
#[derive(Clone, Copy, Debug)]
pub struct RingConfig {
    pub workers: usize,
    pub actors: usize,
    pub tokens: usize,
    pub hops_per_token: u64,
}

impl RingConfig {
    pub fn messages(&self) -> u64 {
        self.tokens as u64 * self.hops_per_token
    }
}

/// Run the ring on the real (lock-free) actor system; returns messages/sec.
pub fn msgring_lockfree(cfg: RingConfig) -> f64 {
    use crate::actor::{no_reply, ActorRef, ActorSystem, Behavior, SystemConfig};
    use std::sync::OnceLock;

    let sys = ActorSystem::new(
        SystemConfig::default().with_threads(cfg.workers),
    );
    let n = cfg.actors;
    let table: std::sync::Arc<Vec<OnceLock<ActorRef>>> =
        std::sync::Arc::new((0..n).map(|_| OnceLock::new()).collect());
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    for i in 0..n {
        let peers = table.clone();
        let tx = done_tx.clone();
        let r = sys.spawn(move |_| {
            Behavior::new().on(move |ctx, &hops_left: &u64| {
                if hops_left == 0 {
                    tx.send(()).ok();
                } else {
                    let next = peers[(i + 1) % n].get().expect("ring wired");
                    ctx.send(next, hops_left - 1);
                }
                no_reply()
            })
        });
        table[i].set(r).ok();
    }
    let me = sys.scoped();
    let t0 = Instant::now();
    for k in 0..cfg.tokens {
        let entry = table[(k * n) / cfg.tokens.max(1)].get().unwrap();
        me.send(entry, cfg.hops_per_token);
    }
    for _ in 0..cfg.tokens {
        done_rx
            .recv_timeout(std::time::Duration::from_secs(600))
            .expect("ring token lost");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    sys.shutdown();
    cfg.messages() as f64 / elapsed
}

/// Run the same ring on a miniature of the *seed* runtime: per-actor
/// `Mutex<VecDeque>` mailboxes, a single locked ready-queue, and sleepy
/// workers polling a condvar with the seed's 10 ms timeout (including its
/// lost-wakeup submit). Returns messages/sec.
pub fn msgring_seed_style(cfg: RingConfig) -> f64 {
    use std::collections::VecDeque;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::{Arc, Condvar, Mutex};

    struct Node {
        mailbox: Mutex<VecDeque<u64>>,
        scheduled: AtomicBool,
    }

    struct Rt {
        nodes: Vec<Node>,
        ready: Mutex<VecDeque<usize>>,
        sleepers: Mutex<usize>,
        wakeup: Condvar,
        shutdown: AtomicBool,
        done: AtomicU64,
        done_gate: Mutex<()>,
        done_cv: Condvar,
    }

    impl Rt {
        fn enqueue(&self, i: usize, hops: u64) {
            self.nodes[i].mailbox.lock().unwrap().push_back(hops);
            if !self.nodes[i].scheduled.swap(true, Ordering::AcqRel) {
                self.ready.lock().unwrap().push_back(i);
                // the seed's racy wake: sleepers read under a separate lock
                // *after* the push
                if *self.sleepers.lock().unwrap() > 0 {
                    self.wakeup.notify_one();
                }
            }
        }
    }

    let n = cfg.actors;
    let rt = Arc::new(Rt {
        nodes: (0..n)
            .map(|_| Node {
                mailbox: Mutex::new(VecDeque::new()),
                scheduled: AtomicBool::new(false),
            })
            .collect(),
        ready: Mutex::new(VecDeque::new()),
        sleepers: Mutex::new(0),
        wakeup: Condvar::new(),
        shutdown: AtomicBool::new(false),
        done: AtomicU64::new(0),
        done_gate: Mutex::new(()),
        done_cv: Condvar::new(),
    });
    let workers: Vec<_> = (0..cfg.workers.max(1))
        .map(|_| {
            let rt = rt.clone();
            std::thread::spawn(move || loop {
                if rt.shutdown.load(Ordering::Acquire) {
                    return;
                }
                let job = rt.ready.lock().unwrap().pop_front();
                match job {
                    Some(i) => {
                        // the seed's slice: up to 25 messages, one locked
                        // dequeue each
                        for _ in 0..25 {
                            let Some(h) = rt.nodes[i].mailbox.lock().unwrap().pop_front()
                            else {
                                break;
                            };
                            if h == 0 {
                                if rt.done.fetch_add(1, Ordering::AcqRel) + 1
                                    == cfg.tokens as u64
                                {
                                    let _g = rt.done_gate.lock().unwrap();
                                    rt.done_cv.notify_all();
                                }
                            } else {
                                rt.enqueue((i + 1) % n, h - 1);
                            }
                        }
                        if rt.nodes[i].mailbox.lock().unwrap().is_empty() {
                            rt.nodes[i].scheduled.store(false, Ordering::Release);
                            if !rt.nodes[i].mailbox.lock().unwrap().is_empty()
                                && !rt.nodes[i].scheduled.swap(true, Ordering::AcqRel)
                            {
                                rt.ready.lock().unwrap().push_back(i);
                            }
                        } else {
                            rt.ready.lock().unwrap().push_back(i);
                        }
                    }
                    None => {
                        // the seed's idle path: 10 ms poll
                        let mut sleepers = rt.sleepers.lock().unwrap();
                        *sleepers += 1;
                        let (mut s2, _) = rt
                            .wakeup
                            .wait_timeout(sleepers, std::time::Duration::from_millis(10))
                            .unwrap();
                        *s2 -= 1;
                    }
                }
            })
        })
        .collect();

    let t0 = Instant::now();
    for k in 0..cfg.tokens {
        rt.enqueue((k * n) / cfg.tokens.max(1), cfg.hops_per_token);
    }
    {
        let mut g = rt.done_gate.lock().unwrap();
        while rt.done.load(Ordering::Acquire) < cfg.tokens as u64 {
            let (g2, _) = rt
                .done_cv
                .wait_timeout(g, std::time::Duration::from_millis(50))
                .unwrap();
            g = g2;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    rt.shutdown.store(true, Ordering::Release);
    rt.wakeup.notify_all();
    for w in workers {
        let _ = w.join();
    }
    cfg.messages() as f64 / elapsed
}

/// Write `BENCH_msgring.json` (repo root when run from `rust/`, else the
/// working directory) with before/after numbers — the machine-readable
/// perf trajectory described in PERF.md.
pub fn write_msgring_json(
    cfg: RingConfig,
    seed_msgs_per_sec: f64,
    lockfree_msgs_per_sec: f64,
    generated_by: &str,
) -> std::io::Result<std::path::PathBuf> {
    let root = std::path::Path::new("../ROADMAP.md");
    let path = if root.exists() {
        std::path::PathBuf::from("../BENCH_msgring.json")
    } else {
        std::path::PathBuf::from("BENCH_msgring.json")
    };
    let speedup = lockfree_msgs_per_sec / seed_msgs_per_sec.max(1e-9);
    let json = format!(
        "{{\n  \"bench\": \"msgring\",\n  \"generated_by\": {generated_by:?},\n  \
         \"config\": {{\"workers\": {}, \"actors\": {}, \"tokens\": {}, \
         \"hops_per_token\": {}, \"messages\": {}}},\n  \
         \"seed_locked_msgs_per_sec\": {:.1},\n  \
         \"lockfree_msgs_per_sec\": {:.1},\n  \"speedup\": {:.3}\n}}\n",
        cfg.workers,
        cfg.actors,
        cfg.tokens,
        cfg.hops_per_token,
        cfg.messages(),
        seed_msgs_per_sec,
        lockfree_msgs_per_sec,
        speedup
    );
    std::fs::write(&path, json)?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Dispatch & batching (PERF.md): the placement-tier probe. Compares a
// spawn-frozen single-device facade against `Placement::Replicated` over
// the simulated inventory, and per-request sub-capacity launches against
// the adaptive batcher, at the sub-second request sizes where the paper
// found launch overhead dominating (§5).
// ---------------------------------------------------------------------------

/// Shared config of the dispatch probes (the `dispatch` bench and the
/// tier-1 `perf_dispatch` test run the same scenarios at different sizes).
#[derive(Clone, Debug)]
pub struct DispatchProbeConfig {
    /// Simulated devices in the inventory.
    pub devices: usize,
    /// Fixed per-command launch pad of every simulated device.
    pub launch: std::time::Duration,
    /// Full-capacity requests for the placement comparison.
    pub requests: usize,
    /// Sub-capacity requests for the batching comparison.
    pub batch_requests: usize,
    /// Elements per sub-capacity request.
    pub request_elems: usize,
    /// Kernel capacity in elements.
    pub capacity: usize,
    /// Artifacts dir holding the probe's stub manifest.
    pub artifacts_dir: String,
}

/// Write a stub (host-emulated) manifest into a per-process temp dir and
/// return the artifacts path — shared by every probe that fabricates its
/// kernels instead of needing `make artifacts`.
fn write_stub_manifest(dir_tag: &str, manifest: &str) -> String {
    let dir = std::env::temp_dir().join(format!("caf-ocl-{dir_tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create stub artifacts dir");
    std::fs::write(dir.join("manifest.txt"), manifest).expect("write stub manifest");
    dir.to_string_lossy().to_string()
}

/// Write the probe's stub manifest (host-emulated identity kernel) into a
/// per-process temp dir; returns the artifacts path.
pub fn write_dispatch_manifest(tag: &str, capacity: usize) -> String {
    write_stub_manifest(
        &format!("dispatch-{tag}"),
        &format!("copy_u32|emu|u32:{capacity}|u32:{capacity}|emu=identity n={capacity}\n"),
    )
}

fn dispatch_system(
    artifacts_dir: &str,
    launch: std::time::Duration,
    n_devices: usize,
) -> (crate::actor::ActorSystem, std::sync::Arc<crate::opencl::Manager>) {
    use crate::opencl::{DeviceInfo, DeviceKind, DeviceSpec, Manager};
    use crate::runtime::client::PadModel;
    let sys = crate::actor::ActorSystem::new(
        crate::actor::SystemConfig::default()
            .with_threads(4)
            .with_artifacts_dir(artifacts_dir.to_string()),
    );
    let specs = (0..n_devices)
        .map(|i| DeviceSpec {
            name: format!("sim-{i}"),
            kind: DeviceKind::Gpu,
            info: DeviceInfo {
                compute_units: 8,
                max_work_items_per_cu: 1024,
            },
            pad: Some(PadModel {
                launch,
                bytes_per_sec: 0.0,
                compute_scale: 1.0,
                busy_wait: false,
            }),
        })
        .collect();
    let mgr = Manager::load_with(&sys, specs);
    (sys, mgr)
}

fn dispatch_spawn_kernel(
    mgr: &crate::opencl::Manager,
    kernel: &str,
    placement: crate::opencl::Placement,
    batching: Option<crate::opencl::BatchConfig>,
) -> crate::actor::ActorRef {
    use crate::opencl::{KernelSpawn, Mode};
    let program = mgr.create_kernel_program(kernel).expect("stub program");
    let mut cfg = KernelSpawn::new(program, kernel)
        .inputs(Mode::Val, 1)
        .output(Mode::Val)
        .placement(placement);
    if let Some(b) = batching {
        cfg = cfg.batched(b);
    }
    mgr.spawn_cl(cfg).expect("dispatch probe spawn")
}

fn dispatch_spawn(
    mgr: &crate::opencl::Manager,
    placement: crate::opencl::Placement,
    batching: Option<crate::opencl::BatchConfig>,
) -> crate::actor::ActorRef {
    dispatch_spawn_kernel(mgr, "copy_u32", placement, batching)
}

/// Fire every payload as a concurrent request and await all replies;
/// returns requests/second.
fn dispatch_drive(
    sys: &crate::actor::ActorSystem,
    worker: &crate::actor::ActorRef,
    payloads: Vec<Vec<u32>>,
) -> f64 {
    let me = sys.scoped();
    let n = payloads.len();
    let t0 = Instant::now();
    let pending: Vec<_> = payloads
        .into_iter()
        .map(|p| me.request(worker, p))
        .collect();
    for p in pending {
        let _: Vec<u32> = p
            .receive(std::time::Duration::from_secs(120))
            .expect("dispatch probe request");
    }
    n as f64 / t0.elapsed().as_secs_f64()
}

/// Placement comparison: (one pinned device, Replicated+least-inflight)
/// requests/second for a burst of full-capacity requests.
pub fn dispatch_placement_probe(cfg: &DispatchProbeConfig) -> (f64, f64) {
    use crate::opencl::{Placement, PlacementPolicy};
    let full: Vec<Vec<u32>> = (0..cfg.requests)
        .map(|i| vec![i as u32; cfg.capacity])
        .collect();
    let (sys, mgr) = dispatch_system(&cfg.artifacts_dir, cfg.launch, cfg.devices);
    let pinned = dispatch_spawn(&mgr, Placement::Pinned, None);
    let one_device = dispatch_drive(&sys, &pinned, full.clone());
    mgr.stop_devices();
    sys.shutdown();

    let (sys, mgr) = dispatch_system(&cfg.artifacts_dir, cfg.launch, cfg.devices);
    let replicated = dispatch_spawn(
        &mgr,
        Placement::replicated(PlacementPolicy::LeastInflight),
        None,
    );
    let n_device = dispatch_drive(&sys, &replicated, full);
    mgr.stop_devices();
    sys.shutdown();
    (one_device, n_device)
}

/// Batching comparison: (per-request launches with caller-side padding,
/// adaptive batcher) requests/second for sub-capacity requests.
pub fn dispatch_batching_probe(cfg: &DispatchProbeConfig) -> (f64, f64) {
    use crate::opencl::{BatchConfig, Placement};
    let small: Vec<Vec<u32>> = (0..cfg.batch_requests)
        .map(|i| vec![i as u32; cfg.request_elems])
        .collect();
    let (sys, mgr) = dispatch_system(&cfg.artifacts_dir, cfg.launch, 1);
    let plain = dispatch_spawn(&mgr, Placement::Pinned, None);
    // the status quo for sub-capacity work: every caller pads to capacity
    let padded: Vec<Vec<u32>> = small
        .iter()
        .map(|v| {
            let mut p = v.clone();
            p.resize(cfg.capacity, 0);
            p
        })
        .collect();
    let unbatched = dispatch_drive(&sys, &plain, padded);
    mgr.stop_devices();
    sys.shutdown();

    let (sys, mgr) = dispatch_system(&cfg.artifacts_dir, cfg.launch, 1);
    let batcher = dispatch_spawn(
        &mgr,
        Placement::Pinned,
        Some(BatchConfig {
            max_requests: (cfg.capacity / cfg.request_elems).max(1),
            max_delay: std::time::Duration::from_millis(2),
        }),
    );
    let batched = dispatch_drive(&sys, &batcher, small);
    mgr.stop_devices();
    sys.shutdown();
    (unbatched, batched)
}

// ---------------------------------------------------------------------------
// Cost-aware steering (PERF.md): the Fig 7b probe. Two simulated devices
// that differ ONLY in per-command dispatch cost (`sim::devices::
// steering_pair`, a ~20x launch gap at equal bandwidth/compute) serve the
// same burst under CostAware and RoundRobin. For small requests the paper
// found offloading to the Phi counterproductive — CostAware must route
// around it entirely, while RoundRobin pays the pad on every second
// request. For large requests the transfer term dominates both devices, so
// queueing everything on the fast device eventually costs more than
// dispatching to the slow one and CostAware spills over.
// ---------------------------------------------------------------------------

/// Config of the cost-aware steering probe.
#[derive(Clone, Debug)]
pub struct CostAwareProbeConfig {
    /// Elements per small request (sub-second, dispatch-dominated).
    pub small_elems: usize,
    /// Elements per large request (transfer-dominated).
    pub large_elems: usize,
    /// Requests in the small burst.
    pub small_requests: usize,
    /// Requests in the large burst.
    pub large_requests: usize,
    /// Artifacts dir holding the probe's two-kernel stub manifest.
    pub artifacts_dir: String,
}

/// One (request size) side of the steering probe: per-device launch
/// distribution and throughput under each policy.
#[derive(Clone, Copy, Debug)]
pub struct CostAwareSide {
    pub requests: usize,
    pub request_elems: usize,
    pub costaware_fast_launches: u64,
    pub costaware_slow_launches: u64,
    pub costaware_reqs_per_sec: f64,
    pub round_robin_fast_launches: u64,
    pub round_robin_slow_launches: u64,
    pub round_robin_reqs_per_sec: f64,
}

/// Write the steering probe's stub manifest (one identity kernel per
/// request size) into a per-process temp dir; returns the artifacts path.
pub fn write_costaware_manifest(tag: &str, small_elems: usize, large_elems: usize) -> String {
    write_stub_manifest(
        &format!("costaware-{tag}"),
        &format!(
            "copy_small_u32|emu|u32:{small_elems}|u32:{small_elems}|emu=identity n={small_elems}\n\
             copy_large_u32|emu|u32:{large_elems}|u32:{large_elems}|emu=identity n={large_elems}\n"
        ),
    )
}

/// Run one burst of `requests` × `elems`-element requests under `policy`
/// on the steering pair; returns (fast launches, slow launches, req/s).
/// With `batching` set, every replica fronts an adaptive batcher and the
/// per-device launch counts are *flush* counts.
fn costaware_run(
    artifacts_dir: &str,
    kernel: &str,
    elems: usize,
    requests: usize,
    policy: crate::opencl::PlacementPolicy,
    batching: Option<crate::opencl::BatchConfig>,
) -> (u64, u64, f64) {
    use crate::opencl::{Manager, Placement};
    let sys = crate::actor::ActorSystem::new(
        crate::actor::SystemConfig::default()
            .with_threads(4)
            .with_artifacts_dir(artifacts_dir.to_string()),
    );
    let (fast, slow) = crate::sim::devices::steering_pair();
    let mgr = Manager::load_with(&sys, vec![fast, slow]);
    let worker = dispatch_spawn_kernel(&mgr, kernel, Placement::replicated(policy), batching);
    let payloads: Vec<Vec<u32>> = (0..requests).map(|i| vec![i as u32; elems]).collect();
    let rps = dispatch_drive(&sys, &worker, payloads);
    let fast_launches = mgr.device(0).expect("fast device").queue.stats().launched();
    let slow_launches = mgr.device(1).expect("slow device").queue.stats().launched();
    mgr.stop_devices();
    sys.shutdown();
    (fast_launches, slow_launches, rps)
}

/// The full steering probe: (small side, large side).
pub fn dispatch_costaware_probe(cfg: &CostAwareProbeConfig) -> (CostAwareSide, CostAwareSide) {
    use crate::opencl::PlacementPolicy;
    let side = |kernel: &str, elems: usize, requests: usize| {
        let (ca_f, ca_s, ca_r) = costaware_run(
            &cfg.artifacts_dir,
            kernel,
            elems,
            requests,
            PlacementPolicy::CostAware,
            None,
        );
        let (rr_f, rr_s, rr_r) = costaware_run(
            &cfg.artifacts_dir,
            kernel,
            elems,
            requests,
            PlacementPolicy::RoundRobin,
            None,
        );
        CostAwareSide {
            requests,
            request_elems: elems,
            costaware_fast_launches: ca_f,
            costaware_slow_launches: ca_s,
            costaware_reqs_per_sec: ca_r,
            round_robin_fast_launches: rr_f,
            round_robin_slow_launches: rr_s,
            round_robin_reqs_per_sec: rr_r,
        }
    };
    let small = side("copy_small_u32", cfg.small_elems, cfg.small_requests);
    let large = side("copy_large_u32", cfg.large_elems, cfg.large_requests);
    (small, large)
}

// ---------------------------------------------------------------------------
// Batched cost-aware steering (PERF.md): the Fig 7b probe with batching
// replicas. Routing a batched pool cannot use the dispatcher's routed
// estimate (one flush serves many requests), so CostAware/LeastInflight
// read the occupancy gauge the batcher publishes into the device
// ExecStats. The probe shows the steering survives batching: small
// requests still avoid the Phi-like device under CostAware while
// RoundRobin pays its pad per window. A second measurement drives one
// batched facade with two interleaved request shapes and records the
// multi-shape coalescing ratio (requests per fused launch) — per-class
// sub-batches fuse each shape with its peers instead of force-flushing
// the other shape's window.
// ---------------------------------------------------------------------------

/// Config of the batched steering + multi-shape coalescing probe.
#[derive(Clone, Debug)]
pub struct BatchedCostAwareProbeConfig {
    /// Elements per small request (dispatch-dominated).
    pub request_elems: usize,
    /// Requests in the steering burst.
    pub requests: usize,
    /// Per-class count trigger of every replica's batcher.
    pub batch_max_requests: usize,
    /// Per-class time trigger (safety valve for uneven routing).
    pub batch_max_delay: std::time::Duration,
    /// Second request shape for the multi-shape measurement.
    pub alt_elems: usize,
    /// Requests per shape class in the multi-shape measurement.
    pub per_class: usize,
    /// Artifacts dir holding the probe's stub manifest.
    pub artifacts_dir: String,
}

/// Results of the batched steering + multi-shape coalescing probe.
#[derive(Clone, Copy, Debug)]
pub struct BatchedCostAwareResult {
    pub requests: usize,
    pub request_elems: usize,
    /// Per-device FLUSH counts under each policy (a batched launch covers
    /// a whole window, so these are coalesced-launch distributions).
    pub costaware_fast_launches: u64,
    pub costaware_slow_launches: u64,
    pub costaware_reqs_per_sec: f64,
    pub round_robin_fast_launches: u64,
    pub round_robin_slow_launches: u64,
    pub round_robin_reqs_per_sec: f64,
    /// Multi-shape coalescing: interleaved requests of two shapes.
    pub multishape_requests: usize,
    pub multishape_classes: usize,
    pub multishape_fused_launches: u64,
    /// Requests per fused launch (== per_class when both windows fuse).
    pub multishape_coalescing_ratio: f64,
}

/// Write the batched steering probe's stub manifest; returns the path.
pub fn write_batched_costaware_manifest(tag: &str, capacity: usize) -> String {
    write_stub_manifest(
        &format!("batched-costaware-{tag}"),
        &format!("copy_b_u32|emu|u32:{capacity}|u32:{capacity}|emu=identity n={capacity}\n"),
    )
}

/// Interleave two request shapes through ONE batched facade on one
/// simulated device; returns (requests, fused launches) — the multi-shape
/// coalescing measurement. With per-class windows the interleaved burst
/// fuses into exactly one launch per shape class.
fn multishape_coalescing_run(
    artifacts_dir: &str,
    kernel: &str,
    elems_a: usize,
    elems_b: usize,
    per_class: usize,
    max_delay: std::time::Duration,
) -> (usize, u64) {
    use crate::opencl::{
        BatchConfig, DeviceInfo, DeviceKind, DeviceSpec, FacadeStats, KernelSpawn, Manager,
        Mode,
    };
    use crate::runtime::client::PadModel;
    let sys = crate::actor::ActorSystem::new(
        crate::actor::SystemConfig::default()
            .with_threads(4)
            .with_artifacts_dir(artifacts_dir.to_string()),
    );
    let spec = DeviceSpec {
        name: "multishape-sim".to_string(),
        kind: DeviceKind::Gpu,
        info: DeviceInfo {
            compute_units: 8,
            max_work_items_per_cu: 1024,
        },
        pad: Some(PadModel {
            launch: std::time::Duration::from_millis(1),
            bytes_per_sec: 0.0,
            compute_scale: 1.0,
            busy_wait: false,
        }),
    };
    let mgr = Manager::load_with(&sys, vec![spec]);
    let program = mgr.create_kernel_program(kernel).expect("stub program");
    let stats = std::sync::Arc::new(FacadeStats::default());
    let worker = mgr
        .spawn_cl(
            KernelSpawn::new(program, kernel)
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .with_stats(stats.clone())
                .batched(BatchConfig {
                    max_requests: per_class,
                    max_delay,
                }),
        )
        .expect("multishape batched spawn");
    let payloads: Vec<Vec<u32>> = (0..per_class * 2)
        .map(|i| {
            let elems = if i % 2 == 0 { elems_a } else { elems_b };
            vec![i as u32; elems]
        })
        .collect();
    let n = payloads.len();
    let _ = dispatch_drive(&sys, &worker, payloads);
    let launches = stats.launched.load(std::sync::atomic::Ordering::Relaxed);
    mgr.stop_devices();
    sys.shutdown();
    (n, launches)
}

/// The batched steering + multi-shape coalescing probe.
pub fn dispatch_batched_costaware_probe(
    cfg: &BatchedCostAwareProbeConfig,
) -> BatchedCostAwareResult {
    use crate::opencl::{BatchConfig, PlacementPolicy};
    let batch = BatchConfig {
        max_requests: cfg.batch_max_requests,
        max_delay: cfg.batch_max_delay,
    };
    let (ca_f, ca_s, ca_r) = costaware_run(
        &cfg.artifacts_dir,
        "copy_b_u32",
        cfg.request_elems,
        cfg.requests,
        PlacementPolicy::CostAware,
        Some(batch),
    );
    let (rr_f, rr_s, rr_r) = costaware_run(
        &cfg.artifacts_dir,
        "copy_b_u32",
        cfg.request_elems,
        cfg.requests,
        PlacementPolicy::RoundRobin,
        Some(batch),
    );
    // a long time valve keeps the measurement deterministic: every class
    // fills its count window (all requests are in flight), so the timer
    // must never split a window on a descheduled CI runner
    let (ms_requests, ms_launches) = multishape_coalescing_run(
        &cfg.artifacts_dir,
        "copy_b_u32",
        cfg.request_elems,
        cfg.alt_elems,
        cfg.per_class,
        std::time::Duration::from_secs(30),
    );
    BatchedCostAwareResult {
        requests: cfg.requests,
        request_elems: cfg.request_elems,
        costaware_fast_launches: ca_f,
        costaware_slow_launches: ca_s,
        costaware_reqs_per_sec: ca_r,
        round_robin_fast_launches: rr_f,
        round_robin_slow_launches: rr_s,
        round_robin_reqs_per_sec: rr_r,
        multishape_requests: ms_requests,
        multishape_classes: 2,
        multishape_fused_launches: ms_launches,
        multishape_coalescing_ratio: ms_requests as f64 / (ms_launches as f64).max(1.0),
    }
}

// ---------------------------------------------------------------------------
// Placement-tier pipelines (PERF.md): the pipeline probe. Three
// comparisons over the same stub copy kernel:
//
// 1. **Composed vs monolithic** — a request through the 3-stage pipeline
//    driver (three launches, device-resident hand-off) vs the same data
//    through one monolithic launch: the per-request latency price of
//    composition is the extra launch pads, never a host round-trip.
// 2. **Interleaved vs lock-step** — the same replicated pipeline under
//    `PipelineMode::Interleaved` and `PipelineMode::LockStep` serving a
//    concurrent burst: requests/second plus the `ExecStats` in-flight
//    high-water mark proving stage launches of different requests
//    actually overlapped (lock-step pins the peak at exactly 1).
// 3. **Migration vs re-upload** — a ref stranded on a dead replica's
//    device, once with `ReplicaSet::migrate(true)` (the dispatcher
//    device-to-device-copies and reschedules) and once without (routed
//    error; the caller recovers by re-uploading its host copy to a live
//    device): wall-clock to a correct result either way.
// ---------------------------------------------------------------------------

/// Config of the placement-tier pipeline probe.
#[derive(Clone, Debug)]
pub struct PipelineProbeConfig {
    /// Fixed per-command launch pad of every simulated device.
    pub launch: std::time::Duration,
    /// Requests per latency/throughput measurement.
    pub requests: usize,
    /// Elements per request (== the stub kernel's capacity).
    pub capacity: usize,
    /// Artifacts dir holding the probe's stub manifest.
    pub artifacts_dir: String,
}

/// Results of the placement-tier pipeline probe.
#[derive(Clone, Copy, Debug)]
pub struct PipelineResults {
    /// Stages of the probe pipeline (Val -> Ref -> Ref -> Val).
    pub stages: usize,
    pub requests: usize,
    pub capacity: usize,
    /// Per-request latency of one monolithic launch...
    pub monolithic_ms_per_req: f64,
    /// ...vs the same request through the 3-stage pipeline driver.
    pub composed_ms_per_req: f64,
    pub interleaved_reqs_per_sec: f64,
    pub lockstep_reqs_per_sec: f64,
    /// `ExecStats` in-flight high-water marks of the two modes.
    pub interleaved_inflight_peak: u64,
    pub lockstep_inflight_peak: u64,
    /// Wall-clock ms from stranded-ref request to a correct result with
    /// migration ON (device-to-device reroute)...
    pub migration_recovery_ms: f64,
    /// ...and OFF (routed error + host-copy re-upload to a live device).
    pub reupload_recovery_ms: f64,
    /// Explicit transfers the source device counted in the migration arm.
    pub migrations: u64,
}

/// The probe's 3-stage copy pipeline (Val -> Ref -> Ref -> Val): the
/// smallest shape with device-resident hand-off between interior stages.
fn pipeline_3stage_spawn(
    mgr: &crate::opencl::Manager,
    placement: crate::opencl::Placement,
    mode: crate::opencl::PipelineMode,
) -> crate::opencl::PipelineSpawn {
    use crate::opencl::{KernelSpawn, Mode, PipelineSpawn};
    let program = mgr.create_kernel_program("copy_u32").expect("stub program");
    let stage = |in_mode: Mode, out: Mode| {
        KernelSpawn::new(program.clone(), "copy_u32")
            .inputs(in_mode, 1)
            .output(out)
    };
    PipelineSpawn::new()
        .stage(stage(Mode::Val, Mode::Ref))
        .stage(stage(Mode::Ref, Mode::Ref))
        .stage(stage(Mode::Ref, Mode::Val))
        .placement(placement)
        .mode(mode)
}

/// Composed-vs-monolithic latency: sequential per-request milliseconds of
/// one monolithic launch vs the 3-stage driver on one pinned device.
fn pipeline_latency_run(cfg: &PipelineProbeConfig) -> (f64, f64) {
    use crate::opencl::{Placement, PipelineMode};
    let run = |driver: &crate::actor::ActorRef, sys: &crate::actor::ActorSystem| -> f64 {
        let me = sys.scoped();
        let t0 = Instant::now();
        for i in 0..cfg.requests {
            let _: Vec<u32> = me
                .request(driver, vec![i as u32; cfg.capacity])
                .receive(std::time::Duration::from_secs(120))
                .expect("pipeline latency request");
        }
        t0.elapsed().as_secs_f64() * 1e3 / cfg.requests.max(1) as f64
    };
    let (sys, mgr) = dispatch_system(&cfg.artifacts_dir, cfg.launch, 1);
    let mono = dispatch_spawn(&mgr, Placement::Pinned, None);
    let monolithic_ms = run(&mono, &sys);
    mgr.stop_devices();
    sys.shutdown();

    let (sys, mgr) = dispatch_system(&cfg.artifacts_dir, cfg.launch, 1);
    let driver = mgr
        .spawn_pipeline(pipeline_3stage_spawn(
            &mgr,
            Placement::Device(0),
            PipelineMode::Interleaved,
        ))
        .expect("pipeline latency spawn");
    let composed_ms = run(&driver, &sys);
    mgr.stop_devices();
    sys.shutdown();
    (monolithic_ms, composed_ms)
}

/// One stage-scheduling arm: (reqs/sec, in-flight peak) of `mode` on a
/// single-device replicated pipeline serving a concurrent burst.
fn pipeline_mode_run(cfg: &PipelineProbeConfig, mode: crate::opencl::PipelineMode) -> (f64, u64) {
    use crate::opencl::{Placement, PlacementPolicy, ReplicaSet};
    let (sys, mgr) = dispatch_system(&cfg.artifacts_dir, cfg.launch, 1);
    let handle = mgr
        .spawn_pipeline_replicated(pipeline_3stage_spawn(
            &mgr,
            Placement::Replicated(ReplicaSet::new(PlacementPolicy::RoundRobin)),
            mode,
        ))
        .expect("pipeline mode spawn");
    let payloads: Vec<Vec<u32>> = (0..cfg.requests)
        .map(|i| vec![i as u32; cfg.capacity])
        .collect();
    let rps = dispatch_drive(&sys, &handle.actor, payloads);
    let peak = mgr.device(0).expect("probe device").queue.stats().inflight_peak();
    mgr.stop_devices();
    sys.shutdown();
    (rps, peak)
}

/// One recovery arm: strand a ref on a dead replica's device, then time
/// the wall-clock to a correct result. With `migrate` the dispatcher
/// reroutes; without it the caller receives the routed error and
/// re-uploads its host copy to the surviving device.
fn pipeline_migration_run(cfg: &PipelineProbeConfig, migrate: bool) -> (f64, u64) {
    use crate::actor::{Exit, Message};
    use crate::opencl::{KernelSpawn, MemRef, Mode, Placement, PlacementPolicy, ReplicaSet};
    let t = std::time::Duration::from_secs(120);
    let (sys, mgr) = dispatch_system(&cfg.artifacts_dir, cfg.launch, 2);
    let program = mgr.create_kernel_program("copy_u32").expect("stub program");
    let produce_on = |dev: usize| {
        mgr.spawn_cl(
            KernelSpawn::new(program.clone(), "copy_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Ref)
                .placement(Placement::Device(dev)),
        )
        .expect("producer spawn")
    };
    let doomed_producer = produce_on(1);
    let live_producer = produce_on(0);
    let consumer = mgr
        .spawn_cl_replicated(
            KernelSpawn::new(program.clone(), "copy_u32")
                .inputs(Mode::Ref, 1)
                .output(Mode::Val)
                .placement(Placement::Replicated(
                    ReplicaSet::new(PlacementPolicy::RoundRobin).migrate(migrate),
                )),
        )
        .expect("consumer spawn");
    let me = sys.scoped();
    let data: Vec<u32> = (0..cfg.capacity as u32).collect();
    let stranded: MemRef = me
        .request(&doomed_producer, data.clone())
        .receive(t)
        .expect("produce stranded ref");
    consumer.pool.replicas()[1]
        .facade()
        .send_from(None, Message::new(Exit::fault("pipeline probe kill")));
    let killed = Instant::now();
    while consumer.pool.replicas()[1].is_alive() {
        assert!(
            killed.elapsed() < std::time::Duration::from_secs(10),
            "probe replica never died"
        );
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    let t0 = Instant::now();
    let out: Vec<u32> = match me.request(&consumer.actor, stranded).receive(t) {
        Ok(v) => v,
        Err(e) => {
            assert!(
                !migrate,
                "migration arm must reroute, not error: {}",
                e.reason
            );
            let re: MemRef = me
                .request(&live_producer, data.clone())
                .receive(t)
                .expect("recovery re-upload");
            me.request(&consumer.actor, re)
                .receive(t)
                .expect("recovery relaunch")
        }
    };
    let recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(out, data, "recovery must reproduce the stranded data");
    let migrations = mgr
        .device(1)
        .expect("source device")
        .queue
        .stats()
        .migrations();
    mgr.stop_devices();
    sys.shutdown();
    (recovery_ms, migrations)
}

/// The full pipeline probe.
pub fn dispatch_pipeline_probe(cfg: &PipelineProbeConfig) -> PipelineResults {
    use crate::opencl::PipelineMode;
    let (monolithic_ms, composed_ms) = pipeline_latency_run(cfg);
    let (inter_rps, inter_peak) = pipeline_mode_run(cfg, PipelineMode::Interleaved);
    let (lock_rps, lock_peak) = pipeline_mode_run(cfg, PipelineMode::LockStep);
    let (migration_ms, migrations) = pipeline_migration_run(cfg, true);
    let (reupload_ms, _) = pipeline_migration_run(cfg, false);
    PipelineResults {
        stages: 3,
        requests: cfg.requests,
        capacity: cfg.capacity,
        monolithic_ms_per_req: monolithic_ms,
        composed_ms_per_req: composed_ms,
        interleaved_reqs_per_sec: inter_rps,
        lockstep_reqs_per_sec: lock_rps,
        interleaved_inflight_peak: inter_peak,
        lockstep_inflight_peak: lock_peak,
        migration_recovery_ms: migration_ms,
        reupload_recovery_ms: reupload_ms,
        migrations,
    }
}

/// Results of one `cargo bench --bench dispatch` run.
#[derive(Clone, Copy, Debug)]
pub struct DispatchResults {
    /// Simulated devices in the inventory.
    pub devices: usize,
    /// Requests per placement measurement.
    pub requests: usize,
    /// Full-capacity requests against one pinned device.
    pub one_device_reqs_per_sec: f64,
    /// The same burst against `Placement::Replicated` + least-inflight.
    pub n_device_reqs_per_sec: f64,
    /// Requests per batching measurement.
    pub batch_requests: usize,
    /// Elements per sub-capacity request.
    pub request_elems: usize,
    /// Kernel capacity in elements.
    pub capacity: usize,
    /// Per-request launches (caller pads to capacity).
    pub unbatched_reqs_per_sec: f64,
    /// Adaptive batcher coalescing the same requests.
    pub batched_reqs_per_sec: f64,
    /// Cost-aware steering, small (dispatch-dominated) requests.
    pub cost_aware_small: CostAwareSide,
    /// Cost-aware steering, large (transfer-dominated) requests.
    pub cost_aware_large: CostAwareSide,
    /// Cost-aware steering over BATCHED replicas (occupancy-gauge routing)
    /// plus the multi-shape coalescing measurement.
    pub batched_costaware: BatchedCostAwareResult,
    /// Placement-tier pipelines: composed-vs-monolithic latency,
    /// interleaved-vs-lock-step scheduling, migration-vs-re-upload
    /// recovery.
    pub pipeline: PipelineResults,
}

/// Write `BENCH_dispatch.json` (repo root when run from `rust/`, else the
/// working directory) — the machine-readable placement/batching trajectory
/// described in PERF.md.
pub fn write_dispatch_json(
    r: &DispatchResults,
    generated_by: &str,
) -> std::io::Result<std::path::PathBuf> {
    let root = std::path::Path::new("../ROADMAP.md");
    let path = if root.exists() {
        std::path::PathBuf::from("../BENCH_dispatch.json")
    } else {
        std::path::PathBuf::from("BENCH_dispatch.json")
    };
    let placement_speedup = r.n_device_reqs_per_sec / r.one_device_reqs_per_sec.max(1e-9);
    let batching_speedup = r.batched_reqs_per_sec / r.unbatched_reqs_per_sec.max(1e-9);
    let side_json = |s: &CostAwareSide| {
        format!(
            "{{\"requests\": {}, \"request_elems\": {}, \
             \"costaware\": {{\"fast_launches\": {}, \"slow_launches\": {}, \
             \"reqs_per_sec\": {:.1}}}, \
             \"round_robin\": {{\"fast_launches\": {}, \"slow_launches\": {}, \
             \"reqs_per_sec\": {:.1}}}}}",
            s.requests,
            s.request_elems,
            s.costaware_fast_launches,
            s.costaware_slow_launches,
            s.costaware_reqs_per_sec,
            s.round_robin_fast_launches,
            s.round_robin_slow_launches,
            s.round_robin_reqs_per_sec
        )
    };
    let bc = &r.batched_costaware;
    let batched_costaware_json = format!(
        "{{\"devices\": [\"steer-fast\", \"steer-phi\"],\n    \
         \"requests\": {}, \"request_elems\": {},\n    \
         \"costaware\": {{\"fast_launches\": {}, \"slow_launches\": {}, \
         \"reqs_per_sec\": {:.1}}},\n    \
         \"round_robin\": {{\"fast_launches\": {}, \"slow_launches\": {}, \
         \"reqs_per_sec\": {:.1}}},\n    \
         \"multishape\": {{\"requests\": {}, \"classes\": {}, \
         \"fused_launches\": {}, \"coalescing_ratio\": {:.3}}}}}",
        bc.requests,
        bc.request_elems,
        bc.costaware_fast_launches,
        bc.costaware_slow_launches,
        bc.costaware_reqs_per_sec,
        bc.round_robin_fast_launches,
        bc.round_robin_slow_launches,
        bc.round_robin_reqs_per_sec,
        bc.multishape_requests,
        bc.multishape_classes,
        bc.multishape_fused_launches,
        bc.multishape_coalescing_ratio
    );
    let p = &r.pipeline;
    let pipeline_json = format!(
        "{{\"stages\": {}, \"requests\": {}, \"capacity\": {},\n    \
         \"latency\": {{\"monolithic_ms_per_req\": {:.3}, \
         \"composed_ms_per_req\": {:.3}, \"overhead\": {:.3}}},\n    \
         \"scheduling\": {{\"interleaved_reqs_per_sec\": {:.1}, \
         \"lockstep_reqs_per_sec\": {:.1}, \"speedup\": {:.3}, \
         \"interleaved_inflight_peak\": {}, \"lockstep_inflight_peak\": {}}},\n    \
         \"recovery\": {{\"migration_ms\": {:.3}, \"reupload_ms\": {:.3}, \
         \"migrations\": {}}}}}",
        p.stages,
        p.requests,
        p.capacity,
        p.monolithic_ms_per_req,
        p.composed_ms_per_req,
        p.composed_ms_per_req / p.monolithic_ms_per_req.max(1e-9),
        p.interleaved_reqs_per_sec,
        p.lockstep_reqs_per_sec,
        p.interleaved_reqs_per_sec / p.lockstep_reqs_per_sec.max(1e-9),
        p.interleaved_inflight_peak,
        p.lockstep_inflight_peak,
        p.migration_recovery_ms,
        p.reupload_recovery_ms,
        p.migrations
    );
    let json = format!(
        "{{\n  \"bench\": \"dispatch\",\n  \"generated_by\": {generated_by:?},\n  \
         \"placement\": {{\"devices\": {}, \"requests\": {}, \
         \"one_device_reqs_per_sec\": {:.1}, \"n_device_reqs_per_sec\": {:.1}, \
         \"speedup\": {:.3}}},\n  \
         \"batching\": {{\"requests\": {}, \"request_elems\": {}, \"capacity\": {}, \
         \"unbatched_reqs_per_sec\": {:.1}, \"batched_reqs_per_sec\": {:.1}, \
         \"speedup\": {:.3}}},\n  \
         \"cost_aware\": {{\"devices\": [\"steer-fast\", \"steer-phi\"],\n    \
         \"small\": {},\n    \"large\": {}}},\n  \
         \"batched_costaware\": {},\n  \
         \"pipeline\": {}\n}}\n",
        r.devices,
        r.requests,
        r.one_device_reqs_per_sec,
        r.n_device_reqs_per_sec,
        placement_speedup,
        r.batch_requests,
        r.request_elems,
        r.capacity,
        r.unbatched_reqs_per_sec,
        r.batched_reqs_per_sec,
        batching_speedup,
        side_json(&r.cost_aware_small),
        side_json(&r.cost_aware_large),
        batched_costaware_json,
        pipeline_json
    );
    std::fs::write(&path, json)?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Soak & overload (PERF.md): the robustness probe. An open-loop Poisson
// arrival process offers a mixed workload (batched small val-mode, large
// transfer-bound, two-stage pipelines) at a configurable multiple of the
// deployment's capacity while a chaos schedule kills replicas, and the
// probe runs the same scenario with admission control ON
// (bounded + DropOldest + deadline) and OFF (unbounded). The report
// checks two things: every request resolves exactly once (reply, typed
// rejection, shed, or deadline — never a hang), and shedding keeps the
// admitted-request tail bounded where the unbounded arm's queues grow
// without limit.
// ---------------------------------------------------------------------------

/// Config of the soak probe (the `soak` bench and the tier-1 `perf_soak`
/// test run the same scenario at different durations/rates).
#[derive(Clone, Debug)]
pub struct SoakConfig {
    /// Simulated devices in the inventory (one replica each).
    pub devices: usize,
    /// Fixed per-command launch pad of every simulated device.
    pub launch: std::time::Duration,
    /// Simulated PCIe bandwidth — makes the large class transfer-bound.
    pub bytes_per_sec: f64,
    /// Soak duration (the arrival schedule spans exactly this window).
    pub duration: std::time::Duration,
    /// Offered (open-loop) arrival rate, requests per second. Overload is
    /// offered_rps vs. what `devices`/`launch` can serve.
    pub offered_rps: f64,
    /// Driver threads sharing the arrival schedule.
    pub drivers: usize,
    /// Elements per small (batched) request.
    pub small_elems: usize,
    /// Elements per large (transfer-bound) request — also the large
    /// kernel's manifest capacity.
    pub large_elems: usize,
    /// Per-class count trigger of the small kernel's batcher; the small
    /// kernel's manifest capacity is `small_elems * batch_max_requests`.
    pub batch_max_requests: usize,
    /// Per-class time-valve ceiling of the small kernel's batcher.
    pub batch_max_delay: std::time::Duration,
    /// Admission bound when shedding is ON.
    pub max_inflight: u64,
    /// Queue-wait deadline when shedding is ON.
    pub max_queue_wait: std::time::Duration,
    /// Gap between chaos replica kills.
    pub chaos_interval: std::time::Duration,
    /// Chaos kill budget (0 = kill for the whole soak).
    pub chaos_kills: u64,
    /// Seed for the arrival schedule, class mix, and chaos victims.
    pub seed: u64,
    /// Artifacts dir holding the probe's two-kernel stub manifest.
    pub artifacts_dir: String,
}

/// Per-class latency digest of one soak arm.
#[derive(Clone, Debug)]
pub struct SoakClassStats {
    pub class: &'static str,
    /// Completed (replied) requests of this class.
    pub n: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
}

/// One soak arm (shedding on or off). `issued` always equals
/// `completed + rejected + shed + deadline + errors + timeouts` — the
/// exactly-once ledger the tier-1 gate asserts on (with `timeouts == 0`:
/// a timeout means some request neither replied nor failed).
#[derive(Clone, Debug)]
pub struct SoakRun {
    pub shedding: bool,
    pub issued: usize,
    /// Requests that got a reply.
    pub completed: usize,
    /// Typed `Overloaded` rejections at admission.
    pub rejected: usize,
    /// Requests shed from a window by `DropOldest`.
    pub shed: usize,
    /// Requests failed fast after exceeding `max_queue_wait`.
    pub deadline: usize,
    /// Other errors (e.g. routed errors while every replica is down).
    pub errors: usize,
    /// Requests that never resolved within the driver's generous receive
    /// timeout — must be zero; anything else is a lost promise.
    pub timeouts: usize,
    /// Completed requests per second of soak wall-clock.
    pub goodput_rps: f64,
    /// Peak of the pools' admitted-but-unretired depth gauge.
    pub peak_depth: u64,
    /// p99 latency over ALL completed (admitted) requests, ms. The
    /// bounded-tail headline: shedding trades rejections for keeping
    /// this finite under overload.
    pub admitted_p99_ms: f64,
    pub classes: Vec<SoakClassStats>,
    /// Replicas the chaos schedule killed during the soak.
    pub replica_kills: u64,
    /// Successful respawns observed across the pools.
    pub respawns: u64,
}

/// Write the soak probe's stub manifest (small batched kernel + large
/// transfer kernel) into a per-process temp dir; returns the path.
pub fn write_soak_manifest(tag: &str, small_capacity: usize, large_elems: usize) -> String {
    write_stub_manifest(
        &format!("soak-{tag}"),
        &format!(
            "soak_small_u32|emu|u32:{small_capacity}|u32:{small_capacity}|emu=identity n={small_capacity}\n\
             soak_large_u32|emu|u32:{large_elems}|u32:{large_elems}|emu=identity n={large_elems}\n"
        ),
    )
}

/// How one soak request ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SoakOutcome {
    Ok,
    Rejected,
    Shed,
    Deadline,
    Timeout,
    Error,
}

fn soak_classify(e: &crate::actor::ErrorMsg) -> SoakOutcome {
    use crate::opencl::Rejection;
    match Rejection::of(e) {
        Some(Rejection::Overloaded) => SoakOutcome::Rejected,
        Some(Rejection::Shed) => SoakOutcome::Shed,
        Some(Rejection::Deadline) => SoakOutcome::Deadline,
        None if e.reason.contains("timed out") => SoakOutcome::Timeout,
        None => SoakOutcome::Error,
    }
}

/// Issue one request and block for its resolution. The 30s ceiling is a
/// hang detector, not a latency bound — the exactly-once invariant says
/// it never fires.
fn soak_one_shot(
    me: &crate::actor::ScopedActor,
    target: &crate::actor::ActorRef,
    elems: usize,
    tag: u32,
) -> SoakOutcome {
    match me
        .request(target, vec![tag; elems])
        .receive_msg(std::time::Duration::from_secs(30))
    {
        Ok(_) => SoakOutcome::Ok,
        Err(e) => soak_classify(&e),
    }
}

/// The deployment shared by the open- and closed-loop soak arms: simulated
/// device inventory, replicated spawns (batched small + large kernels, one
/// admission domain each), and the chaos schedule targeting the small pool.
struct SoakDeployment {
    sys: crate::actor::ActorSystem,
    mgr: std::sync::Arc<crate::opencl::Manager>,
    small: crate::opencl::ReplicatedHandle,
    large: crate::opencl::ReplicatedHandle,
    chaos: crate::sim::ChaosSchedule,
}

fn soak_deploy(cfg: &SoakConfig, shedding: bool) -> SoakDeployment {
    use crate::actor::{ActorSystem, SystemConfig};
    use crate::opencl::{
        AdmissionConfig, BatchConfig, DeviceInfo, DeviceKind, DeviceSpec, KernelSpawn, Manager,
        Mode, Placement, PlacementPolicy, ReplicaSet, RespawnPolicy, ShedPolicy,
    };
    use crate::runtime::client::PadModel;
    use crate::sim::{ChaosConfig, ChaosFault, ChaosSchedule};

    let sys = ActorSystem::new(
        SystemConfig::default()
            .with_threads(4)
            .with_artifacts_dir(cfg.artifacts_dir.clone()),
    );
    let specs = (0..cfg.devices)
        .map(|i| DeviceSpec {
            name: format!("soak-sim-{i}"),
            kind: DeviceKind::Gpu,
            info: DeviceInfo {
                compute_units: 8,
                max_work_items_per_cu: 1024,
            },
            pad: Some(PadModel {
                launch: cfg.launch,
                bytes_per_sec: cfg.bytes_per_sec,
                compute_scale: 1.0,
                busy_wait: false,
            }),
        })
        .collect();
    let mgr = Manager::load_with(&sys, specs);

    let admission = if shedding {
        AdmissionConfig {
            max_inflight: Some(cfg.max_inflight),
            max_queue_wait: Some(cfg.max_queue_wait),
            shed_policy: ShedPolicy::DropOldest,
        }
    } else {
        AdmissionConfig::default()
    };
    let replica_set = || {
        ReplicaSet::new(PlacementPolicy::LeastInflight)
            .respawn(RespawnPolicy::Always)
            .admission(admission)
    };
    let small_prog = mgr
        .create_kernel_program("soak_small_u32")
        .expect("soak small program");
    let small = mgr
        .spawn_cl_replicated(
            KernelSpawn::new(small_prog, "soak_small_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .placement(Placement::Replicated(replica_set()))
                .batched(BatchConfig {
                    max_requests: cfg.batch_max_requests,
                    max_delay: cfg.batch_max_delay,
                }),
        )
        .expect("soak small spawn");
    let large_prog = mgr
        .create_kernel_program("soak_large_u32")
        .expect("soak large program");
    let large = mgr
        .spawn_cl_replicated(
            KernelSpawn::new(large_prog, "soak_large_u32")
                .inputs(Mode::Val, 1)
                .output(Mode::Val)
                .placement(Placement::Replicated(replica_set())),
        )
        .expect("soak large spawn");

    // chaos targets the batched small pool — the harder recovery path
    // (respawned replicas must rejoin the admission domain and republish
    // their occupancy gauge)
    let chaos = ChaosSchedule::start(
        small.pool.clone(),
        ChaosConfig {
            interval: cfg.chaos_interval,
            max_kills: cfg.chaos_kills,
            seed: cfg.seed ^ 0x5eed,
            fault: ChaosFault::Kill,
        },
    );
    SoakDeployment {
        sys,
        mgr,
        small,
        large,
        chaos,
    }
}

/// Stop chaos, wait for in-flight respawns to land, stop the devices and
/// the system; returns `(replica_kills, respawns)`.
fn soak_teardown(d: SoakDeployment) -> (u64, u64) {
    let SoakDeployment {
        sys,
        mgr,
        small,
        large,
        chaos,
    } = d;
    let replica_kills = chaos.stop();
    // give in-flight respawns a moment to land before reading the counts
    let respawn_wait = Instant::now();
    let count_respawns = || -> u64 {
        small
            .pool
            .replicas()
            .iter()
            .chain(large.pool.replicas().iter())
            .map(|r| r.respawns())
            .sum()
    };
    while count_respawns() < replica_kills
        && respawn_wait.elapsed() < std::time::Duration::from_secs(5)
    {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let respawns = count_respawns();
    mgr.stop_devices();
    sys.shutdown();
    (replica_kills, respawns)
}

/// Fold one arm's per-request records into its [`SoakRun`].
fn soak_summarize(
    shedding: bool,
    records: &[(crate::workload::RequestClass, SoakOutcome, f64)],
    elapsed: std::time::Duration,
    peak_depth: u64,
    replica_kills: u64,
    respawns: u64,
) -> SoakRun {
    let mut issued = 0;
    let mut counts = [0usize; 6];
    let mut admitted_ms: Vec<f64> = Vec::new();
    for (_, outcome, ms) in records {
        issued += 1;
        counts[*outcome as usize] += 1;
        if *outcome == SoakOutcome::Ok {
            admitted_ms.push(*ms);
        }
    }
    let class_stats = |class: crate::workload::RequestClass| {
        let ms: Vec<f64> = records
            .iter()
            .filter(|(c, o, _)| *c == class && *o == SoakOutcome::Ok)
            .map(|(_, _, ms)| *ms)
            .collect();
        SoakClassStats {
            class: class.name(),
            n: ms.len(),
            p50_ms: crate::util::stats::percentile(&ms, 0.50),
            p99_ms: crate::util::stats::percentile(&ms, 0.99),
            p999_ms: crate::util::stats::percentile(&ms, 0.999),
        }
    };
    SoakRun {
        shedding,
        issued,
        completed: counts[SoakOutcome::Ok as usize],
        rejected: counts[SoakOutcome::Rejected as usize],
        shed: counts[SoakOutcome::Shed as usize],
        deadline: counts[SoakOutcome::Deadline as usize],
        errors: counts[SoakOutcome::Error as usize],
        timeouts: counts[SoakOutcome::Timeout as usize],
        goodput_rps: counts[SoakOutcome::Ok as usize] as f64 / elapsed.as_secs_f64().max(1e-9),
        peak_depth,
        admitted_p99_ms: crate::util::stats::percentile(&admitted_ms, 0.99),
        classes: crate::workload::RequestClass::ALL
            .iter()
            .map(|c| class_stats(*c))
            .collect(),
        replica_kills,
        respawns,
    }
}

/// Run one open-loop soak arm. With `shedding` the replicated spawns carry
/// the config's admission bounds (`max_inflight` + `DropOldest` +
/// `max_queue_wait`); without it they run unbounded — the control arm
/// whose queues are free to grow.
pub fn soak_probe(cfg: &SoakConfig, shedding: bool) -> SoakRun {
    use crate::workload::{ClassMix, OpenLoop, RequestClass};
    use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

    let d = soak_deploy(cfg, shedding);
    let (sys, small, large) = (&d.sys, &d.small, &d.large);

    let schedule = OpenLoop {
        rps: cfg.offered_rps,
    }
    .schedule(cfg.duration, cfg.seed);
    let mix = ClassMix::soak_default();
    let classes: Vec<RequestClass> = {
        let mut rng = crate::util::Rng::new(cfg.seed.wrapping_add(1));
        (0..schedule.len()).map(|_| mix.pick(&mut rng)).collect()
    };

    let cursor = AtomicUsize::new(0);
    let stop_monitor = AtomicBool::new(false);
    let peak_depth = AtomicU64::new(0);
    let t0 = Instant::now();
    // (class, outcome, ms since the request's *scheduled* arrival — the
    // open-loop convention that charges queueing delay to the system
    // instead of hiding it behind a slow driver)
    let mut records: Vec<(RequestClass, SoakOutcome, f64)> =
        Vec::with_capacity(schedule.len());
    std::thread::scope(|s| {
        let monitor = s.spawn(|| {
            while !stop_monitor.load(Ordering::Acquire) {
                let d = small.pool.total_depth() + large.pool.total_depth();
                peak_depth.fetch_max(d, Ordering::AcqRel);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        });
        let drivers: Vec<_> = (0..cfg.drivers.max(1))
            .map(|_| {
                s.spawn(|| {
                    let me = sys.scoped();
                    let mut out = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= schedule.len() {
                            break;
                        }
                        let due = t0 + schedule[i];
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        let class = classes[i];
                        let outcome = match class {
                            RequestClass::SmallVal => {
                                soak_one_shot(&me, &small.actor, cfg.small_elems, i as u32)
                            }
                            RequestClass::LargeTransfer => {
                                soak_one_shot(&me, &large.actor, cfg.large_elems, i as u32)
                            }
                            RequestClass::Pipeline => {
                                // two chained stages: the pipeline resolves
                                // with its first failure, or Ok after both
                                match soak_one_shot(&me, &large.actor, cfg.large_elems, i as u32)
                                {
                                    SoakOutcome::Ok => soak_one_shot(
                                        &me,
                                        &small.actor,
                                        cfg.small_elems,
                                        i as u32,
                                    ),
                                    other => other,
                                }
                            }
                        };
                        let latency_ms = due.elapsed().as_secs_f64() * 1e3;
                        out.push((class, outcome, latency_ms));
                    }
                    out
                })
            })
            .collect();
        for drv in drivers {
            records.extend(drv.join().expect("soak driver panicked"));
        }
        stop_monitor.store(true, Ordering::Release);
        let _ = monitor.join();
    });
    let elapsed = t0.elapsed();
    let peak = peak_depth.load(Ordering::Acquire);
    let (replica_kills, respawns) = soak_teardown(d);
    soak_summarize(shedding, &records, elapsed, peak, replica_kills, respawns)
}

/// Run the closed-loop soak arm: `loop_cfg.concurrency` workers each issue
/// their next request `loop_cfg.think` after the previous reply resolves
/// ([`crate::workload::ClosedLoop`]), against the same deployment, class
/// mix, and chaos schedule as the open-loop arms. A closed loop
/// self-clocks — offered load tracks service capacity instead of a
/// schedule — so this is the bounded-pressure control arm: its latencies
/// are service times measured from each request's issue instant (there is
/// no scheduled arrival to charge lateness against), and its backlog is
/// capped by `concurrency` rather than by admission control.
pub fn soak_closed_probe(
    cfg: &SoakConfig,
    shedding: bool,
    loop_cfg: crate::workload::ClosedLoop,
) -> SoakRun {
    use crate::workload::{ClassMix, RequestClass};
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    let d = soak_deploy(cfg, shedding);
    let (sys, small, large) = (&d.sys, &d.small, &d.large);

    let mix = ClassMix::soak_default();
    let stop_monitor = AtomicBool::new(false);
    let peak_depth = AtomicU64::new(0);
    let t0 = Instant::now();
    let deadline = t0 + cfg.duration;
    let mut records: Vec<(RequestClass, SoakOutcome, f64)> = Vec::new();
    std::thread::scope(|s| {
        let monitor = s.spawn(|| {
            while !stop_monitor.load(Ordering::Acquire) {
                let depth = small.pool.total_depth() + large.pool.total_depth();
                peak_depth.fetch_max(depth, Ordering::AcqRel);
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
        });
        let mix = &mix;
        let workers: Vec<_> = (0..loop_cfg.concurrency.max(1))
            .map(|w| {
                s.spawn(move || {
                    let me = sys.scoped();
                    let mut rng = crate::util::Rng::new(
                        cfg.seed ^ (w as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
                    );
                    let mut out = Vec::new();
                    let mut i = w as u32;
                    while Instant::now() < deadline {
                        let class = mix.pick(&mut rng);
                        let issued_at = Instant::now();
                        let outcome = match class {
                            RequestClass::SmallVal => {
                                soak_one_shot(&me, &small.actor, cfg.small_elems, i)
                            }
                            RequestClass::LargeTransfer => {
                                soak_one_shot(&me, &large.actor, cfg.large_elems, i)
                            }
                            RequestClass::Pipeline => {
                                match soak_one_shot(&me, &large.actor, cfg.large_elems, i) {
                                    SoakOutcome::Ok => {
                                        soak_one_shot(&me, &small.actor, cfg.small_elems, i)
                                    }
                                    other => other,
                                }
                            }
                        };
                        out.push((class, outcome, issued_at.elapsed().as_secs_f64() * 1e3));
                        i = i.wrapping_add(loop_cfg.concurrency.max(1) as u32);
                        if !loop_cfg.think.is_zero() {
                            std::thread::sleep(loop_cfg.think);
                        }
                    }
                    out
                })
            })
            .collect();
        for wkr in workers {
            records.extend(wkr.join().expect("closed-loop soak worker panicked"));
        }
        stop_monitor.store(true, Ordering::Release);
        let _ = monitor.join();
    });
    let elapsed = t0.elapsed();
    let peak = peak_depth.load(Ordering::Acquire);
    let (replica_kills, respawns) = soak_teardown(d);
    soak_summarize(shedding, &records, elapsed, peak, replica_kills, respawns)
}

/// Write `BENCH_soak.json` (repo root when run from `rust/`, else the
/// working directory): the shed-on/shed-off open-loop comparison plus the
/// closed-loop control arm PERF.md describes.
pub fn write_soak_json(
    on: &SoakRun,
    off: &SoakRun,
    closed: &SoakRun,
    closed_cfg: &crate::workload::ClosedLoop,
    cfg: &SoakConfig,
    generated_by: &str,
) -> std::io::Result<std::path::PathBuf> {
    let root = std::path::Path::new("../ROADMAP.md");
    let path = if root.exists() {
        std::path::PathBuf::from("../BENCH_soak.json")
    } else {
        std::path::PathBuf::from("BENCH_soak.json")
    };
    let fmt_ms = |x: f64| {
        if x.is_nan() {
            "null".to_string()
        } else {
            format!("{x:.2}")
        }
    };
    let run_json = |r: &SoakRun| {
        let classes = r
            .classes
            .iter()
            .map(|c| {
                format!(
                    "\"{}\": {{\"n\": {}, \"p50_ms\": {}, \"p99_ms\": {}, \"p999_ms\": {}}}",
                    c.class,
                    c.n,
                    fmt_ms(c.p50_ms),
                    fmt_ms(c.p99_ms),
                    fmt_ms(c.p999_ms)
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "{{\"shedding\": {}, \"issued\": {}, \"completed\": {}, \
             \"rejected\": {}, \"shed\": {}, \"deadline\": {}, \"errors\": {}, \
             \"timeouts\": {}, \"goodput_rps\": {:.1}, \"peak_depth\": {}, \
             \"admitted_p99_ms\": {},\n    \"classes\": {{{}}},\n    \
             \"replica_kills\": {}, \"respawns\": {}}}",
            r.shedding,
            r.issued,
            r.completed,
            r.rejected,
            r.shed,
            r.deadline,
            r.errors,
            r.timeouts,
            r.goodput_rps,
            r.peak_depth,
            fmt_ms(r.admitted_p99_ms),
            classes,
            r.replica_kills,
            r.respawns
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"soak\",\n  \"generated_by\": {generated_by:?},\n  \
         \"config\": {{\"devices\": {}, \"launch_ms\": {:.3}, \
         \"duration_ms\": {}, \"offered_rps\": {:.1}, \"drivers\": {}, \
         \"max_inflight\": {}, \"max_queue_wait_ms\": {}, \
         \"chaos_interval_ms\": {}, \"closed_concurrency\": {}, \
         \"closed_think_ms\": {}}},\n  \
         \"shed_on\": {},\n  \"shed_off\": {},\n  \"closed_loop\": {}\n}}\n",
        cfg.devices,
        cfg.launch.as_secs_f64() * 1e3,
        cfg.duration.as_millis(),
        cfg.offered_rps,
        cfg.drivers,
        cfg.max_inflight,
        cfg.max_queue_wait.as_millis(),
        cfg.chaos_interval.as_millis(),
        closed_cfg.concurrency,
        closed_cfg.think.as_millis(),
        run_json(on),
        run_json(off),
        run_json(closed)
    );
    std::fs::write(&path, json)?;
    Ok(path)
}

// ---------------------------------------------------------------------------
// Remote request path (PERF.md): blocking vs async request futures over
// loopback. Both arms drive the same published echo actor through one
// proxy connection; the sweep varies the in-flight window. The blocking
// arm parks one OS thread per in-flight slot (the pre-futures baseline);
// the async arm holds the whole window from a small fixed pool of client
// threads via `ActorRef::ask` + a bounded `FutureSet`. Each arm is a
// closed loop at its window size: latencies are issue→resolve service
// times, and req/s over the whole batch is reported alongside so a stall
// that blocks the window shows up in throughput (see PERF.md on
// coordinated omission).
// ---------------------------------------------------------------------------

/// Config of the net probe (the `net` bench and the tier-1 `perf_net` test
/// run the same sweep at different request counts).
#[derive(Clone, Debug)]
pub struct NetProbeConfig {
    /// In-flight windows to sweep, e.g. `[1, 64, 4096]`.
    pub levels: Vec<usize>,
    /// Requests per arm at each level (raised to the level so every slot
    /// issues at least one).
    pub requests: usize,
    /// `u32` elements per request payload (the echoed vector).
    pub elems: usize,
    /// Client threads of the async arm — the bounded pool that holds the
    /// whole window in flight. Never one thread per request.
    pub client_threads: usize,
}

/// One (level, mode) measurement of the net probe.
#[derive(Clone, Debug)]
pub struct NetArm {
    pub inflight: usize,
    /// `"blocking"` (one thread per in-flight slot) or `"async"` (bounded
    /// pool + futures).
    pub mode: &'static str,
    pub issued: usize,
    /// Requests that resolved with a reply.
    pub completed: usize,
    /// Requests that resolved with an error (0 over a healthy loopback).
    pub errors: usize,
    /// Client threads the arm actually ran — the acceptance check that the
    /// async arm never grows a thread per request.
    pub threads: usize,
    pub req_per_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Run the blocking-vs-async sweep over a loopback node pair; returns two
/// arms per configured level.
pub fn net_probe(cfg: &NetProbeConfig) -> Vec<NetArm> {
    use crate::actor::{reply, ActorSystem, Behavior, SpawnOptions, SystemConfig};
    use crate::net::Node;

    let server_sys = ActorSystem::new(SystemConfig::default().with_threads(4));
    let _echo = server_sys.spawn_opts(
        |_| Behavior::new().on(|_c, v: &Vec<u32>| reply(v.clone())),
        SpawnOptions::named("net-echo"),
    );
    let server = Node::new(&server_sys);
    let addr = server.listen("127.0.0.1:0").expect("listen on loopback");

    // generous remote deadline: the reaper is a hang detector here, not a
    // latency bound — the exactly-once ledger asserts it never fires
    let client_sys = ActorSystem::new(
        SystemConfig::default()
            .with_threads(4)
            .with_remote_timeout(std::time::Duration::from_secs(120)),
    );
    let client = Node::new(&client_sys);
    let remote = client
        .remote_actor(&addr.to_string(), "net-echo")
        .expect("connect to loopback node");

    let mut arms = Vec::new();
    for &level in &cfg.levels {
        arms.push(net_blocking_arm(cfg, &client_sys, &remote, level));
        arms.push(net_async_arm(cfg, &remote, level));
    }

    server.stop();
    client_sys.shutdown();
    server_sys.shutdown();
    arms
}

/// The pre-futures baseline: `level` OS threads (small stacks), each
/// holding exactly one blocking request at a time.
fn net_blocking_arm(
    cfg: &NetProbeConfig,
    sys: &crate::actor::ActorSystem,
    remote: &crate::actor::ActorRef,
    level: usize,
) -> NetArm {
    let issued = cfg.requests.max(level);
    let t0 = Instant::now();
    let mut lat_ms: Vec<f64> = Vec::with_capacity(issued);
    let mut errors = 0usize;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..level)
            .map(|slot| {
                // distribute the request budget across the slots
                let n = issued / level + usize::from(slot < issued % level);
                std::thread::Builder::new()
                    .name(format!("net-blk-{slot}"))
                    .stack_size(128 * 1024)
                    .spawn_scoped(s, move || {
                        let me = sys.scoped();
                        let mut out = Vec::with_capacity(n);
                        for i in 0..n {
                            let payload = vec![(slot + i) as u32; cfg.elems];
                            let at = Instant::now();
                            let r = me
                                .request(remote, payload)
                                .receive_msg(std::time::Duration::from_secs(120));
                            out.push((r.is_ok(), at.elapsed().as_secs_f64() * 1e3));
                        }
                        out
                    })
                    .expect("spawn blocking-arm thread")
            })
            .collect();
        for h in handles {
            for (ok, ms) in h.join().expect("blocking-arm thread panicked") {
                if ok {
                    lat_ms.push(ms);
                } else {
                    errors += 1;
                }
            }
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    NetArm {
        inflight: level,
        mode: "blocking",
        issued,
        completed: lat_ms.len(),
        errors,
        threads: level,
        req_per_s: lat_ms.len() as f64 / wall.max(1e-9),
        p50_ms: crate::util::stats::percentile(&lat_ms, 0.50),
        p99_ms: crate::util::stats::percentile(&lat_ms, 0.99),
    }
}

/// The futures arm: a fixed pool of `cfg.client_threads` threads keeps
/// `level` requests in flight via `ActorRef::ask` + a bounded
/// [`FutureSet`](crate::actor::FutureSet). Completion hooks record each
/// latency on the resolver thread; the issuing pool never parks on an
/// individual reply.
fn net_async_arm(cfg: &NetProbeConfig, remote: &crate::actor::ActorRef, level: usize) -> NetArm {
    use crate::actor::FutureSet;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{Arc, Mutex};

    let issued = cfg.requests.max(level);
    let threads = cfg.client_threads.max(1).min(level);
    let set = FutureSet::new(level);
    let cursor = AtomicUsize::new(0);
    let done: Arc<Mutex<Vec<(bool, f64)>>> = Arc::new(Mutex::new(Vec::with_capacity(issued)));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= issued {
                    break;
                }
                let at = Instant::now();
                let fut = remote.ask(vec![i as u32; cfg.elems]);
                let done = done.clone();
                fut.then(move |r| {
                    let ms = at.elapsed().as_secs_f64() * 1e3;
                    done.lock().unwrap_or_else(|p| p.into_inner()).push((r.is_ok(), ms));
                });
                // backpressure: block while the window is full. The request
                // above is already on the wire when push blocks, so the
                // in-flight peak is level + threads — the window, not the
                // thread count, is what bounds the client.
                set.push(&fut);
            });
        }
    });
    let resolved = set.join_all(std::time::Duration::from_secs(300));
    let wall = t0.elapsed().as_secs_f64();
    let recorded = {
        let mut g = done.lock().unwrap_or_else(|p| p.into_inner());
        std::mem::take(&mut *g)
    };
    // the ledger the callers assert on: every issued request must have run
    // its completion hook exactly once (recorded) and drained (resolved)
    let completed = recorded.iter().filter(|(ok, _)| *ok).count();
    let errors = recorded.len() - completed;
    let lat_ms: Vec<f64> = recorded
        .iter()
        .filter(|(ok, _)| *ok)
        .map(|(_, ms)| *ms)
        .collect();
    drop(resolved);
    NetArm {
        inflight: level,
        mode: "async",
        issued,
        completed,
        errors,
        threads,
        req_per_s: completed as f64 / wall.max(1e-9),
        p50_ms: crate::util::stats::percentile(&lat_ms, 0.50),
        p99_ms: crate::util::stats::percentile(&lat_ms, 0.99),
    }
}

/// Write `BENCH_net.json` (repo root when run from `rust/`, else the
/// working directory): the blocking-vs-async remote-request comparison
/// PERF.md describes.
pub fn write_net_json(
    arms: &[NetArm],
    cfg: &NetProbeConfig,
    generated_by: &str,
) -> std::io::Result<std::path::PathBuf> {
    let root = std::path::Path::new("../ROADMAP.md");
    let path = if root.exists() {
        std::path::PathBuf::from("../BENCH_net.json")
    } else {
        std::path::PathBuf::from("BENCH_net.json")
    };
    let fmt_ms = |x: f64| {
        if x.is_nan() {
            "null".to_string()
        } else {
            format!("{x:.3}")
        }
    };
    let arm_json = |a: &NetArm| {
        format!(
            "{{\"mode\": \"{}\", \"inflight\": {}, \"issued\": {}, \
             \"completed\": {}, \"errors\": {}, \"threads\": {}, \
             \"req_per_s\": {:.1}, \"p50_ms\": {}, \"p99_ms\": {}}}",
            a.mode,
            a.inflight,
            a.issued,
            a.completed,
            a.errors,
            a.threads,
            a.req_per_s,
            fmt_ms(a.p50_ms),
            fmt_ms(a.p99_ms)
        )
    };
    let list = arms
        .iter()
        .map(arm_json)
        .collect::<Vec<_>>()
        .join(",\n    ");
    let json = format!(
        "{{\n  \"bench\": \"net\",\n  \"generated_by\": {generated_by:?},\n  \
         \"config\": {{\"levels\": {:?}, \"requests\": {}, \"elems\": {}, \
         \"client_threads\": {}}},\n  \"arms\": [\n    {}\n  ]\n}}\n",
        cfg.levels, cfg.requests, cfg.elems, cfg.client_threads, list
    );
    std::fs::write(&path, json)?;
    Ok(path)
}

/// Quick/full switch: benches default to a fast sweep; set
/// `CAF_OCL_BENCH_FULL=1` for the paper-scale version.
pub fn full_mode() -> bool {
    std::env::var("CAF_OCL_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// Samples per point, honouring the quick/full switch.
pub fn samples_per_point(quick: usize, full: usize) -> usize {
    if full_mode() {
        full
    } else {
        quick
    }
}
