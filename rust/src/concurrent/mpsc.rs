//! Vyukov-style intrusive MPSC queue and a counted/closable wrapper.
//!
//! Push is wait-free (one `swap` + one `store`); pop is a single-consumer
//! operation that never takes a lock. The well-known Vyukov caveat applies:
//! between a producer's `swap` of the head and its `store` of the
//! predecessor's `next` pointer, the queue is transiently unobservable past
//! that node, so `pop` can report "empty" while an element is in flight.
//! [`CountedQueue`] resolves the ambiguity with an element count maintained
//! in the same atomic word as the closed bit.

use crate::loom_types::{AtomicPtr, AtomicU64, Ordering, UnsafeCell};
use std::ptr;

struct Node<T> {
    next: AtomicPtr<Node<T>>,
    value: Option<T>,
}

/// Multi-producer single-consumer queue.
///
/// Any thread may `push`; only one thread at a time may call `pop` (and
/// `Drop` requires exclusive access, which `&mut self` guarantees).
pub struct MpscQueue<T> {
    /// Producer side: the most recently pushed node.
    head: AtomicPtr<Node<T>>,
    /// Consumer side: the current stub node (its `next` is the oldest
    /// element). Only the single consumer touches this cell.
    tail: UnsafeCell<*mut Node<T>>,
}

// SAFETY: producers only touch `head` (atomics); the single consumer owns
// `tail`. T must be Send because values cross threads.
unsafe impl<T: Send> Send for MpscQueue<T> {}
unsafe impl<T: Send> Sync for MpscQueue<T> {}

impl<T> Default for MpscQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> MpscQueue<T> {
    pub fn new() -> MpscQueue<T> {
        let stub = Box::into_raw(Box::new(Node {
            next: AtomicPtr::new(ptr::null_mut()),
            value: None,
        }));
        MpscQueue {
            head: AtomicPtr::new(stub),
            tail: UnsafeCell::new(stub),
        }
    }

    /// Wait-free multi-producer push.
    pub fn push(&self, value: T) {
        let node = Box::into_raw(Box::new(Node {
            next: AtomicPtr::new(ptr::null_mut()),
            value: Some(value),
        }));
        // Publish: whoever swapped before us owns linking us in; the
        // Release store of `next` is what the consumer's Acquire load
        // synchronizes with.
        let prev = self.head.swap(node, Ordering::AcqRel);
        // SAFETY: `prev` is a node we (transitively) own until linked; no
        // other producer can touch its `next`, and the consumer only frees
        // nodes it has traversed past — which requires this store first.
        unsafe { (*prev).next.store(node, Ordering::Release) };
    }

    /// Single-consumer pop.
    ///
    /// Returns `None` when the queue is empty *or* when a producer is
    /// mid-push (swapped the head but not yet linked). Callers that track
    /// an element count (see [`CountedQueue`]) can distinguish the two and
    /// spin briefly.
    ///
    /// Contract: must only be called by the queue's single consumer.
    pub fn pop(&self) -> Option<T> {
        // SAFETY: single-consumer contract makes the `tail` cell and the
        // nodes reachable from it exclusively ours.
        let tail = self.tail.with(|p| unsafe { *p });
        let next = unsafe { (*tail).next.load(Ordering::Acquire) };
        if next.is_null() {
            return None;
        }
        // SAFETY: same exclusivity — `next` was published by the producer's
        // Release link (Acquire-loaded above) and only this consumer unlinks;
        // the old `tail` stub is now unreachable, so Box::from_raw is the
        // unique owner.
        self.tail.with_mut(|p| unsafe { *p = next });
        let value = unsafe { (*next).value.take() };
        drop(unsafe { Box::from_raw(tail) });
        value
    }
}

impl<T> Drop for MpscQueue<T> {
    fn drop(&mut self) {
        // SAFETY: exclusive access (&mut self) — all producer pushes
        // happened-before, so every link is visible, pop() drains
        // everything, and the remaining stub node is uniquely ours to free.
        while self.pop().is_some() {}
        unsafe {
            drop(Box::from_raw(*self.tail.get()));
        }
    }
}

/// Result of a [`CountedQueue::push`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushResult {
    /// Stored and the queue was previously empty — a consumer may need a
    /// wakeup/schedule.
    WasEmpty,
    /// Stored behind existing elements.
    Stored,
    /// Queue closed; the value is returned to the caller.
    Closed,
}

const CLOSED_BIT: u64 = 1 << 63;
const COUNT_MASK: u64 = CLOSED_BIT - 1;

/// An [`MpscQueue`] plus a single atomic state word `count | closed-bit`.
///
/// The count makes two things possible without locks: the producer learns
/// "was empty" from one `fetch_add`, and the consumer can distinguish
/// "empty" from "producer mid-push" (count > 0 but `pop` returned `None`),
/// in which case it spins for the handful of cycles the producer needs to
/// finish linking.
pub struct CountedQueue<T> {
    queue: MpscQueue<T>,
    state: AtomicU64,
}

impl<T> Default for CountedQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> CountedQueue<T> {
    pub fn new() -> CountedQueue<T> {
        CountedQueue {
            queue: MpscQueue::new(),
            state: AtomicU64::new(0),
        }
    }

    /// Multi-producer push; a single atomic RMW decides Closed/WasEmpty.
    pub fn push(&self, value: T) -> Result<PushResult, T> {
        let prev = self.state.fetch_add(1, Ordering::SeqCst);
        if prev & CLOSED_BIT != 0 {
            // Undo the announcement; close() snapshotted the count before
            // our increment, so nobody waits for this element.
            self.state.fetch_sub(1, Ordering::SeqCst);
            return Err(value);
        }
        self.queue.push(value);
        if prev & COUNT_MASK == 0 {
            Ok(PushResult::WasEmpty)
        } else {
            Ok(PushResult::Stored)
        }
    }

    /// Single-consumer pop; returns `None` only when the queue is
    /// observably empty (count 0). Spins through producer mid-push windows
    /// (yielding occasionally so a preempted producer can finish linking
    /// even on a single core).
    pub fn pop(&self) -> Option<T> {
        let mut spins = 0u32;
        loop {
            let s = self.state.load(Ordering::Acquire);
            if s & COUNT_MASK == 0 {
                return None;
            }
            if let Some(v) = self.queue.pop() {
                self.state.fetch_sub(1, Ordering::AcqRel);
                return Some(v);
            }
            spin_backoff(&mut spins);
        }
    }

    /// Close for further pushes. Safe from any thread; elements already
    /// queued remain poppable by the consumer. Returns the element count
    /// observed at close time.
    pub fn close(&self) -> usize {
        let prev = self.state.fetch_or(CLOSED_BIT, Ordering::SeqCst);
        (prev & COUNT_MASK) as usize
    }

    /// Drain everything queued (single-consumer operation, like [`pop`]).
    /// Producers that already announced an element before a racing
    /// [`close`] are waited for, so no accepted value is ever lost.
    ///
    /// [`pop`]: CountedQueue::pop
    /// [`close`]: CountedQueue::close
    pub fn drain(&self) -> Vec<T> {
        let mut out = Vec::new();
        while let Some(v) = self.pop() {
            out.push(v);
        }
        out
    }

    pub fn len(&self) -> usize {
        (self.state.load(Ordering::Acquire) & COUNT_MASK) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.state.load(Ordering::Acquire) & CLOSED_BIT != 0
    }
}

/// Spin briefly, yielding the timeslice now and then so a preempted
/// producer can finish its two-instruction push window on a busy box.
/// Shared by every consumer of the count-word protocol (this module and
/// the actor mailbox).
pub fn spin_backoff(spins: &mut u32) {
    *spins += 1;
    if *spins % 64 == 0 {
        crate::loom_types::thread_yield();
    } else {
        crate::loom_types::cpu_relax();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_single_thread() {
        let q = MpscQueue::new();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn drop_releases_pending_nodes() {
        let q = MpscQueue::new();
        for i in 0..100 {
            q.push(vec![i; 10]);
        }
        drop(q); // miri/leak checkers would flag node leaks here
    }

    #[test]
    fn multi_producer_preserves_per_producer_fifo() {
        let q = Arc::new(CountedQueue::new());
        let producers = 4;
        let per = 2000;
        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    q.push((p, i)).unwrap();
                }
            }));
        }
        let mut last = vec![-1i64; producers];
        let mut got = 0;
        while got < producers * per {
            if let Some((p, i)) = q.pop() {
                assert!(
                    (i as i64) > last[p],
                    "producer {p} out of order: {i} after {}",
                    last[p]
                );
                last[p] = i as i64;
                got += 1;
            } else {
                std::thread::yield_now();
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(q.is_empty());
    }

    #[test]
    fn counted_push_reports_was_empty() {
        let q = CountedQueue::new();
        assert_eq!(q.push(10).unwrap(), PushResult::WasEmpty);
        assert_eq!(q.push(11).unwrap(), PushResult::Stored);
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), None);
        assert_eq!(q.push(12).unwrap(), PushResult::WasEmpty);
    }

    #[test]
    fn close_rejects_then_drain_recovers() {
        let q = CountedQueue::new();
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.close(), 2);
        assert!(q.is_closed());
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.drain(), vec![1, 2]);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn concurrent_close_loses_nothing() {
        // Every pushed value must be either accepted (and then drained or
        // popped) or rejected back to the producer — never dropped.
        for _ in 0..20 {
            let q = Arc::new(CountedQueue::new());
            let producers = 4;
            let per = 500;
            let mut handles = Vec::new();
            for _ in 0..producers {
                let q = q.clone();
                handles.push(std::thread::spawn(move || {
                    let mut accepted = 0u64;
                    for _ in 0..per {
                        if q.push(1u64).is_ok() {
                            accepted += 1;
                        }
                    }
                    accepted
                }));
            }
            // consumer pops a few, then closes mid-storm and drains
            let mut popped = 0u64;
            for _ in 0..200 {
                if q.pop().is_some() {
                    popped += 1;
                }
            }
            q.close();
            let accepted: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
            let drained = q.drain().len() as u64;
            // late pushes that lost the race to close() were rejected and
            // are not part of `accepted`
            assert_eq!(accepted, popped + drained, "value lost or duplicated");
        }
    }
}
