//! A vendored loom-style deterministic model checker for the unsafe
//! messaging core (`concurrent::{mpsc, deque, parker}`, `actor::mailbox`,
//! and the `ActorCell::resume` IDLE/RUNNING state machine).
//!
//! # How it works
//!
//! [`check`] runs a closure repeatedly, once per distinguishable thread
//! interleaving. Interposed primitives ([`sync`]) turn every atomic
//! load/store/RMW/fence, cell access, and mutex/condvar operation into a
//! scheduling decision point; the explorer walks the decision tree
//! depth-first, replaying a recorded prefix and branching at the deepest
//! unexplored sibling. Plain (non-SeqCst) atomic loads additionally branch
//! over every store they may legitimately observe under the modeled weak
//! memory order, so stale-read bugs are found even though execution is
//! serialized. A vector-clock happens-before vault flags data races on
//! [`sync::UnsafeCell`] accesses, and a deadlock detector turns "every
//! unfinished thread is blocked" into a counterexample — which is exactly
//! the shape of a lost-wakeup bug.
//!
//! A counterexample panics with the failing schedule's operation trace.
//! Exhaustive completion returns a [`Report`] with the explored /
//! sleep-set-pruned execution counts.
//!
//! # Scope and bounds
//!
//! Exploration is bounded (operation budget per execution, optional
//! preemption bound, execution-count ceiling); sleep sets prune
//! schedule-equivalent interleavings. See `STATIC_ANALYSIS.md` at the repo
//! root for the modeled memory-order semantics and the documented
//! approximations.
//!
//! # Example
//!
//! ```
//! use caf_ocl::concurrent::model::{self, sync::AtomicU64, sync::Ordering};
//! use std::sync::Arc;
//!
//! let report = model::check(|| {
//!     let a = Arc::new(AtomicU64::new(0));
//!     let a2 = a.clone();
//!     let t = model::thread::spawn(move || {
//!         a2.store(1, Ordering::Release);
//!     });
//!     let _seen = a.load(Ordering::Acquire);
//!     t.join().unwrap();
//! });
//! assert!(report.completed >= 1);
//! ```

mod rt;
pub mod sync;

pub use rt::Report;

/// Model threads: `spawn`/`JoinHandle` with the same shape as
/// `std::thread`, but scheduled by the explorer.
pub mod thread {
    pub use super::rt::{spawn, JoinHandle};
}

/// Configures one exploration. Defaults: 5 000 ops per execution, no
/// preemption bound, sleep sets on, 1 000 000 executions.
#[derive(Clone)]
pub struct Builder {
    /// Per-execution operation budget; exceeding it is reported as a
    /// livelock counterexample (an unbounded spin).
    pub max_ops: usize,
    /// When set, schedules with more than this many preemptions collapse
    /// onto the running thread — a cheap way to keep big models tractable
    /// (most real bugs need very few preemptions).
    pub preemption_bound: Option<usize>,
    /// Sleep-set pruning of schedule-equivalent interleavings. Sound to
    /// disable; only exploration time changes.
    pub sleep_sets: bool,
    /// Hard ceiling on explored + pruned executions; exceeding it panics
    /// rather than silently truncating coverage.
    pub max_executions: usize,
}

impl Default for Builder {
    fn default() -> Builder {
        Builder {
            max_ops: 5_000,
            preemption_bound: None,
            sleep_sets: true,
            max_executions: 1_000_000,
        }
    }
}

impl Builder {
    pub fn new() -> Builder {
        Builder::default()
    }

    /// Exhaustively explore `f`. Panics with a counterexample trace on the
    /// first failing schedule; otherwise returns the exploration [`Report`].
    pub fn check<F>(&self, f: F) -> Report
    where
        F: Fn() + Send + Sync + 'static,
    {
        let cfg = rt::Config {
            max_ops: self.max_ops,
            preemption_bound: self.preemption_bound,
            sleep_sets: self.sleep_sets,
            max_executions: self.max_executions,
        };
        rt::explore(&cfg, std::sync::Arc::new(f))
    }
}

/// [`Builder::check`] with the default bounds.
pub fn check<F>(f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    Builder::default().check(f)
}
