//! The model-checking runtime: serialized execution, DFS exploration,
//! vector-clock happens-before tracking, and counterexample reporting.
//!
//! One real OS thread exists per model thread, but a baton protocol keeps
//! exactly one runnable at a time: a thread runs user code until it reaches
//! an interposition point ([`with_op`]), publishes the operation it wants
//! to perform, and hands the baton to the controller. The controller (the
//! thread that called [`crate::concurrent::model::check`]) picks which
//! pending operation executes next — every such pick is a decision point in
//! the depth-first search over schedules. Atomic loads add a second kind of
//! decision point: under the modeled memory order a load may legitimately
//! observe any store not yet ruled out by coherence or happens-before, so
//! the explorer branches over the readable store set too.
//!
//! See STATIC_ANALYSIS.md for the modeled semantics and its documented
//! approximations (CAS reads the latest store, `wait_timeout` never times
//! out, no load buffering).

use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering as ROrd};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Sentinel "thread id" meaning the controller holds the baton.
const CONTROLLER: usize = usize::MAX;
/// Panic payload used to unwind parked threads when an execution aborts.
const DRAIN: &str = "__model_drain__";

/// Global execution epoch. Statics interposed with model atomics register
/// lazily against the *current* execution; a stale epoch tag means the
/// cached location id belongs to a previous execution and must be re-made.
static EPOCH: AtomicU64 = AtomicU64::new(1);

pub(crate) fn current_epoch() -> u64 {
    EPOCH.load(ROrd::Relaxed)
}

thread_local! {
    static TL: RefCell<Option<(Arc<Rt>, usize)>> = const { RefCell::new(None) };
}

/// True when the calling thread is a model thread inside an active
/// execution. Interposed primitives fall back to the real std behavior
/// when false, so `--features model` builds still run ordinary tests.
pub fn in_model() -> bool {
    TL.with(|t| t.borrow().is_some())
}

fn current() -> (Arc<Rt>, usize) {
    TL.with(|t| t.borrow().clone().expect("not on a model thread")) // lint-ok: checker-internal invariant; callers are gated by in_model()
}

// ---------------------------------------------------------------------------
// Vector clocks

#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub(crate) struct VClock(Vec<u32>);

impl VClock {
    pub(crate) fn get(&self, i: usize) -> u32 {
        self.0.get(i).copied().unwrap_or(0)
    }
    pub(crate) fn set(&mut self, i: usize, v: u32) {
        if self.0.len() <= i {
            self.0.resize(i + 1, 0);
        }
        self.0[i] = v;
    }
    pub(crate) fn join(&mut self, o: &VClock) {
        for (i, &v) in o.0.iter().enumerate() {
            if v > self.get(i) {
                self.set(i, v);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Operations & independence

#[derive(Clone, Debug, PartialEq, Eq)]
pub(crate) enum Op {
    Load(usize, bool),  // (location, is_seq_cst)
    Store(usize, bool),
    Rmw(usize, bool),
    Fence,
    CellRead(usize),
    CellWrite(usize),
    MutexLock(usize),
    MutexUnlock(usize),
    CondWait(usize),
    CondNotify(usize),
    Join(usize),
    Yield,
}

impl Op {
    fn atomic_loc(&self) -> Option<usize> {
        match *self {
            Op::Load(l, _) | Op::Store(l, _) | Op::Rmw(l, _) => Some(l),
            _ => None,
        }
    }
    fn is_sc(&self) -> bool {
        matches!(*self, Op::Load(_, true) | Op::Store(_, true) | Op::Rmw(_, true))
    }
}

/// Conservative dependence relation for sleep-set pruning: two operations
/// are treated as dependent unless they provably commute. Over-reporting
/// dependence only costs exploration time, never soundness.
fn dependent(a: &Op, b: &Op) -> bool {
    use Op::*;
    match (a, b) {
        (Fence, _) | (_, Fence) => true,
        (Join(_), _) | (_, Join(_)) => true,
        (Yield, _) | (_, Yield) => false,
        (CellRead(x), CellRead(y)) => x == y,
        (CellRead(x), CellWrite(y)) | (CellWrite(x), CellRead(y)) | (CellWrite(x), CellWrite(y)) => {
            x == y
        }
        // mutex / condvar traffic interacts through ownership and waiter
        // queues — keep the whole category mutually dependent
        (
            MutexLock(_) | MutexUnlock(_) | CondWait(_) | CondNotify(_),
            MutexLock(_) | MutexUnlock(_) | CondWait(_) | CondNotify(_),
        ) => true,
        (MutexLock(_) | MutexUnlock(_) | CondWait(_) | CondNotify(_), _)
        | (_, MutexLock(_) | MutexUnlock(_) | CondWait(_) | CondNotify(_)) => false,
        _ => {
            // atomic ops: dependent when touching the same location, or
            // when both are SeqCst (they interact through the SC order)
            if a.is_sc() && b.is_sc() {
                return true;
            }
            match (a.atomic_loc(), b.atomic_loc()) {
                (Some(x), Some(y)) => x == y,
                _ => true, // unknown combination: stay conservative
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared execution state

pub(crate) struct StoreEvent {
    pub(crate) val: u64,
    writer: usize,
    writer_time: u32,
    /// Release clock propagated to acquiring readers (synchronizes-with).
    sync: VClock,
    sc: bool,
}

pub(crate) struct AtomicLoc {
    history: Vec<StoreEvent>,
    /// Per-thread coherence floor: index of the newest store each thread
    /// has already observed. A later load may not travel back before it.
    last_read: Vec<usize>,
    /// Per-thread (store index, consecutive observations) of the last load
    /// — backs the finite-visibility bound (see [`MAX_STALE_REPEATS`]).
    repeats: Vec<(usize, u32)>,
    last_sc_store: Option<usize>,
}

/// How many times in a row one thread may observe the same *non-latest*
/// store of a location. C++ [intro.progress] guarantees a store becomes
/// visible to all threads in finite time, so unbounded re-reading of a
/// stale value models no real execution — and without this bound every
/// spin-until-visible loop would regress the DFS forever (each backtrack
/// adding one more stale iteration). Three consecutive stale observations
/// is enough for every protocol bug we model (the weakened-Dekker
/// counterexample needs two).
const MAX_STALE_REPEATS: u32 = 3;

pub(crate) struct CellLoc {
    write: (usize, u32), // (tid, time) stamp of the last write access
    reads: VClock,       // read stamps since the last write
}

pub(crate) struct MutexLoc {
    owner: Option<usize>,
    release: VClock,
}

#[derive(Clone, Debug, PartialEq)]
enum TState {
    Active,
    CondBlocked { cond: usize, mutex: usize },
    MutexBlocked { mutex: usize },
    JoinBlocked { target: usize },
    Finished,
}

struct MThread {
    state: TState,
    clock: VClock,
    /// Sync clocks picked up by relaxed loads, released by `fence(Acquire)`.
    pending_acq: VClock,
    /// Snapshot taken by `fence(Release)`, published by later relaxed stores.
    rel_fence: Option<VClock>,
    yielded: bool,
    pending: Option<Op>,
    /// On a freshly spawned thread: who lent it the baton to run to its
    /// first interposition point (its parent, mid-`spawn`).
    handoff: Option<usize>,
    final_clock: Option<VClock>,
}

impl MThread {
    fn new(clock: VClock) -> MThread {
        MThread {
            state: TState::Active,
            clock,
            pending_acq: VClock::default(),
            rel_fence: None,
            yielded: false,
            pending: None,
            handoff: None,
            final_clock: None,
        }
    }
    fn finished(&self) -> bool {
        self.state == TState::Finished
    }
}

#[derive(Clone, Debug)]
enum Branch {
    Schedule {
        candidates: Vec<usize>,
        idx: usize,
        /// Candidates fully explored at this node in earlier iterations —
        /// they enter the sleep set of every later sibling subtree.
        explored: Vec<usize>,
    },
    Read {
        total: usize,
        idx: usize,
    },
}

#[derive(Default)]
pub(crate) struct Path {
    branches: Vec<Branch>,
    pos: usize,
}

impl Path {
    /// Advance to the next unexplored sibling of the deepest branch that
    /// still has one. Returns false when the whole tree is exhausted.
    fn backtrack(&mut self) -> bool {
        while let Some(last) = self.branches.last_mut() {
            match last {
                Branch::Read { total, idx } if *idx + 1 < *total => {
                    *idx += 1;
                    return true;
                }
                Branch::Schedule {
                    candidates,
                    idx,
                    explored,
                } if *idx + 1 < candidates.len() => {
                    explored.push(candidates[*idx]);
                    *idx += 1;
                    return true;
                }
                _ => {
                    self.branches.pop();
                }
            }
        }
        false
    }
}

#[derive(Clone)]
pub(crate) struct Config {
    pub(crate) max_ops: usize,
    pub(crate) preemption_bound: Option<usize>,
    pub(crate) sleep_sets: bool,
    pub(crate) max_executions: usize,
}

pub(crate) struct Exec {
    threads: Vec<MThread>,
    pub(crate) atomics: Vec<AtomicLoc>,
    pub(crate) cells: Vec<CellLoc>,
    pub(crate) mutexes: Vec<MutexLoc>,
    pub(crate) n_conds: usize,
    sc_clock: VClock,
    active: usize,
    path: Path,
    sleep: Vec<usize>,
    trace: Vec<(usize, String)>,
    ops_executed: usize,
    last_running: usize,
    preemptions: usize,
    /// First failure (assertion, race, deadlock, livelock) in this run.
    abort: Option<String>,
    /// Set when the controller is tearing the execution down: parked
    /// threads unwind with the DRAIN payload instead of continuing.
    drain: bool,
    cfg: Config,
    pub(crate) epoch: u64,
}

pub(crate) struct Rt {
    pub(crate) mx: Mutex<Exec>,
    pub(crate) cv: Condvar,
}

fn lock(rt: &Rt) -> MutexGuard<'_, Exec> {
    rt.mx.lock().unwrap_or_else(|p| p.into_inner())
}

impl Rt {
    /// Block until the baton names `me`; panics with DRAIN on teardown.
    fn wait_for_baton<'a>(&'a self, me: usize, mut g: MutexGuard<'a, Exec>) -> MutexGuard<'a, Exec> {
        loop {
            if g.drain {
                drop(g);
                std::panic::panic_any(DRAIN);
            }
            if g.active == me {
                return g;
            }
            g = self.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-side entry points (called from model::sync and model::thread)

/// Publish `op`, wait for the controller's grant, then run `f` against the
/// shared state (the op's semantics). The calling thread keeps the baton
/// afterwards and continues user code until its next interposition point.
pub(crate) fn with_op<R>(op: Op, f: impl FnOnce(&mut Exec, usize) -> R) -> R {
    let (rt, me) = current();
    let mut g = lock(&rt);
    g.threads[me].pending = Some(op.clone());
    release_baton(&rt, me, &mut g);
    g = rt.wait_for_baton(me, g);
    g.threads[me].pending = None;
    g.ops_executed += 1;
    if g.ops_executed > g.cfg.max_ops {
        abort_from_thread(
            &rt,
            g,
            "operation budget exceeded — unbounded spin or livelock".to_string(),
        );
    }
    g.trace.push((me, format!("{op:?}")));
    let t = g.threads[me].clock.get(me) + 1;
    g.threads[me].clock.set(me, t);
    f(&mut g, me)
}

/// Hand the baton away (to a pending spawn-handoff recipient if one is
/// set, otherwise to the controller) and wake whoever is next.
fn release_baton(rt: &Rt, me: usize, g: &mut MutexGuard<'_, Exec>) {
    g.active = match g.threads[me].handoff.take() {
        Some(parent) => parent,
        None => CONTROLLER,
    };
    rt.cv.notify_all();
}

/// Record the first failure and unwind; the controller turns it into the
/// counterexample panic on the caller's thread.
pub(crate) fn abort_from_thread(rt: &Rt, mut g: MutexGuard<'_, Exec>, msg: String) -> ! {
    if g.abort.is_none() {
        g.abort = Some(msg);
    }
    g.drain = true;
    g.active = CONTROLLER;
    rt.cv.notify_all();
    drop(g);
    std::panic::panic_any(DRAIN)
}

/// A failed in-model invariant (e.g. a data race). Public to model::sync.
pub(crate) fn fail(msg: String) -> ! {
    let (rt, _me) = current();
    let g = lock(&rt);
    abort_from_thread(&rt, g, msg)
}

// -- memory-model op semantics ----------------------------------------------

pub(crate) use std::sync::atomic::Ordering as MemOrd;

fn is_acquire(o: MemOrd) -> bool {
    matches!(o, MemOrd::Acquire | MemOrd::AcqRel | MemOrd::SeqCst)
}
fn is_release(o: MemOrd) -> bool {
    matches!(o, MemOrd::Release | MemOrd::AcqRel | MemOrd::SeqCst)
}

fn sc_acquire(g: &mut Exec, me: usize) {
    let sc = g.sc_clock.clone();
    g.threads[me].clock.join(&sc);
}
fn sc_release(g: &mut Exec, me: usize) {
    let c = g.threads[me].clock.clone();
    g.sc_clock.join(&c);
}

/// Pick which store a load observes: branch over every store not excluded
/// by per-thread coherence or happens-before.
fn choose_read(g: &mut Exec, total: usize) -> usize {
    if total <= 1 {
        return 0;
    }
    if g.path.pos < g.path.branches.len() {
        let b = g.path.branches[g.path.pos].clone();
        g.path.pos += 1;
        match b {
            Branch::Read { total: t, idx } => {
                assert_eq!(t, total, "model replay diverged (read branch)");
                idx
            }
            other => panic!("model replay diverged: expected Read, found {other:?}"),
        }
    } else {
        g.path.branches.push(Branch::Read { total, idx: 0 });
        g.path.pos += 1;
        0
    }
}

pub(crate) fn model_load(g: &mut Exec, me: usize, loc: usize, ord: MemOrd) -> u64 {
    if ord == MemOrd::SeqCst {
        sc_acquire(g, me);
    }
    let clock = g.threads[me].clock.clone();
    let al = &mut g.atomics[loc];
    if al.last_read.len() <= me {
        al.last_read.resize(me + 1, 0);
    }
    if al.repeats.len() <= me {
        al.repeats.resize(me + 1, (usize::MAX, 0));
    }
    let mut floor = al.last_read[me];
    for (i, s) in al.history.iter().enumerate().skip(floor) {
        if s.writer_time <= clock.get(s.writer) {
            floor = i;
        }
    }
    if ord == MemOrd::SeqCst {
        if let Some(j) = al.last_sc_store {
            floor = floor.max(j);
        }
    }
    // finite-visibility bound: after MAX_STALE_REPEATS consecutive reads of
    // the same stale store, it drops out of the readable set
    let n = al.history.len();
    let (ri, rc) = al.repeats[me];
    if rc >= MAX_STALE_REPEATS && ri >= floor && ri + 1 < n {
        floor = ri + 1;
    }
    let idx = floor + choose_read(g, n - floor);
    let al = &mut g.atomics[loc];
    al.repeats[me] = if al.repeats[me].0 == idx {
        (idx, al.repeats[me].1 + 1)
    } else {
        (idx, 1)
    };
    al.last_read[me] = idx;
    let sync = al.history[idx].sync.clone();
    let val = al.history[idx].val;
    if is_acquire(ord) {
        g.threads[me].clock.join(&sync);
    } else {
        g.threads[me].pending_acq.join(&sync);
    }
    if ord == MemOrd::SeqCst {
        sc_release(g, me);
    }
    val
}

fn store_sync_clock(g: &Exec, me: usize, ord: MemOrd) -> VClock {
    if is_release(ord) {
        g.threads[me].clock.clone()
    } else if let Some(rf) = &g.threads[me].rel_fence {
        rf.clone()
    } else {
        VClock::default()
    }
}

pub(crate) fn model_store(g: &mut Exec, me: usize, loc: usize, val: u64, ord: MemOrd) {
    if ord == MemOrd::SeqCst {
        sc_acquire(g, me);
    }
    let sync = store_sync_clock(g, me, ord);
    let writer_time = g.threads[me].clock.get(me);
    let sc = ord == MemOrd::SeqCst;
    let al = &mut g.atomics[loc];
    al.history.push(StoreEvent {
        val,
        writer: me,
        writer_time,
        sync,
        sc,
    });
    let idx = al.history.len() - 1;
    if al.last_read.len() <= me {
        al.last_read.resize(me + 1, 0);
    }
    al.last_read[me] = idx; // a thread always observes its own store
    if sc {
        al.last_sc_store = Some(idx);
        sc_release(g, me);
    }
}

/// RMWs always read the latest store in modification order (atomicity) and
/// continue its release sequence.
pub(crate) fn model_rmw(
    g: &mut Exec,
    me: usize,
    loc: usize,
    ord: MemOrd,
    f: impl FnOnce(u64) -> u64,
) -> u64 {
    if ord == MemOrd::SeqCst {
        sc_acquire(g, me);
    }
    let al = &g.atomics[loc];
    let last = al.history.len() - 1;
    let prev = al.history[last].val;
    let prev_sync = al.history[last].sync.clone();
    if is_acquire(ord) {
        g.threads[me].clock.join(&prev_sync);
    } else {
        g.threads[me].pending_acq.join(&prev_sync);
    }
    let mut sync = prev_sync; // release-sequence continuation
    sync.join(&store_sync_clock(g, me, ord));
    let writer_time = g.threads[me].clock.get(me);
    let sc = ord == MemOrd::SeqCst;
    let newv = f(prev);
    let al = &mut g.atomics[loc];
    al.history.push(StoreEvent {
        val: newv,
        writer: me,
        writer_time,
        sync,
        sc,
    });
    let idx = al.history.len() - 1;
    if al.last_read.len() <= me {
        al.last_read.resize(me + 1, 0);
    }
    al.last_read[me] = idx;
    if sc {
        al.last_sc_store = Some(idx);
        sc_release(g, me);
    }
    prev
}

/// Modeled CAS: reads the latest store (see STATIC_ANALYSIS.md — failure
/// does not branch over stale values, a documented approximation).
pub(crate) fn model_cas(
    g: &mut Exec,
    me: usize,
    loc: usize,
    expect: u64,
    new: u64,
    succ: MemOrd,
    fail_ord: MemOrd,
) -> Result<u64, u64> {
    let last = g.atomics[loc].history.len() - 1;
    let prev = g.atomics[loc].history[last].val;
    if prev == expect {
        model_rmw(g, me, loc, succ, |_| new);
        Ok(prev)
    } else {
        if fail_ord == MemOrd::SeqCst {
            sc_acquire(g, me);
        }
        let sync = g.atomics[loc].history[last].sync.clone();
        if is_acquire(fail_ord) {
            g.threads[me].clock.join(&sync);
        } else {
            g.threads[me].pending_acq.join(&sync);
        }
        let al = &mut g.atomics[loc];
        if al.last_read.len() <= me {
            al.last_read.resize(me + 1, 0);
        }
        al.last_read[me] = last;
        if fail_ord == MemOrd::SeqCst {
            sc_release(g, me);
        }
        Err(prev)
    }
}

pub(crate) fn model_fence(g: &mut Exec, me: usize, ord: MemOrd) {
    if is_acquire(ord) {
        let pa = std::mem::take(&mut g.threads[me].pending_acq);
        g.threads[me].clock.join(&pa);
    }
    if ord == MemOrd::SeqCst {
        sc_acquire(g, me);
        sc_release(g, me);
    }
    if is_release(ord) {
        g.threads[me].rel_fence = Some(g.threads[me].clock.clone());
    }
}

// -- non-atomic cell accesses (race detection) ------------------------------

/// Race checks return `Err` instead of panicking: they run inside the op
/// closure with the execution lock held, and the caller (model::sync)
/// reports the failure via [`fail`] only after the lock is released.
pub(crate) fn cell_read(g: &mut Exec, me: usize, loc: usize, check: bool) -> Result<(), String> {
    // `check == false` is a with_racy access: it neither tests for a race
    // nor leaves a read stamp — a later conflicting write must not be
    // flagged against a read that was explicitly declared racy.
    if !check {
        return Ok(());
    }
    let clock = g.threads[me].clock.clone();
    let (wt, wtime) = g.cells[loc].write;
    if wtime > clock.get(wt) {
        return Err(format!(
            "data race: thread {me} reads a non-atomic cell while thread {wt}'s \
             write does not happen-before it"
        ));
    }
    let t = clock.get(me);
    g.cells[loc].reads.set(me, t);
    Ok(())
}

pub(crate) fn cell_write(g: &mut Exec, me: usize, loc: usize) -> Result<(), String> {
    let clock = g.threads[me].clock.clone();
    let c = &g.cells[loc];
    let (wt, wtime) = c.write;
    let mut racy = wtime > clock.get(wt);
    if !racy {
        for (i, &r) in c.reads.0.iter().enumerate() {
            if r > clock.get(i) {
                racy = true;
                break;
            }
        }
    }
    if racy {
        return Err(format!(
            "data race: thread {me} writes a non-atomic cell concurrently with an \
             unordered access"
        ));
    }
    let t = clock.get(me);
    g.cells[loc].write = (me, t);
    g.cells[loc].reads = VClock::default();
    Ok(())
}

// -- mutex / condvar --------------------------------------------------------

pub(crate) fn mutex_lock(loc: usize) {
    with_op(Op::MutexLock(loc), |g, me| {
        debug_assert!(g.mutexes[loc].owner.is_none(), "granted a held mutex");
        g.mutexes[loc].owner = Some(me);
        let rel = g.mutexes[loc].release.clone();
        g.threads[me].clock.join(&rel);
    });
}

pub(crate) fn mutex_unlock(loc: usize) {
    with_op(Op::MutexUnlock(loc), |g, me| {
        debug_assert_eq!(g.mutexes[loc].owner, Some(me), "unlock by non-owner");
        g.mutexes[loc].owner = None;
        g.mutexes[loc].release = g.threads[me].clock.clone();
    });
}

/// Atomically release the mutex and sleep until notified, then re-acquire.
/// Modeled without timeouts: a `wait_timeout` that would need the timeout
/// to make progress shows up as a deadlock counterexample instead.
pub(crate) fn cond_wait(cond: usize, mutex: usize) {
    with_op(Op::CondWait(cond), |g, me| {
        debug_assert_eq!(g.mutexes[mutex].owner, Some(me), "wait without the lock");
        g.mutexes[mutex].owner = None;
        g.mutexes[mutex].release = g.threads[me].clock.clone();
        g.threads[me].state = TState::CondBlocked { cond, mutex };
    });
    // block until a notify moves us to MutexBlocked and the controller
    // grants the re-acquire
    let (rt, me) = current();
    let mut g = lock(&rt);
    release_baton(&rt, me, &mut g);
    g = rt.wait_for_baton(me, g);
    debug_assert!(g.mutexes[mutex].owner.is_none());
    g.mutexes[mutex].owner = Some(me);
    let rel = g.mutexes[mutex].release.clone();
    g.threads[me].clock.join(&rel);
    g.threads[me].state = TState::Active;
}

pub(crate) fn cond_notify(cond: usize, all: bool) {
    with_op(Op::CondNotify(cond), |g, me| {
        let _ = me;
        let mut woken = 0;
        for t in g.threads.iter_mut() {
            if let TState::CondBlocked { cond: c, mutex } = t.state {
                if c == cond && (all || woken == 0) {
                    t.state = TState::MutexBlocked { mutex };
                    woken += 1;
                }
            }
        }
    });
}

pub(crate) fn model_yield() {
    with_op(Op::Yield, |g, me| {
        g.threads[me].yielded = true;
    });
}

// -- registration (lazy, epoch-tagged, so `const fn new` works) -------------

pub(crate) fn register_atomic(g: &mut Exec, init: u64) -> usize {
    g.atomics.push(AtomicLoc {
        history: vec![StoreEvent {
            val: init,
            writer: 0,
            writer_time: 0, // the initial value happens-before everything
            sync: VClock::default(),
            sc: false,
        }],
        last_read: Vec::new(),
        repeats: Vec::new(),
        last_sc_store: None,
    });
    g.atomics.len() - 1
}

pub(crate) fn register_cell(g: &mut Exec) -> usize {
    g.cells.push(CellLoc {
        write: (0, 0),
        reads: VClock::default(),
    });
    g.cells.len() - 1
}

pub(crate) fn register_mutex(g: &mut Exec) -> usize {
    g.mutexes.push(MutexLoc {
        owner: None,
        release: VClock::default(),
    });
    g.mutexes.len() - 1
}

pub(crate) fn register_cond(g: &mut Exec) -> usize {
    g.n_conds += 1;
    g.n_conds - 1
}

/// Run `f` under the execution lock (for lazy registration from sync.rs).
pub(crate) fn with_exec<R>(f: impl FnOnce(&mut Exec) -> R) -> R {
    let (rt, _me) = current();
    let mut g = lock(&rt);
    f(&mut g)
}

// -- model threads ----------------------------------------------------------

pub struct JoinHandle {
    tid: usize,
    real: Option<std::thread::JoinHandle<()>>,
}

impl JoinHandle {
    /// Join the model thread. Always `Ok`: a panic inside a model thread
    /// aborts the whole execution as a counterexample instead.
    pub fn join(mut self) -> Result<(), String> {
        let (rt, me) = current();
        let mut g = lock(&rt);
        if !g.threads[self.tid].finished() {
            g.threads[me].state = TState::JoinBlocked { target: self.tid };
            g.threads[me].pending = Some(Op::Join(self.tid));
            release_baton(&rt, me, &mut g);
            g = rt.wait_for_baton(me, g);
            g.threads[me].pending = None;
            g.threads[me].state = TState::Active;
        }
        let fc = g.threads[self.tid]
            .final_clock
            .clone()
            .expect("joined thread has no final clock"); // lint-ok: set unconditionally when a model thread finishes
        g.threads[me].clock.join(&fc);
        drop(g);
        if let Some(h) = self.real.take() {
            let _ = h.join();
        }
        Ok(())
    }
}

impl Drop for JoinHandle {
    fn drop(&mut self) {
        // a leaked handle must not leave a real thread attached beyond the
        // execution; the controller joins stragglers during teardown
        let _ = self.real.take();
    }
}

/// Spawn a model thread. The child immediately runs (on the parent's
/// baton) up to its first interposition point, so the scheduler always
/// sees a concrete pending operation — spawning itself is not a decision
/// point and does not multiply the exploration tree.
pub fn spawn<F: FnOnce() + Send + 'static>(f: F) -> JoinHandle {
    let (rt, me) = current();
    let mut g = lock(&rt);
    let tid = g.threads.len();
    let mut clock = g.threads[me].clock.clone();
    clock.set(tid, 1);
    let mut th = MThread::new(clock);
    th.handoff = Some(me); // first yield returns the baton to the parent
    g.threads.push(th);
    g.active = tid;
    rt.cv.notify_all();
    drop(g);
    let rt2 = rt.clone();
    let real = std::thread::Builder::new()
        .name(format!("model-{tid}"))
        .spawn(move || run_model_thread(rt2, tid, f))
        .expect("spawn model thread"); // lint-ok: OS thread spawn failure is unrecoverable in a test harness
    // wait for the child to reach its first interposition point (or finish)
    let g = lock(&rt);
    let _g = rt.wait_for_baton(me, g);
    JoinHandle {
        tid,
        real: Some(real),
    }
}

fn run_model_thread<F: FnOnce()>(rt: Arc<Rt>, tid: usize, f: F) {
    TL.with(|t| *t.borrow_mut() = Some((rt.clone(), tid)));
    let g = lock(&rt);
    let _g = rt.wait_for_baton(tid, g);
    drop(_g);
    let r = catch_unwind(AssertUnwindSafe(f));
    TL.with(|t| *t.borrow_mut() = None);
    let mut g = lock(&rt);
    if let Err(p) = r {
        if !is_drain_payload(&p) && g.abort.is_none() {
            g.abort = Some(payload_str(p));
            g.drain = true;
        }
    }
    g.threads[tid].state = TState::Finished;
    let fc = g.threads[tid].clock.clone();
    g.threads[tid].final_clock = Some(fc);
    let me = tid;
    g.active = match g.threads[me].handoff.take() {
        Some(parent) => parent,
        None => CONTROLLER,
    };
    rt.cv.notify_all();
}

fn is_drain_payload(p: &Box<dyn std::any::Any + Send>) -> bool {
    p.downcast_ref::<&str>().is_some_and(|s| *s == DRAIN)
}

fn payload_str(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

// ---------------------------------------------------------------------------
// Controller: one execution

enum Outcome {
    Complete,
    Pruned,
    Abort(String, String), // message, rendered trace
}

fn intent(t: &MThread) -> Option<Op> {
    if let Some(op) = &t.pending {
        return Some(op.clone());
    }
    match t.state {
        TState::MutexBlocked { mutex } => Some(Op::MutexLock(mutex)),
        TState::JoinBlocked { target } => Some(Op::Join(target)),
        _ => None,
    }
}

fn enabled(g: &Exec, tid: usize) -> bool {
    let t = &g.threads[tid];
    match &t.state {
        TState::Finished | TState::CondBlocked { .. } => false,
        TState::MutexBlocked { mutex } => g.mutexes[*mutex].owner.is_none(),
        TState::JoinBlocked { target } => g.threads[*target].finished(),
        TState::Active => match &t.pending {
            Some(Op::MutexLock(m)) => g.mutexes[*m].owner.is_none(),
            Some(_) => true,
            None => false, // running user code (holds the baton) or not started
        },
    }
}

fn run_once(cfg: &Config, path: Path, f: Arc<dyn Fn() + Send + Sync>) -> (Outcome, Path) {
    EPOCH.fetch_add(1, ROrd::Relaxed);
    let epoch = EPOCH.load(ROrd::Relaxed);
    let rt = Arc::new(Rt {
        mx: Mutex::new(Exec {
            threads: vec![MThread::new({
                let mut c = VClock::default();
                c.set(0, 1);
                c
            })],
            atomics: Vec::new(),
            cells: Vec::new(),
            mutexes: Vec::new(),
            n_conds: 0,
            sc_clock: VClock::default(),
            active: 0, // main model thread starts with the baton
            path,
            sleep: Vec::new(),
            trace: Vec::new(),
            ops_executed: 0,
            last_running: 0,
            preemptions: 0,
            abort: None,
            drain: false,
            cfg: cfg.clone(),
            epoch,
        }),
        cv: Condvar::new(),
    });
    let rt2 = rt.clone();
    let main = std::thread::Builder::new()
        .name("model-0".into())
        .spawn(move || run_model_thread(rt2, 0, move || f()))
        .expect("spawn model main thread"); // lint-ok: OS thread spawn failure is unrecoverable in a test harness

    let outcome = controller_loop(&rt);
    // teardown: unwind every thread still parked at an interposition point
    drain_execution(&rt);
    let _ = main.join();
    let mut g = lock(&rt);
    let path = std::mem::take(&mut g.path);
    drop(g);
    (outcome, path)
}

fn controller_loop(rt: &Arc<Rt>) -> Outcome {
    loop {
        let mut g = lock(rt);
        while g.active != CONTROLLER {
            g = rt.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
        if let Some(msg) = g.abort.clone() {
            let trace = render_trace(&g);
            return Outcome::Abort(msg, trace);
        }
        if g.threads.iter().all(|t| t.finished()) {
            return Outcome::Complete;
        }
        let enabled_tids: Vec<usize> =
            (0..g.threads.len()).filter(|&t| enabled(&g, t)).collect();
        if enabled_tids.is_empty() {
            let blocked: Vec<usize> = (0..g.threads.len())
                .filter(|&t| !g.threads[t].finished())
                .collect();
            let trace = render_trace(&g);
            return Outcome::Abort(
                format!(
                    "deadlock: threads {blocked:?} are all blocked — lost wakeup, \
                     lost message, or missing notify"
                ),
                trace,
            );
        }
        // yield demotion: a spinning thread (one that executed a model
        // yield) is not scheduled while non-yielded work exists, so spin
        // loops cannot starve the store they are waiting for
        let non_yielded: Vec<usize> = enabled_tids
            .iter()
            .copied()
            .filter(|&t| !g.threads[t].yielded)
            .collect();
        let mut base = if non_yielded.is_empty() {
            for t in g.threads.iter_mut() {
                t.yielded = false;
            }
            enabled_tids.clone()
        } else {
            non_yielded
        };
        // preemption bounding: once the budget is spent, keep running the
        // current thread while it stays enabled
        if let Some(bound) = g.cfg.preemption_bound {
            if g.preemptions >= bound && base.contains(&g.last_running) {
                base = vec![g.last_running];
            }
        }
        // sleep-set pruning: skip threads whose next op was already fully
        // explored at an ancestor and has not been woken by a dependent op
        let candidates: Vec<usize> = if g.cfg.sleep_sets {
            let sleeping = g.sleep.clone();
            let filtered: Vec<usize> = base
                .iter()
                .copied()
                .filter(|t| !sleeping.contains(t))
                .collect();
            if filtered.is_empty() {
                // everything enabled is asleep: this schedule is equivalent
                // to one already explored
                return Outcome::Pruned;
            }
            filtered
        } else {
            base
        };
        let chosen = schedule_branch(&mut g, candidates);
        if chosen != g.last_running
            && enabled(&g, g.last_running)
            && !g.threads[g.last_running].finished()
        {
            g.preemptions += 1;
        }
        // wake sleepers whose op is dependent with what is about to run
        if let Some(op) = intent(&g.threads[chosen]) {
            let threads_ops: Vec<(usize, Option<Op>)> = g
                .sleep
                .iter()
                .map(|&s| (s, intent(&g.threads[s])))
                .collect();
            g.sleep = threads_ops
                .into_iter()
                .filter(|(_, sop)| match sop {
                    Some(sop) => !dependent(sop, &op),
                    None => false,
                })
                .map(|(s, _)| s)
                .collect();
        }
        g.last_running = chosen;
        g.threads[chosen].yielded = false;
        g.active = chosen;
        rt.cv.notify_all();
    }
}

/// Replay or extend the schedule decision at the current path position.
fn schedule_branch(g: &mut Exec, candidates: Vec<usize>) -> usize {
    if g.path.pos < g.path.branches.len() {
        let b = g.path.branches[g.path.pos].clone();
        g.path.pos += 1;
        match b {
            Branch::Schedule {
                candidates: c,
                idx,
                explored,
            } => {
                assert_eq!(
                    c, candidates,
                    "model replay diverged (schedule candidates changed)"
                );
                // siblings fully explored at this node sleep in this subtree
                for e in &explored {
                    if !g.sleep.contains(e) {
                        g.sleep.push(*e);
                    }
                }
                c[idx]
            }
            other => panic!("model replay diverged: expected Schedule, found {other:?}"),
        }
    } else {
        let chosen = candidates[0];
        g.path.branches.push(Branch::Schedule {
            candidates,
            idx: 0,
            explored: Vec::new(),
        });
        g.path.pos += 1;
        chosen
    }
}

fn drain_execution(rt: &Arc<Rt>) {
    loop {
        let mut g = lock(rt);
        g.drain = true;
        let next = (0..g.threads.len()).find(|&t| !g.threads[t].finished());
        let Some(tid) = next else { return };
        g.active = tid;
        rt.cv.notify_all();
        while !(g.active == CONTROLLER || g.threads[tid].finished()) {
            g = rt.cv.wait(g).unwrap_or_else(|p| p.into_inner());
        }
    }
}

fn render_trace(g: &Exec) -> String {
    let mut s = String::new();
    for (tid, op) in &g.trace {
        s.push_str(&format!("    t{tid}: {op}\n"));
    }
    s
}

// ---------------------------------------------------------------------------
// Exploration driver

/// Summary of one exhaustive exploration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Report {
    /// Executions that ran to completion with every invariant holding.
    pub completed: usize,
    /// Schedules abandoned by sleep-set pruning as equivalent to an
    /// already-explored execution.
    pub pruned: usize,
}

pub(crate) fn explore(cfg: &Config, f: Arc<dyn Fn() + Send + Sync>) -> Report {
    let mut path = Path::default();
    let mut completed = 0usize;
    let mut pruned = 0usize;
    loop {
        path.pos = 0;
        let (outcome, p) = run_once(cfg, path, f.clone());
        path = p;
        match outcome {
            Outcome::Complete => completed += 1,
            Outcome::Pruned => pruned += 1,
            Outcome::Abort(msg, trace) => {
                panic!(
                    "model counterexample after {} execution(s): {msg}\n  trace (tid: op):\n{trace}",
                    completed + pruned + 1
                );
            }
        }
        if completed + pruned >= cfg.max_executions {
            panic!(
                "model exploration exceeded the execution bound ({}) — tighten the \
                 model or raise Builder::max_executions",
                cfg.max_executions
            );
        }
        if !path.backtrack() {
            break;
        }
    }
    Report { completed, pruned }
}
