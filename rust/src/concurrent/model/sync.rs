//! Model-instrumented drop-in replacements for the std sync primitives
//! used by the messaging core.
//!
//! Inside a [`super::check`] execution, every operation is an
//! interposition point: it is recorded with its `Ordering`, becomes a
//! scheduling decision, and feeds the happens-before vault. Outside an
//! execution (e.g. ordinary unit tests compiled with `--features model`)
//! every type falls back to the real std primitive, so model builds stay
//! runnable everywhere — only `check` turns the instrumentation on.
//!
//! Location registration is lazy and epoch-tagged: a `const fn new` only
//! stores the initial value; the first access inside an execution
//! registers the location against that execution's epoch, which is what
//! lets interposed `static`s exist (each execution sees a fresh location
//! holding the initial value).

use super::rt;
use super::rt::Op;
use std::sync::atomic::Ordering as ROrd;

pub use std::sync::atomic::Ordering;

pub use std::sync::Arc;

// ---------------------------------------------------------------------------
// Lazy epoch-tagged registration

/// Packed (epoch << 32) | (location id + 1); 0 = unregistered.
struct Reg(std::sync::atomic::AtomicU64);

impl Reg {
    const fn new() -> Reg {
        Reg(std::sync::atomic::AtomicU64::new(0))
    }

    fn loc(&self, register: impl FnOnce() -> usize) -> usize {
        let epoch = rt::current_epoch() & 0xffff_ffff;
        let packed = self.0.load(ROrd::Relaxed);
        if packed >> 32 == epoch && packed & 0xffff_ffff != 0 {
            return (packed & 0xffff_ffff) as usize - 1;
        }
        let id = register();
        self.0
            .store((epoch << 32) | (id as u64 + 1), ROrd::Relaxed);
        id
    }
}

// ---------------------------------------------------------------------------
// Atomics

macro_rules! model_atomic {
    ($name:ident, $real:ty, $prim:ty) => {
        pub struct $name {
            fallback: $real,
            reg: Reg,
            init: u64,
        }

        impl $name {
            pub const fn new(v: $prim) -> $name {
                $name {
                    fallback: <$real>::new(v),
                    reg: Reg::new(),
                    init: v as u64,
                }
            }

            fn loc(&self) -> usize {
                let init = self.init;
                self.reg
                    .loc(|| rt::with_exec(|g| rt::register_atomic(g, init)))
            }

            pub fn load(&self, ord: Ordering) -> $prim {
                if !rt::in_model() {
                    return self.fallback.load(ord);
                }
                let loc = self.loc();
                rt::with_op(Op::Load(loc, ord == Ordering::SeqCst), |g, me| {
                    rt::model_load(g, me, loc, ord) as $prim
                })
            }

            pub fn store(&self, v: $prim, ord: Ordering) {
                if !rt::in_model() {
                    return self.fallback.store(v, ord);
                }
                let loc = self.loc();
                rt::with_op(Op::Store(loc, ord == Ordering::SeqCst), |g, me| {
                    rt::model_store(g, me, loc, v as u64, ord)
                })
            }

            pub fn swap(&self, v: $prim, ord: Ordering) -> $prim {
                if !rt::in_model() {
                    return self.fallback.swap(v, ord);
                }
                let loc = self.loc();
                rt::with_op(Op::Rmw(loc, ord == Ordering::SeqCst), |g, me| {
                    rt::model_rmw(g, me, loc, ord, |_| v as u64) as $prim
                })
            }

            pub fn compare_exchange(
                &self,
                cur: $prim,
                new: $prim,
                succ: Ordering,
                fail: Ordering,
            ) -> Result<$prim, $prim> {
                if !rt::in_model() {
                    return self.fallback.compare_exchange(cur, new, succ, fail);
                }
                let loc = self.loc();
                rt::with_op(Op::Rmw(loc, succ == Ordering::SeqCst), |g, me| {
                    rt::model_cas(g, me, loc, cur as u64, new as u64, succ, fail)
                        .map(|v| v as $prim)
                        .map_err(|v| v as $prim)
                })
            }

            pub fn compare_exchange_weak(
                &self,
                cur: $prim,
                new: $prim,
                succ: Ordering,
                fail: Ordering,
            ) -> Result<$prim, $prim> {
                // no spurious failures in the model: a weak CAS explores a
                // subset of the strong CAS's behaviors plus retry loops the
                // schedules already cover
                self.compare_exchange(cur, new, succ, fail)
            }
        }

        impl $name {
            fn rmw_with(&self, ord: Ordering, f: impl FnOnce($prim) -> $prim) -> $prim {
                let loc = self.loc();
                rt::with_op(Op::Rmw(loc, ord == Ordering::SeqCst), |g, me| {
                    rt::model_rmw(g, me, loc, ord, |old| f(old as $prim) as u64) as $prim
                })
            }

            pub fn fetch_add(&self, v: $prim, ord: Ordering) -> $prim {
                if !rt::in_model() {
                    return self.fallback.fetch_add(v, ord);
                }
                self.rmw_with(ord, |old| old.wrapping_add(v))
            }

            pub fn fetch_sub(&self, v: $prim, ord: Ordering) -> $prim {
                if !rt::in_model() {
                    return self.fallback.fetch_sub(v, ord);
                }
                self.rmw_with(ord, |old| old.wrapping_sub(v))
            }

            pub fn fetch_or(&self, v: $prim, ord: Ordering) -> $prim {
                if !rt::in_model() {
                    return self.fallback.fetch_or(v, ord);
                }
                self.rmw_with(ord, |old| old | v)
            }

            pub fn fetch_and(&self, v: $prim, ord: Ordering) -> $prim {
                if !rt::in_model() {
                    return self.fallback.fetch_and(v, ord);
                }
                self.rmw_with(ord, |old| old & v)
            }
        }
    };
}

model_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
model_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
model_atomic!(AtomicU32, std::sync::atomic::AtomicU32, u32);
model_atomic!(AtomicU8, std::sync::atomic::AtomicU8, u8);

/// Signed variant: values round-trip through the u64 store as a bit cast.
pub struct AtomicIsize {
    fallback: std::sync::atomic::AtomicIsize,
    reg: Reg,
    init: u64,
}

impl AtomicIsize {
    pub const fn new(v: isize) -> AtomicIsize {
        AtomicIsize {
            fallback: std::sync::atomic::AtomicIsize::new(v),
            reg: Reg::new(),
            init: v as u64,
        }
    }

    fn loc(&self) -> usize {
        let init = self.init;
        self.reg
            .loc(|| rt::with_exec(|g| rt::register_atomic(g, init)))
    }

    pub fn load(&self, ord: Ordering) -> isize {
        if !rt::in_model() {
            return self.fallback.load(ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Load(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_load(g, me, loc, ord) as isize
        })
    }

    pub fn store(&self, v: isize, ord: Ordering) {
        if !rt::in_model() {
            return self.fallback.store(v, ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Store(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_store(g, me, loc, v as u64, ord)
        })
    }

    pub fn compare_exchange(
        &self,
        cur: isize,
        new: isize,
        succ: Ordering,
        fail: Ordering,
    ) -> Result<isize, isize> {
        if !rt::in_model() {
            return self.fallback.compare_exchange(cur, new, succ, fail);
        }
        let loc = self.loc();
        rt::with_op(Op::Rmw(loc, succ == Ordering::SeqCst), |g, me| {
            rt::model_cas(g, me, loc, cur as u64, new as u64, succ, fail)
                .map(|v| v as isize)
                .map_err(|v| v as isize)
        })
    }
}

pub struct AtomicBool {
    fallback: std::sync::atomic::AtomicBool,
    reg: Reg,
    init: u64,
}

impl AtomicBool {
    pub const fn new(v: bool) -> AtomicBool {
        AtomicBool {
            fallback: std::sync::atomic::AtomicBool::new(v),
            reg: Reg::new(),
            init: v as u64,
        }
    }

    fn loc(&self) -> usize {
        let init = self.init;
        self.reg
            .loc(|| rt::with_exec(|g| rt::register_atomic(g, init)))
    }

    pub fn load(&self, ord: Ordering) -> bool {
        if !rt::in_model() {
            return self.fallback.load(ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Load(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_load(g, me, loc, ord) != 0
        })
    }

    pub fn store(&self, v: bool, ord: Ordering) {
        if !rt::in_model() {
            return self.fallback.store(v, ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Store(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_store(g, me, loc, v as u64, ord)
        })
    }

    pub fn swap(&self, v: bool, ord: Ordering) -> bool {
        if !rt::in_model() {
            return self.fallback.swap(v, ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Rmw(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_rmw(g, me, loc, ord, |_| v as u64) != 0
        })
    }

    pub fn fetch_or(&self, v: bool, ord: Ordering) -> bool {
        if !rt::in_model() {
            return self.fallback.fetch_or(v, ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Rmw(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_rmw(g, me, loc, ord, |old| old | v as u64) != 0
        })
    }

    pub fn compare_exchange(
        &self,
        cur: bool,
        new: bool,
        succ: Ordering,
        fail: Ordering,
    ) -> Result<bool, bool> {
        if !rt::in_model() {
            return self.fallback.compare_exchange(cur, new, succ, fail);
        }
        let loc = self.loc();
        rt::with_op(Op::Rmw(loc, succ == Ordering::SeqCst), |g, me| {
            rt::model_cas(g, me, loc, cur as u64, new as u64, succ, fail)
                .map(|v| v != 0)
                .map_err(|v| v != 0)
        })
    }
}

pub struct AtomicPtr<T> {
    fallback: std::sync::atomic::AtomicPtr<T>,
    reg: Reg,
    init: u64,
}

impl<T> AtomicPtr<T> {
    pub fn new(p: *mut T) -> AtomicPtr<T> {
        AtomicPtr {
            fallback: std::sync::atomic::AtomicPtr::new(p),
            reg: Reg::new(),
            init: p as usize as u64,
        }
    }

    fn loc(&self) -> usize {
        let init = self.init;
        self.reg
            .loc(|| rt::with_exec(|g| rt::register_atomic(g, init)))
    }

    pub fn load(&self, ord: Ordering) -> *mut T {
        if !rt::in_model() {
            return self.fallback.load(ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Load(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_load(g, me, loc, ord) as usize as *mut T
        })
    }

    pub fn store(&self, p: *mut T, ord: Ordering) {
        if !rt::in_model() {
            return self.fallback.store(p, ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Store(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_store(g, me, loc, p as usize as u64, ord)
        })
    }

    pub fn swap(&self, p: *mut T, ord: Ordering) -> *mut T {
        if !rt::in_model() {
            return self.fallback.swap(p, ord);
        }
        let loc = self.loc();
        rt::with_op(Op::Rmw(loc, ord == Ordering::SeqCst), |g, me| {
            rt::model_rmw(g, me, loc, ord, |_| p as usize as u64) as usize as *mut T
        })
    }

    pub fn compare_exchange(
        &self,
        cur: *mut T,
        new: *mut T,
        succ: Ordering,
        fail: Ordering,
    ) -> Result<*mut T, *mut T> {
        if !rt::in_model() {
            return self.fallback.compare_exchange(cur, new, succ, fail);
        }
        let loc = self.loc();
        rt::with_op(Op::Rmw(loc, succ == Ordering::SeqCst), |g, me| {
            rt::model_cas(
                g,
                me,
                loc,
                cur as usize as u64,
                new as usize as u64,
                succ,
                fail,
            )
            .map(|v| v as usize as *mut T)
            .map_err(|v| v as usize as *mut T)
        })
    }
}

pub fn fence(ord: Ordering) {
    if !rt::in_model() {
        return std::sync::atomic::fence(ord);
    }
    rt::with_op(Op::Fence, |g, me| rt::model_fence(g, me, ord));
}

/// Spin-backoff hook: under the model a spin/yield becomes a demoting
/// yield op — the spinner is not rescheduled while other non-yielded
/// threads can run, which keeps spin loops from exploding the schedule
/// space or starving the store they wait for.
pub fn yield_now() {
    if !rt::in_model() {
        return std::thread::yield_now();
    }
    rt::model_yield();
}

pub fn spin_loop() {
    if !rt::in_model() {
        return std::hint::spin_loop();
    }
    rt::model_yield();
}

// ---------------------------------------------------------------------------
// UnsafeCell with checked access

/// An `UnsafeCell` whose accesses are race-checked under the model.
///
/// `with`/`with_mut` declare a read/write access: the checker verifies the
/// access is happens-before-ordered against every conflicting access and
/// panics with a `data race` counterexample otherwise. `with_racy` is the
/// *checked exemption* used by `deque.rs::steal`'s speculative slot read —
/// it is still an interposition point (schedules explore it) but skips the
/// race verdict, which documents exactly which access is intentionally racy.
pub struct UnsafeCell<T: ?Sized> {
    reg: Reg,
    data: std::cell::UnsafeCell<T>,
}

// SAFETY: mirrors std::cell::UnsafeCell — Send iff T: Send (the Reg is a
// plain integer id); never Sync, the wrapping type opts in, exactly as
// with the std cell.
unsafe impl<T: ?Sized + Send> Send for UnsafeCell<T> {}

impl<T> UnsafeCell<T> {
    pub const fn new(v: T) -> UnsafeCell<T> {
        UnsafeCell {
            reg: Reg::new(),
            data: std::cell::UnsafeCell::new(v),
        }
    }

    fn loc(&self) -> usize {
        self.reg.loc(|| rt::with_exec(rt::register_cell))
    }

    /// Declare a read access and run `f` on the raw pointer.
    ///
    /// # Safety
    ///
    /// Same contract as reading through `std::cell::UnsafeCell::get`: the
    /// caller guarantees no concurrent mutable access outside the model.
    pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        if rt::in_model() {
            let loc = self.loc();
            let verdict =
                rt::with_op(Op::CellRead(loc), |g, me| rt::cell_read(g, me, loc, true));
            if let Err(msg) = verdict {
                rt::fail(msg);
            }
        }
        f(self.data.get())
    }

    /// Declare a write access and run `f` on the raw pointer.
    ///
    /// # Safety
    ///
    /// Same contract as writing through `std::cell::UnsafeCell::get`.
    pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
        if rt::in_model() {
            let loc = self.loc();
            let verdict = rt::with_op(Op::CellWrite(loc), |g, me| rt::cell_write(g, me, loc));
            if let Err(msg) = verdict {
                rt::fail(msg);
            }
        }
        f(self.data.get())
    }

    /// Declare a deliberately racy read (no race verdict, still an
    /// interposition point). Use only with an adjacent comment citing the
    /// reason — the linter's interposition rule plus this name make the
    /// exemption greppable.
    pub fn with_racy<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
        if rt::in_model() {
            let loc = self.loc();
            let _ = rt::with_op(Op::CellRead(loc), |g, me| rt::cell_read(g, me, loc, false));
        }
        f(self.data.get())
    }

    /// Raw pointer without an access declaration — single-threaded setup
    /// and teardown only (constructors, `Drop`).
    pub fn get(&self) -> *mut T {
        self.data.get()
    }

    pub fn into_inner(self) -> T {
        self.data.into_inner()
    }
}

// ---------------------------------------------------------------------------
// Mutex / Condvar

pub struct Mutex<T: ?Sized> {
    reg: Reg,
    /// Fallback raw lock (outside-model use); data lives in the cell so
    /// the model path can hand out guards without a real lock.
    raw: std::sync::Mutex<()>,
    data: std::cell::UnsafeCell<T>,
}

// SAFETY: same bounds as std::sync::Mutex — the raw lock (outside the
// model) or the checker's lock registry (inside it) serializes every
// access to `data`, so sharing needs only T: Send.
unsafe impl<T: ?Sized + Send> Send for Mutex<T> {}
unsafe impl<T: ?Sized + Send> Sync for Mutex<T> {}

pub struct MutexGuard<'a, T: ?Sized> {
    mx: &'a Mutex<T>,
    real: Option<std::sync::MutexGuard<'a, ()>>,
    model_loc: Option<usize>,
}

pub type LockResult<G> = Result<G, std::sync::PoisonError<G>>;

impl<T> Mutex<T> {
    pub const fn new(v: T) -> Mutex<T> {
        Mutex {
            reg: Reg::new(),
            raw: std::sync::Mutex::new(()),
            data: std::cell::UnsafeCell::new(v),
        }
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

impl<T: ?Sized> Mutex<T> {
    fn loc(&self) -> usize {
        self.reg.loc(|| rt::with_exec(rt::register_mutex))
    }

    /// Never poisoned under the model: a panic while holding the lock
    /// aborts the whole execution as a counterexample instead.
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        if !rt::in_model() {
            let real = self.raw.lock().unwrap_or_else(|p| p.into_inner());
            return Ok(MutexGuard {
                mx: self,
                real: Some(real),
                model_loc: None,
            });
        }
        let loc = self.loc();
        rt::mutex_lock(loc);
        Ok(MutexGuard {
            mx: self,
            real: None,
            model_loc: Some(loc),
        })
    }
}

impl<T: ?Sized> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        if let Some(loc) = self.model_loc {
            rt::mutex_unlock(loc);
        }
        // the real guard (if any) unlocks on its own drop
    }
}

impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        // SAFETY: lock discipline — this guard is the unique owner
        unsafe { &*self.mx.data.get() }
    }
}

impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        // SAFETY: lock discipline — this guard is the unique owner
        unsafe { &mut *self.mx.data.get() }
    }
}

/// Mirrors `std::sync::WaitTimeoutResult` (which has no public
/// constructor). Under the model a wait never times out — a protocol that
/// *needs* the timeout to make progress surfaces as a deadlock
/// counterexample, which is the bug the timeout would have been hiding.
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

pub struct Condvar {
    reg: Reg,
    raw: std::sync::Condvar,
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

impl Condvar {
    pub const fn new() -> Condvar {
        Condvar {
            reg: Reg::new(),
            raw: std::sync::Condvar::new(),
        }
    }

    fn loc(&self) -> usize {
        self.reg.loc(|| rt::with_exec(rt::register_cond))
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        if let Some(mloc) = guard.model_loc {
            let cloc = self.loc();
            let mx = guard.mx;
            std::mem::forget(guard); // the model wait releases the lock itself
            rt::cond_wait(cloc, mloc);
            return Ok(MutexGuard {
                mx,
                real: None,
                model_loc: Some(mloc),
            });
        }
        let mut guard = guard;
        let real = guard.real.take().expect("non-model guard holds the raw lock"); // lint-ok: fallback guards always hold the raw lock by construction
        let mx = guard.mx;
        std::mem::forget(guard);
        let real = self.raw.wait(real).unwrap_or_else(|p| p.into_inner());
        Ok(MutexGuard {
            mx,
            real: Some(real),
            model_loc: None,
        })
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: std::time::Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        if guard.model_loc.is_some() {
            // modeled as an untimed wait; see the WaitTimeoutResult docs
            let g = self.wait(guard).unwrap_or_else(|p| p.into_inner());
            return Ok((g, WaitTimeoutResult(false)));
        }
        let mut guard = guard;
        let real = guard.real.take().expect("non-model guard holds the raw lock"); // lint-ok: fallback guards always hold the raw lock by construction
        let mx = guard.mx;
        std::mem::forget(guard);
        let (real, to) = self
            .raw
            .wait_timeout(real, dur)
            .unwrap_or_else(|p| p.into_inner());
        Ok((
            MutexGuard {
                mx,
                real: Some(real),
                model_loc: None,
            },
            WaitTimeoutResult(to.timed_out()),
        ))
    }

    pub fn notify_one(&self) {
        if !rt::in_model() {
            return self.raw.notify_one();
        }
        let loc = self.loc();
        rt::cond_notify(loc, false);
    }

    pub fn notify_all(&self) {
        if !rt::in_model() {
            return self.raw.notify_all();
        }
        let loc = self.loc();
        rt::cond_notify(loc, true);
    }
}
