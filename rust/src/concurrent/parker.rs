//! Token-based thread parking.
//!
//! The token makes the unpark/park race benign: `unpark` deposits a token,
//! and `park` returns immediately if one is present — so a wakeup that
//! arrives between "decided to sleep" and "actually slept" is never lost.
//! This is the property the seed scheduler's bare `Condvar` + counter
//! lacked (its `notify_one` could fire before the sleeper reached
//! `wait`, and only a 10 ms poll timeout papered over the lost wakeup).

use crate::loom_types::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Default)]
pub struct Parker {
    token: Mutex<bool>,
    cv: Condvar,
}

impl Parker {
    pub fn new() -> Parker {
        Parker::default()
    }

    /// Block until a token is available, then consume it.
    pub fn park(&self) {
        let mut t = self.token.lock().unwrap_or_else(|p| p.into_inner());
        while !*t {
            t = self.cv.wait(t).unwrap_or_else(|p| p.into_inner());
        }
        *t = false;
    }

    /// Block until a token arrives or `timeout` elapses; consumes the token
    /// if one is present. Returns true if a token was consumed.
    pub fn park_timeout(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut t = self.token.lock().unwrap_or_else(|p| p.into_inner());
        while !*t {
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _) = self.cv.wait_timeout(t, deadline - now).unwrap_or_else(|p| p.into_inner());
            t = g;
        }
        *t = false;
        true
    }

    /// Deposit a token and wake the parked thread, if any. Multiple
    /// unparks coalesce into one token.
    pub fn unpark(&self) {
        let mut t = self.token.lock().unwrap_or_else(|p| p.into_inner());
        *t = true;
        self.cv.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn unpark_before_park_is_not_lost() {
        let p = Parker::new();
        p.unpark();
        p.park(); // returns immediately — the token was banked
    }

    #[test]
    fn park_blocks_until_unpark() {
        let p = Arc::new(Parker::new());
        let p2 = p.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || {
            p2.park();
            t0.elapsed()
        });
        std::thread::sleep(Duration::from_millis(30));
        p.unpark();
        let waited = h.join().unwrap();
        assert!(waited >= Duration::from_millis(25), "woke too early: {waited:?}");
    }

    #[test]
    fn park_timeout_expires_without_token() {
        let p = Parker::new();
        assert!(!p.park_timeout(Duration::from_millis(10)));
        p.unpark();
        assert!(p.park_timeout(Duration::from_millis(10)));
    }
}
