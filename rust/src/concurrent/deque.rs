//! Chase–Lev work-stealing deque.
//!
//! One owner thread pushes and takes at the bottom (LIFO, cache-warm);
//! any other thread steals from the top (FIFO), lock-free. Memory
//! orderings follow Lê, Pop, Cohen & Petri Nardelli, "Correct and
//! Efficient Work-Stealing for Weak Memory Models" (PPoPP'13), the
//! canonical C11 formulation of Chase & Lev's deque.
//!
//! Buffer growth never frees the old buffer while the deque is alive:
//! a racing stealer may still hold a pointer into it. Retired buffers are
//! parked on a side list and released in `Drop`; with doubling growth the
//! retired memory is strictly smaller than the live buffer, so the
//! overhead is bounded — the standard trade for not needing epoch-based
//! reclamation.
//!
//! Known caveat (shared with crossbeam-deque): `steal` bit-copies a slot
//! before the top CAS validates ownership, and the owner's `push` can
//! concurrently overwrite that physical slot after other stealers advance
//! `top` far enough to wrap around. That racing read is formally a data
//! race — UB under the abstract memory model; Miri/TSan would flag it —
//! tolerated in practice on mainstream targets because the torn copy is
//! only kept when the CAS proves no overwrite happened and is `forget`ten
//! otherwise. The defined-behavior alternative (copying slots as atomic
//! words) pessimizes the hot path; see the comment in [`WorkDeque::steal`].
//! Under the model checker this is a *checked exemption*: the speculative
//! slot copy goes through [`crate::loom_types::UnsafeCell::with_racy`],
//! which keeps it an explored interposition point but skips the race
//! verdict — every other slot access stays fully race-checked, so any
//! *new* race in this file is still caught. TSan CI runs with
//! `continue-on-error` for the same reason (see STATIC_ANALYSIS.md).

use crate::loom_types::{fence, AtomicIsize, AtomicPtr, Ordering, UnsafeCell};
use std::mem::MaybeUninit;
use std::sync::Mutex;

struct Buffer<T> {
    mask: usize,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
}

impl<T> Buffer<T> {
    fn alloc(cap: usize) -> *mut Buffer<T> {
        debug_assert!(cap.is_power_of_two());
        let slots: Box<[UnsafeCell<MaybeUninit<T>>]> = (0..cap)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect();
        Box::into_raw(Box::new(Buffer {
            mask: cap - 1,
            slots,
        }))
    }

    fn cap(&self) -> usize {
        self.slots.len()
    }

    /// Bit-copy the element at logical index `i` out of the buffer
    /// (owner-side: race-checked under the model).
    ///
    /// SAFETY: caller must guarantee the slot holds an initialized element
    /// and must resolve ownership (top CAS) before dropping the value.
    unsafe fn read(&self, i: isize) -> T {
        self.slots[i as usize & self.mask].with(|p| unsafe { (*p).as_ptr().read() })
    }

    /// The stealer's speculative bit-copy — the documented Chase–Lev race,
    /// exempted from the model's race verdict (see the module docs).
    ///
    /// SAFETY: same as [`Buffer::read`], plus the caller must `forget` the
    /// copy whenever the validating CAS fails.
    unsafe fn read_racy(&self, i: isize) -> T {
        self.slots[i as usize & self.mask].with_racy(|p| unsafe { (*p).as_ptr().read() })
    }

    /// SAFETY: caller must be the deque owner and `i` must be outside the
    /// live range of any concurrent reader.
    unsafe fn write(&self, i: isize, v: T) {
        self.slots[i as usize & self.mask].with_mut(|p| unsafe { (*p).as_mut_ptr().write(v) });
    }
}

/// Result of a steal attempt.
pub enum Steal<T> {
    /// Nothing to steal.
    Empty,
    /// Lost a race; the queue may still be non-empty.
    Retry,
    /// Got one.
    Success(T),
}

/// The work-stealing deque. `push`/`take` are owner-only (see the safety
/// contracts); `steal` and `is_empty` are safe from any thread.
pub struct WorkDeque<T> {
    top: AtomicIsize,
    bottom: AtomicIsize,
    buf: AtomicPtr<Buffer<T>>,
    /// Old buffers kept alive for racing stealers; only touched on grow
    /// (rare) and drop.
    retired: Mutex<Vec<*mut Buffer<T>>>,
}

// SAFETY: cross-thread element transfer requires T: Send; all shared state
// is atomics plus buffers whose slot ownership is mediated by top/bottom.
unsafe impl<T: Send> Send for WorkDeque<T> {}
unsafe impl<T: Send> Sync for WorkDeque<T> {}

impl<T> Default for WorkDeque<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> WorkDeque<T> {
    pub fn new() -> WorkDeque<T> {
        Self::with_capacity(64)
    }

    /// A deque whose initial buffer holds `cap` elements (rounded up to a
    /// power of two, minimum 2). Small capacities force the grow path
    /// early, which is what the model tests use to pin `steal` racing
    /// against a buffer swap.
    pub fn with_capacity(cap: usize) -> WorkDeque<T> {
        let cap = cap.next_power_of_two().max(2);
        WorkDeque {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Buffer::alloc(cap)),
            retired: Mutex::new(Vec::new()),
        }
    }

    /// Owner-only: push at the bottom.
    ///
    /// # Safety
    ///
    /// Only the deque's single owner thread may call this (or `take`)
    /// at any given time; `steal` remains safe from other threads.
    pub unsafe fn push(&self, value: T) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut a = self.buf.load(Ordering::Relaxed);
        if b - t >= (*a).cap() as isize {
            a = self.grow(t, b);
        }
        (*a).write(b, value);
        fence(Ordering::Release);
        self.bottom.store(b + 1, Ordering::Relaxed);
    }

    /// Owner-only: pop at the bottom (LIFO).
    ///
    /// # Safety
    ///
    /// Only the deque's single owner thread may call this (or `push`)
    /// at any given time; `steal` remains safe from other threads.
    pub unsafe fn take(&self) -> Option<T> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let a = self.buf.load(Ordering::Relaxed);
        self.bottom.store(b, Ordering::Relaxed);
        // pairs with: deque.rs::steal (its top-load → fence → bottom-load
        // must totally order against our bottom-store → fence → top-load)
        fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t <= b {
            if t == b {
                // last element: race the stealers via the top CAS
                let won = self
                    .top
                    .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.store(b + 1, Ordering::Relaxed);
                if won {
                    Some((*a).read(b))
                } else {
                    None
                }
            } else {
                Some((*a).read(b))
            }
        } else {
            self.bottom.store(b + 1, Ordering::Relaxed);
            None
        }
    }

    /// Steal one element from the top (any thread).
    pub fn steal(&self) -> Steal<T> {
        let t = self.top.load(Ordering::Acquire);
        // pairs with: deque.rs::take (the owner's bottom-decrement fence)
        fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t < b {
            let a = self.buf.load(Ordering::Acquire);
            // SAFETY: t < b means slot t was initialized; the read is a bit
            // copy and ownership is decided by the CAS below — on failure
            // the copy is forgotten, never dropped.
            //
            // ACCEPTED UB: if other stealers advance `top` past us and the
            // owner pushes enough to wrap around onto this physical slot,
            // this non-atomic read races with that write (the classic
            // Chase–Lev / crossbeam-deque caveat). The torn value never
            // escapes: the CAS below then necessarily fails (top moved) and
            // the copy is forgotten. Making the race defined would require
            // per-word atomic slot copies on every steal. The model checker
            // exempts exactly this read (read_racy → with_racy) and checks
            // every other slot access.
            let v = unsafe { (*a).read_racy(t) };
            if self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_err()
            {
                std::mem::forget(v);
                return Steal::Retry;
            }
            Steal::Success(v)
        } else {
            Steal::Empty
        }
    }

    /// Approximate emptiness (safe from any thread; used by the scheduler's
    /// pre-park re-check, which brackets it with SeqCst fences).
    pub fn is_empty(&self) -> bool {
        let t = self.top.load(Ordering::Acquire);
        let b = self.bottom.load(Ordering::Acquire);
        b <= t
    }

    /// Approximate length (diagnostics).
    pub fn len(&self) -> usize {
        let t = self.top.load(Ordering::Acquire);
        let b = self.bottom.load(Ordering::Acquire);
        (b - t).max(0) as usize
    }

    /// SAFETY: owner-only, called from `push` when full.
    unsafe fn grow(&self, t: isize, b: isize) -> *mut Buffer<T> {
        let old = self.buf.load(Ordering::Relaxed);
        let new = Buffer::alloc((*old).cap() * 2);
        for i in t..b {
            (*new).write(i, (*old).read(i));
        }
        self.buf.store(new, Ordering::Release);
        self.retired.lock().unwrap_or_else(|p| p.into_inner()).push(old);
        new
    }
}

impl<T> Drop for WorkDeque<T> {
    fn drop(&mut self) {
        // SAFETY: exclusive access (&mut self) — no owner or stealer is
        // live, so top/bottom are quiescent, slots in t..b are initialized
        // and uniquely ours to drop, and the current + retired buffer
        // allocations are uniquely ours to free.
        unsafe {
            let t = self.top.load(Ordering::Relaxed);
            let b = self.bottom.load(Ordering::Relaxed);
            let a = self.buf.load(Ordering::Relaxed);
            for i in t..b {
                drop((*a).read(i));
            }
            drop(Box::from_raw(a));
            // retired buffers hold only stale bit-copies (MaybeUninit slots
            // never drop contents) — free the allocations only
            for p in self.retired.lock().unwrap_or_else(|p| p.into_inner()).drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn lifo_owner_fifo_thief() {
        let d = WorkDeque::new();
        unsafe {
            d.push(1);
            d.push(2);
            d.push(3);
            assert_eq!(d.take(), Some(3)); // owner side is LIFO
        }
        match d.steal() {
            Steal::Success(v) => assert_eq!(v, 1), // thief side is FIFO
            _ => panic!("steal failed"),
        }
        unsafe {
            assert_eq!(d.take(), Some(2));
            assert_eq!(d.take(), None);
        }
        assert!(d.is_empty());
    }

    #[test]
    fn growth_preserves_elements() {
        let d = WorkDeque::new();
        unsafe {
            for i in 0..1000 {
                d.push(i); // forces several grows past the initial 64
            }
            for i in (0..1000).rev() {
                assert_eq!(d.take(), Some(i));
            }
            assert_eq!(d.take(), None);
        }
    }

    #[test]
    fn drop_releases_remaining_elements() {
        let live = Arc::new(AtomicUsize::new(0));
        struct Tracked(Arc<AtomicUsize>);
        impl Drop for Tracked {
            fn drop(&mut self) {
                self.0.fetch_sub(1, Ordering::SeqCst);
            }
        }
        let d = WorkDeque::new();
        for _ in 0..300 {
            live.fetch_add(1, Ordering::SeqCst);
            unsafe { d.push(Tracked(live.clone())) };
        }
        unsafe {
            drop(d.take());
        }
        drop(d);
        assert_eq!(live.load(Ordering::SeqCst), 0, "elements leaked on drop");
    }

    #[test]
    fn concurrent_steal_owner_take_no_loss_no_dup() {
        // One owner pushes N tagged jobs and takes; 3 thieves steal.
        // Every job must be seen exactly once.
        let n = 20_000usize;
        let d = Arc::new(WorkDeque::new());
        let seen = Arc::new((0..n).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>());
        let done = Arc::new(AtomicUsize::new(0));
        let mut thieves = Vec::new();
        for _ in 0..3 {
            let d = d.clone();
            let seen = seen.clone();
            let done = done.clone();
            thieves.push(std::thread::spawn(move || {
                while done.load(Ordering::Acquire) == 0 {
                    match d.steal() {
                        Steal::Success(i) => {
                            seen[i].fetch_add(1, Ordering::SeqCst);
                        }
                        Steal::Retry => std::hint::spin_loop(),
                        Steal::Empty => std::thread::yield_now(),
                    }
                }
            }));
        }
        // owner: interleave pushes and takes
        for i in 0..n {
            unsafe { d.push(i) };
            if i % 3 == 0 {
                if let Some(j) = unsafe { d.take() } {
                    seen[j].fetch_add(1, Ordering::SeqCst);
                }
            }
        }
        loop {
            match unsafe { d.take() } {
                Some(j) => {
                    seen[j].fetch_add(1, Ordering::SeqCst);
                }
                None => {
                    if d.is_empty() {
                        break;
                    }
                }
            }
        }
        // drain whatever thieves still race on, then stop them
        std::thread::sleep(std::time::Duration::from_millis(50));
        done.store(1, Ordering::Release);
        for t in thieves {
            t.join().unwrap();
        }
        for (i, s) in seen.iter().enumerate() {
            assert_eq!(s.load(Ordering::SeqCst), 1, "job {i} seen wrong number of times");
        }
    }
}
