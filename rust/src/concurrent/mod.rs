//! Lock-free building blocks for the messaging hot path.
//!
//! The paper's performance claims (negligible overhead over the native
//! OpenCL API, Fig 5; cheap spawn/dispatch, Fig 4) rest on CAF's lock-free
//! runtime: a Vyukov-style MPSC mailbox and Chase–Lev work-stealing deques.
//! This module provides the same primitives for our substrate:
//!
//! * [`MpscQueue`] — intrusive multi-producer single-consumer node queue
//!   (Vyukov); wait-free push, lock-free pop.
//! * [`CountedQueue`] — an [`MpscQueue`] plus one atomic state word carrying
//!   an element count and a closed bit, so "enqueue and learn whether the
//!   queue was empty" is a single atomic RMW.
//! * [`WorkDeque`] — Chase–Lev work-stealing deque (owner LIFO push/take,
//!   lock-free FIFO steal) following the C11 orderings of Lê et al.,
//!   "Correct and Efficient Work-Stealing for Weak Memory Models" (PPoPP'13).
//! * [`Parker`] — token-based thread parking; an unpark that races ahead of
//!   the park is never lost.

pub mod deque;
#[cfg(feature = "model")]
pub mod model;
pub mod mpsc;
pub mod parker;

pub use deque::{Steal, WorkDeque};
pub use mpsc::{spin_backoff, CountedQueue, MpscQueue, PushResult};
pub use parker::Parker;
