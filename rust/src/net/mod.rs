//! Network transparency: remote actor messaging over TCP (CAF's BASP
//! equivalent, minimal). Publishing an actor under a name lets remote nodes
//! obtain a proxy [`ActorRef`] that behaves like any local handle —
//! requests round-trip transparently, including `Vec<ArgValue>` kernel
//! invocations against a published OpenCL facade (the paper's §3.5
//! "transparent message passing in distributed systems" scenario; see
//! `examples/distributed.rs`).
//!
//! `mem_ref` handles are deliberately **not** serializable (paper §3.5,
//! design option (a)): "prohibit serialization of the reference type to
//! raise an error when a reference would be sent over the network...
//! making expensive copy operations explicit." This applies to bare
//! [`MemRef`] payloads and to `Ref` entries inside an argument list alike.
//!
//! Robustness contract (see [`node`] for details): malformed or hostile
//! frames close their connection without panicking any thread; a lost
//! connection fails every pending request with an error within
//! `remote_actor_timeout`; proxies reconnect on the next send.
//!
//! [`ActorRef`]: crate::actor::ActorRef
//! [`MemRef`]: crate::opencl::MemRef

pub mod codec;
pub mod node;
pub mod slab;

pub use codec::{decode_message, encode_message, encode_scatter, CodecError, ScatterPayload};
pub use node::{Node, MAX_CHUNKED, MAX_FRAME};
pub use slab::FrameSlab;
