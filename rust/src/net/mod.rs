//! Network transparency: remote actor messaging over TCP (CAF's BASP
//! equivalent, minimal). Publishing an actor under a name lets remote nodes
//! obtain a proxy [`ActorRef`] that behaves like any local handle —
//! requests round-trip transparently.
//!
//! `mem_ref` handles are deliberately **not** serializable (paper §3.5,
//! design option (a)): "prohibit serialization of the reference type to
//! raise an error when a reference would be sent over the network...
//! making expensive copy operations explicit."
//!
//! [`ActorRef`]: crate::actor::ActorRef

pub mod codec;
pub mod node;

pub use codec::{decode_message, encode_message, CodecError};
pub use node::Node;
