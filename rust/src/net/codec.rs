//! Hand-rolled binary serialization for actor messages (serde is
//! unavailable offline; DESIGN.md §3). Tag byte + little-endian payload for
//! the message types that may legally cross node boundaries.
//!
//! `Vec<ArgValue>` — the kernel-invocation payload of the paper's OpenCL
//! actors — has a self-describing encoding (`TAG_ARGS`): an argument count
//! followed by one element-tagged vector per argument, so a remote client
//! can drive a published facade without flattening its inputs into ad-hoc
//! tuples.
//!
//! Device references ([`MemRef`], [`ArgValue`] vectors containing them) are
//! rejected with [`CodecError::DeviceLocal`] — the paper's design
//! option (a).
//!
//! Decoding is length-validated end to end: every vector preallocation is
//! clamped to the bytes actually remaining in the buffer, so a crafted
//! count (`0xFFFF_FFFF` elements in a 20-byte frame) fails with
//! [`CodecError::Malformed`] instead of reserving gigabytes.
//!
//! [`MemRef`]: crate::opencl::MemRef
//! [`ArgValue`]: crate::opencl::ArgValue

use crate::actor::message::UnitReply;
use crate::actor::{ErrorMsg, Message};
use crate::opencl::{ArgValue, MemRef};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The payload holds device-local state (mem_ref) — not serializable.
    DeviceLocal,
    /// The payload type has no wire representation.
    Unsupported(&'static str),
    /// Malformed wire data.
    Malformed(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::DeviceLocal => write!(
                f,
                "mem_ref is bound to its local device and cannot be serialized \
                 (transfer the data explicitly with a Val-output stage)"
            ),
            CodecError::Unsupported(t) => write!(f, "no wire representation for {t}"),
            CodecError::Malformed(w) => write!(f, "malformed frame: {w}"),
        }
    }
}

impl std::error::Error for CodecError {}

const TAG_U32: u8 = 1;
const TAG_U64: u8 = 2;
const TAG_I64: u8 = 3;
const TAG_F64: u8 = 4;
const TAG_STRING: u8 = 5;
const TAG_VEC_U32: u8 = 6;
const TAG_VEC_F32: u8 = 7;
const TAG_VEC_U8: u8 = 8;
const TAG_UNIT: u8 = 9;
const TAG_ERROR: u8 = 10;
const TAG_PAIR_VEC_U32: u8 = 11;
const TAG_PAIR_VEC_F32: u8 = 12;
const TAG_ARGS: u8 = 13;

// Element tags inside a TAG_ARGS payload (one per ArgValue variant with a
// wire representation; `Ref` deliberately has none — design option (a)).
const ARG_U32: u8 = 1;
const ARG_F32: u8 = 2;

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn put_vec_u32(out: &mut Vec<u8>, v: &[u32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_vec_f32(out: &mut Vec<u8>, v: &[f32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize a message payload.
pub fn encode_message(msg: &Message) -> Result<Vec<u8>, CodecError> {
    // device-local payloads first: explicit, actionable error
    if msg.is::<MemRef>()
        || msg.is::<(MemRef,)>()
        || msg.is::<(MemRef, MemRef)>()
    {
        return Err(CodecError::DeviceLocal);
    }
    if let Some(args) = msg.downcast_ref::<Vec<ArgValue>>() {
        return encode_args(args);
    }
    let mut out = Vec::new();
    if let Some(&x) = msg.downcast_ref::<u32>() {
        out.push(TAG_U32);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<u64>() {
        out.push(TAG_U64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<i64>() {
        out.push(TAG_I64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<f64>() {
        out.push(TAG_F64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(s) = msg.downcast_ref::<String>() {
        out.push(TAG_STRING);
        put_bytes(&mut out, s.as_bytes());
    } else if let Some(v) = msg.downcast_ref::<Vec<u32>>() {
        out.push(TAG_VEC_U32);
        put_vec_u32(&mut out, v);
    } else if let Some(v) = msg.downcast_ref::<Vec<f32>>() {
        out.push(TAG_VEC_F32);
        put_vec_f32(&mut out, v);
    } else if let Some(v) = msg.downcast_ref::<Vec<u8>>() {
        out.push(TAG_VEC_U8);
        put_bytes(&mut out, v);
    } else if msg.is::<UnitReply>() {
        out.push(TAG_UNIT);
    } else if let Some(e) = msg.downcast_ref::<ErrorMsg>() {
        out.push(TAG_ERROR);
        put_bytes(&mut out, e.reason.as_bytes());
    } else if let Some((a, b)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>)>() {
        out.push(TAG_PAIR_VEC_U32);
        put_vec_u32(&mut out, a);
        put_vec_u32(&mut out, b);
    } else if let Some((a, b)) = msg.downcast_ref::<(Vec<f32>, Vec<f32>)>() {
        out.push(TAG_PAIR_VEC_F32);
        put_vec_f32(&mut out, a);
        put_vec_f32(&mut out, b);
    } else {
        return Err(CodecError::Unsupported(msg.type_name()));
    }
    Ok(out)
}

/// Serialize a kernel-argument list (`TAG_ARGS`): `count:u32` then one
/// `elem_tag:u8 len:u32 data` record per argument. A `Ref` anywhere in the
/// list fails with the actionable device-locality error before any bytes
/// move.
fn encode_args(args: &[ArgValue]) -> Result<Vec<u8>, CodecError> {
    if args.iter().any(|a| a.is_ref()) {
        return Err(CodecError::DeviceLocal);
    }
    let mut out = vec![TAG_ARGS];
    out.extend_from_slice(&(args.len() as u32).to_le_bytes());
    for a in args {
        match a {
            ArgValue::U32(v) => {
                out.push(ARG_U32);
                put_vec_u32(&mut out, v);
            }
            ArgValue::F32(v) => {
                out.push(ARG_F32);
                put_vec_f32(&mut out, v);
            }
            ArgValue::Ref(_) => unreachable!("checked above"),
        }
    }
    Ok(out)
}

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if n > self.buf.len() - self.at {
            return Err(CodecError::Malformed("truncated".into()));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    /// Bytes not yet consumed — the upper bound for any sane element count.
    fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap())) // lint-ok: take(4) yields exactly 4 bytes
    }

    /// Read an element count and bound it by the bytes that could possibly
    /// back it (`min_elem_bytes` per element), so a hostile count cannot
    /// drive `Vec::with_capacity` into a multi-GiB reservation before the
    /// first `take` fails.
    fn count(&mut self, min_elem_bytes: usize) -> Result<usize, CodecError> {
        let n = self.u32()? as usize;
        if n > self.remaining() / min_elem_bytes {
            return Err(CodecError::Malformed(format!(
                "count {n} exceeds remaining {} bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    fn vec_u32(&mut self) -> Result<Vec<u32>, CodecError> {
        let n = self.count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_le_bytes(self.take(4)?.try_into().unwrap())); // lint-ok: take(4) yields exactly 4 bytes
        }
        Ok(v)
    }

    fn bytes(&mut self) -> Result<Vec<u8>, CodecError> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Decode a `TAG_ARGS` body (the tag byte already consumed).
    fn args(&mut self) -> Result<Vec<ArgValue>, CodecError> {
        // each argument is at least elem_tag(1) + len(4)
        let n = self.count(5)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.u8()? {
                ARG_U32 => out.push(ArgValue::U32(std::sync::Arc::new(self.vec_u32()?))),
                ARG_F32 => out.push(ArgValue::F32(std::sync::Arc::new(self.vec_f32()?))),
                other => {
                    return Err(CodecError::Malformed(format!(
                        "unknown ArgValue element tag {other}"
                    )))
                }
            }
        }
        Ok(out)
    }
}

/// Deserialize a message payload.
pub fn decode_message(buf: &[u8]) -> Result<Message, CodecError> {
    let mut r = Reader { buf, at: 1 };
    let tag = *buf.first().ok_or(CodecError::Malformed("empty".into()))?;
    Ok(match tag {
        TAG_U32 => Message::new(r.u32()?),
        TAG_U64 => Message::new(u64::from_le_bytes(r.take(8)?.try_into().unwrap())), // lint-ok: take(8) yields 8 bytes
        TAG_I64 => Message::new(i64::from_le_bytes(r.take(8)?.try_into().unwrap())), // lint-ok: take(8) yields 8 bytes
        TAG_F64 => Message::new(f64::from_le_bytes(r.take(8)?.try_into().unwrap())), // lint-ok: take(8) yields 8 bytes
        TAG_STRING => Message::new(
            String::from_utf8(r.bytes()?)
                .map_err(|_| CodecError::Malformed("bad utf8".into()))?,
        ),
        TAG_VEC_U32 => Message::new(r.vec_u32()?),
        TAG_VEC_F32 => Message::new(r.vec_f32()?),
        TAG_VEC_U8 => Message::new(r.bytes()?),
        TAG_UNIT => Message::new(UnitReply),
        TAG_ERROR => Message::new(ErrorMsg::new(
            String::from_utf8_lossy(&r.bytes()?).to_string(),
        )),
        TAG_PAIR_VEC_U32 => {
            let a = r.vec_u32()?;
            let b = r.vec_u32()?;
            Message::new((a, b))
        }
        TAG_PAIR_VEC_F32 => {
            let a = r.vec_f32()?;
            let b = r.vec_f32()?;
            Message::new((a, b))
        }
        TAG_ARGS => Message::new(r.args()?),
        other => return Err(CodecError::Malformed(format!("unknown tag {other}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) -> Message {
        decode_message(&encode_message(&m).unwrap()).unwrap()
    }

    #[test]
    fn scalars_and_vectors() {
        assert_eq!(roundtrip(Message::new(42u32)).take::<u32>(), Some(42));
        assert_eq!(roundtrip(Message::new(-7i64)).take::<i64>(), Some(-7));
        assert_eq!(
            roundtrip(Message::new("hi".to_string())).take::<String>(),
            Some("hi".to_string())
        );
        let v = vec![1u32, 2, 3];
        assert_eq!(roundtrip(Message::new(v.clone())).take::<Vec<u32>>(), Some(v));
        let f = vec![1.5f32, -2.5];
        assert_eq!(roundtrip(Message::new(f.clone())).take::<Vec<f32>>(), Some(f));
    }

    #[test]
    fn pairs() {
        let m = Message::new((vec![1u32], vec![2u32, 3]));
        assert_eq!(
            roundtrip(m).take::<(Vec<u32>, Vec<u32>)>(),
            Some((vec![1], vec![2, 3]))
        );
    }

    #[test]
    fn error_and_unit() {
        let e = roundtrip(Message::new(ErrorMsg::new("boom")));
        assert_eq!(e.downcast_ref::<ErrorMsg>().unwrap().reason, "boom");
        assert!(roundtrip(Message::new(UnitReply)).is::<UnitReply>());
    }

    #[test]
    fn unsupported_type_is_reported() {
        #[derive(Clone)]
        struct Custom;
        let err = encode_message(&Message::new(Custom)).unwrap_err();
        assert!(matches!(err, CodecError::Unsupported(_)));
    }

    #[test]
    fn malformed_rejected() {
        assert!(decode_message(&[]).is_err());
        assert!(decode_message(&[200]).is_err());
        assert!(decode_message(&[TAG_VEC_U32, 255, 0, 0, 0]).is_err());
    }

    #[test]
    fn arg_list_roundtrip() {
        let args = vec![
            ArgValue::from(vec![1u32, 2, 3]),
            ArgValue::from(vec![1.5f32, -2.5]),
            ArgValue::from(Vec::<u32>::new()),
        ];
        let back = roundtrip(Message::new(args.clone()))
            .take::<Vec<ArgValue>>()
            .unwrap();
        assert_eq!(back, args);
    }

    #[test]
    fn empty_arg_list_roundtrips() {
        let back = roundtrip(Message::new(Vec::<ArgValue>::new()))
            .take::<Vec<ArgValue>>()
            .unwrap();
        assert!(back.is_empty());
    }

    // NOTE: the Ref-in-arg-list → DeviceLocal path needs a live device to
    // construct a MemRef; it is covered end-to-end in tests/net.rs.

    #[test]
    fn hostile_counts_fail_without_reserving() {
        // TAG_ARGS claiming u32::MAX arguments in a tiny buffer
        let mut b = vec![TAG_ARGS];
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.extend_from_slice(&[ARG_U32, 1, 0, 0, 0]);
        let err = decode_message(&b).unwrap_err();
        assert!(matches!(err, CodecError::Malformed(_)));

        // vector element count far beyond the buffer
        let mut b = vec![TAG_ARGS];
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(ARG_F32);
        b.extend_from_slice(&0x4000_0000u32.to_le_bytes());
        b.extend_from_slice(&[0; 16]);
        assert!(decode_message(&b).is_err());
    }

    #[test]
    fn truncated_and_unknown_arg_elements_rejected() {
        // count says 2, body holds 1
        let mut b = vec![TAG_ARGS];
        b.extend_from_slice(&2u32.to_le_bytes());
        b.push(ARG_U32);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&7u32.to_le_bytes());
        assert!(decode_message(&b).is_err());

        // unknown element tag
        let mut b = vec![TAG_ARGS];
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&[99, 0, 0, 0, 0]);
        let err = decode_message(&b).unwrap_err();
        assert!(err.to_string().contains("element tag"));
    }
}
