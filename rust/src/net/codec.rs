//! Hand-rolled binary serialization for actor messages (serde is
//! unavailable offline; DESIGN.md §3). Tag byte + little-endian payload for
//! the message types that may legally cross node boundaries.
//!
//! `Vec<ArgValue>` — the kernel-invocation payload of the paper's OpenCL
//! actors — has a self-describing encoding (`TAG_ARGS`): an argument count
//! followed by one element-tagged vector per argument, so a remote client
//! can drive a published facade without flattening its inputs into ad-hoc
//! tuples.
//!
//! Device references ([`MemRef`], [`ArgValue`] vectors containing them) are
//! rejected with [`CodecError::DeviceLocal`] — the paper's design
//! option (a).
//!
//! Decoding is length-validated end to end: every vector preallocation is
//! clamped to the bytes actually remaining in the buffer, so a crafted
//! count (`0xFFFF_FFFF` elements in a 20-byte frame) fails with
//! [`CodecError::Malformed`] instead of reserving gigabytes.
//!
//! [`MemRef`]: crate::opencl::MemRef
//! [`ArgValue`]: crate::opencl::ArgValue

use crate::actor::message::UnitReply;
use crate::actor::{ErrorMsg, Message};
use crate::opencl::{ArgValue, MemRef};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The payload holds device-local state (mem_ref) — not serializable.
    DeviceLocal,
    /// The payload type has no wire representation.
    Unsupported(&'static str),
    /// Malformed wire data.
    Malformed(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::DeviceLocal => write!(
                f,
                "mem_ref is bound to its local device and cannot be serialized \
                 (transfer the data explicitly with a Val-output stage)"
            ),
            CodecError::Unsupported(t) => write!(f, "no wire representation for {t}"),
            CodecError::Malformed(w) => write!(f, "malformed frame: {w}"),
        }
    }
}

impl std::error::Error for CodecError {}

const TAG_U32: u8 = 1;
const TAG_U64: u8 = 2;
const TAG_I64: u8 = 3;
const TAG_F64: u8 = 4;
const TAG_STRING: u8 = 5;
const TAG_VEC_U32: u8 = 6;
const TAG_VEC_F32: u8 = 7;
const TAG_VEC_U8: u8 = 8;
const TAG_UNIT: u8 = 9;
const TAG_ERROR: u8 = 10;
const TAG_PAIR_VEC_U32: u8 = 11;
const TAG_PAIR_VEC_F32: u8 = 12;
const TAG_ARGS: u8 = 13;

// Element tags inside a TAG_ARGS payload (one per ArgValue variant with a
// wire representation; `Ref` deliberately has none — design option (a)).
const ARG_U32: u8 = 1;
const ARG_F32: u8 = 2;

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn put_vec_u32(out: &mut Vec<u8>, v: &[u32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_vec_f32(out: &mut Vec<u8>, v: &[f32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize a message payload.
pub fn encode_message(msg: &Message) -> Result<Vec<u8>, CodecError> {
    // device-local payloads first: explicit, actionable error
    if msg.is::<MemRef>()
        || msg.is::<(MemRef,)>()
        || msg.is::<(MemRef, MemRef)>()
    {
        return Err(CodecError::DeviceLocal);
    }
    if let Some(args) = msg.downcast_ref::<Vec<ArgValue>>() {
        return encode_args(args);
    }
    let mut out = Vec::new();
    if let Some(&x) = msg.downcast_ref::<u32>() {
        out.push(TAG_U32);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<u64>() {
        out.push(TAG_U64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<i64>() {
        out.push(TAG_I64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<f64>() {
        out.push(TAG_F64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(s) = msg.downcast_ref::<String>() {
        out.push(TAG_STRING);
        put_bytes(&mut out, s.as_bytes());
    } else if let Some(v) = msg.downcast_ref::<Vec<u32>>() {
        out.push(TAG_VEC_U32);
        put_vec_u32(&mut out, v);
    } else if let Some(v) = msg.downcast_ref::<Vec<f32>>() {
        out.push(TAG_VEC_F32);
        put_vec_f32(&mut out, v);
    } else if let Some(v) = msg.downcast_ref::<Vec<u8>>() {
        out.push(TAG_VEC_U8);
        put_bytes(&mut out, v);
    } else if msg.is::<UnitReply>() {
        out.push(TAG_UNIT);
    } else if let Some(e) = msg.downcast_ref::<ErrorMsg>() {
        out.push(TAG_ERROR);
        put_bytes(&mut out, e.reason.as_bytes());
    } else if let Some((a, b)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>)>() {
        out.push(TAG_PAIR_VEC_U32);
        put_vec_u32(&mut out, a);
        put_vec_u32(&mut out, b);
    } else if let Some((a, b)) = msg.downcast_ref::<(Vec<f32>, Vec<f32>)>() {
        out.push(TAG_PAIR_VEC_F32);
        put_vec_f32(&mut out, a);
        put_vec_f32(&mut out, b);
    } else {
        return Err(CodecError::Unsupported(msg.type_name()));
    }
    Ok(out)
}

/// Serialize a kernel-argument list (`TAG_ARGS`): `count:u32` then one
/// `elem_tag:u8 len:u32 data` record per argument. A `Ref` anywhere in the
/// list fails with the actionable device-locality error before any bytes
/// move.
fn encode_args(args: &[ArgValue]) -> Result<Vec<u8>, CodecError> {
    if args.iter().any(|a| a.is_ref()) {
        return Err(CodecError::DeviceLocal);
    }
    let mut out = vec![TAG_ARGS];
    out.extend_from_slice(&(args.len() as u32).to_le_bytes());
    for a in args {
        match a {
            ArgValue::U32(v) => {
                out.push(ARG_U32);
                put_vec_u32(&mut out, v);
            }
            ArgValue::F32(v) => {
                out.push(ARG_F32);
                put_vec_f32(&mut out, v);
            }
            ArgValue::Ref(_) => unreachable!("checked above"),
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// scatter-gather encode
// ---------------------------------------------------------------------------

/// A zero-copy encoded message: a small owned header arena plus *borrowed*
/// element-payload slices, written to the socket with vectored I/O
/// ([`crate::net::node`]). For the hot payloads (`Vec<ArgValue>`,
/// `Vec<u32>`/`Vec<f32>` and their pairs) the element data never lands in an
/// intermediate assembly buffer: the wire segments point straight into the
/// message's own storage. Cold payload types fall back to the owned
/// [`encode_message`] bytes carried in the arena.
pub struct ScatterPayload<'a> {
    /// Owned header bytes (tags, counts, lengths), shared by all Head parts.
    head: Vec<u8>,
    parts: Vec<Part<'a>>,
    total: usize,
}

enum Part<'a> {
    /// `head[start..start + len]`.
    Head { start: usize, len: usize },
    /// Borrowed element data, already in wire (little-endian) byte order.
    Data(&'a [u8]),
}

/// Reinterpret a `u32` slice as its wire bytes (little-endian targets only:
/// there the in-memory representation *is* the encoding).
#[cfg(target_endian = "little")]
fn u32_wire_bytes(v: &[u32]) -> &[u8] {
    // SAFETY: u32 has no padding, u8 alignment is 1, and the length in
    // bytes is exactly `4 * v.len()` within one allocation.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4) }
}

#[cfg(target_endian = "little")]
fn f32_wire_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: as above — f32 is a 4-byte POD with no padding.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4) }
}

impl<'a> ScatterPayload<'a> {
    fn new() -> Self {
        ScatterPayload {
            head: Vec::with_capacity(64),
            parts: Vec::with_capacity(8),
            total: 0,
        }
    }

    /// Append owned header bytes; contiguous head writes merge into one part.
    fn put_head(&mut self, f: impl FnOnce(&mut Vec<u8>)) {
        let start = self.head.len();
        f(&mut self.head);
        let len = self.head.len() - start;
        self.total += len;
        if let Some(Part::Head { start: s, len: l }) = self.parts.last_mut() {
            if *s + *l == start {
                *l += len;
                return;
            }
        }
        self.parts.push(Part::Head { start, len });
    }

    /// Append a borrowed element-data segment (little-endian targets); on
    /// big-endian targets the elements are byte-swapped into the arena.
    fn put_u32_elems(&mut self, v: &'a [u32]) {
        #[cfg(target_endian = "little")]
        {
            if !v.is_empty() {
                let d = u32_wire_bytes(v);
                self.total += d.len();
                self.parts.push(Part::Data(d));
            }
        }
        #[cfg(not(target_endian = "little"))]
        self.put_head(|h| {
            for x in v {
                h.extend_from_slice(&x.to_le_bytes());
            }
        });
    }

    fn put_f32_elems(&mut self, v: &'a [f32]) {
        #[cfg(target_endian = "little")]
        {
            if !v.is_empty() {
                let d = f32_wire_bytes(v);
                self.total += d.len();
                self.parts.push(Part::Data(d));
            }
        }
        #[cfg(not(target_endian = "little"))]
        self.put_head(|h| {
            for x in v {
                h.extend_from_slice(&x.to_le_bytes());
            }
        });
    }

    /// Total encoded length in bytes (sum of all segments).
    pub fn total_len(&self) -> usize {
        self.total
    }

    /// The wire segments in order. Concatenated they are byte-identical to
    /// [`encode_message`]'s output; written with vectored I/O they never
    /// are concatenated.
    pub fn segments(&self) -> Vec<&[u8]> {
        self.parts
            .iter()
            .map(|p| match p {
                Part::Head { start, len } => &self.head[*start..*start + *len],
                Part::Data(d) => *d,
            })
            .collect()
    }

    /// Number of borrowed (non-arena) segments — diagnostics and tests.
    pub fn borrowed_segments(&self) -> usize {
        self.parts
            .iter()
            .filter(|p| matches!(p, Part::Data(_)))
            .count()
    }
}

/// Serialize a message as header arena + borrowed payload slices. Same wire
/// format and same error surface as [`encode_message`]; the difference is
/// purely where the bytes live until the socket write.
pub fn encode_scatter(msg: &Message) -> Result<ScatterPayload<'_>, CodecError> {
    let mut sp = ScatterPayload::new();
    if let Some(args) = msg.downcast_ref::<Vec<ArgValue>>() {
        if args.iter().any(|a| a.is_ref()) {
            return Err(CodecError::DeviceLocal);
        }
        sp.put_head(|h| {
            h.push(TAG_ARGS);
            h.extend_from_slice(&(args.len() as u32).to_le_bytes());
        });
        for a in args {
            match a {
                ArgValue::U32(v) => {
                    sp.put_head(|h| {
                        h.push(ARG_U32);
                        h.extend_from_slice(&(v.len() as u32).to_le_bytes());
                    });
                    sp.put_u32_elems(v);
                }
                ArgValue::F32(v) => {
                    sp.put_head(|h| {
                        h.push(ARG_F32);
                        h.extend_from_slice(&(v.len() as u32).to_le_bytes());
                    });
                    sp.put_f32_elems(v);
                }
                ArgValue::Ref(_) => unreachable!("checked above"),
            }
        }
        return Ok(sp);
    }
    if let Some(v) = msg.downcast_ref::<Vec<u32>>() {
        sp.put_head(|h| {
            h.push(TAG_VEC_U32);
            h.extend_from_slice(&(v.len() as u32).to_le_bytes());
        });
        sp.put_u32_elems(v);
        return Ok(sp);
    }
    if let Some(v) = msg.downcast_ref::<Vec<f32>>() {
        sp.put_head(|h| {
            h.push(TAG_VEC_F32);
            h.extend_from_slice(&(v.len() as u32).to_le_bytes());
        });
        sp.put_f32_elems(v);
        return Ok(sp);
    }
    if let Some((a, b)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>)>() {
        sp.put_head(|h| {
            h.push(TAG_PAIR_VEC_U32);
            h.extend_from_slice(&(a.len() as u32).to_le_bytes());
        });
        sp.put_u32_elems(a);
        sp.put_head(|h| h.extend_from_slice(&(b.len() as u32).to_le_bytes()));
        sp.put_u32_elems(b);
        return Ok(sp);
    }
    if let Some((a, b)) = msg.downcast_ref::<(Vec<f32>, Vec<f32>)>() {
        sp.put_head(|h| {
            h.push(TAG_PAIR_VEC_F32);
            h.extend_from_slice(&(a.len() as u32).to_le_bytes());
        });
        sp.put_f32_elems(a);
        sp.put_head(|h| h.extend_from_slice(&(b.len() as u32).to_le_bytes()));
        sp.put_f32_elems(b);
        return Ok(sp);
    }
    // cold types: owned full encoding carried in the arena
    let owned = encode_message(msg)?;
    sp.put_head(|h| h.extend_from_slice(&owned));
    Ok(sp)
}

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if n > self.buf.len() - self.at {
            return Err(CodecError::Malformed("truncated".into()));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    /// Bytes not yet consumed — the upper bound for any sane element count.
    fn remaining(&self) -> usize {
        self.buf.len() - self.at
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap())) // lint-ok: take(4) yields exactly 4 bytes
    }

    /// Read an element count and bound it by the bytes that could possibly
    /// back it (`min_elem_bytes` per element), so a hostile count cannot
    /// drive `Vec::with_capacity` into a multi-GiB reservation before the
    /// first `take` fails.
    fn count(&mut self, min_elem_bytes: usize) -> Result<usize, CodecError> {
        let n = self.u32()? as usize;
        if n > self.remaining() / min_elem_bytes {
            return Err(CodecError::Malformed(format!(
                "count {n} exceeds remaining {} bytes",
                self.remaining()
            )));
        }
        Ok(n)
    }

    /// Bulk-decode `n` little-endian u32s: one length-checked `take`, one
    /// `memcpy` into the element vector (on LE targets), instead of the
    /// per-element loop this replaced — the decode half of the zero-copy
    /// wire path (the single host-side copy a remote upload pays).
    fn vec_u32(&mut self) -> Result<Vec<u32>, CodecError> {
        let n = self.count(4)?;
        let bytes = self.take(4 * n)?;
        let mut v: Vec<u32> = Vec::with_capacity(n);
        #[cfg(target_endian = "little")]
        // SAFETY: `bytes` holds exactly `4 * n` bytes, the fresh Vec has
        // capacity for `n` u32s, and on a little-endian target the wire
        // bytes are the in-memory representation.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), v.as_mut_ptr().cast::<u8>(), 4 * n);
            v.set_len(n);
        }
        #[cfg(not(target_endian = "little"))]
        for c in bytes.chunks_exact(4) {
            v.push(u32::from_le_bytes(c.try_into().unwrap())); // lint-ok: chunks_exact(4) yields 4-byte slices
        }
        Ok(v)
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.count(4)?;
        let bytes = self.take(4 * n)?;
        let mut v: Vec<f32> = Vec::with_capacity(n);
        #[cfg(target_endian = "little")]
        // SAFETY: as in `vec_u32` — f32 is a 4-byte POD.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), v.as_mut_ptr().cast::<u8>(), 4 * n);
            v.set_len(n);
        }
        #[cfg(not(target_endian = "little"))]
        for c in bytes.chunks_exact(4) {
            v.push(f32::from_le_bytes(c.try_into().unwrap())); // lint-ok: chunks_exact(4) yields 4-byte slices
        }
        Ok(v)
    }

    fn bytes(&mut self) -> Result<Vec<u8>, CodecError> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Decode a `TAG_ARGS` body (the tag byte already consumed).
    fn args(&mut self) -> Result<Vec<ArgValue>, CodecError> {
        // each argument is at least elem_tag(1) + len(4)
        let n = self.count(5)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.u8()? {
                ARG_U32 => out.push(ArgValue::U32(std::sync::Arc::new(self.vec_u32()?))),
                ARG_F32 => out.push(ArgValue::F32(std::sync::Arc::new(self.vec_f32()?))),
                other => {
                    return Err(CodecError::Malformed(format!(
                        "unknown ArgValue element tag {other}"
                    )))
                }
            }
        }
        Ok(out)
    }
}

/// Deserialize a message payload.
pub fn decode_message(buf: &[u8]) -> Result<Message, CodecError> {
    let mut r = Reader { buf, at: 1 };
    let tag = *buf.first().ok_or(CodecError::Malformed("empty".into()))?;
    Ok(match tag {
        TAG_U32 => Message::new(r.u32()?),
        TAG_U64 => Message::new(u64::from_le_bytes(r.take(8)?.try_into().unwrap())), // lint-ok: take(8) yields 8 bytes
        TAG_I64 => Message::new(i64::from_le_bytes(r.take(8)?.try_into().unwrap())), // lint-ok: take(8) yields 8 bytes
        TAG_F64 => Message::new(f64::from_le_bytes(r.take(8)?.try_into().unwrap())), // lint-ok: take(8) yields 8 bytes
        TAG_STRING => Message::new(
            String::from_utf8(r.bytes()?)
                .map_err(|_| CodecError::Malformed("bad utf8".into()))?,
        ),
        TAG_VEC_U32 => Message::new(r.vec_u32()?),
        TAG_VEC_F32 => Message::new(r.vec_f32()?),
        TAG_VEC_U8 => Message::new(r.bytes()?),
        TAG_UNIT => Message::new(UnitReply),
        TAG_ERROR => Message::new(ErrorMsg::new(
            String::from_utf8_lossy(&r.bytes()?).to_string(),
        )),
        TAG_PAIR_VEC_U32 => {
            let a = r.vec_u32()?;
            let b = r.vec_u32()?;
            Message::new((a, b))
        }
        TAG_PAIR_VEC_F32 => {
            let a = r.vec_f32()?;
            let b = r.vec_f32()?;
            Message::new((a, b))
        }
        TAG_ARGS => Message::new(r.args()?),
        other => return Err(CodecError::Malformed(format!("unknown tag {other}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) -> Message {
        decode_message(&encode_message(&m).unwrap()).unwrap()
    }

    #[test]
    fn scalars_and_vectors() {
        assert_eq!(roundtrip(Message::new(42u32)).take::<u32>(), Some(42));
        assert_eq!(roundtrip(Message::new(-7i64)).take::<i64>(), Some(-7));
        assert_eq!(
            roundtrip(Message::new("hi".to_string())).take::<String>(),
            Some("hi".to_string())
        );
        let v = vec![1u32, 2, 3];
        assert_eq!(roundtrip(Message::new(v.clone())).take::<Vec<u32>>(), Some(v));
        let f = vec![1.5f32, -2.5];
        assert_eq!(roundtrip(Message::new(f.clone())).take::<Vec<f32>>(), Some(f));
    }

    #[test]
    fn pairs() {
        let m = Message::new((vec![1u32], vec![2u32, 3]));
        assert_eq!(
            roundtrip(m).take::<(Vec<u32>, Vec<u32>)>(),
            Some((vec![1], vec![2, 3]))
        );
    }

    #[test]
    fn error_and_unit() {
        let e = roundtrip(Message::new(ErrorMsg::new("boom")));
        assert_eq!(e.downcast_ref::<ErrorMsg>().unwrap().reason, "boom");
        assert!(roundtrip(Message::new(UnitReply)).is::<UnitReply>());
    }

    #[test]
    fn unsupported_type_is_reported() {
        #[derive(Clone)]
        struct Custom;
        let err = encode_message(&Message::new(Custom)).unwrap_err();
        assert!(matches!(err, CodecError::Unsupported(_)));
    }

    #[test]
    fn malformed_rejected() {
        assert!(decode_message(&[]).is_err());
        assert!(decode_message(&[200]).is_err());
        assert!(decode_message(&[TAG_VEC_U32, 255, 0, 0, 0]).is_err());
    }

    #[test]
    fn arg_list_roundtrip() {
        let args = vec![
            ArgValue::from(vec![1u32, 2, 3]),
            ArgValue::from(vec![1.5f32, -2.5]),
            ArgValue::from(Vec::<u32>::new()),
        ];
        let back = roundtrip(Message::new(args.clone()))
            .take::<Vec<ArgValue>>()
            .unwrap();
        assert_eq!(back, args);
    }

    #[test]
    fn empty_arg_list_roundtrips() {
        let back = roundtrip(Message::new(Vec::<ArgValue>::new()))
            .take::<Vec<ArgValue>>()
            .unwrap();
        assert!(back.is_empty());
    }

    // NOTE: the Ref-in-arg-list → DeviceLocal path needs a live device to
    // construct a MemRef; it is covered end-to-end in tests/net.rs.

    #[test]
    fn hostile_counts_fail_without_reserving() {
        // TAG_ARGS claiming u32::MAX arguments in a tiny buffer
        let mut b = vec![TAG_ARGS];
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.extend_from_slice(&[ARG_U32, 1, 0, 0, 0]);
        let err = decode_message(&b).unwrap_err();
        assert!(matches!(err, CodecError::Malformed(_)));

        // vector element count far beyond the buffer
        let mut b = vec![TAG_ARGS];
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(ARG_F32);
        b.extend_from_slice(&0x4000_0000u32.to_le_bytes());
        b.extend_from_slice(&[0; 16]);
        assert!(decode_message(&b).is_err());
    }

    fn gather(sp: &ScatterPayload<'_>) -> Vec<u8> {
        let mut out = Vec::new();
        for s in sp.segments() {
            out.extend_from_slice(s);
        }
        out
    }

    #[test]
    fn scatter_matches_owned_encoding() {
        let msgs = vec![
            Message::new(vec![ArgValue::from(vec![1u32, 2, 3]), ArgValue::from(vec![1.5f32])]),
            Message::new(vec![9u32, 8, 7]),
            Message::new(vec![0.5f32; 33]),
            Message::new((vec![1u32, 2], vec![3u32])),
            Message::new((vec![1.0f32], vec![2.0f32, 3.0])),
            Message::new(Vec::<ArgValue>::new()),
            // cold types take the arena fallback but stay byte-identical
            Message::new(42u32),
            Message::new("hello".to_string()),
            Message::new(ErrorMsg::new("boom")),
        ];
        for m in &msgs {
            let sp = encode_scatter(m).unwrap();
            let owned = encode_message(m).unwrap();
            assert_eq!(gather(&sp), owned, "scatter bytes differ for {}", m.type_name());
            assert_eq!(sp.total_len(), owned.len());
        }
    }

    #[cfg(target_endian = "little")]
    #[test]
    fn scatter_borrows_element_data_without_copying() {
        let payload = vec![7u32; 1024];
        let elem_ptr = payload.as_ptr().cast::<u8>();
        let args = vec![ArgValue::from(payload)];
        let m = Message::new(args);
        let sp = encode_scatter(&m).unwrap();
        assert_eq!(sp.borrowed_segments(), 1, "element data must be a borrowed segment");
        let segs = sp.segments();
        let data_seg = segs.last().unwrap();
        assert_eq!(data_seg.len(), 1024 * 4);
        assert_eq!(
            data_seg.as_ptr(),
            elem_ptr,
            "borrowed segment must point into the message's own storage"
        );
    }

    #[test]
    fn scatter_rejects_refs_and_unsupported() {
        #[derive(Clone)]
        struct Custom;
        assert!(matches!(
            encode_scatter(&Message::new(Custom)).unwrap_err(),
            CodecError::Unsupported(_)
        ));
    }

    #[test]
    fn truncated_and_unknown_arg_elements_rejected() {
        // count says 2, body holds 1
        let mut b = vec![TAG_ARGS];
        b.extend_from_slice(&2u32.to_le_bytes());
        b.push(ARG_U32);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&7u32.to_le_bytes());
        assert!(decode_message(&b).is_err());

        // unknown element tag
        let mut b = vec![TAG_ARGS];
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&[99, 0, 0, 0, 0]);
        let err = decode_message(&b).unwrap_err();
        assert!(err.to_string().contains("element tag"));
    }
}
