//! Hand-rolled binary serialization for actor messages (serde is
//! unavailable offline; DESIGN.md §3). Tag byte + little-endian payload for
//! the message types that may legally cross node boundaries.
//!
//! Device references ([`MemRef`], [`ArgValue`] vectors containing them) are
//! rejected with [`CodecError::DeviceLocal`] — the paper's design
//! option (a).
//!
//! [`MemRef`]: crate::opencl::MemRef
//! [`ArgValue`]: crate::opencl::ArgValue

use crate::actor::message::UnitReply;
use crate::actor::{ErrorMsg, Message};
use crate::opencl::{ArgValue, MemRef};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    /// The payload holds device-local state (mem_ref) — not serializable.
    DeviceLocal,
    /// The payload type has no wire representation.
    Unsupported(&'static str),
    /// Malformed wire data.
    Malformed(String),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::DeviceLocal => write!(
                f,
                "mem_ref is bound to its local device and cannot be serialized \
                 (transfer the data explicitly with a Val-output stage)"
            ),
            CodecError::Unsupported(t) => write!(f, "no wire representation for {t}"),
            CodecError::Malformed(w) => write!(f, "malformed frame: {w}"),
        }
    }
}

impl std::error::Error for CodecError {}

const TAG_U32: u8 = 1;
const TAG_U64: u8 = 2;
const TAG_I64: u8 = 3;
const TAG_F64: u8 = 4;
const TAG_STRING: u8 = 5;
const TAG_VEC_U32: u8 = 6;
const TAG_VEC_F32: u8 = 7;
const TAG_VEC_U8: u8 = 8;
const TAG_UNIT: u8 = 9;
const TAG_ERROR: u8 = 10;
const TAG_PAIR_VEC_U32: u8 = 11;
const TAG_PAIR_VEC_F32: u8 = 12;

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

fn put_vec_u32(out: &mut Vec<u8>, v: &[u32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_vec_f32(out: &mut Vec<u8>, v: &[f32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Serialize a message payload.
pub fn encode_message(msg: &Message) -> Result<Vec<u8>, CodecError> {
    // device-local payloads first: explicit, actionable error
    if msg.is::<MemRef>()
        || msg.is::<(MemRef,)>()
        || msg.is::<(MemRef, MemRef)>()
    {
        return Err(CodecError::DeviceLocal);
    }
    if let Some(args) = msg.downcast_ref::<Vec<ArgValue>>() {
        if args.iter().any(|a| a.is_ref()) {
            return Err(CodecError::DeviceLocal);
        }
        return Err(CodecError::Unsupported("Vec<ArgValue> (flatten first)"));
    }
    let mut out = Vec::new();
    if let Some(&x) = msg.downcast_ref::<u32>() {
        out.push(TAG_U32);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<u64>() {
        out.push(TAG_U64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<i64>() {
        out.push(TAG_I64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(&x) = msg.downcast_ref::<f64>() {
        out.push(TAG_F64);
        out.extend_from_slice(&x.to_le_bytes());
    } else if let Some(s) = msg.downcast_ref::<String>() {
        out.push(TAG_STRING);
        put_bytes(&mut out, s.as_bytes());
    } else if let Some(v) = msg.downcast_ref::<Vec<u32>>() {
        out.push(TAG_VEC_U32);
        put_vec_u32(&mut out, v);
    } else if let Some(v) = msg.downcast_ref::<Vec<f32>>() {
        out.push(TAG_VEC_F32);
        put_vec_f32(&mut out, v);
    } else if let Some(v) = msg.downcast_ref::<Vec<u8>>() {
        out.push(TAG_VEC_U8);
        put_bytes(&mut out, v);
    } else if msg.is::<UnitReply>() {
        out.push(TAG_UNIT);
    } else if let Some(e) = msg.downcast_ref::<ErrorMsg>() {
        out.push(TAG_ERROR);
        put_bytes(&mut out, e.reason.as_bytes());
    } else if let Some((a, b)) = msg.downcast_ref::<(Vec<u32>, Vec<u32>)>() {
        out.push(TAG_PAIR_VEC_U32);
        put_vec_u32(&mut out, a);
        put_vec_u32(&mut out, b);
    } else if let Some((a, b)) = msg.downcast_ref::<(Vec<f32>, Vec<f32>)>() {
        out.push(TAG_PAIR_VEC_F32);
        put_vec_f32(&mut out, a);
        put_vec_f32(&mut out, b);
    } else {
        return Err(CodecError::Unsupported(msg.type_name()));
    }
    Ok(out)
}

struct Reader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.at + n > self.buf.len() {
            return Err(CodecError::Malformed("truncated".into()));
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn vec_u32(&mut self) -> Result<Vec<u32>, CodecError> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.u32()? as usize;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(f32::from_le_bytes(self.take(4)?.try_into().unwrap()));
        }
        Ok(v)
    }

    fn bytes(&mut self) -> Result<Vec<u8>, CodecError> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }
}

/// Deserialize a message payload.
pub fn decode_message(buf: &[u8]) -> Result<Message, CodecError> {
    let mut r = Reader { buf, at: 1 };
    let tag = *buf.first().ok_or(CodecError::Malformed("empty".into()))?;
    Ok(match tag {
        TAG_U32 => Message::new(r.u32()?),
        TAG_U64 => Message::new(u64::from_le_bytes(r.take(8)?.try_into().unwrap())),
        TAG_I64 => Message::new(i64::from_le_bytes(r.take(8)?.try_into().unwrap())),
        TAG_F64 => Message::new(f64::from_le_bytes(r.take(8)?.try_into().unwrap())),
        TAG_STRING => Message::new(
            String::from_utf8(r.bytes()?)
                .map_err(|_| CodecError::Malformed("bad utf8".into()))?,
        ),
        TAG_VEC_U32 => Message::new(r.vec_u32()?),
        TAG_VEC_F32 => Message::new(r.vec_f32()?),
        TAG_VEC_U8 => Message::new(r.bytes()?),
        TAG_UNIT => Message::new(UnitReply),
        TAG_ERROR => Message::new(ErrorMsg::new(
            String::from_utf8_lossy(&r.bytes()?).to_string(),
        )),
        TAG_PAIR_VEC_U32 => {
            let a = r.vec_u32()?;
            let b = r.vec_u32()?;
            Message::new((a, b))
        }
        TAG_PAIR_VEC_F32 => {
            let a = r.vec_f32()?;
            let b = r.vec_f32()?;
            Message::new((a, b))
        }
        other => return Err(CodecError::Malformed(format!("unknown tag {other}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(m: Message) -> Message {
        decode_message(&encode_message(&m).unwrap()).unwrap()
    }

    #[test]
    fn scalars_and_vectors() {
        assert_eq!(roundtrip(Message::new(42u32)).take::<u32>(), Some(42));
        assert_eq!(roundtrip(Message::new(-7i64)).take::<i64>(), Some(-7));
        assert_eq!(
            roundtrip(Message::new("hi".to_string())).take::<String>(),
            Some("hi".to_string())
        );
        let v = vec![1u32, 2, 3];
        assert_eq!(roundtrip(Message::new(v.clone())).take::<Vec<u32>>(), Some(v));
        let f = vec![1.5f32, -2.5];
        assert_eq!(roundtrip(Message::new(f.clone())).take::<Vec<f32>>(), Some(f));
    }

    #[test]
    fn pairs() {
        let m = Message::new((vec![1u32], vec![2u32, 3]));
        assert_eq!(
            roundtrip(m).take::<(Vec<u32>, Vec<u32>)>(),
            Some((vec![1], vec![2, 3]))
        );
    }

    #[test]
    fn error_and_unit() {
        let e = roundtrip(Message::new(ErrorMsg::new("boom")));
        assert_eq!(e.downcast_ref::<ErrorMsg>().unwrap().reason, "boom");
        assert!(roundtrip(Message::new(UnitReply)).is::<UnitReply>());
    }

    #[test]
    fn unsupported_type_is_reported() {
        #[derive(Clone)]
        struct Custom;
        let err = encode_message(&Message::new(Custom)).unwrap_err();
        assert!(matches!(err, CodecError::Unsupported(_)));
    }

    #[test]
    fn malformed_rejected() {
        assert!(decode_message(&[]).is_err());
        assert!(decode_message(&[200]).is_err());
        assert!(decode_message(&[TAG_VEC_U32, 255, 0, 0, 0]).is_err());
    }
}
