//! Frame-page recycling for the decode path.
//!
//! Each reader thread owns a [`FrameSlab`] and draws its frame-body buffers
//! from it instead of allocating a fresh `Vec<u8>` per frame. After a frame
//! is decoded (the element data having been bulk-copied once into its
//! `ArgValue` vectors — the single host-side copy the wire path pays), the
//! page goes back to the slab for the next frame. Under a steady request
//! stream the reader reaches an allocation-free steady state, the same
//! recycling discipline the device [`BufferPool`] applies on the upload
//! side (`runtime/client.rs`) — pages here feed vectors that stage straight
//! into pool-recycled device buffers, so a remote upload never copies twice.
//!
//! Single-owner by design (one slab per reader thread): no locking.
//!
//! [`BufferPool`]: crate::runtime::client

use super::node::MAX_FRAME;

/// Pages larger than this are dropped instead of retained, so one giant
/// chunked frame cannot pin its peak footprint forever.
const MAX_RETAINED: usize = MAX_FRAME;

/// Retained page count; beyond this, returned pages are freed.
const MAX_PAGES: usize = 4;

/// A tiny freelist of frame-body pages.
#[derive(Default)]
pub struct FrameSlab {
    free: Vec<Vec<u8>>,
    reused: u64,
    fresh: u64,
}

impl FrameSlab {
    pub fn new() -> FrameSlab {
        FrameSlab::default()
    }

    /// A zeroed page of exactly `len` bytes, recycled when possible.
    pub fn take(&mut self, len: usize) -> Vec<u8> {
        match self.free.iter().position(|p| p.capacity() >= len) {
            Some(i) => {
                let mut p = self.free.swap_remove(i);
                self.reused += 1;
                p.clear();
                p.resize(len, 0);
                p
            }
            None => {
                self.fresh += 1;
                vec![0u8; len]
            }
        }
    }

    /// Return a page for reuse.
    pub fn put(&mut self, page: Vec<u8>) {
        if page.capacity() == 0 || page.capacity() > MAX_RETAINED {
            return;
        }
        if self.free.len() >= MAX_PAGES {
            // keep the largest pages: evict the smallest retained one if the
            // newcomer beats it
            if let Some((i, _)) = self
                .free
                .iter()
                .enumerate()
                .min_by_key(|(_, p)| p.capacity())
            {
                if self.free[i].capacity() < page.capacity() {
                    self.free[i] = page;
                }
            }
            return;
        }
        self.free.push(page);
    }

    /// (reused, fresh) page counts — diagnostics and tests.
    pub fn stats(&self) -> (u64, u64) {
        (self.reused, self.fresh)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_recycle() {
        let mut s = FrameSlab::new();
        let p = s.take(1024);
        assert_eq!(p.len(), 1024);
        s.put(p);
        let q = s.take(512);
        assert_eq!(q.len(), 512);
        let (reused, fresh) = s.stats();
        assert_eq!((reused, fresh), (1, 1));
    }

    #[test]
    fn reused_pages_are_zeroed_to_len() {
        let mut s = FrameSlab::new();
        let mut p = s.take(8);
        p.copy_from_slice(&[0xAB; 8]);
        s.put(p);
        let q = s.take(4);
        assert_eq!(s.stats().0, 1, "second take must recycle the page");
        assert!(q.iter().all(|&b| b == 0), "recycled page must be re-zeroed");
    }

    #[test]
    fn oversized_and_excess_pages_are_dropped() {
        let mut s = FrameSlab::new();
        s.put(Vec::with_capacity(MAX_RETAINED + 1));
        assert_eq!(s.free.len(), 0);
        for _ in 0..(MAX_PAGES + 3) {
            s.put(vec![0u8; 64]);
        }
        assert_eq!(s.free.len(), MAX_PAGES);
    }

    #[test]
    fn larger_newcomer_evicts_smallest_retained() {
        let mut s = FrameSlab::new();
        for _ in 0..MAX_PAGES {
            s.put(vec![0u8; 64]);
        }
        s.put(vec![0u8; 4096]);
        assert!(s.free.iter().any(|p| p.capacity() >= 4096));
    }
}
