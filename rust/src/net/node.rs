//! TCP node: publish registry-named actors, obtain remote proxies.
//!
//! Wire protocol (all little-endian, length-prefixed frames):
//!
//! ```text
//! frame   := len:u32 kind:u8 body
//! REQUEST := mid:u64 name_len:u16 name payload     (kind 1)
//! REPLY   := mid:u64 payload                       (kind 2)
//! SEND    := name_len:u16 name payload             (kind 3, fire-and-forget)
//! ```
//!
//! A mem_ref in a payload fails at `encode_message` — the error surfaces on
//! the *sender*, before any bytes move (design option (a), §3.5).

use super::codec::{decode_message, encode_message};
use crate::actor::envelope::{ActorId, Envelope, MessageId};
use crate::actor::{AbstractActor, ActorRef, ActorSystem, ErrorMsg, Message};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

const KIND_REQUEST: u8 = 1;
const KIND_REPLY: u8 = 2;
const KIND_SEND: u8 = 3;

fn write_frame(stream: &mut TcpStream, kind: u8, body: &[u8]) -> std::io::Result<()> {
    let len = (body.len() + 1) as u32;
    stream.write_all(&len.to_le_bytes())?;
    stream.write_all(&[kind])?;
    stream.write_all(body)?;
    stream.flush()
}

fn read_frame(stream: &mut TcpStream) -> std::io::Result<(u8, Vec<u8>)> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    let kind = body.remove(0);
    Ok((kind, body))
}

/// A node endpoint: can listen (publish) and connect (proxy).
pub struct Node {
    system: ActorSystem,
    listener_stop: Arc<AtomicBool>,
    listen_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    local_addr: Mutex<Option<std::net::SocketAddr>>,
}

impl Node {
    pub fn new(system: &ActorSystem) -> Arc<Node> {
        Arc::new(Node {
            system: system.clone(),
            listener_stop: Arc::new(AtomicBool::new(false)),
            listen_thread: Mutex::new(None),
            local_addr: Mutex::new(None),
        })
    }

    /// Publish all registry-named actors at `addr` (CAF's `publish`).
    /// `addr` may use port 0 to pick an ephemeral port; the bound address
    /// is returned.
    pub fn listen(self: &Arc<Node>, addr: &str) -> Result<std::net::SocketAddr> {
        let listener = TcpListener::bind(addr).context("bind")?;
        let bound = listener.local_addr()?;
        *self.local_addr.lock().unwrap() = Some(bound);
        listener.set_nonblocking(true)?;
        let stop = self.listener_stop.clone();
        let sys = self.system.clone();
        let th = std::thread::Builder::new()
            .name("caf-node-accept".into())
            .spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            stream.set_nonblocking(false).ok();
                            let sys = sys.clone();
                            std::thread::spawn(move || serve_connection(sys, stream));
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        *self.listen_thread.lock().unwrap() = Some(th);
        Ok(bound)
    }

    /// Connect to a remote node and build a proxy for its published actor
    /// `name` (CAF's `remote_actor`).
    pub fn remote_actor(self: &Arc<Node>, addr: &str, name: &str) -> Result<ActorRef> {
        let stream = TcpStream::connect(addr).context("connect")?;
        let conn = Connection::start(self.system.clone(), stream)?;
        Ok(ActorRef::new(Arc::new(RemoteProxy {
            id: next_proxy_id(),
            name: name.to_string(),
            conn,
        })))
    }

    pub fn stop(&self) {
        self.listener_stop.store(true, Ordering::Release);
        if let Some(t) = self.listen_thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

impl Drop for Node {
    fn drop(&mut self) {
        self.stop();
    }
}

static NEXT_PROXY_ID: AtomicU64 = AtomicU64::new(1 << 48);

fn next_proxy_id() -> ActorId {
    NEXT_PROXY_ID.fetch_add(1, Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// server side
// ---------------------------------------------------------------------------

/// Responder handle: routes an actor's reply back over the wire.
struct WireResponder {
    id: ActorId,
    mid: u64,
    writer: Arc<Mutex<TcpStream>>,
}

impl AbstractActor for WireResponder {
    fn enqueue(&self, env: Envelope) {
        let body = match encode_message(&env.msg) {
            Ok(mut payload) => {
                let mut b = self.mid.to_le_bytes().to_vec();
                b.append(&mut payload);
                b
            }
            Err(e) => {
                let mut b = self.mid.to_le_bytes().to_vec();
                b.append(&mut encode_message(&Message::new(ErrorMsg::new(e.to_string())))
                    .expect("ErrorMsg always encodes"));
                b
            }
        };
        if let Ok(mut w) = self.writer.lock() {
            let _ = write_frame(&mut w, KIND_REPLY, &body);
        }
    }

    fn id(&self) -> ActorId {
        self.id
    }

    fn attach_monitor(&self, _watcher: ActorRef) {}
    fn attach_link(&self, _peer: ActorRef) {}

    fn kind(&self) -> &'static str {
        "wire-responder"
    }
}

fn serve_connection(sys: ActorSystem, stream: TcpStream) {
    let writer = Arc::new(Mutex::new(stream.try_clone().expect("clone stream")));
    let mut reader = stream;
    loop {
        let (kind, body) = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return, // peer closed
        };
        match kind {
            KIND_REQUEST | KIND_SEND => {
                let mut at = 0usize;
                let mid = if kind == KIND_REQUEST {
                    let m = u64::from_le_bytes(body[0..8].try_into().unwrap());
                    at += 8;
                    Some(m)
                } else {
                    None
                };
                let name_len =
                    u16::from_le_bytes(body[at..at + 2].try_into().unwrap()) as usize;
                at += 2;
                let name = String::from_utf8_lossy(&body[at..at + name_len]).to_string();
                at += name_len;
                let payload = decode_message(&body[at..]);
                let target = sys.registry().get(&name);
                match (target, payload, mid) {
                    (Some(t), Ok(msg), Some(mid)) => {
                        let responder = ActorRef::new(Arc::new(WireResponder {
                            id: next_proxy_id(),
                            mid,
                            writer: writer.clone(),
                        }));
                        t.enqueue(Envelope {
                            sender: Some(responder),
                            mid: MessageId(mid),
                            msg,
                        });
                    }
                    (Some(t), Ok(msg), None) => {
                        t.enqueue(Envelope::asynchronous(None, msg));
                    }
                    (None, _, Some(mid)) => {
                        let responder = WireResponder {
                            id: 0,
                            mid,
                            writer: writer.clone(),
                        };
                        responder.enqueue(Envelope::asynchronous(
                            None,
                            Message::new(ErrorMsg::new(format!("no actor published as {name:?}"))),
                        ));
                    }
                    (_, Err(e), _) => {
                        log::warn!("dropping malformed remote message: {e}");
                    }
                    _ => {}
                }
            }
            _ => return,
        }
    }
}

// ---------------------------------------------------------------------------
// client side
// ---------------------------------------------------------------------------

struct Connection {
    writer: Arc<Mutex<TcpStream>>,
    pending: Arc<Mutex<HashMap<u64, ActorRef>>>,
}

impl Connection {
    fn start(_sys: ActorSystem, stream: TcpStream) -> Result<Arc<Connection>> {
        let writer = Arc::new(Mutex::new(stream.try_clone()?));
        let pending: Arc<Mutex<HashMap<u64, ActorRef>>> = Arc::new(Mutex::new(HashMap::new()));
        let p2 = pending.clone();
        let mut reader = stream;
        std::thread::Builder::new()
            .name("caf-node-client".into())
            .spawn(move || loop {
                let (kind, body) = match read_frame(&mut reader) {
                    Ok(f) => f,
                    Err(_) => {
                        // connection lost: fail all pending requests
                        let mut p = p2.lock().unwrap();
                        for (mid, who) in p.drain() {
                            who.enqueue(Envelope {
                                sender: None,
                                mid: MessageId(mid).response_for(),
                                msg: Message::new(ErrorMsg::new("remote node disconnected")),
                            });
                        }
                        return;
                    }
                };
                if kind != KIND_REPLY || body.len() < 8 {
                    continue;
                }
                let mid = u64::from_le_bytes(body[0..8].try_into().unwrap());
                let Some(who) = p2.lock().unwrap().remove(&mid) else {
                    continue;
                };
                match decode_message(&body[8..]) {
                    Ok(msg) => who.enqueue(Envelope {
                        sender: None,
                        mid: MessageId(mid).response_for(),
                        msg,
                    }),
                    Err(e) => who.enqueue(Envelope {
                        sender: None,
                        mid: MessageId(mid).response_for(),
                        msg: Message::new(ErrorMsg::new(e.to_string())),
                    }),
                }
            })?;
        Ok(Arc::new(Connection { writer, pending }))
    }
}

/// Client-side proxy: a normal [`ActorRef`] whose mailbox is a TCP stream.
struct RemoteProxy {
    id: ActorId,
    name: String,
    conn: Arc<Connection>,
}

impl AbstractActor for RemoteProxy {
    fn enqueue(&self, env: Envelope) {
        let payload = match encode_message(&env.msg) {
            Ok(p) => p,
            Err(e) => {
                // serialization failures surface to the requester
                if env.mid.is_request() {
                    if let Some(s) = env.sender {
                        s.enqueue(Envelope {
                            sender: None,
                            mid: env.mid.response_for(),
                            msg: Message::new(ErrorMsg::new(e.to_string())),
                        });
                    }
                }
                return;
            }
        };
        let mut body = Vec::with_capacity(payload.len() + 32);
        let kind = if env.mid.is_request() {
            body.extend_from_slice(&env.mid.0.to_le_bytes());
            if let Some(s) = env.sender {
                self.conn.pending.lock().unwrap().insert(env.mid.0, s);
            }
            KIND_REQUEST
        } else {
            KIND_SEND
        };
        body.extend_from_slice(&(self.name.len() as u16).to_le_bytes());
        body.extend_from_slice(self.name.as_bytes());
        body.extend_from_slice(&payload);
        if let Ok(mut w) = self.conn.writer.lock() {
            let _ = write_frame(&mut w, kind, &body);
        }
    }

    fn id(&self) -> ActorId {
        self.id
    }

    fn attach_monitor(&self, _watcher: ActorRef) {}
    fn attach_link(&self, _peer: ActorRef) {}

    fn kind(&self) -> &'static str {
        "remote"
    }
}
