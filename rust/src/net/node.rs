//! TCP node: publish registry-named actors, obtain remote proxies.
//!
//! Wire protocol (all little-endian, length-prefixed frames):
//!
//! ```text
//! frame       := len:u32 kind:u8 body          (1 <= len <= MAX_FRAME)
//! REQUEST     := mid:u64 name_len:u16 name payload     (kind 1)
//! REPLY       := mid:u64 payload                       (kind 2)
//! SEND        := name_len:u16 name payload             (kind 3, fire-and-forget)
//! CHUNK_START := total:u64 inner_kind:u8 data          (kind 4)
//! CHUNK_CONT  := data                                  (kind 5)
//! ```
//!
//! `payload` is a tagged message body (see [`super::codec`]); kernel
//! argument lists travel as the self-describing `TAG_ARGS` encoding, which
//! is what lets a remote client drive a published OpenCL facade.
//!
//! **Zero-copy writes.** Outbound frames are written as scatter-gather
//! segment lists ([`super::codec::encode_scatter`]) with vectored I/O:
//! header bytes come from a small arena, element data (`Vec<u32>`/
//! `Vec<f32>` payloads) is written as borrowed slices straight out of the
//! message's own storage — there is no intermediate full-frame assembly
//! buffer on the encode path. On decode, frame bodies land in recycled
//! [`super::slab::FrameSlab`] pages and element data is bulk-copied once
//! into its `ArgValue` vectors, which the facade's upload path stages
//! directly into pool-recycled device buffers (`runtime/client.rs`) — so a
//! remote upload pays exactly one host-side copy.
//!
//! **Chunked continuation frames.** A logical message larger than
//! [`MAX_FRAME`] shards into a `CHUNK_START` frame (announcing the total
//! reassembled size and the inner frame kind) followed by `CHUNK_CONT`
//! frames, reassembled on the receiver under the [`MAX_CHUNKED`] clamp
//! (256 MiB). A hostile announced total — larger than the clamp, overrun
//! by the actual data, or starved by an empty continuation — is a protocol
//! error that closes the connection; reassembly allocates as data arrives,
//! never from the announced total alone.
//!
//! Framing is panic-proof on both sides: zero-length frames and frames
//! larger than [`MAX_FRAME`] (16 MiB) are protocol errors that close the
//! connection cleanly, and inbound bodies are parsed through fallible
//! readers — one short or hostile frame can log-and-close its connection
//! but never kill a thread by panic or reserve unbounded memory.
//!
//! Connection lifecycle:
//!
//! * **Client side** — proxies to the same peer address share one
//!   connection (one socket, one reader thread) through a per-address
//!   `PeerLink` cache. A dead connection is re-established on the next
//!   send ("reconnect-on-next-send"; concurrent reconnects collapse into
//!   one attempt, capped at `CONNECT_CAP`, with a short fail-fast backoff
//!   while the peer keeps refusing); requests in flight when a connection
//!   dies all fail with an [`ErrorMsg`]. Every request additionally arms a
//!   deadline ([`SystemConfig::remote_actor_timeout`]): an unanswered
//!   request fails with an `ErrorMsg` instead of leaking its pending-map
//!   entry forever. Monitors attached to a remote proxy
//!   ([`ActorRef::monitor_with`]) receive a [`Down`] message with
//!   [`ExitReason::Unreachable`] when the proxy's connection is lost.
//! * **Server side** — [`Node::listen`] publishes all registry-named
//!   actors; each accepted connection runs on its own thread, tracked in a
//!   served-connection registry so [`Node::stop`] can shut the sockets and
//!   join the threads instead of leaking them. A node can listen on one
//!   address at a time; a second `listen` call is rejected while the first
//!   is active.
//!
//! A mem_ref in a payload fails at `encode_message` — the error surfaces on
//! the *sender*, before any bytes move (design option (a), §3.5).
//!
//! Placement transparency: a facade spawned with
//! [`Placement::Replicated`](crate::opencl::Placement) is published like
//! any other registry-named actor — the name resolves to the routing
//! dispatcher, so inbound remote requests fan out across the server's
//! device inventory (and batched facades coalesce them) without the wire
//! protocol knowing anything about placement.
//!
//! [`SystemConfig::remote_actor_timeout`]: crate::actor::SystemConfig
//! [`Down`]: crate::actor::Down
//! [`ExitReason::Unreachable`]: crate::actor::ExitReason

use super::codec::{decode_message, encode_message, encode_scatter};
use super::slab::FrameSlab;
use crate::actor::envelope::{ActorId, Envelope, MessageId};
use crate::actor::monitor::{Down, ExitReason};
use crate::actor::{AbstractActor, ActorRef, ActorSystem, ErrorMsg, Message};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{IoSlice, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

const KIND_REQUEST: u8 = 1;
const KIND_REPLY: u8 = 2;
const KIND_SEND: u8 = 3;
const KIND_CHUNK_START: u8 = 4;
const KIND_CHUNK_CONT: u8 = 5;

/// Hard cap on one frame (`kind` byte + body). A peer announcing a larger
/// length is a protocol violation — the connection closes before a single
/// body byte is read, so a hostile `len:u32` cannot drive a 4 GiB
/// allocation.
pub const MAX_FRAME: usize = 16 << 20;

/// Hard cap on one *logical* message reassembled from chunked frames
/// (`CHUNK_START` + `CHUNK_CONT`). An announced total beyond this closes
/// the connection before any continuation is read.
pub const MAX_CHUNKED: usize = 256 << 20;

/// `CHUNK_START` body prefix: `total:u64` + `inner_kind:u8`.
const CHUNK_HDR: usize = 9;

fn proto_err(what: String) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, what)
}

/// Write every byte of `segs` in order via vectored I/O, advancing across
/// partial writes without copying segments together.
fn write_segments(stream: &mut TcpStream, segs: &[&[u8]]) -> std::io::Result<()> {
    let mut rem: Vec<&[u8]> = segs.iter().copied().filter(|s| !s.is_empty()).collect();
    while !rem.is_empty() {
        let iov: Vec<IoSlice<'_>> = rem.iter().map(|s| IoSlice::new(s)).collect();
        let mut n = stream.write_vectored(&iov)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                "socket accepted zero bytes",
            ));
        }
        let mut done = 0;
        while done < rem.len() && n >= rem[done].len() {
            n -= rem[done].len();
            done += 1;
        }
        rem.drain(..done);
        if n > 0 {
            rem[0] = &rem[0][n..];
        }
    }
    Ok(())
}

/// Write one logical message (`kind` + concatenation of `segs`) without
/// ever assembling it: small messages go out as a single vectored frame,
/// larger ones shard into `CHUNK_START`/`CHUNK_CONT` frames cut across the
/// segment list. The caller must hold the connection's writer lock for the
/// whole call so chunks of different messages never interleave.
fn write_logical_frame(stream: &mut TcpStream, kind: u8, segs: &[&[u8]]) -> std::io::Result<()> {
    let total: usize = segs.iter().map(|s| s.len()).sum();
    if total + 1 <= MAX_FRAME {
        let len4 = ((total + 1) as u32).to_le_bytes();
        let kind1 = [kind];
        let mut iov: Vec<&[u8]> = Vec::with_capacity(segs.len() + 2);
        iov.push(&len4);
        iov.push(&kind1);
        iov.extend_from_slice(segs);
        write_segments(stream, &iov)?;
        return stream.flush();
    }
    if total > MAX_CHUNKED {
        return Err(proto_err(format!(
            "outbound message of {total} bytes exceeds MAX_CHUNKED ({MAX_CHUNKED})"
        )));
    }
    // shard: a START frame carrying the reassembly header, then CONT frames,
    // each cut across the segment list at MAX_FRAME boundaries
    let mut idx = 0usize; // next segment
    let mut off = 0usize; // offset into segs[idx]
    let mut first = true;
    while first || idx < segs.len() {
        let cap = MAX_FRAME - 1 - if first { CHUNK_HDR } else { 0 };
        let mut parts: Vec<&[u8]> = Vec::new();
        let mut n = 0usize;
        while idx < segs.len() && n < cap {
            let s = &segs[idx][off..];
            let take = s.len().min(cap - n);
            parts.push(&s[..take]);
            n += take;
            if take == s.len() {
                idx += 1;
                off = 0;
            } else {
                off += take;
            }
        }
        let (frame_kind, hdr_extra) = if first {
            (KIND_CHUNK_START, CHUNK_HDR)
        } else {
            (KIND_CHUNK_CONT, 0)
        };
        let len4 = ((n + hdr_extra + 1) as u32).to_le_bytes();
        let kind1 = [frame_kind];
        let mut start_hdr = [0u8; CHUNK_HDR];
        let mut iov: Vec<&[u8]> = Vec::with_capacity(parts.len() + 3);
        iov.push(&len4);
        iov.push(&kind1);
        if first {
            start_hdr[..8].copy_from_slice(&(total as u64).to_le_bytes());
            start_hdr[8] = kind;
            iov.push(&start_hdr);
        }
        iov.extend_from_slice(&parts);
        write_segments(stream, &iov)?;
        first = false;
    }
    stream.flush()
}

/// Convenience for contiguous bodies (error replies, tests).
fn write_frame(stream: &mut TcpStream, kind: u8, body: &[u8]) -> std::io::Result<()> {
    write_logical_frame(stream, kind, &[body])
}

/// Read one raw frame into a slab-recycled page.
fn read_frame(stream: &mut TcpStream, slab: &mut FrameSlab) -> std::io::Result<(u8, Vec<u8>)> {
    let mut len4 = [0u8; 4];
    stream.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len == 0 {
        return Err(proto_err("zero-length frame".to_string()));
    }
    if len > MAX_FRAME {
        return Err(proto_err(format!(
            "{len}-byte frame exceeds MAX_FRAME ({MAX_FRAME})"
        )));
    }
    let mut kind = [0u8; 1];
    stream.read_exact(&mut kind)?;
    let mut body = slab.take(len - 1);
    stream.read_exact(&mut body)?;
    Ok((kind[0], body))
}

/// Read one logical message: a plain frame passes through; a `CHUNK_START`
/// frame triggers reassembly of its continuations under the [`MAX_CHUNKED`]
/// clamp. Reassembly allocates as data arrives — a hostile announced total
/// fails before reserving anything.
fn read_logical_frame(
    stream: &mut TcpStream,
    slab: &mut FrameSlab,
) -> std::io::Result<(u8, Vec<u8>)> {
    let (kind, body) = read_frame(stream, slab)?;
    if kind == KIND_CHUNK_CONT {
        return Err(proto_err("CHUNK_CONT without a CHUNK_START".to_string()));
    }
    if kind != KIND_CHUNK_START {
        return Ok((kind, body));
    }
    if body.len() < CHUNK_HDR {
        return Err(proto_err(format!(
            "CHUNK_START body of {} bytes is shorter than its header",
            body.len()
        )));
    }
    let total = u64::from_le_bytes(body[0..8].try_into().unwrap()) as usize; // lint-ok: length checked above
    let inner_kind = body[8];
    if total > MAX_CHUNKED {
        return Err(proto_err(format!(
            "chunked message announcing {total} bytes exceeds MAX_CHUNKED ({MAX_CHUNKED})"
        )));
    }
    if matches!(inner_kind, KIND_CHUNK_START | KIND_CHUNK_CONT) {
        return Err(proto_err(format!(
            "chunked message with nested chunk kind {inner_kind}"
        )));
    }
    // grow as data arrives; the initial reservation is bounded by what one
    // frame can legally carry, not by the (attacker-controlled) total
    let mut assembled = Vec::with_capacity((body.len() - CHUNK_HDR).min(total));
    assembled.extend_from_slice(&body[CHUNK_HDR..]);
    slab.put(body);
    if assembled.len() > total {
        return Err(proto_err(format!(
            "chunk data overruns the announced total of {total} bytes"
        )));
    }
    while assembled.len() < total {
        let (k, cont) = read_frame(stream, slab)?;
        if k != KIND_CHUNK_CONT {
            return Err(proto_err(format!(
                "frame kind {k} interleaved into a chunked message"
            )));
        }
        if cont.is_empty() || assembled.len() + cont.len() > total {
            return Err(proto_err(format!(
                "continuation of {} bytes breaks the announced total of {total}",
                cont.len()
            )));
        }
        assembled.extend_from_slice(&cont);
        slab.put(cont);
    }
    Ok((inner_kind, assembled))
}

/// A node endpoint: can listen (publish) and connect (proxy).
pub struct Node {
    system: ActorSystem,
    listener: Mutex<Option<ListenState>>,
    served: Arc<ServedConns>,
    /// Peer-connection cache: proxies to the same address share one
    /// connection and its reader thread.
    peers: Mutex<HashMap<String, Arc<PeerLink>>>,
}

struct ListenState {
    stop: Arc<AtomicBool>,
    thread: std::thread::JoinHandle<()>,
    addr: SocketAddr,
}

impl Node {
    pub fn new(system: &ActorSystem) -> Arc<Node> {
        Arc::new(Node {
            system: system.clone(),
            listener: Mutex::new(None),
            served: Arc::new(ServedConns::default()),
            peers: Mutex::new(HashMap::new()),
        })
    }

    /// Publish all registry-named actors at `addr` (CAF's `publish`).
    /// `addr` may use port 0 to pick an ephemeral port; the bound address
    /// is returned. A node listens on at most one address: while a
    /// listener is active, another `listen` is an error (stop the node
    /// first) rather than a silent leak of the previous accept loop.
    pub fn listen(&self, addr: &str) -> Result<SocketAddr> {
        let mut guard = self.listener.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(active) = guard.as_ref() {
            bail!(
                "node is already listening at {} — call stop() before re-listening",
                active.addr
            );
        }
        let listener = TcpListener::bind(addr).context("bind")?;
        let bound = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let sys = self.system.clone();
        let served = self.served.clone();
        let thread = std::thread::Builder::new()
            .name("caf-node-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Acquire) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            stream.set_nonblocking(false).ok();
                            served.serve(sys.clone(), stream);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        *guard = Some(ListenState {
            stop,
            thread,
            addr: bound,
        });
        Ok(bound)
    }

    /// The address this node is currently listening on, if any.
    pub fn local_addr(&self) -> Option<SocketAddr> {
        self.listener.lock().unwrap_or_else(|p| p.into_inner()).as_ref().map(|l| l.addr)
    }

    /// Connect to a remote node and build a proxy for its published actor
    /// `name` (CAF's `remote_actor`). Proxies created for the same `addr`
    /// share one connection; the connection is established eagerly so an
    /// unreachable peer surfaces here, and re-established transparently on
    /// the next send if it later drops.
    pub fn remote_actor(&self, addr: &str, name: &str) -> Result<ActorRef> {
        let link = self.peer_link(addr);
        link.connection()
            .map_err(|e| anyhow!("remote_actor({addr}, {name:?}): {e:#}"))?;
        Ok(ActorRef::new(Arc::new(RemoteProxy {
            id: next_proxy_id(),
            name: name.to_string(),
            link,
        })))
    }

    fn peer_link(&self, addr: &str) -> Arc<PeerLink> {
        self.peers
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .entry(addr.to_string())
            .or_insert_with(|| {
                Arc::new(PeerLink {
                    addr: addr.to_string(),
                    system: self.system.clone(),
                    timeout: self.system.config().remote_actor_timeout,
                    conn: Mutex::new(None),
                    connect_gate: Mutex::new(()),
                    last_connect_failure: Mutex::new(None),
                    watchers: Mutex::new(Vec::new()),
                })
            })
            .clone()
    }

    /// Number of cached peer links (diagnostics; proxies to one address
    /// share one link).
    pub fn peer_count(&self) -> usize {
        self.peers.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// Number of currently served inbound connections (diagnostics).
    pub fn served_count(&self) -> usize {
        self.served.conns.lock().unwrap_or_else(|p| p.into_inner()).len()
    }

    /// Tear the node down: stop accepting, close and join every served
    /// connection, and close client-side peer connections (failing their
    /// pending requests with [`ErrorMsg`]).
    pub fn stop(&self) {
        if let Some(ls) = self.listener.lock().unwrap_or_else(|p| p.into_inner()).take() {
            ls.stop.store(true, Ordering::Release);
            let _ = ls.thread.join();
        }
        self.served.stop();
        let links: Vec<Arc<PeerLink>> =
            self.peers.lock().unwrap_or_else(|p| p.into_inner()).drain().map(|(_, l)| l).collect();
        for l in links {
            l.close();
        }
    }
}

impl Drop for Node {
    fn drop(&mut self) {
        self.stop();
    }
}

static NEXT_PROXY_ID: AtomicU64 = AtomicU64::new(1 << 48);

fn next_proxy_id() -> ActorId {
    NEXT_PROXY_ID.fetch_add(1, Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// server side
// ---------------------------------------------------------------------------

/// Registry of inbound connections being served, so `Node::stop` can close
/// the sockets (unblocking the reader threads) and join the handlers
/// instead of leaking one thread per connection ever accepted.
#[derive(Default)]
struct ServedConns {
    next: AtomicU64,
    conns: Mutex<HashMap<u64, ServedConn>>,
}

struct ServedConn {
    /// Clone of the handler's stream, used only for `shutdown`.
    stream: TcpStream,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl ServedConns {
    /// Spawn a handler thread for an accepted connection and track it.
    fn serve(self: &Arc<Self>, sys: ActorSystem, stream: TcpStream) {
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        let clone = match stream.try_clone() {
            Ok(c) => c,
            Err(e) => {
                // can't register a shutdown handle — still serve the
                // connection rather than silently dropping it; it ends on
                // its own EOF instead of via stop()
                log::warn!("net: cannot clone accepted stream ({e}); serving untracked");
                let _ = std::thread::Builder::new()
                    .name(format!("caf-node-serve-{id}"))
                    .spawn(move || serve_connection(sys, stream));
                return;
            }
        };
        self.conns.lock().unwrap_or_else(|p| p.into_inner()).insert(
            id,
            ServedConn {
                stream: clone,
                thread: None,
            },
        );
        let registry = self.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("caf-node-serve-{id}"))
            .spawn(move || {
                serve_connection(sys, stream);
                // self-deregister on natural exit (no-op during stop(),
                // which takes the whole map first)
                registry.conns.lock().unwrap_or_else(|p| p.into_inner()).remove(&id);
            });
        match spawned {
            Ok(h) => {
                let mut conns = self.conns.lock().unwrap_or_else(|p| p.into_inner());
                match conns.get_mut(&id) {
                    Some(c) => c.thread = Some(h),
                    None => {
                        // the entry is gone: either the handler exited and
                        // deregistered itself, or stop() took the map (and
                        // shut the socket down) before we could file the
                        // handle — join here so stop()'s "all handlers
                        // joined" contract holds either way
                        drop(conns);
                        let _ = h.join();
                    }
                }
            }
            Err(_) => {
                self.conns.lock().unwrap_or_else(|p| p.into_inner()).remove(&id);
            }
        }
    }

    fn stop(&self) {
        let taken: HashMap<u64, ServedConn> =
            std::mem::take(&mut *self.conns.lock().unwrap_or_else(|p| p.into_inner()));
        for (_, c) in taken {
            let _ = c.stream.shutdown(Shutdown::Both);
            if let Some(h) = c.thread {
                let _ = h.join();
            }
        }
    }
}

/// Responder handle: routes an actor's reply back over the wire.
struct WireResponder {
    id: ActorId,
    mid: u64,
    writer: Arc<Mutex<TcpStream>>,
}

impl AbstractActor for WireResponder {
    fn enqueue(&self, env: Envelope) {
        let mid_bytes = self.mid.to_le_bytes();
        // encode as header arena + borrowed element slices; a payload with
        // no wire representation answers the requester with the codec error
        let err_payload;
        let sp = match encode_scatter(&env.msg) {
            Ok(sp) => sp,
            Err(e) => {
                err_payload = Message::new(ErrorMsg::new(e.to_string()));
                encode_scatter(&err_payload).expect("ErrorMsg always encodes") // lint-ok: ErrorMsg encodes infallibly
            }
        };
        let mut segs: Vec<&[u8]> = Vec::with_capacity(8);
        segs.push(&mid_bytes);
        segs.extend(sp.segments());
        if let Ok(mut w) = self.writer.lock() {
            if let Err(e) = write_logical_frame(&mut w, KIND_REPLY, &segs) {
                // a local size violation (reply over MAX_CHUNKED) leaves the
                // socket healthy: answer with a small error so the remote
                // requester learns why instead of timing out. Real I/O
                // errors mean the connection is gone — the client's reader
                // fails its pending requests on its own.
                if e.kind() == std::io::ErrorKind::InvalidData {
                    log::warn!("net: reply for mid {} not sent: {e}", self.mid);
                    let mut b = self.mid.to_le_bytes().to_vec();
                    b.append(
                        &mut encode_message(&Message::new(ErrorMsg::new(e.to_string())))
                            .expect("ErrorMsg always encodes"), // lint-ok: ErrorMsg encodes infallibly
                    );
                    let _ = write_frame(&mut w, KIND_REPLY, &b);
                }
            }
        }
    }

    fn id(&self) -> ActorId {
        self.id
    }

    fn attach_monitor(&self, _watcher: ActorRef) {}
    fn attach_link(&self, _peer: ActorRef) {}

    fn kind(&self) -> &'static str {
        "wire-responder"
    }
}

/// Reply to a remote request with an error (used when no actor payload
/// ever reaches a local actor).
fn reply_error(writer: &Arc<Mutex<TcpStream>>, mid: u64, reason: String) {
    let responder = WireResponder {
        id: 0,
        mid,
        writer: writer.clone(),
    };
    responder.enqueue(Envelope::asynchronous(
        None,
        Message::new(ErrorMsg::new(reason)),
    ));
}

/// Fallibly split an inbound REQUEST/SEND body into (mid, target name,
/// payload bytes). Every index is bounds-checked: a short frame is a
/// protocol error, not a handler-thread panic.
fn parse_inbound(kind: u8, body: &[u8]) -> Result<(Option<u64>, String, usize), String> {
    let mut at = 0usize;
    let mid = if kind == KIND_REQUEST {
        if body.len() < 8 {
            return Err(format!(
                "REQUEST body of {} bytes is shorter than the 8-byte mid",
                body.len()
            ));
        }
        at = 8;
        Some(u64::from_le_bytes(body[0..8].try_into().unwrap())) // lint-ok: length checked above
    } else {
        None
    };
    if body.len() < at + 2 {
        return Err("frame ends before the name length".to_string());
    }
    let name_len = u16::from_le_bytes(body[at..at + 2].try_into().unwrap()) as usize; // lint-ok: length checked above
    at += 2;
    if body.len() - at < name_len {
        return Err(format!(
            "name of {name_len} bytes extends past the frame ({} bytes left)",
            body.len() - at
        ));
    }
    let name = std::str::from_utf8(&body[at..at + name_len])
        .map_err(|_| "actor name is not valid utf-8".to_string())?
        .to_string();
    at += name_len;
    Ok((mid, name, at))
}

fn serve_connection(sys: ActorSystem, stream: TcpStream) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".to_string());
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => {
            log::warn!("net: cannot clone stream for {peer}: {e}");
            return;
        }
    };
    let mut reader = stream;
    let mut slab = FrameSlab::new();
    loop {
        let (kind, body) = match read_logical_frame(&mut reader, &mut slab) {
            Ok(f) => f,
            Err(e) => {
                // EOF is the normal end of a connection; anything else —
                // including our own protocol-violation errors — is logged
                if e.kind() == std::io::ErrorKind::InvalidData {
                    log::warn!("net: closing connection from {peer}: {e}");
                }
                return;
            }
        };
        match kind {
            KIND_REQUEST | KIND_SEND => {
                let (mid, name, payload_at) = match parse_inbound(kind, &body) {
                    Ok(p) => p,
                    Err(why) => {
                        log::warn!("net: malformed frame from {peer}: {why}; closing");
                        return;
                    }
                };
                let payload = decode_message(&body[payload_at..]);
                let target = sys.registry().get(&name);
                match (target, payload, mid) {
                    (Some(t), Ok(msg), Some(mid)) => {
                        let responder = ActorRef::new(Arc::new(WireResponder {
                            id: next_proxy_id(),
                            mid,
                            writer: writer.clone(),
                        }));
                        t.enqueue(Envelope {
                            sender: Some(responder),
                            mid: MessageId(mid),
                            msg,
                        });
                    }
                    (Some(t), Ok(msg), None) => {
                        t.enqueue(Envelope::asynchronous(None, msg));
                    }
                    (None, _, Some(mid)) => {
                        reply_error(
                            &writer,
                            mid,
                            format!("no actor published as {name:?}"),
                        );
                    }
                    (Some(_), Err(e), Some(mid)) => {
                        // requester is waiting: tell it what was wrong
                        reply_error(&writer, mid, format!("malformed payload: {e}"));
                    }
                    (_, Err(e), None) => {
                        log::warn!("net: dropping malformed SEND for {name:?} from {peer}: {e}");
                    }
                    (None, Ok(_), None) => {
                        log::warn!("net: dropping SEND for unpublished actor {name:?}");
                    }
                }
            }
            other => {
                log::warn!("net: unknown frame kind {other} from {peer}; closing");
                return;
            }
        }
        // the frame is fully decoded (element data bulk-copied once into
        // its vectors); recycle the page for the next frame
        slab.put(body);
    }
}

// ---------------------------------------------------------------------------
// client side
// ---------------------------------------------------------------------------

/// Cap on one TCP connect attempt, so a send to an unreachable peer
/// cannot pin a scheduler worker for the full `remote_actor_timeout`.
const CONNECT_CAP: Duration = Duration::from_secs(5);

/// After a failed connect, further sends fail fast for this long instead
/// of each paying a full connect attempt (coalesces the reconnect
/// stampede when many actors share one dead peer).
const RECONNECT_BACKOFF: Duration = Duration::from_millis(250);

/// The shared route to one peer address: at most one live [`Connection`]
/// at a time, plus the monitors to notify when it drops.
struct PeerLink {
    addr: String,
    system: ActorSystem,
    timeout: Duration,
    conn: Mutex<Option<Arc<Connection>>>,
    /// Serializes (re)connect attempts. Separate from `conn` so the slot
    /// lock is never held across a blocking connect — `is_down`,
    /// `close`, and the fast path stay wait-free while someone dials.
    connect_gate: Mutex<()>,
    /// When the last connect attempt failed (drives the fail-fast window).
    last_connect_failure: Mutex<Option<std::time::Instant>>,
    /// Monitors attached to proxies on this link: (proxy id, watcher).
    /// Drained (one-shot, like local monitors) when the connection drops.
    watchers: Mutex<Vec<(ActorId, ActorRef)>>,
}

impl PeerLink {
    /// The current connection if it is alive.
    fn live(&self) -> Option<Arc<Connection>> {
        self.conn
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .as_ref()
            .filter(|c| c.alive.load(Ordering::Acquire))
            .cloned()
    }

    /// The live connection, re-established if the previous one died
    /// (reconnect-on-next-send). Concurrent reconnects collapse into one
    /// attempt; while a peer keeps refusing, sends fail fast for
    /// [`RECONNECT_BACKOFF`] instead of dialing again each time.
    fn connection(self: &Arc<Self>) -> Result<Arc<Connection>> {
        if let Some(c) = self.live() {
            return Ok(c);
        }
        let _gate = self.connect_gate.lock().unwrap_or_else(|p| p.into_inner());
        // someone else may have reconnected while we waited for the gate
        if let Some(c) = self.live() {
            return Ok(c);
        }
        if let Some(at) = *self.last_connect_failure.lock().unwrap_or_else(|p| p.into_inner()) {
            if at.elapsed() < RECONNECT_BACKOFF {
                bail!(
                    "peer {} unreachable (last connect attempt {:?} ago)",
                    self.addr,
                    at.elapsed()
                );
            }
        }
        match Connection::open(self) {
            Ok(fresh) => {
                *self.last_connect_failure.lock().unwrap_or_else(|p| p.into_inner()) = None;
                *self.conn.lock().unwrap_or_else(|p| p.into_inner()) = Some(fresh.clone());
                Ok(fresh)
            }
            Err(e) => {
                *self.last_connect_failure.lock().unwrap_or_else(|p| p.into_inner()) =
                    Some(std::time::Instant::now());
                Err(e)
            }
        }
    }

    /// True if a connection existed and is now dead (for immediate-`Down`
    /// monitor semantics). A link that never connected is not "down".
    fn is_down(&self) -> bool {
        match self.conn.lock().unwrap_or_else(|p| p.into_inner()).as_ref() {
            Some(c) => !c.alive.load(Ordering::Acquire),
            None => false,
        }
    }

    /// Deliver `Down { Unreachable }` to every registered watcher.
    fn notify_unreachable(&self) {
        let watchers: Vec<(ActorId, ActorRef)> =
            self.watchers.lock().unwrap_or_else(|p| p.into_inner()).drain(..).collect();
        for (source, w) in watchers {
            w.enqueue(Envelope::asynchronous(
                None,
                Message::new(Down {
                    source,
                    reason: ExitReason::Unreachable,
                }),
            ));
        }
    }

    fn close(&self) {
        let c = self.conn.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(c) = c {
            c.close();
        }
    }
}

struct Connection {
    peer: String,
    /// Clone used only for `shutdown` (never read/written).
    sock: TcpStream,
    writer: Mutex<TcpStream>,
    alive: AtomicBool,
    pending: Mutex<HashMap<u64, ActorRef>>,
}

impl Connection {
    fn open(link: &Arc<PeerLink>) -> Result<Arc<Connection>> {
        // try every address the name resolves to (std's TcpStream::connect
        // behavior, e.g. `localhost` → ::1 then 127.0.0.1), but with a
        // bounded timeout per attempt
        let addrs: Vec<SocketAddr> = link
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolve {}", link.addr))?
            .collect();
        let mut stream = None;
        let mut last_err: Option<std::io::Error> = None;
        for a in &addrs {
            match TcpStream::connect_timeout(a, link.timeout.min(CONNECT_CAP)) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last_err = Some(e),
            }
        }
        let stream = stream.ok_or_else(|| match last_err {
            Some(e) => anyhow!("connect {}: {e}", link.addr),
            None => anyhow!("{} resolves to no address", link.addr),
        })?;
        let conn = Arc::new(Connection {
            peer: link.addr.clone(),
            sock: stream.try_clone()?,
            writer: Mutex::new(stream.try_clone()?),
            alive: AtomicBool::new(true),
            pending: Mutex::new(HashMap::new()),
        });
        let reader_conn = conn.clone();
        let weak_link = Arc::downgrade(link);
        let mut reader = stream;
        std::thread::Builder::new()
            .name("caf-node-client".into())
            .spawn(move || {
                reader_loop(&mut reader, &reader_conn);
                // connection lost: flip the flag before draining so a
                // racing `enqueue` either finds its entry drained here or
                // sees `alive == false` and cleans up after itself
                reader_conn.alive.store(false, Ordering::Release);
                reader_conn
                    .fail_pending(&format!("remote node {} disconnected", reader_conn.peer));
                if let Some(l) = weak_link.upgrade() {
                    l.notify_unreachable();
                }
            })?;
        Ok(conn)
    }

    /// Mark dead and close the socket (unblocks the reader thread).
    fn close(&self) {
        self.alive.store(false, Ordering::Release);
        let _ = self.sock.shutdown(Shutdown::Both);
    }

    /// Fail every pending request with `reason`.
    fn fail_pending(&self, reason: &str) {
        let drained: Vec<(u64, ActorRef)> =
            self.pending.lock().unwrap_or_else(|p| p.into_inner()).drain().collect();
        for (mid, who) in drained {
            who.enqueue(Envelope {
                sender: None,
                mid: MessageId(mid).response_for(),
                msg: Message::new(ErrorMsg::new(reason)),
            });
        }
    }

    /// Fail one pending request with `reason`, if it is still pending
    /// (the reply, the deadline reaper, and the disconnect drain race on
    /// the same map — whoever removes the entry delivers).
    fn fail_one(&self, mid: u64, reason: String) {
        if let Some(who) = self.pending.lock().unwrap_or_else(|p| p.into_inner()).remove(&mid) {
            who.enqueue(Envelope {
                sender: None,
                mid: MessageId(mid).response_for(),
                msg: Message::new(ErrorMsg::new(reason)),
            });
        }
    }
}

/// Pump replies off the wire until the connection dies.
fn reader_loop(reader: &mut TcpStream, conn: &Arc<Connection>) {
    let mut slab = FrameSlab::new();
    loop {
        let (kind, body) = match read_logical_frame(reader, &mut slab) {
            Ok(f) => f,
            Err(e) => {
                if e.kind() == std::io::ErrorKind::InvalidData {
                    log::warn!("net: closing connection to {}: {e}", conn.peer);
                }
                return;
            }
        };
        if kind != KIND_REPLY || body.len() < 8 {
            log::warn!(
                "net: unexpected frame (kind {kind}, {} bytes) from {}; ignoring",
                body.len(),
                conn.peer
            );
            slab.put(body);
            continue;
        }
        let mid = u64::from_le_bytes(body[0..8].try_into().unwrap()); // lint-ok: length checked above
        let Some(who) = conn.pending.lock().unwrap_or_else(|p| p.into_inner()).remove(&mid) else {
            // already failed by deadline/disconnect, or never ours
            slab.put(body);
            continue;
        };
        match decode_message(&body[8..]) {
            Ok(msg) => who.enqueue(Envelope {
                sender: None,
                mid: MessageId(mid).response_for(),
                msg,
            }),
            Err(e) => who.enqueue(Envelope {
                sender: None,
                mid: MessageId(mid).response_for(),
                msg: Message::new(ErrorMsg::new(e.to_string())),
            }),
        }
        slab.put(body);
    }
}

/// Fired by the system timer when a remote request's deadline expires:
/// fails the pending entry (if still pending) so the requester gets an
/// [`ErrorMsg`] instead of waiting forever on a reply that will never come.
struct PendingReaper {
    conn: Weak<Connection>,
    mid: u64,
    timeout: Duration,
}

impl AbstractActor for PendingReaper {
    fn enqueue(&self, _env: Envelope) {
        let Some(conn) = self.conn.upgrade() else {
            return;
        };
        conn.fail_one(
            self.mid,
            format!(
                "remote request timed out after {:?} (remote_actor_timeout)",
                self.timeout
            ),
        );
    }

    fn id(&self) -> ActorId {
        0
    }

    fn attach_monitor(&self, _watcher: ActorRef) {}
    fn attach_link(&self, _peer: ActorRef) {}

    fn kind(&self) -> &'static str {
        "net-deadline"
    }
}

/// Client-side proxy: a normal [`ActorRef`] whose mailbox is a TCP stream.
struct RemoteProxy {
    id: ActorId,
    name: String,
    link: Arc<PeerLink>,
}

impl RemoteProxy {
    /// Route a failure back to the requester (requests) or the log (sends).
    fn fail(&self, env_sender: &Option<ActorRef>, mid: MessageId, reason: String) {
        if mid.is_request() {
            if let Some(s) = env_sender {
                s.enqueue(Envelope {
                    sender: None,
                    mid: mid.response_for(),
                    msg: Message::new(ErrorMsg::new(reason)),
                });
                return;
            }
        }
        log::warn!("net: dropping send to {:?}@{}: {reason}", self.name, self.link.addr);
    }
}

impl AbstractActor for RemoteProxy {
    fn enqueue(&self, env: Envelope) {
        // scatter encode: header arena + borrowed element slices, no
        // full-frame assembly buffer (the element data is written to the
        // socket straight out of the message's own storage)
        let sp = match encode_scatter(&env.msg) {
            Ok(p) => p,
            Err(e) => {
                // serialization failures surface to the requester
                self.fail(&env.sender, env.mid, e.to_string());
                return;
            }
        };
        let conn = match self.link.connection() {
            Ok(c) => c,
            Err(e) => {
                self.fail(&env.sender, env.mid, format!("cannot reach peer: {e:#}"));
                return;
            }
        };
        let mut head = Vec::with_capacity(10 + self.name.len());
        let kind = if env.mid.is_request() {
            head.extend_from_slice(&env.mid.0.to_le_bytes());
            KIND_REQUEST
        } else {
            KIND_SEND
        };
        head.extend_from_slice(&(self.name.len() as u16).to_le_bytes());
        head.extend_from_slice(self.name.as_bytes());
        // oversized payloads are a *local* error: fail this message only,
        // before touching the shared connection (closing it would tear
        // down every other proxy's in-flight requests for no reason).
        // Messages over MAX_FRAME shard into chunked frames; the cap here
        // is the reassembly clamp.
        let total = head.len() + sp.total_len();
        if total + 1 > MAX_CHUNKED {
            self.fail(
                &env.sender,
                env.mid,
                format!(
                    "message of {} bytes exceeds the {MAX_CHUNKED}-byte chunked-message cap",
                    total + 1
                ),
            );
            return;
        }
        // register before writing so a fast reply cannot miss the entry,
        // and arm the deadline that reaps it if no reply ever arrives
        let registered = kind == KIND_REQUEST && env.sender.is_some();
        if registered {
            let sender = env.sender.clone().expect("checked above"); // lint-ok: guarded by env.sender.is_some()
            conn.pending.lock().unwrap_or_else(|p| p.into_inner()).insert(env.mid.0, sender);
            let reaper = ActorRef::new(Arc::new(PendingReaper {
                conn: Arc::downgrade(&conn),
                mid: env.mid.0,
                timeout: self.link.timeout,
            }));
            self.link
                .system
                .timer()
                .schedule(self.link.timeout, reaper, Message::new(()));
        }
        let write_res = {
            let mut segs: Vec<&[u8]> = Vec::with_capacity(8);
            segs.push(&head);
            segs.extend(sp.segments());
            let mut w = conn.writer.lock().unwrap_or_else(|p| p.into_inner());
            write_logical_frame(&mut w, kind, &segs)
        };
        match write_res {
            Ok(()) => {
                // the reader may have drained `pending` (disconnect)
                // between our insert and the write completing; if the flag
                // already flipped, make sure our entry does not linger
                if registered && !conn.alive.load(Ordering::Acquire) {
                    conn.fail_one(
                        env.mid.0,
                        format!("remote node {} disconnected", conn.peer),
                    );
                }
            }
            Err(e) => {
                // dead socket: force a reconnect on the next send, and fail
                // this request now instead of leaking its pending entry
                conn.close();
                if registered {
                    conn.fail_one(env.mid.0, format!("writing to {} failed: {e}", conn.peer));
                } else {
                    self.fail(&env.sender, env.mid, format!("writing to {} failed: {e}", conn.peer));
                }
            }
        }
    }

    fn id(&self) -> ActorId {
        self.id
    }

    /// Remote monitoring: `watcher` receives [`Down`] with
    /// [`ExitReason::Unreachable`] when this proxy's connection drops. If
    /// the connection is already down the message fires immediately,
    /// mirroring local monitor semantics for dead actors.
    fn attach_monitor(&self, watcher: ActorRef) {
        // publish first, then check: if the connection died before the
        // push, the reader's drain may have missed this watcher, so
        // deliver now. notify_unreachable drains under the same lock the
        // push takes, which makes the delivery exactly-once — either the
        // reader's drain sees the entry, or the push happens after the
        // drain and the re-check (ordered by the watchers mutex) sees
        // `alive == false`.
        self.link.watchers.lock().unwrap_or_else(|p| p.into_inner()).push((self.id, watcher));
        if self.link.is_down() {
            self.link.notify_unreachable();
        }
    }

    fn attach_link(&self, _peer: ActorRef) {}

    fn kind(&self) -> &'static str {
        "remote"
    }
}
