//! Request/response plumbing: continuations, response promises, delegation.
//!
//! CAF's `request(...).then(...)` and `response_promise` — the machinery the
//! composition operator (§3.5) and the OpenCL facade's asynchronous command
//! completion are built on.

use super::cell::Ctx;
use super::envelope::{Envelope, MessageId};
use super::message::Message;
use super::monitor::ErrorMsg;
use super::ActorRef;
use std::time::Duration;

/// A continuation invoked when the response to an issued request arrives
/// (or the request fails / times out).
pub type Continuation = Box<dyn FnOnce(&mut Ctx, Result<Message, ErrorMsg>) + Send>;

/// Fluent handle returned by [`Ctx::request_msg`]: register a continuation
/// and optionally arm a timeout.
pub struct RequestBuilder<'a, 'b> {
    pub(crate) ctx: &'a mut Ctx<'b>,
    pub(crate) rid: u64,
}

impl RequestBuilder<'_, '_> {
    /// Arm a timeout: if no response arrives within `d`, the continuation
    /// fires with an error and any late response is dropped.
    pub fn with_timeout(self, d: Duration) -> Self {
        self.ctx.arm_request_timeout(self.rid, d);
        self
    }

    /// Register the continuation (CAF's one-shot response handler).
    pub fn then<F>(self, f: F)
    where
        F: FnOnce(&mut Ctx, Result<Message, ErrorMsg>) + Send + 'static,
    {
        self.ctx.store_continuation(self.rid, Box::new(f));
    }
}

/// A deferred response (CAF `response_promise`): captures the requester and
/// correlation id so the reply can be produced after the current handler
/// returned — e.g. once an OpenCL command's completion event fired.
///
/// Dropping an unfulfilled promise sends a "broken promise" error, so
/// requesters never hang silently.
pub struct ResponsePromise {
    target: Option<ActorRef>,
    mid: MessageId,
    me: Option<ActorRef>,
    delivered: bool,
}

impl ResponsePromise {
    pub(crate) fn new(target: Option<ActorRef>, mid: MessageId, me: Option<ActorRef>) -> Self {
        // async sends expect no response: the promise becomes a sink
        let target = if mid.is_request() { target } else { None };
        ResponsePromise {
            target,
            mid,
            me,
            delivered: false,
        }
    }

    /// A promise that discards its value (for async senders).
    pub fn sink() -> Self {
        ResponsePromise {
            target: None,
            mid: MessageId::ASYNC,
            me: None,
            delivered: false,
        }
    }

    /// True if a requester is actually waiting on this promise.
    pub fn is_live(&self) -> bool {
        self.target.is_some()
    }

    pub fn deliver<T: std::any::Any + Send + Sync>(self, v: T) {
        self.deliver_msg(Message::new(v));
    }

    pub fn deliver_msg(mut self, m: Message) {
        if let Some(t) = self.target.take() {
            t.enqueue(Envelope {
                sender: self.me.clone(),
                mid: self.mid.response_for(),
                msg: m,
            });
        }
        self.delivered = true;
    }

    pub fn deliver_err(self, e: ErrorMsg) {
        self.deliver_msg(Message::new(e));
    }

    pub fn deliver_result(self, r: Result<Message, ErrorMsg>) {
        match r {
            Ok(m) => self.deliver_msg(m),
            Err(e) => self.deliver_err(e),
        }
    }
}

impl Drop for ResponsePromise {
    fn drop(&mut self) {
        if !self.delivered {
            if let Some(t) = self.target.take() {
                t.enqueue(Envelope {
                    sender: self.me.clone(),
                    mid: self.mid.response_for(),
                    msg: Message::new(ErrorMsg::new("broken promise")),
                });
            }
        }
    }
}
