//! Dynamically typed message payloads.
//!
//! CAF messages are copy-on-write tuples matched by runtime type; here a
//! [`Message`] wraps an `Arc<dyn Any>` so clones are cheap (the paper relies
//! on zero-copy message passing for `mem_ref` pipelines) and handlers match
//! by downcasting to their parameter type.

use std::any::Any;
use std::sync::Arc;

/// A type-erased, cheaply clonable message payload.
#[derive(Clone)]
pub struct Message {
    payload: Arc<dyn Any + Send + Sync>,
    type_name: &'static str,
}

impl Message {
    pub fn new<T: Any + Send + Sync>(value: T) -> Self {
        Message {
            payload: Arc::new(value),
            type_name: std::any::type_name::<T>(),
        }
    }

    /// Borrow the payload as `T`, if the runtime type matches.
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.payload.downcast_ref::<T>()
    }

    pub fn is<T: Any>(&self) -> bool {
        self.payload.is::<T>()
    }

    /// Clone the payload out as `T` (messages may have multiple readers,
    /// so extraction clones — mirroring CAF's copy-on-write semantics).
    pub fn take<T: Any + Clone>(&self) -> Option<T> {
        self.downcast_ref::<T>().cloned()
    }

    /// Move the payload out without cloning when this is the only reference;
    /// falls back to cloning otherwise.
    pub fn unwrap_or_clone<T: Any + Clone + Send + Sync>(self) -> Option<T> {
        if self.payload.is::<T>() {
            match Arc::downcast::<T>(self.payload) {
                Ok(arc) => Some(Arc::try_unwrap(arc).unwrap_or_else(|a| (*a).clone())),
                Err(_) => None,
            }
        } else {
            None
        }
    }

    /// The Rust type name of the payload (diagnostics only).
    pub fn type_name(&self) -> &'static str {
        self.type_name
    }
}

impl std::fmt::Debug for Message {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Message<{}>", self.type_name)
    }
}

/// Unit response payload sent for `void` handlers of requests, so that
/// `request(...).then(...)` continuations always fire (CAF sends an empty
/// message in this case).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct UnitReply;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_typed_payload() {
        let m = Message::new((1u32, 2u32));
        assert!(m.is::<(u32, u32)>());
        assert_eq!(m.take::<(u32, u32)>(), Some((1, 2)));
        assert!(m.downcast_ref::<u64>().is_none());
    }

    #[test]
    fn clone_shares_payload() {
        let m = Message::new(vec![1f32; 1024]);
        let m2 = m.clone();
        let a = m.downcast_ref::<Vec<f32>>().unwrap().as_ptr();
        let b = m2.downcast_ref::<Vec<f32>>().unwrap().as_ptr();
        assert_eq!(a, b, "clones must share the payload allocation");
    }

    #[test]
    fn unwrap_moves_unique_payload() {
        let m = Message::new(vec![1u32, 2, 3]);
        let v: Vec<u32> = m.unwrap_or_clone().unwrap();
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn type_name_is_informative() {
        let m = Message::new(3.5f64);
        assert!(m.type_name().contains("f64"));
    }
}
