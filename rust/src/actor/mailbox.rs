//! Actor mailboxes: FIFO per priority class, with system messages (down,
//! exit, timeouts) overtaking ordinary traffic — CAF's two-queue design.

use super::envelope::Envelope;
use std::collections::VecDeque;
use std::sync::Mutex;

/// Result of an enqueue, telling the caller whether it must schedule the
/// owning actor.
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
pub enum EnqueueResult {
    /// Message stored; the mailbox was empty, caller should schedule.
    NeedsSchedule,
    /// Message stored; actor already has work queued.
    Stored,
    /// Mailbox closed (actor terminated); message was rejected.
    Closed,
}

#[derive(Default)]
struct Inner {
    normal: VecDeque<Envelope>,
    system: VecDeque<Envelope>,
    closed: bool,
}

/// Two-priority FIFO mailbox.
pub struct Mailbox {
    inner: Mutex<Inner>,
}

impl Default for Mailbox {
    fn default() -> Self {
        Self::new()
    }
}

impl Mailbox {
    pub fn new() -> Self {
        Mailbox {
            inner: Mutex::new(Inner::default()),
        }
    }

    pub fn enqueue(&self, env: Envelope, system: bool) -> EnqueueResult {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return EnqueueResult::Closed;
        }
        let was_empty = inner.normal.is_empty() && inner.system.is_empty();
        if system {
            inner.system.push_back(env);
        } else {
            inner.normal.push_back(env);
        }
        if was_empty {
            EnqueueResult::NeedsSchedule
        } else {
            EnqueueResult::Stored
        }
    }

    /// Push a message back to the *front* of the normal queue (used when a
    /// behavior change un-stashes skipped messages).
    pub fn push_front(&self, env: Envelope) {
        let mut inner = self.inner.lock().unwrap();
        if !inner.closed {
            inner.normal.push_front(env);
        }
    }

    /// Dequeue the next message, system queue first.
    pub fn dequeue(&self) -> Option<Envelope> {
        let mut inner = self.inner.lock().unwrap();
        inner.system.pop_front().or_else(|| inner.normal.pop_front())
    }

    pub fn is_empty(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.normal.is_empty() && inner.system.is_empty()
    }

    pub fn len(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.normal.len() + inner.system.len()
    }

    /// Close the mailbox and drain everything still queued.
    pub fn close(&self) -> Vec<Envelope> {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        let mut out: Vec<Envelope> = inner.system.drain(..).collect();
        out.extend(inner.normal.drain(..));
        out
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::actor::message::Message;

    fn env(tag: u32) -> Envelope {
        Envelope::asynchronous(None, Message::new(tag))
    }

    fn tag(e: &Envelope) -> u32 {
        *e.msg.downcast_ref::<u32>().unwrap()
    }

    #[test]
    fn fifo_order() {
        let mb = Mailbox::new();
        assert_eq!(mb.enqueue(env(1), false), EnqueueResult::NeedsSchedule);
        assert_eq!(mb.enqueue(env(2), false), EnqueueResult::Stored);
        assert_eq!(tag(&mb.dequeue().unwrap()), 1);
        assert_eq!(tag(&mb.dequeue().unwrap()), 2);
        assert!(mb.dequeue().is_none());
    }

    #[test]
    fn system_messages_overtake() {
        let mb = Mailbox::new();
        mb.enqueue(env(1), false);
        mb.enqueue(env(99), true);
        assert_eq!(tag(&mb.dequeue().unwrap()), 99);
        assert_eq!(tag(&mb.dequeue().unwrap()), 1);
    }

    #[test]
    fn closed_mailbox_rejects() {
        let mb = Mailbox::new();
        mb.enqueue(env(1), false);
        let drained = mb.close();
        assert_eq!(drained.len(), 1);
        assert_eq!(mb.enqueue(env(2), false), EnqueueResult::Closed);
        assert!(mb.is_closed());
    }

    #[test]
    fn push_front_reorders() {
        let mb = Mailbox::new();
        mb.enqueue(env(2), false);
        mb.push_front(env(1));
        assert_eq!(tag(&mb.dequeue().unwrap()), 1);
    }
}
